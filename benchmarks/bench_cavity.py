"""Paper Fig. 3 — lid-driven cavity validation against Ghia et al. (1982).

Runs the descriptor-generated solver to (near) steady state at Re=100 —
through the ``repro.api`` front door — and reports centerline-velocity
deviations from Ghia's tabulated profiles.  The paper shows the same
comparison as its correctness evidence.
"""
from __future__ import annotations

import time


def run(n: int = 48, t_end: float = 12.0, quick: bool = False) -> dict:
    from repro import api

    if quick:
        n, t_end = 32, 6.0
    t0 = time.time()
    rt = api.runtime(n=n)
    res = rt.run("cavity", t_end=t_end, re=100.0)
    errors = res.diagnostics["ghia"]
    dt = time.time() - t0
    # tolerance scales with resolution: 1st/2nd-order scheme on n^2 grid
    tol = 0.035 if n >= 48 else 0.06
    passed = errors["u_rms"] < tol and errors["v_rms"] < tol
    result = {
        "bench": "cavity_ghia",
        "paper_analogue": "Fig. 3 (Ghia centerline comparison)",
        "grid": f"{n}x{n}x4",
        "t_end": t_end,
        "u_rms": round(errors["u_rms"], 5),
        "u_max": round(errors["u_max"], 5),
        "v_rms": round(errors["v_rms"], 5),
        "v_max": round(errors["v_max"], 5),
        "tolerance": tol,
        "passed": passed,
        "wall_s": round(dt, 1),
    }
    return result


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
