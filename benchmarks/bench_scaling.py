"""Paper Fig. 4 — scaling of the framework-built CFD code.

The paper shows near-linear speed-up of the CaCUDA CFD code to 12 GPUs
(weak scaling, domain grows with node count).  Without real hardware the
analogue is structural: dry-run the sharded step at 1/2/4/8 devices (weak
scaling: fixed per-device block), extract the roofline terms per device,
and report the modeled parallel efficiency

    eff(N) = T_model(1) / T_model(N),  T_model = max(compute, memory, coll)

where per-device compute/memory stay constant under weak scaling and the
halo-exchange collective grows with the surface — the same efficiency
shape as the paper's figure.  Runs in subprocesses (device count is
locked at jax init).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = """
import sys, json
import jax
from repro.cfd.ns3d import CFDConfig, NavierStokes3D
from repro.launch.mesh import make_mesh
from repro.launch import hlo_cost
from repro.core.rooflinemodel import V5E, terms_from_counts

n_dev = int(sys.argv[1])
block = int(sys.argv[2])
mesh = make_mesh((n_dev,), ("data",)) if n_dev > 1 else None
cfg = CFDConfig(shape=(block * max(n_dev, 1), block, block),
                case="taylor_green", nu=1e-3, dt=1e-3, jacobi_iters=20,
                decomposition=((0, "data"),) if n_dev > 1 else ())
solver = NavierStokes3D(cfg, mesh)
state = solver.init_state()
step = solver.make_step()
lowered = jax.jit(step).lower(state)
compiled = lowered.compile()
cost = hlo_cost.analyze(compiled.as_text(), max(n_dev, 1))
terms = terms_from_counts(cost.flops, cost.bytes,
                          cost.collective_wire_bytes, dtype="fp32")
print("RESULT " + json.dumps({
    "n_dev": n_dev,
    "flops": cost.flops, "bytes": cost.bytes,
    "coll": cost.collective_wire_bytes,
    "compute_s": terms.compute_s, "memory_s": terms.memory_s,
    "collective_s": terms.collective_s,
    "t_model": terms.step_time_s}))
"""


def run(block: int = 32, devices=(1, 2, 4, 8), quick: bool = False) -> dict:
    if quick:
        block, devices = 24, (1, 2, 4)
    rows = []
    for n in devices:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={max(n,1)}"
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        proc = subprocess.run(
            [sys.executable, "-c", _SCRIPT, str(n), str(block)],
            env=env, capture_output=True, text=True, timeout=900)
        if proc.returncode != 0:
            raise RuntimeError(proc.stderr[-2000:])
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("RESULT ")][0]
        rows.append(json.loads(line[len("RESULT "):]))
    t1 = rows[0]["t_model"]
    for r in rows:
        r["efficiency"] = round(t1 / r["t_model"], 4)
        r["speedup"] = round(r["n_dev"] * t1 / r["t_model"], 3)
    return {
        "bench": "scaling_weak",
        "paper_analogue": "Fig. 4 (speed-up to 12 GPUs)",
        "per_device_block": f"{block}^3",
        "rows": rows,
        "passed": all(r["efficiency"] > 0.7 for r in rows),
    }


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
