"""Roofline table generator: reads the dry-run artifacts and renders the
per-(arch × shape × mesh) table for EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import os

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ART = os.path.join(REPO, "artifacts", "dryrun")


def load(mesh: str = "single") -> list[dict]:
    d = os.path.join(ART, mesh)
    rows = []
    if not os.path.isdir(d):
        return rows
    for name in sorted(os.listdir(d)):
        if name.endswith(".json"):
            with open(os.path.join(d, name)) as f:
                rows.append(json.load(f))
    return rows


def table(mesh: str = "single") -> str:
    rows = load(mesh)
    hdr = ("| arch | shape | status | compute_s | memory_s | coll_s | "
           "bottleneck | frac | useful | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r['status']} | "
                       f"— | — | — | — | — | — | — |\n")
            continue
        rf = r["roofline"]
        fit = r.get("fits_hbm")
        fit_s = {True: "yes", False: "NO", None: "?"}[fit]
        useful = r.get("useful_flops_ratio")
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | "
            f"{rf['compute_s']:.3g} | {rf['memory_s']:.3g} | "
            f"{rf['collective_s']:.3g} | {rf['bottleneck']} | "
            f"{rf['roofline_fraction']:.3f} | "
            f"{useful:.2f} | {fit_s} |\n" if useful else
            f"| {r['arch']} | {r['shape']} | ok | — | — | — | — | — | — "
            f"| {fit_s} |\n")
    return "".join(out)


def run(quick: bool = False) -> dict:
    single = load("single")
    multi = load("multi")
    ok_s = sum(1 for r in single if r["status"] == "ok")
    sk_s = sum(1 for r in single if r["status"] == "skipped")
    ok_m = sum(1 for r in multi if r["status"] == "ok")
    sk_m = sum(1 for r in multi if r["status"] == "skipped")
    bottl = {}
    for r in single:
        if r["status"] == "ok":
            b = r["roofline"]["bottleneck"]
            bottl[b] = bottl.get(b, 0) + 1
    return {
        "bench": "roofline_table",
        "paper_analogue": "scale deliverable (40-cell baseline)",
        "single_ok": ok_s, "single_skipped": sk_s,
        "multi_ok": ok_m, "multi_skipped": sk_m,
        "bottleneck_histogram": bottl,
        "passed": (ok_s + sk_s >= 40) and (ok_m + sk_m >= 40),
    }


if __name__ == "__main__":
    print(table("single"))
    print(json.dumps(run(), indent=1))
