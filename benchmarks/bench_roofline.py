"""Roofline table generator — cost-model predictions over live lowerings.

Earlier revisions read pre-baked ``artifacts/dryrun`` JSON (a directory
this repo no longer ships); the table is now produced directly from the
perf accounting layer: each (grid × mesh) cell lowers the slots × shards
ensemble step over an :class:`jax.sharding.AbstractMesh` (no devices
needed — CI's 1-CPU fast lane covers a 2×4 pod cell), runs the
trip-count-aware HLO cost model over it, and attributes the predicted
FLOPs / HBM bytes / collective wire bytes against TPU v5e rooflines.
Decomposed cells additionally double-check the predicted
``collective-permute`` bytes against the analytic ghost-zone model
(:func:`repro.obs.perf.halo_bytes_per_step`) — a MISMATCH fails the
bench.
"""
from __future__ import annotations

import json

CHIP = "tpu-v5e"         # the paper-table attribution target
JACOBI_ITERS = 8

# (n, slot_extent, shard_extent) cells; shard_extent 1 degrades to the
# plain slot-parallel step (plan_decomposition drops extent-1 axes)
CELLS_QUICK = [(16, 2, 1), (16, 2, 2), (32, 2, 2), (32, 2, 4)]
CELLS_FULL = CELLS_QUICK + [(48, 2, 4), (64, 2, 4), (64, 2, 8), (64, 4, 4)]


def perf_rows(quick: bool = False) -> list[dict]:
    from repro.cfd.ns3d import CFDConfig
    from repro.obs import perf

    rows = []
    for n, slots, shards in (CELLS_QUICK if quick else CELLS_FULL):
        n_slots = 2 * slots
        cfg = CFDConfig(shape=(n, n, n), extent=1.0, case="cavity",
                        jacobi_iters=JACOBI_ITERS,
                        decomposition={0: "shard"})
        name = f"cavity/n{n}/slot{slots}.shard{shards}"
        try:
            text, active = perf.decomposed_step_hlo(
                cfg, n_slots=n_slots,
                mesh_axes=(("slot", slots), ("shard", shards)))
            row = perf.cost_row_from_hlo(
                text, name=name, kind="farm-step",
                n_devices=slots * shards)
            if active:
                row.halo_bytes_analytic = float(perf.halo_bytes_per_step(
                    cfg, active, {"slot": slots, "shard": shards},
                    slots_local=perf._slots_local(n_slots, slots)))
        except Exception as e:
            row = perf.CostRow(name=name, kind="farm-step",
                               status="unparsed",
                               n_devices=slots * shards,
                               error=f"{type(e).__name__}: {e}")
        d = perf.PerfReport([row], chip=CHIP)._attribute(row)
        d.update(n=n, slots=slots, shards=shards)
        rows.append(d)
    return rows


def table(rows: list[dict] | None = None) -> str:
    """Markdown roofline table (EXPERIMENTS.md §Roofline)."""
    if rows is None:
        rows = perf_rows(quick=True)
    out = [
        "| cell | status | flops/inv | HBM B/inv | wire B/inv | "
        "compute_s | memory_s | coll_s | bottleneck | halo |\n",
        "|---|---|---|---|---|---|---|---|---|---|\n",
    ]
    for d in rows:
        if d["status"] != "ok":
            out.append(f"| {d['name']} | {d['status']} "
                       "| — | — | — | — | — | — | — | — |\n")
            continue
        halo = {True: "match", False: "MISMATCH",
                None: "n/a"}[d["halo_match"]]
        out.append(
            f"| {d['name']} | ok | {d['flops']:.3g} | "
            f"{d['hbm_bytes']:.3g} | {d['collective_wire_bytes']:.3g} | "
            f"{d['compute_s']:.3g} | {d['memory_s']:.3g} | "
            f"{d['collective_s']:.3g} | {d['bottleneck']} | {halo} |\n")
    return "".join(out)


def run(quick: bool = False) -> dict:
    rows = perf_rows(quick=quick)
    ok = sum(1 for d in rows if d["status"] == "ok")
    mismatched = [d["name"] for d in rows if d["halo_match"] is False]
    bottl: dict = {}
    for d in rows:
        if d["status"] == "ok":
            bottl[d["bottleneck"]] = bottl.get(d["bottleneck"], 0) + 1
    return {
        "bench": "roofline_table",
        "paper_analogue": "scale deliverable (40-cell baseline)",
        "chip": CHIP,
        "cells_ok": ok,
        "cells_total": len(rows),
        "table_cells": 10 * ok,
        "halo_mismatches": mismatched,
        "bottleneck_histogram": bottl,
        "rows": [{k: d[k] for k in ("name", "status", "flops", "hbm_bytes",
                                    "collective_wire_bytes", "bottleneck",
                                    "halo_match")} for d in rows],
        "passed": ok == len(rows) and not mismatched and 10 * ok >= 40,
    }


if __name__ == "__main__":
    rows = perf_rows(quick=True)
    print(table(rows))
    print(json.dumps(run(quick=True), indent=1))
