"""Shared helpers for the decomposed-benchmark variants.

Both bench_ensemble and bench_stencil report a decomposed row; they must
pick the SAME shard count for the same host and format block sizes the
same way, or the per-slot-grid-normalized numbers in ``BENCH_*.json``
stop being comparable across benches.
"""
from __future__ import annotations


def pick_shards(ndev: int, n: int) -> int:
    """Largest supported shard count for an x-extent of ``n`` on ``ndev``
    devices (powers of two only — the halo exchange is happiest on even
    splits); 1 when the host cannot shard."""
    return next((k for k in (8, 4, 2) if ndev >= k and n % k == 0), 1)


def slot_grid(shape, decomposition, mesh) -> str:
    """The per-device block of one slot's grid, as "nx x ny x nz"."""
    local = list(shape)
    if mesh is not None:
        for a, name in dict(decomposition).items():
            local[a] //= mesh.shape[name]
    return "x".join(str(d) for d in local)
