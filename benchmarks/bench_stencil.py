"""Paper §4.3 — framework-generated vs standalone hand-written CFD step.

The paper's headline: the CaCUDA framework-generated kernels reached 58
GFlop/s/node vs 43.5 for the hand-written standalone code (1.33x) — the
template was better optimized than the hand code.  We reproduce the
comparison structurally: the SAME Navier-Stokes step built (a) from
descriptor-generated kernels resolved through the ``repro.api`` runtime
(full driver stack: halo exchange + overlap machinery) and (b) as a
straight hand-written jnp implementation (the ref.py oracle path), both
jitted, timed on identical states.

On CPU the two converge to similar XLA programs — the claim reproduced is
"the framework abstraction costs nothing (or less than nothing) relative
to hand code", which is the transferable core of the paper's 58-vs-43.5
result.  The roofline terms of the generated kernel on the TPU target
are reported from the dry-run artifacts instead (see §Roofline).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _flops_per_step(shape, jacobi_iters):
    """Analytic FLOPs of one projection step (see kernels/stencil3d.py)."""
    cells = int(np.prod(shape))
    upd = 90 * cells          # advection + diffusion, 3 components
    div = 7 * cells
    jac = 10 * cells * jacobi_iters
    proj = 9 * cells
    return upd + div + jac + proj


PHYS = dict(nu=1e-3, dt=1e-3)


def run(n: int = 64, steps: int = 40, quick: bool = False) -> dict:
    from repro import api
    from repro.kernels import ref

    if quick:
        n, steps = 32, 15
    # (a) framework: descriptor-generated kernels + driver + overlap,
    # resolved through the runtime front door
    rt = api.runtime(n=n, nz=16, jacobi_iters=20)
    pr = rt.prepare("taylor_green", **PHYS)
    cfg = pr.config
    state = pr.state
    step_framework = pr.step

    # (b) standalone: hand-written jnp (the ref oracle path), same math,
    # no descriptor/driver machinery — periodic pads written by hand
    h, dt, nu, iters = cfg.h, cfg.dt, cfg.nu, cfg.jacobi_iters

    def wrap(u, lo, hi):
        return jnp.pad(u, [(lo, hi)] * 3, mode="wrap")

    def step_standalone(state):
        vx, vy, vz, p = (state[k] for k in ("vx", "vy", "vz", "p"))
        vxs, vys, vzs = ref.update_velocity(
            wrap(vx, 1, 1), wrap(vy, 1, 1), wrap(vz, 1, 1),
            dt=dt, h=h, nu=nu)
        rhs = ref.divergence(wrap(vxs, 1, 0), wrap(vys, 1, 0),
                             wrap(vzs, 1, 0), h=h) / dt

        def body(_, pc):
            return ref.jacobi_pressure(wrap(pc, 1, 1), rhs, h=h)

        p = jax.lax.fori_loop(0, iters, body, p)
        p = p - jnp.mean(p)
        vxn, vyn, vzn = ref.project_velocity(vxs, vys, vzs, wrap(p, 0, 1),
                                             dt=dt, h=h)
        return dict(state, vx=vxn, vy=vyn, vz=vzn, p=p)

    step_standalone = jax.jit(step_standalone)

    def bench(step, state):
        state = step(state)                       # compile + warm
        jax.block_until_ready(state["vx"])
        t0 = time.time()
        for _ in range(steps):
            state = step(state)
        jax.block_until_ready(state["vx"])
        return (time.time() - t0) / steps, state

    t_fw, s_fw = bench(step_framework, state)
    t_sa, s_sa = bench(step_standalone, state)
    # numerical agreement (same discretization)
    du = float(jnp.abs(s_fw["vx"] - s_sa["vx"]).max())

    # decomposed variant: the same framework step with the grid sharded
    # over a "shard" mesh axis (driver-managed halo exchange on a real
    # device axis when the host has one; block size reported so the row
    # is comparable to the single-shard number)
    from benchmarks._util import pick_shards, slot_grid

    shards = pick_shards(jax.device_count(), n)
    decomposed = {"shards": shards}
    if shards > 1:
        drt = api.runtime(n=n, nz=16, jacobi_iters=20,
                          mesh_shape=(shards,), mesh_axes=("shard",),
                          decomposition=((0, "shard"),))
        dpr = drt.prepare("taylor_green", **PHYS)
        decomposed["local_grid"] = slot_grid(cfg.shape,
                                             ((0, "shard"),), drt.mesh)
        t_dec, _ = bench(dpr.step, dpr.state)
        decomposed["ms_per_step"] = round(t_dec * 1e3, 2)
        decomposed["gflops"] = round(
            _flops_per_step(cfg.shape, cfg.jacobi_iters) / t_dec / 1e9, 2)
    else:
        decomposed["local_grid"] = slot_grid(cfg.shape, (), None)
        decomposed["note"] = "single device: decomposition degrades to 1 shard"

    flops = _flops_per_step(cfg.shape, cfg.jacobi_iters)
    return {
        "bench": "stencil_framework_vs_standalone",
        "paper_analogue": "§4.3 (58 vs 43.5 GFlop/s per node)",
        "grid": f"{n}x{n}x16",
        "framework_ms_per_step": round(t_fw * 1e3, 2),
        "standalone_ms_per_step": round(t_sa * 1e3, 2),
        "framework_gflops": round(flops / t_fw / 1e9, 2),
        "standalone_gflops": round(flops / t_sa / 1e9, 2),
        "framework_over_standalone": round(t_sa / t_fw, 3),
        "paper_ratio": round(58.0 / 43.5, 3),
        "decomposed": decomposed,
        "max_field_deviation": du,
        "passed": bool(du < 1e-4 and t_fw < 3.0 * t_sa),
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
