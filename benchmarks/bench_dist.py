"""Distribution substrate: compressed vs exact DP gradient all-reduce.

Two measurements, both on a forced-8-device host mesh (subprocess, like
the multi-device tests — the parent process must keep its 1-CPU view):

  1. allreduce microbench — ``ef_allreduce_mean`` (int8 + error feedback)
     vs exact fp32 ``pmean`` over a ``pod`` axis at several gradient
     sizes, reporting step time and the wire-byte model
     (``dist.compression.wire_bytes``: 1 B/elem + scale vs 4 B/elem).
  2. end-to-end — ``_make_dp_train_step`` exact vs
     ``compress_pod_grads=True`` on the smoke llama3-8b over a
     (pod, data, model) mesh: per-step wall time plus the loss/param
     deltas (the correctness margin the equivalence test pins at 5e-3).

On emulated host devices the "wire" is a memcpy, so int8's 4× byte saving
does NOT show up as time — the gate here is bytes + correctness; time
columns are for the roofline model and real-DCN extrapolation.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

_INNER = r"""
import json, time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P

from repro.dist.compression import ef_allreduce_mean, wire_bytes
from repro.launch.mesh import make_mesh

QUICK = %(quick)r
sizes = [1 << 16, 1 << 20] if QUICK else [1 << 16, 1 << 20, 1 << 22]
reps = 5 if QUICK else 20
mesh = make_mesh((8,), ("pod",))
rows = []
for n in sizes:
    g = jax.random.normal(jax.random.PRNGKey(0), (8, n))
    err = jnp.zeros((8, n))

    def exact(g_l):
        return jax.lax.pmean(g_l, "pod")

    def comp(g_l, e_l):
        gm, ne = ef_allreduce_mean(g_l[0], e_l[0], "pod")
        return gm[None], ne[None]

    f_ex = jax.jit(jax.shard_map(exact, mesh=mesh, in_specs=P("pod"),
                                 out_specs=P("pod"), check_vma=False))
    f_cp = jax.jit(jax.shard_map(comp, mesh=mesh,
                                 in_specs=(P("pod"), P("pod")),
                                 out_specs=(P("pod"), P("pod")),
                                 check_vma=False))

    def bench(fn, *args):
        jax.block_until_ready(fn(*args))          # compile
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps

    t_ex = bench(f_ex, g)
    t_cp = bench(f_cp, g, err)
    gm, _ = f_cp(g, err)
    rel = float(jnp.linalg.norm(gm[0] - g.mean(0))
                / jnp.linalg.norm(g.mean(0)))
    rows.append({
        "n_elements": n,
        "exact_ms": round(t_ex * 1e3, 3),
        "compressed_ms": round(t_cp * 1e3, 3),
        "exact_wire_bytes": wire_bytes(n, compressed=False),
        "compressed_wire_bytes": wire_bytes(n, compressed=True),
        "mean_rel_err": rel,
    })

# -- end-to-end smoke train step -------------------------------------------
from repro.configs.registry import get_config, smoke
from repro.dist import sharding as shd
from repro.models import model
from repro.optim.adamw import AdamW
from repro.train import step as step_lib

cfg = smoke(get_config("llama3-8b"))
key = jax.random.PRNGKey(0)
params = model.init_params(cfg, key)
B, S = 8, 32
batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "targets": jax.random.randint(jax.random.fold_in(key, 1), (B, S),
                                       0, cfg.vocab_size)}
mesh3 = make_mesh((2, 2, 2), ("pod", "data", "model"))
shard = shd.make_shard_cfg(mesh3, cfg, global_batch=B, mode="dp")
opt = AdamW(lr=1e-3)
step_reps = 3 if QUICK else 10
steps = {}
outs = {}
st0 = opt.init(params)
for name, kw in (("exact", {}), ("compressed", {"compress_pod_grads": True})):
    fn = jax.jit(step_lib._make_dp_train_step(cfg, shard, opt, **kw))
    p, st, m = fn(params, st0, batch)                   # compile + step 1
    jax.block_until_ready(p)
    t0 = time.perf_counter()
    for _ in range(step_reps):
        p2, st2, m2 = fn(params, st0, batch)
    jax.block_until_ready(p2)
    steps[name] = round((time.perf_counter() - t0) / step_reps * 1e3, 2)
    outs[name] = (p, float(m["loss"]))

dloss = abs(outs["exact"][1] - outs["compressed"][1])
dparam = max(float(jnp.abs(a.astype(jnp.float32)
                           - b.astype(jnp.float32)).max())
             for a, b in zip(jax.tree.leaves(outs["exact"][0]),
                             jax.tree.leaves(outs["compressed"][0])))
grad_elems = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
print("RESULT " + json.dumps({
    "allreduce": rows,
    "train_step_ms": steps,
    "train_loss_delta": dloss,
    "train_param_delta": dparam,
    "train_grad_elements": grad_elems,
    "train_pod_wire_bytes": {
        "exact": wire_bytes(grad_elems, compressed=False),
        "compressed": wire_bytes(grad_elems, compressed=True)},
}))
"""


def run(quick: bool = False) -> dict:
    t0 = time.time()
    env = dict(os.environ)
    # strip any inherited device-count flag: the LAST duplicate wins in
    # XLA's parser, so appending ours first would let the environment
    # override the required 8 (same fix as tests/helpers.run_with_devices)
    inherited = [f for f in env.get("XLA_FLAGS", "").split()
                 if not f.startswith("--xla_force_host_platform_device_count")]
    env["XLA_FLAGS"] = " ".join(
        ["--xla_force_host_platform_device_count=8"] + inherited)
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(repo, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", _INNER % {"quick": quick}],
                          env=env, capture_output=True, text=True,
                          timeout=1800)
    if proc.returncode != 0:
        return {"bench": "dist", "passed": False,
                "error": proc.stderr[-2000:]}
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    ok = (all(r["compressed_wire_bytes"] * 3.9 <= r["exact_wire_bytes"]
              for r in res["allreduce"])
          and all(r["mean_rel_err"] < 0.05 for r in res["allreduce"])
          and res["train_loss_delta"] < 1e-4
          and res["train_param_delta"] < 5e-3)
    return {"bench": "dist", "passed": bool(ok),
            "wall_s": round(time.time() - t0, 1), **res}


if __name__ == "__main__":
    print(json.dumps(run(quick="--quick" in sys.argv), indent=1))
