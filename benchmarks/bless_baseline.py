"""Bless the current smoke bench as the committed regression baseline.

    PYTHONPATH=src python -m benchmarks.bless_baseline [--from DIR_OR_FILE]

Runs the smoke bench (or takes an existing ``BENCH_smoke.json``),
validates it, and installs it as ``benchmarks/baselines/BENCH_smoke.json``
— the file ``benchmarks/check_regression.py`` gates CI against.  Commit
the result deliberately: blessing a slow run lowers the bar for every
future push.
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--from", dest="src", default=None,
                    help="existing BENCH_smoke.json (or a directory "
                    "holding one) to bless instead of running the bench")
    args = ap.parse_args(argv)

    from repro import obs

    if args.src:
        src = args.src
        if os.path.isdir(src):
            src = os.path.join(src, "BENCH_smoke.json")
    else:
        from benchmarks.run import run_smoke

        doc = run_smoke(BASELINE_DIR)
        if not doc["passed"]:
            print("[bless] refusing to bless a failing smoke run")
            return 1
        print(f"[bless] baseline -> "
              f"{os.path.join(BASELINE_DIR, 'BENCH_smoke.json')}")
        return 0

    obs.load_bench(src)     # schema-validate before installing
    os.makedirs(BASELINE_DIR, exist_ok=True)
    dst = os.path.join(BASELINE_DIR, "BENCH_smoke.json")
    shutil.copyfile(src, dst)
    print(f"[bless] baseline -> {dst}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
