"""Simulation-farm throughput: batched ensemble vs serial execution.

The farm's claim is the LM-serving claim transplanted: advancing B resident
simulations with one vmapped step costs far less than B serial steps,
because per-step dispatch and per-op overheads amortize across the slot
axis.  We measure sim-steps/sec for ensemble sizes 1/4/8/16 on the JNP
path and report speedup over running the same work serially (one
simulation at a time, the pre-farm workflow) — both sides resolved through
the ``repro.api`` front door: ``Runtime.prepare`` hands the serial jitted
step, ``Runtime.submit``/``drain`` drive the farm.

Every row reports the per-slot grid block (``slot_grid`` × ``shards_per
_slot``) so the slots × shards variant — each slot's grid decomposed over
a "shard" mesh axis — lands in ``BENCH_*.json`` directly comparable to
the undecomposed rows (same sim-steps/sec unit, explicit block size).

``--backend pallas`` runs the same matrix on the Pallas 3DBLOCK path
(resolved to ``pallas-interpret`` on non-TPU hosts — the correctness
mode, NOT a speed claim) and emits ``BENCH_ensemble_pallas.json``: its
structural fields — farm-vs-serial bitwise parity, one compiled
executable per static signature, a throughput row per ensemble size —
are gated by ``benchmarks/check_regression.py`` on every CI push, so
the farm's Pallas backend cannot silently regress to literal-baking or
per-scalar recompiles between real-hardware runs.
"""
from __future__ import annotations

import time

import numpy as np

FIELDS = ("vx", "vy", "vz", "p")


def resolve_backend(backend: str) -> str:
    """``pallas`` needs TPU hardware; everywhere else the interpret mode
    runs the same kernels (and the same scalar-table machinery)."""
    import jax

    if backend == "pallas" and jax.default_backend() != "tpu":
        return "pallas-interpret"
    return backend


def _parity_check(farm_rt, serial_rt, steps: int = 6) -> bool:
    """One heterogeneous pair, farm vs serial, bitwise — the structural
    claim of the scalar-table design, embedded in the artifact."""
    import jax

    sids = [farm_rt.submit("cavity", re=re, steps=steps)
            for re in (123.0, 321.0)]
    out = farm_rt.drain()
    ok = True
    for sid, re in zip(sids, (123.0, 321.0)):
        pr = serial_rt.prepare("cavity", re=re)
        st = pr.state
        for _ in range(steps):
            st = pr.step(st)
        st = jax.device_get(st)
        ok &= all(np.array_equal(np.asarray(st[f]),
                                 np.asarray(out[sid].state[f]))
                  for f in FIELDS)
    return bool(ok)


def _bench_serial(rt, res_values, steps):
    import jax

    # warm the compile (the serial path shares one jitted step per config
    # signature via jax's own jit cache; time only the steady state)
    runs = [rt.prepare("cavity", re=float(r)) for r in res_values]
    for pr in runs:
        jax.block_until_ready(pr.step(pr.state))
    t0 = time.perf_counter()
    for pr in runs:
        st = pr.state
        for _ in range(steps):
            st = pr.step(st)
        jax.block_until_ready(st)
    return time.perf_counter() - t0


def _bench_farm(rt, res_values, steps):
    # warm: run a throwaway batch of 1 step
    for r in res_values:
        rt.submit("cavity", re=float(r), steps=1)
    rt.drain()
    sids = [rt.submit("cavity", re=float(r), steps=steps)
            for r in res_values]
    t0 = time.perf_counter()
    out = rt.drain()
    dt = time.perf_counter() - t0
    assert all(out[s].steps_done == steps for s in sids)
    return dt


def _ugrid(shape) -> str:
    from benchmarks._util import slot_grid

    return slot_grid(shape, (), None)


def _bench_decomposed(n, steps, n_slots=4, backend="jnp"):
    """Slots × shards variant: same ensemble work with each slot's grid
    decomposed over a "shard" mesh axis.  Runs at however many shards the
    host allows (1 on the single-device CI harness — the degraded fast
    path — so the row is always present and comparable)."""
    import jax

    from benchmarks._util import pick_shards, slot_grid
    from repro import api

    shards = pick_shards(jax.device_count(), n)
    decomposition = ((0, "shard"),)
    rt = api.runtime(n=n, n_slots=n_slots, jacobi_iters=20, backend=backend,
                     mesh_shape=(1, shards), mesh_axes=("slot", "shard"),
                     decomposition=decomposition)
    res = np.linspace(60.0, 400.0, n_slots)
    t = _bench_farm(rt, res, steps)
    base = rt.configure("cavity")
    return {
        "ensemble": n_slots,
        "shards_per_slot": shards,
        "slot_grid": slot_grid(base.shape, decomposition,
                               rt.mesh),
        "farm_steps_per_s": round(n_slots * steps / t, 1),
    }


def run(n: int = 16, steps: int = 80, quick: bool = False, repeats: int = 2,
        backend: str = "jnp") -> dict:
    """Ensemble members are the small/medium cases real sweeps are made of
    (UQ, parameter studies) — the regime where per-step dispatch and per-op
    overheads, not raw flops, bound serial throughput.

    ``backend`` selects the kernel template (``api.BACKENDS``); the
    Pallas variants additionally record the structural fields the CI
    regression gate pins: bitwise farm-vs-serial parity and the compile
    -cache miss count (one executable per static signature).
    """
    from repro import api
    from repro.sim import reset_compile_cache

    resolved = resolve_backend(backend)
    pallas = resolved != "jnp"
    reset_compile_cache()
    # quick trims the largest ensemble, not the measurement length: short
    # timing windows are noise-dominated and flake the >=2x gate
    batches = (1, 4, 8) if quick else (1, 4, 8, 16)
    t_start = time.time()
    rows = []
    for b in batches:
        res = np.linspace(60.0, 400.0, b)
        serial_rt = api.runtime(n=n, jacobi_iters=20, backend=resolved)
        farm_rt = api.runtime(n=n, n_slots=b, jacobi_iters=20,
                              backend=resolved)
        t_serial = min(_bench_serial(serial_rt, res, steps)
                       for _ in range(repeats))
        t_farm = min(_bench_farm(farm_rt, res, steps)
                     for _ in range(repeats))
        total = b * steps
        rows.append({
            "ensemble": b,
            # per-slot grid size: decomposed and undecomposed runs are
            # only comparable normalized to the block each device steps
            "slot_grid": _ugrid(serial_rt.configure("cavity").shape),
            "shards_per_slot": 1,
            "serial_steps_per_s": round(total / t_serial, 1),
            "farm_steps_per_s": round(total / t_farm, 1),
            "speedup": round(t_serial / t_farm, 2),
        })
    by_b = {r["ensemble"]: r for r in rows}
    # interpret mode trades speed for auditability: the farm>serial gate
    # is a hardware claim, asserted only where the kernels are compiled
    passed = (by_b[8]["speedup"] >= 2.0) if resolved != "pallas-interpret" \
        else all(r["farm_steps_per_s"] > 0 for r in rows)
    out = {
        "bench": "ensemble_farm",
        "paper_analogue": "runtime layer scheduling many generated kernels",
        "backend": backend,
        "resolved_backend": resolved,
        "grid": f"{n}x{n}x4",
        "steps_per_sim": steps,
        "batches": rows,
        "decomposed": _bench_decomposed(n, steps, backend=resolved),
        "speedup_at_8": by_b[8]["speedup"],
        "passed": passed,
        "wall_s": round(time.time() - t_start, 1),
    }
    if pallas:
        # structural fields the regression gate pins (host-independent):
        # each undecomposed farm is one static signature (one miss per
        # ensemble size), the decomposed variant adds one more; the
        # parity farm below re-hits the n_slots=4 signature
        expected = len(batches) + 1
        parity_rt = api.runtime(n=n, n_slots=4, jacobi_iters=20,
                                backend=resolved)
        serial_rt = api.runtime(n=n, jacobi_iters=20, backend=resolved)
        out["parity"] = {"bitwise_ok": _parity_check(parity_rt, serial_rt)}
        out["expected_compile_misses"] = expected
        out["compile_cache"] = api.compile_cache_stats()
        out["passed"] = bool(
            out["passed"] and out["parity"]["bitwise_ok"]
            and out["compile_cache"]["misses"] == expected)
    return out


def main(argv=None) -> int:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jnp",
                    help="kernel backend (api.BACKENDS); 'pallas' falls "
                         "back to interpret mode off-TPU")
    ap.add_argument("--n", type=int, default=16)
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out-dir", default=None,
                    help="write BENCH_ensemble[_pallas].json (repro.bench"
                         ".v1 envelope) here instead of printing raw JSON")
    args = ap.parse_args(argv)

    res = run(n=args.n, steps=args.steps, quick=args.quick,
              repeats=args.repeats, backend=args.backend)
    if args.out_dir is None:
        print(json.dumps(res, indent=1))
        return 0 if res["passed"] else 1

    from repro import obs

    name = "ensemble" if res["resolved_backend"] == "jnp" \
        else "ensemble_pallas"
    doc = obs.make_bench_doc(
        name, {k: v for k, v in res.items() if k not in ("passed", "wall_s")},
        passed=bool(res["passed"]), wall_s=res["wall_s"])
    path = obs.write_bench(doc, args.out_dir)
    obs.load_bench(path)   # round-trip: the artifact on disk validates
    print(f"[benchmarks] {name} -> {path} "
          f"(passed={doc['passed']}, {doc['wall_s']}s)")
    return 0 if doc["passed"] else 1


if __name__ == "__main__":
    import sys

    sys.exit(main())
