"""Simulation-farm throughput: batched ensemble vs serial execution.

The farm's claim is the LM-serving claim transplanted: advancing B resident
simulations with one vmapped step costs far less than B serial steps,
because per-step dispatch and per-op overheads amortize across the slot
axis.  We measure sim-steps/sec for ensemble sizes 1/4/8/16 on the JNP
path and report speedup over running the same work serially through
``GridDriver`` (one simulation at a time, the pre-farm workflow).

Every row reports the per-slot grid block (``slot_grid`` × ``shards_per
_slot``) so the slots × shards variant — each slot's grid decomposed over
a "shard" mesh axis — lands in ``BENCH_*.json`` directly comparable to
the undecomposed rows (same sim-steps/sec unit, explicit block size).
"""
from __future__ import annotations

import time

import numpy as np


def _bench_serial(configs, steps):
    import jax

    from repro.cfd.ns3d import NavierStokes3D

    # warm the compile (the serial path shares one jitted step per config
    # signature via jax's own jit cache; time only the steady state)
    solvers = [NavierStokes3D(c) for c in configs]
    states = [s.init_state() for s in solvers]
    step_fns = [s.make_step() for s in solvers]
    for s, st in zip(step_fns, states):
        jax.block_until_ready(s(st))
    t0 = time.perf_counter()
    for i, (fn, st) in enumerate(zip(step_fns, states)):
        for _ in range(steps):
            st = fn(st)
        jax.block_until_ready(st)
    return time.perf_counter() - t0


def _bench_farm(base, configs, steps, mesh=None, slot_axis="data"):
    import jax

    from repro.sim.farm import SimRequest, SimulationFarm

    farm = SimulationFarm(base, n_slots=len(configs), mesh=mesh,
                          slot_axis=slot_axis)
    # warm: run a throwaway batch of 1 step
    for c in configs:
        farm.submit(SimRequest(config=c, steps=1))
    farm.run_until_drained()
    for c in configs:
        farm.submit(SimRequest(config=c, steps=steps))
    t0 = time.perf_counter()
    farm.run_until_drained()
    jax.block_until_ready(farm.exec.state)
    return time.perf_counter() - t0


def _ugrid(shape) -> str:
    from benchmarks._util import slot_grid

    return slot_grid(shape, (), None)


def _bench_decomposed(n, steps, n_slots=4):
    """Slots × shards variant: same ensemble work with each slot's grid
    decomposed over a "shard" mesh axis.  Runs at however many shards the
    host allows (1 on the single-device CI harness — the degraded fast
    path — so the row is always present and comparable)."""
    import jax

    from benchmarks._util import pick_shards, slot_grid
    from repro.cfd import cavity
    from repro.launch.mesh import make_mesh

    shards = pick_shards(jax.device_count(), n)
    kw = dict(jacobi_iters=20, decomposition=((0, "shard"),))
    mesh = make_mesh((1, shards), ("slot", "shard"))
    res = np.linspace(60.0, 400.0, n_slots)
    configs = [cavity.config(n, re=float(r), **kw) for r in res]
    base = cavity.config(n, **kw)
    t = _bench_farm(base, configs, steps, mesh=mesh, slot_axis="slot")
    return {
        "ensemble": n_slots,
        "shards_per_slot": shards,
        "slot_grid": slot_grid(base.shape, kw["decomposition"], mesh),
        "farm_steps_per_s": round(n_slots * steps / t, 1),
    }


def run(n: int = 16, steps: int = 80, quick: bool = False, repeats: int = 2
        ) -> dict:
    """Ensemble members are the small/medium cases real sweeps are made of
    (UQ, parameter studies) — the regime where per-step dispatch and per-op
    overheads, not raw flops, bound serial throughput."""
    from repro.cfd import cavity

    # quick trims the largest ensemble, not the measurement length: short
    # timing windows are noise-dominated and flake the >=2x gate
    batches = (1, 4, 8) if quick else (1, 4, 8, 16)
    t_start = time.time()
    rows = []
    for b in batches:
        res = np.linspace(60.0, 400.0, b)
        configs = [cavity.config(n, re=float(r), jacobi_iters=20)
                   for r in res]
        base = cavity.config(n, jacobi_iters=20)
        t_serial = min(_bench_serial(configs, steps) for _ in range(repeats))
        t_farm = min(_bench_farm(base, configs, steps)
                     for _ in range(repeats))
        total = b * steps
        rows.append({
            "ensemble": b,
            # per-slot grid size: decomposed and undecomposed runs are
            # only comparable normalized to the block each device steps
            "slot_grid": _ugrid(base.shape),
            "shards_per_slot": 1,
            "serial_steps_per_s": round(total / t_serial, 1),
            "farm_steps_per_s": round(total / t_farm, 1),
            "speedup": round(t_serial / t_farm, 2),
        })
    by_b = {r["ensemble"]: r for r in rows}
    passed = by_b[8]["speedup"] >= 2.0
    return {
        "bench": "ensemble_farm",
        "paper_analogue": "runtime layer scheduling many generated kernels",
        "grid": f"{n}x{n}x4",
        "steps_per_sim": steps,
        "batches": rows,
        "decomposed": _bench_decomposed(n, steps),
        "speedup_at_8": by_b[8]["speedup"],
        "passed": passed,
        "wall_s": round(time.time() - t_start, 1),
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
