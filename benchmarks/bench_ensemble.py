"""Simulation-farm throughput: batched ensemble vs serial execution.

The farm's claim is the LM-serving claim transplanted: advancing B resident
simulations with one vmapped step costs far less than B serial steps,
because per-step dispatch and per-op overheads amortize across the slot
axis.  We measure sim-steps/sec for ensemble sizes 1/4/8/16 on the JNP
path and report speedup over running the same work serially (one
simulation at a time, the pre-farm workflow) — both sides resolved through
the ``repro.api`` front door: ``Runtime.prepare`` hands the serial jitted
step, ``Runtime.submit``/``drain`` drive the farm.

Every row reports the per-slot grid block (``slot_grid`` × ``shards_per
_slot``) so the slots × shards variant — each slot's grid decomposed over
a "shard" mesh axis — lands in ``BENCH_*.json`` directly comparable to
the undecomposed rows (same sim-steps/sec unit, explicit block size).
"""
from __future__ import annotations

import time

import numpy as np


def _bench_serial(rt, res_values, steps):
    import jax

    # warm the compile (the serial path shares one jitted step per config
    # signature via jax's own jit cache; time only the steady state)
    runs = [rt.prepare("cavity", re=float(r)) for r in res_values]
    for pr in runs:
        jax.block_until_ready(pr.step(pr.state))
    t0 = time.perf_counter()
    for pr in runs:
        st = pr.state
        for _ in range(steps):
            st = pr.step(st)
        jax.block_until_ready(st)
    return time.perf_counter() - t0


def _bench_farm(rt, res_values, steps):
    # warm: run a throwaway batch of 1 step
    for r in res_values:
        rt.submit("cavity", re=float(r), steps=1)
    rt.drain()
    sids = [rt.submit("cavity", re=float(r), steps=steps)
            for r in res_values]
    t0 = time.perf_counter()
    out = rt.drain()
    dt = time.perf_counter() - t0
    assert all(out[s].steps_done == steps for s in sids)
    return dt


def _ugrid(shape) -> str:
    from benchmarks._util import slot_grid

    return slot_grid(shape, (), None)


def _bench_decomposed(n, steps, n_slots=4):
    """Slots × shards variant: same ensemble work with each slot's grid
    decomposed over a "shard" mesh axis.  Runs at however many shards the
    host allows (1 on the single-device CI harness — the degraded fast
    path — so the row is always present and comparable)."""
    import jax

    from benchmarks._util import pick_shards, slot_grid
    from repro import api

    shards = pick_shards(jax.device_count(), n)
    decomposition = ((0, "shard"),)
    rt = api.runtime(n=n, n_slots=n_slots, jacobi_iters=20,
                     mesh_shape=(1, shards), mesh_axes=("slot", "shard"),
                     decomposition=decomposition)
    res = np.linspace(60.0, 400.0, n_slots)
    t = _bench_farm(rt, res, steps)
    base = rt.configure("cavity")
    return {
        "ensemble": n_slots,
        "shards_per_slot": shards,
        "slot_grid": slot_grid(base.shape, decomposition,
                               rt.mesh),
        "farm_steps_per_s": round(n_slots * steps / t, 1),
    }


def run(n: int = 16, steps: int = 80, quick: bool = False, repeats: int = 2
        ) -> dict:
    """Ensemble members are the small/medium cases real sweeps are made of
    (UQ, parameter studies) — the regime where per-step dispatch and per-op
    overheads, not raw flops, bound serial throughput."""
    from repro import api

    # quick trims the largest ensemble, not the measurement length: short
    # timing windows are noise-dominated and flake the >=2x gate
    batches = (1, 4, 8) if quick else (1, 4, 8, 16)
    t_start = time.time()
    rows = []
    for b in batches:
        res = np.linspace(60.0, 400.0, b)
        serial_rt = api.runtime(n=n, jacobi_iters=20)
        farm_rt = api.runtime(n=n, n_slots=b, jacobi_iters=20)
        t_serial = min(_bench_serial(serial_rt, res, steps)
                       for _ in range(repeats))
        t_farm = min(_bench_farm(farm_rt, res, steps)
                     for _ in range(repeats))
        total = b * steps
        rows.append({
            "ensemble": b,
            # per-slot grid size: decomposed and undecomposed runs are
            # only comparable normalized to the block each device steps
            "slot_grid": _ugrid(serial_rt.configure("cavity").shape),
            "shards_per_slot": 1,
            "serial_steps_per_s": round(total / t_serial, 1),
            "farm_steps_per_s": round(total / t_farm, 1),
            "speedup": round(t_serial / t_farm, 2),
        })
    by_b = {r["ensemble"]: r for r in rows}
    passed = by_b[8]["speedup"] >= 2.0
    return {
        "bench": "ensemble_farm",
        "paper_analogue": "runtime layer scheduling many generated kernels",
        "grid": f"{n}x{n}x4",
        "steps_per_sim": steps,
        "batches": rows,
        "decomposed": _bench_decomposed(n, steps),
        "speedup_at_8": by_b[8]["speedup"],
        "passed": passed,
        "wall_s": round(time.time() - t_start, 1),
    }


if __name__ == "__main__":
    import json

    print(json.dumps(run(), indent=1))
