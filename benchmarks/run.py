"""Benchmark harness: one bench per paper table/figure + the roofline
deliverable — every result lands in the ``BENCH_*.json`` trajectory.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only cavity,...]
                                           [--smoke] [--out-dir DIR]

Each bench's result is written as ``BENCH_<name>.json`` in the fixed
``repro.bench.v1`` envelope (see :mod:`repro.obs.bench`): schema version,
bench name, creation time, host fingerprint, pass verdict, wall time, and
the bench's numbers under ``metrics``.  Every file is schema-validated
before it is written, so a malformed entry can never enter the
trajectory.

``--smoke`` runs a seconds-scale telemetry-enabled ensemble pass instead
of the full suite and emits ``BENCH_smoke.json`` — the CI fast lane runs
it on every push and archives the artifact, which is what keeps the
trajectory populated (and the schema honest) between real-hardware runs.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

BENCHES = ["stencil", "cavity", "ensemble", "scaling", "roofline", "dist"]


def run_smoke(out_dir: str) -> dict:
    """Telemetry-on mini ensemble: the first entry of any trajectory.

    Small enough for CI (seconds on one CPU), but it exercises the whole
    instrumented stack: front door -> farm -> ensemble step with timers,
    metrics, and per-sim traces — and its BENCH document carries the
    telemetry snapshot, so the artifact doubles as an observability
    regression record.
    """
    from repro import api, obs

    n, steps, slots = 12, 16, 2
    reynolds = (60.0, 140.0, 260.0, 380.0)
    rt = api.runtime(n=n, n_slots=slots, jacobi_iters=8, telemetry=True)
    t0 = time.perf_counter()
    sids = [rt.submit("cavity", re=re, steps=steps, tag=f"re{re:.0f}")
            for re in reynolds]
    out = rt.drain()
    wall = time.perf_counter() - t0
    # second wave on the now-warm compile cache: its throughput is the
    # stable number the regression gate compares (wave A's includes the
    # one-time ensemble-step compile)
    t1 = time.perf_counter()
    warm_sids = [rt.submit("cavity", re=re, steps=steps,
                           tag=f"warm-re{re:.0f}") for re in reynolds]
    warm_out = rt.drain()
    warm_wall = time.perf_counter() - t1
    done = [out[s].steps_done == steps and out[s].terminated == "steps"
            for s in sids]
    done += [warm_out[s].steps_done == steps and
             warm_out[s].terminated == "steps" for s in warm_sids]
    traced = [rt.telemetry.trace.kinds_for(s) for s in sids]
    lifecycle_ok = all(
        ("submit" in k and "admit" in k and "result" in k) for k in traced)
    obs.validate_chrome_trace(rt.telemetry.trace.to_chrome())
    perf_doc = rt.perf_report().as_dict()
    doc = obs.make_bench_doc(
        "smoke",
        {
            "grid": f"{n}x{n}x4",
            "ensemble": len(reynolds),
            "slots": slots,
            "steps_per_sim": steps,
            "sim_steps_per_s": round(len(reynolds) * steps / wall, 1),
            "steady_sim_steps_per_s": round(
                len(reynolds) * steps / warm_wall, 1),
            "device_steps": rt.device_steps(),
            "compile_cache": api.compile_cache_stats(),
            "telemetry": rt.telemetry.snapshot(),
            "perf": perf_doc,
        },
        passed=all(done) and lifecycle_ok,
        wall_s=round(wall + warm_wall, 3),
    )
    path = obs.write_bench(doc, out_dir)
    obs.load_bench(path)   # round-trip: the artifact on disk validates
    print(f"[benchmarks] smoke -> {path} "
          f"(passed={doc['passed']}, {doc['wall_s']}s)")
    print(rt.report())
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale telemetry bench -> BENCH_smoke.json")
    ap.add_argument("--out-dir", default=".",
                    help="where BENCH_*.json artifacts land")
    args = ap.parse_args()

    if args.smoke:
        doc = run_smoke(args.out_dir)
        sys.exit(0 if doc["passed"] else 1)

    from repro import obs

    names = args.only.split(",") if args.only else BENCHES
    results = []
    for name in names:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        print(f"=== bench_{name} ===", flush=True)
        try:
            res = mod.run(quick=args.quick)
            res["wall_s"] = res.get("wall_s", round(time.time() - t0, 1))
        except Exception as e:  # pragma: no cover
            res = {"bench": name, "passed": False,
                   "error": f"{type(e).__name__}: {e}",
                   "wall_s": round(time.time() - t0, 1)}
        print(json.dumps(res, indent=1, default=str), flush=True)
        doc = obs.make_bench_doc(
            name, {k: v for k, v in res.items()
                   if k not in ("passed", "wall_s")},
            passed=bool(res.get("passed")), wall_s=res["wall_s"])
        path = obs.write_bench(doc, args.out_dir)
        print(f"[benchmarks] wrote {path}", flush=True)
        results.append(res)

    n_pass = sum(1 for r in results if r.get("passed"))
    print(f"\n[benchmarks] {n_pass}/{len(results)} passed")
    if n_pass < len(results):
        for r in results:
            if not r.get("passed"):
                print(f"  FAILED: {r['bench']}: {r.get('error', '')}")
        sys.exit(1)


if __name__ == "__main__":
    main()
