"""Benchmark harness: one bench per paper table/figure + the roofline
deliverable.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only cavity,...]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

BENCHES = ["stencil", "cavity", "ensemble", "scaling", "roofline", "dist"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    names = args.only.split(",") if args.only else BENCHES

    results = []
    for name in names:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        print(f"=== bench_{name} ===", flush=True)
        try:
            res = mod.run(quick=args.quick)
            res["wall_s"] = res.get("wall_s", round(time.time() - t0, 1))
        except Exception as e:  # pragma: no cover
            res = {"bench": name, "passed": False,
                   "error": f"{type(e).__name__}: {e}"}
        print(json.dumps(res, indent=1, default=str), flush=True)
        results.append(res)

    n_pass = sum(1 for r in results if r.get("passed"))
    print(f"\n[benchmarks] {n_pass}/{len(results)} passed")
    if n_pass < len(results):
        for r in results:
            if not r.get("passed"):
                print(f"  FAILED: {r['bench']}: {r.get('error', '')}")
        sys.exit(1)


if __name__ == "__main__":
    main()
