"""Benchmark harness: one bench per paper table/figure + the roofline
deliverable — every result lands in the ``BENCH_*.json`` trajectory.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only cavity,...]
                                           [--smoke] [--out-dir DIR]

Each bench's result is written as ``BENCH_<name>.json`` in the fixed
``repro.bench.v1`` envelope (see :mod:`repro.obs.bench`): schema version,
bench name, creation time, host fingerprint, pass verdict, wall time, and
the bench's numbers under ``metrics``.  Every file is schema-validated
before it is written, so a malformed entry can never enter the
trajectory.

``--smoke`` runs a seconds-scale telemetry-enabled ensemble pass instead
of the full suite and emits ``BENCH_smoke.json`` — the CI fast lane runs
it on every push and archives the artifact, which is what keeps the
trajectory populated (and the schema honest) between real-hardware runs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

BENCHES = ["stencil", "cavity", "ensemble", "scaling", "roofline", "dist"]

# warm waves per mode in the smoke: the recorded steady numbers are
# best-of-N, damping CI scheduling noise (the 3% health gate binds on the
# deterministic cost model, not on these wall numbers)
WARM_WAVES = 3


def _wave(rt, reynolds: tuple, steps: int, tag: str, **kw):
    """One submit+drain wave; ``(sids, wall_s, all_finished)``."""
    t0 = time.perf_counter()
    sids = [rt.submit("cavity", re=re, steps=steps,
                      tag=f"{tag}-re{re:.0f}", **kw) for re in reynolds]
    out = rt.drain()
    wall = time.perf_counter() - t0
    ok = all(out[s].steps_done == steps and out[s].terminated == "steps"
             for s in sids)
    return sids, wall, ok


def run_smoke(out_dir: str) -> dict:
    """Telemetry-on mini ensemble: the first entry of any trajectory.

    Small enough for CI (seconds on one CPU), but it exercises the whole
    instrumented stack: front door -> farm -> ensemble step with timers,
    metrics, and per-sim traces — and its BENCH document carries the
    telemetry snapshot, so the artifact doubles as an observability
    regression record.

    Besides the baseline-compared ``steady_sim_steps_per_s`` (warm
    compile cache, health off, no steady checks), the smoke records the
    health-overhead pair: ``steady_sim_steps_per_s_checked`` (health off,
    sims carrying a steady tolerance, so the farm already syncs residuals
    at every ``check_steady_every`` boundary) vs
    ``steady_sim_steps_per_s_health`` (same duty cycle with the in-situ
    monitor compiled in, ring drains riding those same boundaries).
    Those wall numbers are informational; the number ``check_regression``
    holds to the 3% bound is ``health.model.modeled_overhead`` — the HLO
    cost model's price of one diagnostics pass amortized over the
    ``check_steady_every`` steps its chunk covers, lowered from the two
    farms' real compiled executables (see
    :func:`repro.obs.perf.health_overhead_model` for why wall-clock
    cannot gate at 3%).  The "zero extra host syncs" claim is gated
    separately and exactly: ``health.drains == health.boundaries``.
    """
    from repro import api, obs

    n, steps, slots = 12, 16, 2
    reynolds = (60.0, 140.0, 260.0, 380.0)
    # a tolerance no residual ever meets: the sims run their full step
    # budget, but the farm performs a real residual sync at every
    # check_steady_every boundary — the duty cycle health drains ride
    never_tol = 1e-30
    rt = api.runtime(n=n, n_slots=slots, jacobi_iters=8, telemetry=True,
                     check_every=8)
    sids, wall, cold_ok = _wave(rt, reynolds, steps, "cold")
    # warm waves on the now-warm compile cache: their throughput is the
    # stable number the regression gate compares (the cold wave's
    # includes the one-time ensemble-step compile)
    warm = [_wave(rt, reynolds, steps, f"warm{i}")
            for i in range(WARM_WAVES)]
    warm_wall = min(w for _, w, _ in warm)
    checked = [_wave(rt, reynolds, steps, f"checked{i}",
                     steady_tol=never_tol) for i in range(WARM_WAVES)]
    checked_wall = min(w for _, w, _ in checked)
    done = [cold_ok] + [ok for _, _, ok in warm + checked]
    traced = [rt.telemetry.trace.kinds_for(s) for s in sids]
    lifecycle_ok = all(
        ("submit" in k and "admit" in k and "result" in k) for k in traced)
    obs.validate_chrome_trace(rt.telemetry.trace.to_chrome())
    perf_doc = rt.perf_report().as_dict()

    # same farm shape and steady-check duty cycle, health monitor
    # compiled in: the ring drains ride the boundaries the checked waves
    # already sync at, so checked-vs-health isolates the monitor's cost
    rt_h = api.runtime(n=n, n_slots=slots, jacobi_iters=8, telemetry=True,
                       health=True, check_every=8)
    _, _, h_cold_ok = _wave(rt_h, reynolds, steps, "hcold",
                            steady_tol=never_tol)
    h_warm = [_wave(rt_h, reynolds, steps, f"hwarm{i}",
                    steady_tol=never_tol) for i in range(WARM_WAVES)]
    h_wall = min(w for _, w, _ in h_warm)
    done += [h_cold_ok] + [ok for _, _, ok in h_warm]
    svc_h = next(iter(rt_h._services.values()))
    boundaries = (svc_h.farm.device_steps
                  // svc_h.farm.check_steady_every)
    drains = int(rt_h.telemetry.metrics.get("health.drains") or 0)
    # the gated overhead number: deterministic HLO-cost price of the
    # monitor, from the two farms' real lowered executables
    svc = next(iter(rt._services.values()))
    model = obs.perf.health_overhead_model(
        svc.farm.exec, svc_h.farm.exec, svc_h.farm.check_steady_every)
    model_ok = (model["status"] == "ok"
                and model["modeled_overhead"] is not None
                and model["modeled_overhead"] <= 0.03)
    total_wall = wall + sum(w for _, w, _ in warm + checked) \
        + sum(w for _, w, _ in h_warm)

    doc = obs.make_bench_doc(
        "smoke",
        {
            "grid": f"{n}x{n}x4",
            "ensemble": len(reynolds),
            "slots": slots,
            "steps_per_sim": steps,
            "sim_steps_per_s": round(len(reynolds) * steps / wall, 1),
            "steady_sim_steps_per_s": round(
                len(reynolds) * steps / warm_wall, 1),
            "steady_sim_steps_per_s_checked": round(
                len(reynolds) * steps / checked_wall, 1),
            "steady_sim_steps_per_s_health": round(
                len(reynolds) * steps / h_wall, 1),
            "health": {"drains": drains, "boundaries": boundaries,
                       "model": model},
            "device_steps": rt.device_steps(),
            "compile_cache": api.compile_cache_stats(),
            "telemetry": rt.telemetry.snapshot(),
            "perf": perf_doc,
        },
        passed=all(done) and lifecycle_ok and drains == boundaries
        and model_ok,
        wall_s=round(total_wall, 3),
    )
    path = obs.write_bench(doc, out_dir)
    obs.load_bench(path)   # round-trip: the artifact on disk validates
    print(f"[benchmarks] smoke -> {path} "
          f"(passed={doc['passed']}, {doc['wall_s']}s)")
    print(rt.report())
    return doc


def run_health_smoke(out_dir: str) -> dict:
    """NaN-injection smoke: poison one slot of a health-monitored farm
    and verify the quarantine machinery end to end, leaving the health
    trace JSONL and the flight record in ``out_dir`` as CI artifacts.

    Checks (all must hold for ``passed``): the poisoned sim quarantines
    with ``terminated="diverged"``, every healthy sim finishes, the
    flight record reads back from disk, and the ring drained exactly
    once per harvest boundary (zero extra host syncs).
    """
    from repro import api, obs
    from repro.obs.health import load_flight_record

    n, slots, steps = 12, 4, 24
    trace_path = os.path.join(out_dir, "health_events.jsonl")
    flight_dir = os.path.join(out_dir, "flight-records")
    rt = api.runtime(n=n, n_slots=slots, check_every=8, jacobi_iters=8,
                     telemetry={"trace_path": trace_path},
                     health={"flight_dir": flight_dir})
    t0 = time.perf_counter()
    healthy = [rt.submit("cavity", re=re, steps=steps, tag=f"re{re:.0f}")
               for re in (80.0, 150.0, 240.0)]
    bad = rt.submit("cavity", re=100.0, steps=steps, dt=50.0, tag="poison")
    res = rt.drain()
    wall = time.perf_counter() - t0
    rt.telemetry.trace.close()   # flush the JSONL artifact

    quarantined = res[bad].terminated == "diverged"
    healthy_done = all(res[s].terminated == "steps"
                       and res[s].steps_done == steps for s in healthy)
    svc = next(iter(rt._services.values()))
    boundaries = svc.farm.device_steps // svc.farm.check_steady_every
    drains = int(rt.telemetry.metrics.get("health.drains") or 0)
    try:
        rec = load_flight_record(flight_dir, rt._routes[bad][1])
        flight_ok = rec["meta"]["tag"] == "poison" and len(rec["frames"])
    except Exception as e:
        print(f"[benchmarks] flight record unreadable: {e}")
        flight_ok = False

    doc = obs.make_bench_doc(
        "health_smoke",
        {
            "grid": f"{n}x{n}x4",
            "slots": slots,
            "quarantined": bool(quarantined),
            "quarantine_error": res[bad].error,
            "healthy_done": bool(healthy_done),
            "drains": drains,
            "boundaries": boundaries,
            "flight_record_ok": bool(flight_ok),
            "dashboard": rt.watch(),
        },
        passed=bool(quarantined and healthy_done and flight_ok
                    and drains == boundaries),
        wall_s=round(wall, 3),
    )
    path = obs.write_bench(doc, out_dir)
    obs.load_bench(path)
    print(f"[benchmarks] health_smoke -> {path} "
          f"(passed={doc['passed']}, {doc['wall_s']}s)")
    print(doc["metrics"]["dashboard"])
    return doc


_DURABILITY_CHILD = """\
import os, signal
from repro import api

rt = api.runtime(n={n}, n_slots=2, jacobi_iters=8,
                 store={{"path": {store!r}, "ttl_s": 1.0}})
sids = [rt.submit("cavity", re=re, steps={steps}, tag=tag)
        for re, tag in ((80.0, "a"), (160.0, "b"), (240.0, "c"))]
rt.enqueue("cavity", re=320.0, steps={steps}, tag="d")
svc = rt.services()[0]
svc.run(4)                     # a, b mid-flight; c queued; d detached
assert rt.evict(sids[0])       # a spills a durable resume pointer
svc.run(2)                     # b keeps going; c admitted into a's slot
print("READY", flush=True)
os.kill(os.getpid(), signal.SIGKILL)
"""


def run_durability_smoke(out_dir: str) -> dict:
    """Kill-and-resume smoke for the durable job engine (repro.jobs).

    A child process submits four simulations against a shared SQLite
    ``JobStore`` (one evicted with a durable snapshot, two mid-run, one
    detached enqueue) and SIGKILLs itself mid-chunk.  After the dead
    process's leases expire, a fresh Runtime on the same store must (a)
    resume every incomplete job BEFORE claiming queued work, (b) finish
    all four, (c) execute each job exactly once (one terminal ``result``
    audit event per job), and (d) produce final states bitwise-identical
    to an uninterrupted run of the same requests.  The store file and its
    snapshot directories are left in ``out_dir`` as CI artifacts.
    """
    import shutil
    import signal as _signal
    import subprocess

    import numpy as np

    from repro import api, obs, jobs
    from repro.jobs import JobStore

    n, steps = 12, 12
    store_dir = os.path.join(out_dir, "durability-store")
    shutil.rmtree(store_dir, ignore_errors=True)
    store_path = os.path.join(store_dir, "jobs.sqlite")
    t0 = time.perf_counter()

    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    child = subprocess.run(
        [sys.executable, "-c",
         _DURABILITY_CHILD.format(n=n, steps=steps, store=store_path)],
        env=env, capture_output=True, text=True, timeout=600)
    killed = ("READY" in child.stdout
              and child.returncode == -_signal.SIGKILL)
    if not killed:
        print(f"[benchmarks] durability child failed:\n{child.stderr}")

    probe = JobStore(store_path)
    tags = {j.tag: j.job_id for j in probe.jobs()}
    incomplete = {j.job_id for j in probe.jobs()
                  if j.status in jobs.INCOMPLETE}
    seq0 = probe.last_seq()
    orphaned_ok = (len(tags) == 4 and len(incomplete) >= 2
                   and probe.latest_snapshot(tags.get("a", -1)) is not None)
    time.sleep(1.2)                      # let the dead leases expire

    rt = api.runtime(n=n, n_slots=2, jacobi_iters=8, telemetry=True,
                     store={"path": store_path, "ttl_s": 30.0})
    resumed = len(rt._jobs_local & incomplete)
    rt.drain()
    st = rt.store
    all_done = st.counts()[jobs.DONE] == 4 and st.queue_depth() == 0
    # resume-first, from the audit log: every claim of an incomplete job
    # precedes every claim of a queued one
    claims = {e["job_id"]: e["seq"] for e in st.events(after_seq=seq0)
              if e["event"] in ("claim", "takeover")
              and e["owner"] == st.owner}
    queued_seqs = [s for j, s in claims.items() if j not in incomplete]
    resumed_first = bool(incomplete) and bool(queued_seqs) and \
        max(claims[j] for j in incomplete) < min(queued_seqs)
    single_execution = all(
        len(st.events(jid, event="result")) == 1 for jid in tags.values())

    # bitwise parity against a never-interrupted run of the same requests
    ref = api.runtime(n=n, n_slots=2, jacobi_iters=8)
    ref_sids = {tag: ref.submit("cavity", re=re, steps=steps, tag=tag)
                for re, tag in ((80.0, "a"), (160.0, "b"),
                                (240.0, "c"), (320.0, "d"))}
    ref_res = ref.drain()
    parity_ok = bool(tags) and all(
        np.array_equal(st.load_result(jid)[f],
                       np.asarray(ref_res[ref_sids[tag]].state[f]))
        for tag, jid in tags.items()
        for f in ("vx", "vy", "vz", "p")) if all_done else False

    wall = time.perf_counter() - t0
    doc = obs.make_bench_doc(
        "durability_smoke",
        {
            "grid": f"{n}x{n}x4",
            "jobs": len(tags),
            "killed": bool(killed),
            "orphaned_ok": bool(orphaned_ok),
            "incomplete_at_restart": len(incomplete),
            "resumed": resumed,
            "resumed_first": bool(resumed_first),
            "lease_takeovers": st.takeovers,
            "single_execution": bool(single_execution),
            "all_done": bool(all_done),
            "parity_ok": bool(parity_ok),
            "store_counts": st.counts(),
        },
        passed=bool(killed and orphaned_ok and all_done and resumed >= 1
                    and resumed_first and single_execution and parity_ok),
        wall_s=round(wall, 3),
    )
    path = obs.write_bench(doc, out_dir)
    obs.load_bench(path)
    print(f"[benchmarks] durability_smoke -> {path} "
          f"(passed={doc['passed']}, {doc['wall_s']}s)")
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="seconds-scale telemetry bench -> BENCH_smoke.json")
    ap.add_argument("--health-smoke", action="store_true",
                    help="NaN-injection quarantine smoke -> "
                         "BENCH_health_smoke.json + health_events.jsonl + "
                         "flight-records/")
    ap.add_argument("--durability-smoke", action="store_true",
                    help="SIGKILL-and-resume durable-jobs smoke -> "
                         "BENCH_durability_smoke.json + durability-store/")
    ap.add_argument("--out-dir", default=".",
                    help="where BENCH_*.json artifacts land")
    args = ap.parse_args()

    if args.smoke or args.health_smoke or args.durability_smoke:
        ok = True
        if args.smoke:
            ok &= run_smoke(args.out_dir)["passed"]
        if args.health_smoke:
            ok &= run_health_smoke(args.out_dir)["passed"]
        if args.durability_smoke:
            ok &= run_durability_smoke(args.out_dir)["passed"]
        sys.exit(0 if ok else 1)

    from repro import obs

    names = args.only.split(",") if args.only else BENCHES
    results = []
    for name in names:
        mod = __import__(f"benchmarks.bench_{name}", fromlist=["run"])
        t0 = time.time()
        print(f"=== bench_{name} ===", flush=True)
        try:
            res = mod.run(quick=args.quick)
            res["wall_s"] = res.get("wall_s", round(time.time() - t0, 1))
        except Exception as e:  # pragma: no cover
            res = {"bench": name, "passed": False,
                   "error": f"{type(e).__name__}: {e}",
                   "wall_s": round(time.time() - t0, 1)}
        print(json.dumps(res, indent=1, default=str), flush=True)
        doc = obs.make_bench_doc(
            name, {k: v for k, v in res.items()
                   if k not in ("passed", "wall_s")},
            passed=bool(res.get("passed")), wall_s=res["wall_s"])
        path = obs.write_bench(doc, args.out_dir)
        print(f"[benchmarks] wrote {path}", flush=True)
        results.append(res)

    n_pass = sum(1 for r in results if r.get("passed"))
    print(f"\n[benchmarks] {n_pass}/{len(results)} passed")
    if n_pass < len(results):
        for r in results:
            if not r.get("passed"):
                print(f"  FAILED: {r['bench']}: {r.get('error', '')}")
        sys.exit(1)


if __name__ == "__main__":
    main()
