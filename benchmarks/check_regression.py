"""Bench regression gate: fresh ``BENCH_smoke.json`` vs committed baseline.

    PYTHONPATH=src python -m benchmarks.check_regression \
        --fresh bench-artifacts/BENCH_smoke.json \
        [--baseline benchmarks/baselines/BENCH_smoke.json] \
        [--max-regression 0.2] [--write-report report.md]

Failure conditions (exit 1, CI-red):

* the fresh bench itself did not pass;
* steady throughput (``steady_sim_steps_per_s``, warm compile cache)
  regressed by more than ``--max-regression`` (default 20%) against the
  baseline — only when fresh and baseline ran on comparable hosts (same
  backend + device count); cross-host wall-clock compares are skipped
  with a warning, never silently trusted;
* a perf row's achieved utilization collapsed to under half its baseline
  (same-host only);
* any fresh perf row reports a halo-byte MISMATCH or turned
  ``unparsed`` relative to its baseline row;
* a ``BENCH_ensemble_pallas.json`` artifact breaks a structural
  invariant — farm-vs-serial bitwise parity, one compiled executable
  per static signature, a throughput row per ensemble size — gated
  baseline-free on any host (``structural_failures``).

When the throughput gate trips, the perf attribution explains *why* by
diffing the predicted-cost rows: measured seconds up with predicted
FLOPs/bytes/wire flat means a runtime/scheduling regression (not added
work); collective seconds or wire bytes up with halo analytics flat
means a schedule/decomposition regression; HBM bytes up means the
compiled program itself grew.  A missing baseline warns and passes
(bootstrap) — commit one with ``benchmarks/bless_baseline.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "baselines", "BENCH_smoke.json")
THROUGHPUT_KEYS = ("steady_sim_steps_per_s", "sim_steps_per_s")
UTIL_COLLAPSE = 0.5          # fresh utilization < 50% of baseline -> fail
# the health monitor's modeled steady-state cost (one diagnostics pass
# amortized over the check_steady_every steps its chunk covers, priced
# by the HLO cost model on the real lowered executables) must stay
# within 3% of the health-off step cost.  Deterministic, so it gates
# baseline-free on any host — unlike a wall-clock ratio of two
# separately compiled programs, which carries several-percent
# process-level layout variance and would make a 3% gate a coin flip.
HEALTH_OVERHEAD = 0.03


def _throughput(doc: dict) -> tuple[float | None, str | None]:
    for k in THROUGHPUT_KEYS:
        v = doc.get("metrics", {}).get(k)
        if v:
            return float(v), k
    return None, None


def _perf_rows(doc: dict) -> dict:
    rows = doc.get("metrics", {}).get("perf", {}).get("rows", [])
    return {r.get("name"): r for r in rows if isinstance(r, dict)}


def _same_host(fresh: dict, baseline: dict) -> bool:
    fh, bh = fresh.get("host", {}), baseline.get("host", {})
    return (fh.get("backend") == bh.get("backend")
            and fh.get("device_count") == bh.get("device_count"))


def _ratio(a, b):
    if not a or not b:
        return None
    return float(a) / float(b)


def explain(base_row: dict, fresh_row: dict) -> list[str]:
    """Attribute a slowdown by diffing one perf row against its baseline."""
    name = fresh_row.get("name", "?")
    notes = []
    rm = _ratio(fresh_row.get("measured_s"), base_row.get("measured_s"))
    rh = _ratio(fresh_row.get("hbm_bytes"), base_row.get("hbm_bytes"))
    rw = _ratio(fresh_row.get("collective_wire_bytes"),
                base_row.get("collective_wire_bytes"))
    rc = _ratio(fresh_row.get("collective_s"), base_row.get("collective_s"))
    halo_flat = (fresh_row.get("halo_bytes_analytic")
                 == base_row.get("halo_bytes_analytic"))
    if rm and rm > 1.2:
        notes.append(f"{name}: measured_s grew {rm:.2f}x")
        if rh and rh > 1.2:
            notes.append(f"{name}: predicted HBM bytes grew {rh:.2f}x -> "
                         "the compiled program itself does more memory "
                         "traffic (solver/fusion change)")
        if rc and rc > 1.5 or (rw and rw > 1.5):
            if halo_flat:
                notes.append(
                    f"{name}: collective_s grew "
                    f"{(rc or rw):.2f}x, analytic halo bytes unchanged -> "
                    "schedule regression (extra/badly-placed collectives), "
                    "not a decomposition change")
            else:
                notes.append(f"{name}: collective traffic AND analytic "
                             "halo bytes changed -> decomposition change")
        if (rh is None or rh <= 1.2) and (rw is None or rw <= 1.2):
            notes.append(f"{name}: predicted cost flat while measured time "
                         "grew -> runtime/dispatch regression, not added "
                         "work")
    return notes


def structural_failures(fresh: dict) -> list[str]:
    """Host-independent invariants, gated without any baseline, on any
    machine.

    ``ensemble_pallas``: the farm really ran the Pallas template, stayed
    bitwise with serial, and compiled exactly one executable per static
    signature.  ``smoke``: the health monitor's modeled steady-state
    cost within ``HEALTH_OVERHEAD`` of the health-off step, and ring
    drains exactly on the harvest cadence.  ``health_smoke``: the
    NaN-injection quarantine
    actually quarantined, kept the healthy slots, and left a readable
    flight record.  ``durability_smoke``: a SIGKILLed farm really
    resumed from the job store — incomplete jobs first, exactly once,
    bitwise identical to an uninterrupted run.
    """
    if fresh.get("bench") == "smoke":
        return _smoke_health_failures(fresh)
    if fresh.get("bench") == "health_smoke":
        return _health_smoke_failures(fresh)
    if fresh.get("bench") == "durability_smoke":
        return _durability_smoke_failures(fresh)
    if fresh.get("bench") != "ensemble_pallas":
        return []
    m = fresh.get("metrics", {})
    fails = []
    if not str(m.get("resolved_backend", "")).startswith("pallas"):
        fails.append("ensemble_pallas: resolved_backend "
                     f"{m.get('resolved_backend')!r} is not a pallas "
                     "backend")
    rows = m.get("batches") or []
    if not rows:
        fails.append("ensemble_pallas: no per-ensemble throughput rows")
    for r in rows:
        if not (isinstance(r, dict) and r.get("farm_steps_per_s", 0) > 0):
            fails.append(f"ensemble_pallas: ensemble={r.get('ensemble')} "
                         "row has no farm throughput")
    if m.get("parity", {}).get("bitwise_ok") is not True:
        fails.append("ensemble_pallas: farm-vs-serial bitwise parity did "
                     "not hold (scalar-table regression?)")
    misses = m.get("compile_cache", {}).get("misses")
    if misses != m.get("expected_compile_misses"):
        fails.append(
            f"ensemble_pallas: {misses} compile misses, expected "
            f"{m.get('expected_compile_misses')} — not one executable per "
            "static signature (per-scalar recompile regression?)")
    return fails


def _smoke_health_failures(fresh: dict) -> list[str]:
    """Health-overhead gate inside one smoke artifact, baseline-free.

    Two deterministic invariants: the modeled steady-state cost of the
    monitor (``health.model.modeled_overhead`` — one diagnostics pass
    amortized over its chunk, priced by the HLO cost model on both
    farms' real lowered executables) within ``HEALTH_OVERHEAD``, and
    ring drains landing exactly on the harvest cadence (zero extra host
    syncs).  The wall-clock pair ``steady_sim_steps_per_s_checked`` /
    ``_health`` stays recorded in the artifact for humans but is not
    gated — see :func:`repro.obs.perf.health_overhead_model`.  Older
    artifacts without a health block pass untouched (bootstrap); an
    artifact that records health throughput but no model fails, so the
    model cannot be dropped silently."""
    m = fresh.get("metrics", {})
    fails = []
    if "health" not in m:
        return fails
    h = m.get("health", {})
    model = h.get("model")
    if not model:
        if m.get("steady_sim_steps_per_s_health"):
            fails.append("smoke: health throughput recorded but no "
                         "health.model block — the cost-model gate was "
                         "dropped")
        return fails
    if model.get("status") != "ok":
        fails.append(f"smoke: health cost model unparsed "
                     f"({model.get('error')}) — overhead cannot be gated")
    elif model.get("modeled_overhead", 1.0) > HEALTH_OVERHEAD:
        fails.append(
            f"smoke: modeled health overhead "
            f"{100 * model['modeled_overhead']:.2f}% exceeds the "
            f"{100 * HEALTH_OVERHEAD:.0f}% bound — the diagnostics pass "
            f"moves {model.get('hbm_bytes_diag_per_chunk'):.3g} HBM "
            f"bytes per chunk against a "
            f"{model.get('hbm_bytes_step'):.3g}-byte step (heavier "
            "diagnostics, or a shorter check_steady_every cadence?)")
    if h.get("drains") != h.get("boundaries"):
        fails.append(
            f"smoke: {h.get('drains')} health drains over "
            f"{h.get('boundaries')} harvest boundaries — the ring is "
            "not draining exactly on the check_steady_every cadence")
    return fails


def _health_smoke_failures(fresh: dict) -> list[str]:
    m = fresh.get("metrics", {})
    fails = []
    if m.get("quarantined") is not True:
        fails.append("health_smoke: the poisoned sim was not quarantined "
                     "(no terminated='diverged' result)")
    if m.get("healthy_done") is not True:
        fails.append("health_smoke: a healthy sim did not finish — "
                     "quarantine leaked into other slots")
    if m.get("flight_record_ok") is not True:
        fails.append("health_smoke: flight record missing or unreadable")
    if m.get("drains") != m.get("boundaries"):
        fails.append(
            f"health_smoke: {m.get('drains')} drains over "
            f"{m.get('boundaries')} boundaries — extra host syncs")
    return fails


def _durability_smoke_failures(fresh: dict) -> list[str]:
    """Kill-and-resume invariants, all host-independent.

    The child process must really have died by SIGKILL mid-run leaving
    orphaned rows behind; the restarted Runtime must resume every
    incomplete job *before* claiming fresh queued work, execute each
    job exactly once (one ``result`` audit event per row), drain the
    queue to empty, and produce results bitwise identical to an
    uninterrupted run."""
    m = fresh.get("metrics", {})
    fails = []
    if m.get("killed") is not True:
        fails.append("durability_smoke: child was not SIGKILLed mid-run — "
                     "the smoke never exercised a crash")
    if m.get("orphaned_ok") is not True:
        fails.append("durability_smoke: expected orphaned store state "
                     "(incomplete rows + evict snapshot) not found after "
                     "the kill")
    if not m.get("resumed", 0) >= 1:
        fails.append("durability_smoke: restarted Runtime resumed no "
                     "incomplete jobs")
    if m.get("resumed_first") is not True:
        fails.append("durability_smoke: a queued job was claimed before "
                     "the orphaned incomplete jobs — resume-first order "
                     "violated")
    if m.get("single_execution") is not True:
        fails.append("durability_smoke: a job recorded more than one "
                     "terminal 'result' event — double execution")
    if m.get("all_done") is not True:
        fails.append("durability_smoke: queue did not drain to all-done "
                     f"(store_counts={m.get('store_counts')})")
    if m.get("parity_ok") is not True:
        fails.append("durability_smoke: resumed results are not bitwise "
                     "identical to an uninterrupted run")
    return fails


def compare(fresh: dict, baseline: dict | None,
            max_regression: float = 0.2) -> dict:
    """Pure gate logic over two ``repro.bench.v1`` docs (the unit-tested
    core of the CLI)."""
    failures: list[str] = []
    warnings: list[str] = []
    explanations: list[str] = []

    if not fresh.get("passed"):
        failures.append("fresh bench did not pass")
    failures.extend(structural_failures(fresh))
    if baseline is not None and baseline.get("bench") != fresh.get("bench"):
        warnings.append(
            f"baseline is for bench {baseline.get('bench')!r}, fresh is "
            f"{fresh.get('bench')!r}: baseline gates skipped")
        baseline = None
    fresh_perf = _perf_rows(fresh)
    for name, row in fresh_perf.items():
        if row.get("halo_match") is False:
            failures.append(
                f"perf row {name}: predicted halo bytes "
                f"{row.get('halo_bytes_predicted')} != analytic "
                f"{row.get('halo_bytes_analytic')}")

    if baseline is None:
        warnings.append("no baseline: throughput/utilization gates skipped "
                        "(bless one with benchmarks/bless_baseline.py)")
        return {"passed": not failures, "failures": failures,
                "warnings": warnings, "explanations": explanations}

    base_perf = _perf_rows(baseline)
    for name, row in fresh_perf.items():
        b = base_perf.get(name)
        if b and b.get("status") == "ok" and row.get("status") != "ok":
            failures.append(f"perf row {name} turned "
                            f"{row.get('status')!r} (was ok): "
                            f"{row.get('error')}")

    if not _same_host(fresh, baseline):
        warnings.append(
            f"host mismatch (fresh {fresh.get('host')}, baseline "
            f"{baseline.get('host')}): wall-clock gates skipped")
        return {"passed": not failures, "failures": failures,
                "warnings": warnings, "explanations": explanations}

    ft, fk = _throughput(fresh)
    bt, bk = _throughput(baseline)
    if ft is None or bt is None:
        warnings.append("throughput metric missing from fresh or baseline")
    elif ft < bt * (1.0 - max_regression):
        failures.append(
            f"throughput regression: {fk}={ft:g} vs baseline {bk}={bt:g} "
            f"({100 * (1 - ft / bt):.1f}% slower, gate "
            f"{100 * max_regression:.0f}%)")
        for name, row in fresh_perf.items():
            if name in base_perf:
                explanations.extend(explain(base_perf[name], row))

    for name, row in fresh_perf.items():
        b = base_perf.get(name)
        if not b:
            continue
        fu, bu = row.get("utilization"), b.get("utilization")
        if fu is not None and bu and fu < UTIL_COLLAPSE * bu:
            failures.append(
                f"utilization collapse on {name}: {fu:.3g} vs baseline "
                f"{bu:.3g} (gate {UTIL_COLLAPSE:.0%} of baseline)")
            explanations.extend(explain(b, row))

    return {"passed": not failures, "failures": failures,
            "warnings": warnings, "explanations": explanations}


def render(verdict: dict) -> str:
    lines = ["# bench regression gate",
             f"**{'PASS' if verdict['passed'] else 'FAIL'}**", ""]
    for w in verdict["warnings"]:
        lines.append(f"- warning: {w}")
    for f in verdict["failures"]:
        lines.append(f"- FAIL: {f}")
    if verdict["explanations"]:
        lines.append("")
        lines.append("## attribution")
        for e in verdict["explanations"]:
            lines.append(f"- {e}")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh", required=True,
                    help="freshly produced BENCH_smoke.json")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE)
    ap.add_argument("--max-regression", type=float, default=0.2,
                    help="tolerated fractional throughput drop (0.2 = 20%%)")
    ap.add_argument("--write-report", default=None,
                    help="also write the verdict as markdown here")
    args = ap.parse_args(argv)

    from repro import obs

    fresh = obs.load_bench(args.fresh)
    baseline = None
    if os.path.exists(args.baseline):
        baseline = obs.load_bench(args.baseline)
    verdict = compare(fresh, baseline, max_regression=args.max_regression)
    text = render(verdict)
    print(text)
    if args.write_report:
        with open(args.write_report, "w") as f:
            f.write(text)
        with open(args.write_report + ".json", "w") as f:
            json.dump(verdict, f, indent=1)
    return 0 if verdict["passed"] else 1


if __name__ == "__main__":
    sys.exit(main())
