"""End-to-end driver: lid-driven cavity at Re=100, validated against Ghia
et al. (1982) — the paper's own demonstration application (its Fig. 3),
several hundred solver steps through the full framework stack, reached
through the ``repro.api`` front door: the scenario's ANALYSIS schedule
bin delivers the Ghia comparison as run diagnostics.

Run:  PYTHONPATH=src python examples/cavity_flow.py [--n 48] [--t-end 12]
"""
import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--t-end", type=float, default=12.0)
    args = ap.parse_args()

    from repro import api
    from repro.cfd.cavity import GHIA_RE100_U

    print(f"lid-driven cavity Re=100, {args.n}^2 grid, t_end={args.t_end}")
    rt = api.runtime(n=args.n)
    res = rt.run("cavity", t_end=args.t_end, re=100.0, progress=200)
    errors = res.diagnostics["ghia"]
    print(f"steps: {res.steps_done}")
    print(f"Ghia centerline deviation: u_rms={errors['u_rms']:.4f} "
          f"v_rms={errors['v_rms']:.4f}")

    # ASCII profile: u(y) through the vertical centerline vs Ghia points
    y, u = res.diagnostics["centerline_u"]
    print("\n  u(y) at x=0.5   (*=ours, o=Ghia)")
    for gy, gu in GHIA_RE100_U[1:-1]:
        ui = float(np.interp(gy, y, u))
        col = int((ui + 0.4) / 1.4 * 58)
        gcol = int((gu + 0.4) / 1.4 * 58)
        line = [" "] * 60
        line[min(max(gcol, 0), 59)] = "o"
        line[min(max(col, 0), 59)] = "*"
        print(f"  y={gy:5.3f} |{''.join(line)}|")
    ok = errors["u_rms"] < 0.035 and errors["v_rms"] < 0.035
    print("\nVALIDATION", "PASSED" if ok else "FAILED")


if __name__ == "__main__":
    main()
