"""End-to-end driver: lid-driven cavity at Re=100, validated against Ghia
et al. (1982) — the paper's own demonstration application (its Fig. 3),
several hundred solver steps through the full framework stack
(descriptor-generated kernels, driver halo exchange, comm/compute
overlap, Method-of-Lines stepping).

Run:  PYTHONPATH=src python examples/cavity_flow.py [--n 48] [--t-end 12]
"""
import argparse

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=48)
    ap.add_argument("--t-end", type=float, default=12.0)
    args = ap.parse_args()

    from repro.cfd import cavity

    print(f"lid-driven cavity Re=100, {args.n}^2 grid, t_end={args.t_end}")
    solver, state, errors = cavity.run(n=args.n, t_end=args.t_end,
                                       progress=200)
    print(f"steps: {int(args.t_end / solver.config.dt)}")
    print(f"Ghia centerline deviation: u_rms={errors['u_rms']:.4f} "
          f"v_rms={errors['v_rms']:.4f}")

    # ASCII profile: u(y) through the vertical centerline vs Ghia points
    y, u = cavity.centerline_u(solver, state)
    print("\n  u(y) at x=0.5   (*=ours, o=Ghia)")
    for gy, gu in cavity.GHIA_RE100_U[1:-1]:
        ui = float(np.interp(gy, y, u))
        col = int((ui + 0.4) / 1.4 * 58)
        gcol = int((gu + 0.4) / 1.4 * 58)
        line = [" "] * 60
        line[min(max(gcol, 0), 59)] = "o"
        line[min(max(col, 0), 59)] = "*"
        print(f"  y={gy:5.3f} |{''.join(line)}|")
    ok = errors["u_rms"] < 0.035 and errors["v_rms"] < 0.035
    print("\nVALIDATION", "PASSED" if ok else "FAILED")


if __name__ == "__main__":
    main()
