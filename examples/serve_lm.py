"""Batched serving with continuous batching: requests stream through a
fixed-slot engine (prefill on admission, per-slot decode positions, slot
reuse on completion) — the serving-side end-to-end driver.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-1.2b]
"""
import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    from repro.launch import serve

    serve.main(["--arch", args.arch, "--smoke",
                "--requests", str(args.requests),
                "--slots", "4", "--max-new", "12"])


if __name__ == "__main__":
    main()
