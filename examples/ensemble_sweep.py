"""Reynolds-number sweep through the simulation farm.

Eight lid-driven cavity variants share one device batch: submit them all,
drain the farm, and compare the steady centerline profiles — one compiled
step served every simulation (submit/poll/result against the service, the
multi-tenant surface).

Run:  PYTHONPATH=src python examples/ensemble_sweep.py [--n 24] [--slots 4]
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--t-end", type=float, default=4.0)
    args = ap.parse_args()

    import numpy as np

    from repro.cfd import cavity
    from repro.cfd.ns3d import NavierStokes3D
    from repro.sim import SimulationService, compile_cache_stats

    reynolds = [50, 75, 100, 150, 200, 250, 300, 400]
    svc = SimulationService(cavity.config(args.n), n_slots=args.slots)
    print(f"cavity sweep: {len(reynolds)} Reynolds numbers through "
          f"{args.slots} slots on a {args.n}^2 grid")

    t0 = time.time()
    sids = {svc.submit(cavity.sim_request(args.n, re=float(re),
                                          t_end=args.t_end,
                                          tag=f"re{re}")): re
            for re in reynolds}
    results = {sid: svc.result(sid) for sid in sids}
    dt = time.time() - t0

    total_steps = sum(r.steps_done for r in results.values())
    print(f"{total_steps} sim-steps in {dt:.1f}s "
          f"({total_steps / dt:.0f} steps/s), "
          f"{svc.farm.device_steps} device dispatch rounds")
    print(f"compile cache: {compile_cache_stats()}")

    print("\n  Re    min u(y)   max u(y)   (centerline, z-averaged)")
    for sid, re in sorted(sids.items(), key=lambda kv: kv[1]):
        r = results[sid]
        solver = NavierStokes3D(r.config)
        _, u = cavity.centerline_u(solver, r.state)
        print(f"  {re:4d}  {float(np.min(u)):9.4f}  {float(np.max(u)):9.4f}"
              f"   ({r.steps_done} steps, {r.terminated})")
    # at fixed (short) time the lid's momentum has diffused less at higher
    # Re: the near-lid boundary layer is thinner, so the centerline maximum
    # decreases monotonically with Re — the expected developing-flow trend
    u_max = [float(np.max(cavity.centerline_u(
        NavierStokes3D(results[s].config), results[s].state)[1]))
        for s, _ in sorted(sids.items(), key=lambda kv: kv[1])]
    ok = all(a > b for a, b in zip(u_max, u_max[1:]))
    print("OK" if ok else "FAILED: boundary layer did not thin with Re")


if __name__ == "__main__":
    main()
