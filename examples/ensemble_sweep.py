"""Reynolds-number sweep through the simulation farm — via ``repro.api``.

Eight lid-driven cavity variants share one device batch: submit them all
through the runtime front door, drain, and compare the centerline profiles
— one compiled step served every simulation.  The runtime resolves the
``SimulationService`` (queue + slots + compile cache) behind
``submit``/``result``; nothing here constructs a farm.

Run:  PYTHONPATH=src python examples/ensemble_sweep.py [--n 24] [--slots 4]
          [--trace-out events.jsonl] [--report]

``--trace-out`` enables telemetry and streams every per-sim lifecycle
event (submit -> admit -> first_step -> result) to a JSON-lines file; a
Chrome-trace twin (``<path>.chrome.json``) is written alongside for
Perfetto.  ``--report`` prints the Cactus-style timer/metrics summary.
"""
import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--t-end", type=float, default=4.0)
    ap.add_argument("--trace-out", default=None,
                    help="stream lifecycle events here as JSON-lines")
    ap.add_argument("--report", action="store_true",
                    help="print the repro.obs timer/metrics report")
    args = ap.parse_args()

    import numpy as np

    from repro import api

    telemetry = ({"trace_path": args.trace_out} if args.trace_out
                 else bool(args.report))
    reynolds = [50, 75, 100, 150, 200, 250, 300, 400]
    rt = api.runtime(n=args.n, n_slots=args.slots, telemetry=telemetry)
    print(f"cavity sweep: {len(reynolds)} Reynolds numbers through "
          f"{args.slots} slots on a {args.n}^2 grid")

    t0 = time.time()
    sids = {rt.submit("cavity", re=float(re), t_end=args.t_end,
                      tag=f"re{re}"): re
            for re in reynolds}
    results = {sid: rt.result(sid) for sid in sids}
    dt = time.time() - t0

    total_steps = sum(r.steps_done for r in results.values())
    print(f"{total_steps} sim-steps in {dt:.1f}s "
          f"({total_steps / dt:.0f} steps/s), "
          f"{rt.device_steps()} device dispatch rounds")
    print(f"compile cache: {api.compile_cache_stats()}")

    if args.report or args.trace_out:
        print(rt.report())
    if args.trace_out:
        chrome = rt.telemetry.trace.save_chrome(
            args.trace_out + ".chrome.json")
        print(f"trace: {len(rt.telemetry.trace.events)} events -> "
              f"{args.trace_out} (+ {chrome} for Perfetto)")

    print("\n  Re    min u(y)   max u(y)   (centerline, z-averaged)")
    u_max = []
    for sid, re in sorted(sids.items(), key=lambda kv: kv[1]):
        r = results[sid]
        _, u = rt.analyze(r)["centerline_u"]
        u_max.append(float(np.max(u)))
        print(f"  {re:4d}  {float(np.min(u)):9.4f}  {float(np.max(u)):9.4f}"
              f"   ({r.steps_done} steps, {r.terminated})")
    # at fixed (short) time the lid's momentum has diffused less at higher
    # Re: the near-lid boundary layer is thinner, so the centerline maximum
    # decreases monotonically with Re — the expected developing-flow trend
    ok = all(a > b for a, b in zip(u_max, u_max[1:]))
    print("OK" if ok else "FAILED: boundary layer did not thin with Re")


if __name__ == "__main__":
    main()
