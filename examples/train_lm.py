"""End-to-end LM training: a ~20M-param llama-family model trained for a
few hundred steps on the deterministic synthetic corpus, with async
checkpointing, watchdog, and restart-resume — every substrate layer of
the framework in one run.

(The assigned full configs train identically via the same launcher on a
real pod; the CPU container sizes this demo so it finishes in minutes.
The loss should drop by >1 nat over 200 steps.)

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    from repro.launch import train

    losses = train.main([
        "--arch", "llama3-8b", "--smoke",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "256",
        "--lr", "3e-3", "--ckpt-dir", args.ckpt_dir,
        "--ckpt-every", "100", "--log-every", "20",
    ])
    drop = losses[0] - losses[-1]
    print(f"loss drop over {args.steps} steps: {drop:.3f} nats")
    if drop < 0.5:
        print("WARNING: expected >0.5 nats of improvement")
        sys.exit(1)


if __name__ == "__main__":
    main()
