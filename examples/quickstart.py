"""Quickstart: the runtime front door in three lines.

Scenarios are registered problem declarations (config builder + parameter
schema + IC/analysis routines wired into the INITIAL/EVOLVE/ANALYSIS
schedule bins); the Runtime resolves them onto an execution stack — serial
driver here, simulation farm / decomposed mesh with the same three lines
plus a ``mesh_shape``.  Nothing below names a kernel, a halo exchange, or
a device: that is the point.

    rt = api.runtime(n=24)
    res = rt.run("cavity", t_end=2.0, re=100.0)
    print(res.diagnostics["ghia"])

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro import api


def main():
    # the registry: every problem the runtime can serve by name
    print("registered scenarios:")
    for name in api.scenario_names():
        print(f"  {name:18s} {api.get_scenario(name).description}")

    # -- the three-line quickstart -------------------------------------------
    rt = api.runtime(n=24)
    res = rt.run("cavity", t_end=2.0, re=100.0)
    print(f"\ncavity Re=100, {res.steps_done} steps "
          f"(terminated: {res.terminated})")
    print("Ghia centerline deviation:",
          {k: round(v, 4) for k, v in res.diagnostics["ghia"].items()})

    # same front door, different scenario + per-run parameters
    tg = rt.run("taylor_green", steps=40, nu=0.05)
    err = tg.diagnostics["analytic_error"]
    print(f"taylor_green nu=0.05: max |v - analytic| = "
          f"{max(err['err_vx'], err['err_vy']):.2e} at t={err['t']:.3f}")
    assert max(err["err_vx"], err["err_vy"]) < 5e-3
    assert res.steps_done > 0
    print("OK — scenario registry -> runtime -> driver stack, one surface.")


if __name__ == "__main__":
    main()
