"""Quickstart: the paper's abstraction stack in five minutes.

1. declare a stencil kernel with a CaCUDA descriptor (paper Listing 1)
2. the generator expands it against a template (Pallas 3DBLOCK on TPU,
   fused-jnp elsewhere)
3. the driver decomposes the domain and fills ghost zones
4. run a few diffusion steps — with communication/computation overlap

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import descriptor, generate
from repro.core.halo import AxisSpec, bc_neumann, exchange_pad


def main():
    # -- 1. declare the kernel (the cacuda.ccl equivalent) -------------------
    DIFFUSE = descriptor(
        "DIFFUSE",
        stencil=(1, 1, 1, 1, 1, 1),
        tile=(8, 8, 8),
        u=dict(names=("u",), intent="SEPARATEINOUT", cached=True),
        parameters=("dt", "h", "nu"),
    )

    # -- 2. give the per-cell update; the generator builds the kernel --------
    def body(ctx):
        u = ctx["u"]
        h, dt, nu = ctx.param("h"), ctx.param("dt"), ctx.param("nu")
        lap = (u.at(1, 0, 0) + u.at(-1, 0, 0) + u.at(0, 1, 0)
               + u.at(0, -1, 0) + u.at(0, 0, 1) + u.at(0, 0, -1)
               - 6.0 * u.c) / h ** 2
        return {"u": u.c + dt * nu * lap}

    kernel = generate(DIFFUSE, body, template="JNP")  # "3DBLOCK" on TPU

    # -- 3. domain + ghost exchange -------------------------------------------
    n = 32
    u = jnp.zeros((n, n, n)).at[n // 2, n // 2, n // 2].set(1.0)
    specs = [AxisSpec(array_axis=i, bc_lo=bc_neumann(), bc_hi=bc_neumann())
             for i in range(3)]

    # -- 4. step ------------------------------------------------------------------
    @jax.jit
    def step(u):
        padded = exchange_pad(u, (1, 1, 1), specs)
        return kernel({"u": padded}, dt=0.1, h=1.0, nu=1.0)["u"]

    total0 = float(u.sum())
    for i in range(50):
        u = step(u)
    total1 = float(u.sum())
    print(f"diffused peak: {float(u.max()):.5f} (from 1.0)")
    print(f"mass conserved: {total0:.6f} -> {total1:.6f}")
    assert abs(total1 - total0) < 1e-3
    print("OK — descriptor -> generated kernel -> driver halo -> stepped.")


if __name__ == "__main__":
    main()
