"""Define a NEW kernel from the paper's own declarative syntax: parse a
``cacuda.ccl`` text block (paper Listing 1 format) and run the generated
kernel — the extensibility story of the CaCUDA abstraction.

Run:  PYTHONPATH=src python examples/custom_kernel.py
"""
import jax
import jax.numpy as jnp

from repro.core import generate, parse_ccl

CCL = """
CCTK_CUDA_KERNEL GRADIENT_MAG
  TYPE=3DBLOCK
  STENCIL="1,1,1,1,1,1"
  TILE="8,8,8"
{
  CCTK_CUDA_KERNEL_VARIABLE CACHED=YES INTENT=IN
  {
    phi
  } "SCALAR_FIELD"
  CCTK_CUDA_KERNEL_VARIABLE INTENT=OUT
  {
    gmag
  } "GRADIENT_MAGNITUDE"
  CCTK_CUDA_KERNEL_PARAMETER
  {
    h
  } "SPACING"
}
"""


def main():
    desc = parse_ccl(CCL)[0]
    print(f"parsed descriptor: {desc.name}, stencil={desc.stencil}, "
          f"tile={desc.tile}")
    print(f"  variables: {[g.names for g in desc.variables]}")

    def body(ctx):
        phi = ctx["phi"]
        h = ctx.param("h")
        gx = (phi.at(1, 0, 0) - phi.at(-1, 0, 0)) / (2 * h)
        gy = (phi.at(0, 1, 0) - phi.at(0, -1, 0)) / (2 * h)
        gz = (phi.at(0, 0, 1) - phi.at(0, 0, -1)) / (2 * h)
        return {"gmag": jnp.sqrt(gx * gx + gy * gy + gz * gz)}

    kernel = generate(desc, body, template="JNP")
    # also validate through the Pallas 3DBLOCK template in interpret mode
    kernel_pallas = generate(desc, body, template="3DBLOCK", interpret=True)

    n = 24
    x = jnp.linspace(0, 1, n + 2)
    phi = (x[:, None, None] ** 2 + x[None, :, None]
           + 0 * x[None, None, :]) * jnp.ones((n + 2, n + 2, n + 2))
    h = float(x[1] - x[0])
    out_jnp = kernel({"phi": phi}, h=h)["gmag"]
    out_pl = kernel_pallas({"phi": phi}, h=h)["gmag"]
    err = float(jnp.abs(out_jnp - out_pl).max())
    print(f"JNP vs Pallas(3DBLOCK, interpret) max err: {err:.2e}")
    assert err < 1e-5
    # analytic: |grad| = sqrt((2x)^2 + 1)
    xc = x[1:-1]
    expect = jnp.sqrt((2 * xc[:, None, None]) ** 2 + 1.0)
    mid_err = float(jnp.abs(out_jnp - expect).mean())
    print(f"mean deviation from analytic gradient: {mid_err:.4f}")
    print("OK — new kernel from .ccl text, validated on both templates.")


if __name__ == "__main__":
    main()
