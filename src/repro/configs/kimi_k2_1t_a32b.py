"""kimi-k2-1t-a32b [moe] — trillion-param MoE, paper-table dims
[arXiv:2501.kimi2; unverified].  61L d_model=7168 64H (GQA kv=8)
d_ff=2048 (expert width) vocab=163840, MoE 384 experts top-8 + 1 shared.

Per the assignment's [unverified] tier we use standard GQA (not MLA).
bf16 params — at 1T params the fp32-master scheme does not fit 512×16GB;
see optim/adamw.py dtype knobs + EXPERIMENTS.md §Dry-run.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        num_layers=61,
        d_model=7168,
        num_heads=64,
        num_kv_heads=8,
        d_ff=2048,
        vocab_size=163_840,
        num_experts=384,
        num_experts_per_tok=8,
        num_shared_experts=1,
        rope_theta=50_000.0,
        param_dtype=jnp.bfloat16,
    )
