"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].
94L d_model=4096 64H (GQA kv=4) d_ff=1536 (expert width) vocab=151936.
"""
import jax.numpy as jnp

from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        d_ff=1536,
        vocab_size=151_936,
        head_dim=128,
        num_experts=128,
        num_experts_per_tok=8,
        num_shared_experts=0,
        rope_theta=1_000_000.0,
        param_dtype=jnp.bfloat16,
    )
