"""Arch registry: ``get_config("<id>")`` + reduced smoke configs.

The FULL configs are exercised only through the dry-run (ShapeDtypeStruct,
no allocation); ``smoke(cfg)`` shrinks every family to a CPU-runnable size
(few layers, thin width, few experts, tiny vocab) while keeping the exact
block composition, so the smoke tests execute the same code paths the
production configs lower through.
"""
from __future__ import annotations

import dataclasses
import importlib

import jax.numpy as jnp

from repro.models.config import ModelConfig

ARCHS = {
    "musicgen-large": "repro.configs.musicgen_large",
    "zamba2-1.2b": "repro.configs.zamba2_1p2b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "qwen1.5-4b": "repro.configs.qwen1p5_4b",
    "llama3-8b": "repro.configs.llama3_8b",
    "minitron-4b": "repro.configs.minitron_4b",
    "granite-8b": "repro.configs.granite_8b",
    "paligemma-3b": "repro.configs.paligemma_3b",
}


def list_archs() -> list[str]:
    return list(ARCHS)


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {list(ARCHS)}")
    return importlib.import_module(ARCHS[name]).config()


def smoke(cfg: ModelConfig, *, layers: int = 2) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    heads = min(cfg.num_heads, 4)
    kv = min(cfg.num_kv_heads, heads)
    heads = (heads // kv) * kv or kv
    repl = dict(
        num_layers=max(layers, 2),
        d_model=128,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        param_dtype=jnp.float32,
        compute_dtype=jnp.float32,
        q_chunk=64,
        kv_chunk=64,
        remat="none",
    )
    if cfg.num_experts:
        repl.update(num_experts=8,
                    num_experts_per_tok=min(2, cfg.num_experts_per_tok),
                    num_shared_experts=min(1, cfg.num_shared_experts))
    if cfg.family == "hybrid":
        repl.update(ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
                    attn_every=2)
    if cfg.family == "ssm":
        repl.update(slstm_indices=(1,), ssm_chunk=16, d_model=64,
                    num_heads=2, num_kv_heads=2)
    if cfg.num_prefix_tokens:
        repl.update(num_prefix_tokens=8)
    return dataclasses.replace(cfg, **repl)
