"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf].  48L d_model=2048 32H (GQA kv=32) d_ff=8192
vocab=2048.  The EnCodec frontend is a stub: train/prefill consume
precomputed frame embeddings (models/multimodal.frame_embeddings);
decode embeds single-codebook tokens (vocab 2048).
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        rope_theta=10_000.0,
        num_codebooks=4,
    )
