"""Assigned input shapes — every LM arch runs each applicable shape.

  train_4k     train_step   seq 4096    global_batch 256
  prefill_32k  prefill      seq 32768   global_batch 32
  decode_32k   serve_step   KV len 32768, global_batch 128 (one new token)
  long_500k    serve_step   KV/state len 524288, global_batch 1 — requires
               sub-quadratic attention (SSM/hybrid only; skips recorded)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def applicable(cfg, shape: ShapeSpec) -> bool:
    """long_500k needs sub-quadratic sequence mixing (no dense 500k KV)."""
    if shape.name == "long_500k":
        return bool(cfg.subquadratic)
    return True
