"""paligemma-3b [vlm] — SigLIP + gemma backbone [arXiv:2407.07726; hf].
18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.

The SigLIP frontend is a stub: 256 precomputed patch embeddings
(models/multimodal.patch_embeddings) consumed as a bidirectional prefix
(prefix-LM masking); loss over the text suffix only.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="paligemma-3b",
        family="vlm",
        num_layers=18,
        d_model=2048,
        num_heads=8,
        num_kv_heads=1,
        d_ff=16_384,
        vocab_size=257_216,
        head_dim=256,
        rope_theta=10_000.0,
        tie_embeddings=True,
        num_prefix_tokens=256,
    )
