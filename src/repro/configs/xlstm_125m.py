"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].
12L d_model=768 4H d_ff=0 (proj-factor blocks instead of MLP) vocab=50304.

Block mix follows the paper's [x:1] notation: sLSTM at ``slstm_indices``,
mLSTM elsewhere.  O(1) decode state — runs long_500k.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        num_layers=12,
        d_model=768,
        num_heads=4,
        num_kv_heads=4,
        d_ff=0,
        vocab_size=50_304,
        slstm_indices=(5, 11),
        mlstm_proj_factor=2.0,
        conv_width=4,
        ssm_chunk=128,
        tie_embeddings=True,
        scan_layers=False,          # heterogeneous 12-layer stack: unrolled
        subquadratic=True,
    )
