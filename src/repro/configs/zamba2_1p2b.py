"""zamba2-1.2b [hybrid] — Mamba2 blocks + one shared (weight-tied)
attention+MLP block [arXiv:2411.15242; hf].  38L d_model=2048 32H
(GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.

The shared block is applied every ``attn_every`` Mamba2 layers (weight-tied
across applications; the published LoRA per-application specialization is
omitted — see DESIGN.md).  Sub-quadratic: runs long_500k.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b",
        family="hybrid",
        num_layers=38,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,
        d_ff=8192,
        vocab_size=32_000,
        ssm_state=64,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_groups=1,
        conv_width=4,
        attn_every=2,          # shared block every 2 mamba layers (19 applications)
        rope_theta=10_000.0,
        tie_embeddings=True,
        subquadratic=True,
    )
