"""qwen1.5-4b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].
40L d_model=2560 20H (GQA kv=20) d_ff=6912 vocab=151936.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-4b",
        family="dense",
        num_layers=40,
        d_model=2560,
        num_heads=20,
        num_kv_heads=20,
        d_ff=6912,
        vocab_size=151_936,
        qkv_bias=True,
        rope_theta=5_000_000.0,
    )
