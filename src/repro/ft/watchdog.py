"""Straggler detection + step-time watchdog.

At 1000+ nodes the dominant failure modes are (a) hard node loss — handled
by checkpoint/restart (ckpt/, ft/failures.py) — and (b) *stragglers*:
nodes that run 1.2-3x slow (thermal throttle, ECC retry storms, noisy
neighbors) and drag every synchronous collective with them.

``StepWatchdog`` keeps an EWMA + robust deviation of step wall-times and
flags anomalies.  Policy hooks (the runtime wires these):
  * slow_step   -> log + mark; repeated -> request a preemptive checkpoint
  * hang        -> deadline exceeded; orchestrator kills + restarts from
                   the last checkpoint (tested via ft/failures.py)

Mitigations available to the launcher:
  * preemptive checkpoint + evict (re-mesh without the straggler pod — the
    mesh's ``pod`` axis is the eviction unit; elastic restore reshards)
  * within-step: gradient accumulation gives slack absorption; input
    prefetch (data/pipeline.Prefetcher) removes host-side jitter.
"""
from __future__ import annotations

import dataclasses
import time


@dataclasses.dataclass
class WatchdogEvent:
    kind: str          # "slow_step" | "hang" | "checkpoint_requested"
    step: int
    step_time: float
    threshold: float


class StepWatchdog:
    def __init__(self, *, ewma_alpha: float = 0.1, slow_factor: float = 1.5,
                 hang_factor: float = 5.0, warmup_steps: int = 5,
                 checkpoint_after_slow: int = 3):
        self.alpha = ewma_alpha
        self.slow_factor = slow_factor
        self.hang_factor = hang_factor
        self.warmup = warmup_steps
        self.checkpoint_after_slow = checkpoint_after_slow
        self.ewma: float | None = None
        self.n = 0
        self.consecutive_slow = 0
        self.events: list[WatchdogEvent] = []
        self._t0: float | None = None

    # -- timing interface ------------------------------------------------------
    def start_step(self):
        self._t0 = time.perf_counter()

    def end_step(self, step: int) -> list[WatchdogEvent]:
        assert self._t0 is not None, "end_step without start_step"
        dt = time.perf_counter() - self._t0
        self._t0 = None
        return self.observe(step, dt)

    def observe(self, step: int, step_time: float) -> list[WatchdogEvent]:
        """Feed one step time; returns any new events."""
        new: list[WatchdogEvent] = []
        self.n += 1
        if self.ewma is None:
            self.ewma = step_time
        if self.n > self.warmup:
            slow_thr = self.slow_factor * self.ewma
            hang_thr = self.hang_factor * self.ewma
            if step_time > hang_thr:
                new.append(WatchdogEvent("hang", step, step_time, hang_thr))
            elif step_time > slow_thr:
                self.consecutive_slow += 1
                new.append(WatchdogEvent("slow_step", step, step_time,
                                         slow_thr))
                if self.consecutive_slow >= self.checkpoint_after_slow:
                    new.append(WatchdogEvent("checkpoint_requested", step,
                                             step_time, slow_thr))
                    self.consecutive_slow = 0
            else:
                self.consecutive_slow = 0
        # EWMA updates on non-hang steps only (hangs would poison the mean)
        if not any(e.kind == "hang" for e in new):
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
        self.events.extend(new)
        return new

    @property
    def should_checkpoint(self) -> bool:
        return any(e.kind == "checkpoint_requested" for e in self.events)


class Heartbeat:
    """Deadline-based liveness marker for the orchestrator (file mtime —
    the single-host analogue of the coordination-service heartbeat)."""

    def __init__(self, path: str, interval_s: float = 30.0):
        self.path = path
        self.interval_s = interval_s
        self._last = 0.0

    def beat(self):
        now = time.time()
        if now - self._last >= self.interval_s:
            with open(self.path, "w") as f:
                f.write(str(now))
            self._last = now

    @staticmethod
    def is_alive(path: str, deadline_s: float) -> bool:
        import os

        try:
            return (time.time() - os.path.getmtime(path)) < deadline_s
        except OSError:
            return False
