"""Gradient compression for the slow links: int8 + error feedback.

Inter-pod (DCN-class) links are ~10× slower than in-pod ICI, and the DP
gradient all-reduce is the only cross-pod traffic in the dp posture — so
it is the one transfer worth compressing.  Scheme:

  1. add the carried error-feedback residual to the local gradient
  2. symmetric per-tensor int8 quantization (scale = amax/127)
  3. all-reduce the int8 payload (4× fewer wire bytes than fp32;
     modeled here as a pmean of the dequantized values)
  4. keep the NEW quantization error as the next step's residual

Error feedback (Seide et al. 1-bit SGD; Karimireddy et al. EF-SGD) makes
the compression unbiased over time: the residual re-enters the next
step's gradient, so the series of applied updates telescopes to the true
gradient sum and convergence matches uncompressed SGD/Adam to first
order.  ``train/step.py::_make_dp_train_step(compress_pod_grads=True)``
threads the residual through the step as explicit (n_pod,)-leading state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(g):
    """Symmetric per-tensor int8 quantization.

    Returns ``(q, scale, err)`` with ``q*scale + err == g`` (fp32 exact up
    to one rounding): q int8 in [-127, 127], scale fp32 scalar, err the
    quantization residual in g's shape — the error-feedback carry.
    """
    gf = g.astype(jnp.float32)
    amax = jnp.max(jnp.abs(gf))
    scale = jnp.where(amax > 0, amax / 127.0, jnp.float32(1.0))
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    err = gf - q.astype(jnp.float32) * scale
    return q, scale, err


def dequantize_int8(q, scale, shape):
    """Inverse of ``quantize_int8`` (up to the quantization error)."""
    return (q.astype(jnp.float32) * scale).reshape(shape)


def ef_allreduce_mean(g, err, axis_name: str):
    """Error-feedback int8 all-reduce-mean over ``axis_name``.

    Call under ``shard_map``/``pmap`` with per-device gradient ``g`` and
    carried residual ``err`` (same shape).  Returns ``(grad_mean,
    new_err)``: the cross-device mean of the int8-compressed compensated
    gradients, and the residual to carry into the next step.  Wire bytes:
    1 per element + one fp32 scale, vs 4 per element exact.
    """
    comp = g.astype(jnp.float32) + err.astype(jnp.float32)
    q, scale, new_err = quantize_int8(comp)
    deq = dequantize_int8(q, scale, comp.shape)
    return lax.pmean(deq, axis_name), new_err


def wire_bytes(n_elements: int, *, compressed: bool) -> int:
    """Per-hop payload bytes for one gradient tensor (benchmark model)."""
    if compressed:
        return n_elements + 4          # int8 payload + fp32 scale
    return 4 * n_elements
