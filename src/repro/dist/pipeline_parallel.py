"""GPipe pipeline parallelism over a ``pod`` mesh axis.

The layer stack is cut into ``n_stage`` contiguous stages, one per pod;
the batch is cut into microbatches that relay through the stages
bucket-brigade style (``ppermute`` neighbor exchange — the paper's
ghost-zone pattern applied to the LAYER axis instead of the grid).  With
M microbatches and S stages the schedule runs M+S-1 ticks; every stage is
busy except the S-1-tick fill/drain bubble, and only (mb, seq, d_model)
activations ever cross a pod boundary.

The relay is numerically exact: each microbatch visits the same layers in
the same order as the sequential reference, so outputs agree to fp
rounding (tested at 2e-4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig


def stage_params(tree, mesh, axis: str = "pod"):
    """PartitionSpecs slicing the leading (layer-stacked) axis of every
    leaf over the pipeline ``axis`` — stage s holds layers
    [s*L/S, (s+1)*L/S)."""
    n = mesh.shape[axis]

    def spec(leaf):
        shape = tuple(leaf.shape)
        assert shape and shape[0] % n == 0, (
            f"layer dim {shape} must divide over {n} pipeline stages")
        return P(axis, *([None] * (len(shape) - 1)))

    return jax.tree.map(spec, tree)


def gpipe_forward(cfg: ModelConfig, mesh, apply_layer, ws, x,
                  n_microbatch: int = 4, axis: str = "pod"):
    """Microbatched pipeline forward matching the sequential stack.

    ``apply_layer(w_i, h) -> h`` is one layer; ``ws`` is a pytree of
    layer-stacked params (leading axis ``cfg.num_layers``); ``x`` is the
    global (B, ...) activation.  Stage s applies its contiguous layer
    slice; microbatch m leaves the last stage at tick m + n_stage - 1.
    """
    n_stage = mesh.shape[axis]
    n_layers = jax.tree.leaves(ws)[0].shape[0]
    assert n_layers == cfg.num_layers, (n_layers, cfg.num_layers)
    assert n_layers % n_stage == 0, (n_layers, n_stage)
    b = x.shape[0]
    assert b % n_microbatch == 0, (b, n_microbatch)
    mb = b // n_microbatch
    fwd = [(i, (i + 1) % n_stage) for i in range(n_stage)]

    def local(ws_l, x_full):
        stage = lax.axis_index(axis)
        xm = x_full.reshape(n_microbatch, mb, *x_full.shape[1:])

        def apply_stage(h):
            return lax.scan(lambda h, w: (apply_layer(w, h), None),
                            h, ws_l)[0]

        act = jnp.zeros_like(xm[0])
        out = jnp.zeros_like(xm)
        for t in range(n_microbatch + n_stage - 1):
            # stage 0 injects microbatch t; everyone else takes the
            # neighbor's tick-(t-1) output (the wrap-around to stage 0 is
            # discarded by the select)
            recv = lax.ppermute(act, axis, fwd)
            inject = xm[min(t, n_microbatch - 1)]
            act = apply_stage(jnp.where(stage == 0, inject, recv))
            m = t - (n_stage - 1)          # microbatch leaving the last stage
            if 0 <= m < n_microbatch:
                out = out.at[m].set(
                    jnp.where(stage == n_stage - 1, act, out[m]))
        # only the last stage holds real outputs; sum-broadcast them
        out = lax.psum(
            out * (stage == n_stage - 1).astype(out.dtype), axis)
        return out.reshape(x_full.shape)

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(stage_params(ws, mesh, axis), P()),
        out_specs=P(), check_vma=False)
    return fn(ws, x)
