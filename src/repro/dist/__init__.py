"""repro.dist — the distribution substrate.

The paper's framework distributes structured-grid computation across a
hybrid machine by pushing ALL placement decisions (domain decomposition,
ghost-zone exchange, device mapping) into a substrate layer so application
code stays serial-looking.  This package is that layer for the jax world:

  sharding           — declarative PartitionSpec rules (FSDP×TP layouts,
                       divisibility guards, batch/cache specs, mesh modes)
  compression        — int8 error-feedback gradient allreduce for the
                       slow (cross-pod / DCN-class) links
  pipeline_parallel  — GPipe microbatch relay over a ``pod`` axis

Model/optimizer code never names a device: it receives a ``ShardCfg`` and
spec trees built here, and the same numerics run single-device (mesh=None)
or across a pod slice unchanged.
"""
from repro.dist import compression, pipeline_parallel, sharding  # noqa: F401
