"""Sharding rules: param/cache/batch PartitionSpec builders + mesh modes.

The substrate owns every placement decision; the numerics never see an
axis name.  Three ideas:

* ``make_shard_cfg`` — turn (mesh, model config, batch) into a ``ShardCfg``
  posture.  ``mode="fsdp_tp"`` is the production 2-D layout (params FSDP-
  sharded over the data axes, tensor-parallel over ``model``);
  ``mode="dp"`` is the pure data-parallel posture (params replicated, one
  gradient all-reduce per step — the shape the compressed cross-pod
  all-reduce plugs into).

* spec builders walk the actual param/cache pytree (arrays or
  ``ShapeDtypeStruct``s from ``jax.eval_shape``) and emit a mirrored tree
  of ``PartitionSpec``s from per-leaf rules keyed on the tree path.  Every
  rule is divisibility-guarded: a dim that does not divide by its mesh
  axis stays replicated rather than erroring (kv_heads=8 shards over
  model=4 but is replicated over model=16).

* ``named`` lifts a spec tree to ``NamedSharding``s for device_put /
  jit in_shardings.

``_path_str`` is the canonical "a/b/c" rendering of a tree path; the
optimizer's weight-decay filter keys on it, so spec rules and decay masks
agree on what a leaf is called.
"""
from __future__ import annotations

import math
from typing import Any

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ModelConfig, ShardCfg


# ---------------------------------------------------------------------------
# paths
# ---------------------------------------------------------------------------
def _path_str(path) -> str:
    """Render a jax tree path as "a/b/0/c" (DictKey/SequenceKey/attr)."""
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "name"):
            parts.append(str(k.name))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# ---------------------------------------------------------------------------
# mesh posture
# ---------------------------------------------------------------------------
def _axes_prod(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return math.prod(mesh.shape[a] for a in axes)


def make_shard_cfg(mesh, cfg: ModelConfig, global_batch: int, *,
                   mode: str = "fsdp_tp", moe_mode: str | None = None,
                   ssm_sp: bool = False) -> ShardCfg:
    """Distribution posture for ``cfg`` on ``mesh``.

    mode:
      fsdp_tp (default) — batch/FSDP over the ("pod", "data") axes, tensor
                          parallelism over "model" (the 2-D production
                          layout; "auto" is an alias)
      dp                — pure data parallelism over EVERY mesh axis:
                          params replicated, batch sharded over all axes,
                          one gradient all-reduce per step
                          (train/step.py::_make_dp_train_step; with a
                          "pod" axis the cross-pod hop can run int8-EF
                          compressed — dist.compression)
    """
    names = tuple(mesh.axis_names)
    if mode in ("fsdp_tp", "auto"):
        dp_axes = tuple(a for a in ("pod", "data") if a in names)
        dp: Any = dp_axes[0] if len(dp_axes) == 1 else dp_axes
        tp = "model" if "model" in names else None
        replicate = False
    elif mode == "dp":
        dp = names if len(names) > 1 else names[0]
        tp = None
        replicate = True
    else:
        raise ValueError(f"unknown shard mode {mode!r}")

    if moe_mode is None:
        moe_mode = "tp" if (cfg.num_experts and tp is not None) else "local"
    batch_sharded = global_batch % _axes_prod(mesh, dp) == 0
    return ShardCfg(mesh=mesh, dp=dp, tp=tp, moe_mode=moe_mode,
                    ssm_sp=ssm_sp, batch_sharded=batch_sharded,
                    replicate_params=replicate)


# ---------------------------------------------------------------------------
# param specs
# ---------------------------------------------------------------------------
def _guard(mesh, axis, dim: int):
    """axis iff ``dim`` divides evenly over it (else replicated)."""
    if axis is None:
        return None
    if dim % _axes_prod(mesh, axis) != 0:
        return None
    return axis


def param_spec_tree(params, cfg: ModelConfig, mesh, shard: ShardCfg):
    """PartitionSpec tree mirroring ``params`` (arrays or eval_shape
    structs).

    Layout rules (fsdp_tp): attention heads and ffn hidden dims are
    tensor-parallel over ``tp``; the embedding is vocab-parallel; the
    model dim is FSDP-sharded over the data axes.  Rules match on the
    leaf's path, are right-aligned against its trailing dims, and pad
    leading (layer-stack) axes with None — the same rule covers a layer
    leaf and its ``lax.scan``-stacked form.
    """
    fsdp = None if shard.replicate_params else shard.dp
    tp = None if shard.replicate_params else shard.tp
    F = lambda d: _guard(mesh, fsdp, d)
    T = lambda d: _guard(mesh, tp, d)

    def rule(parts: tuple, shape: tuple):
        """Returns right-aligned entries for the trailing dims, or None
        for 'no rule' (fallback)."""
        name = parts[-1]
        parent = parts[-2] if len(parts) >= 2 else ""
        if len(shape) <= 1:
            return tuple(None for _ in shape)   # scalars / norm scales /
            # biases: tiny — replicate rather than ZeRO-shard
        if parent == "attn" and name in ("wq", "wk", "wv") and len(shape) >= 3:
            d, h, hd = shape[-3:]
            return (F(d), T(h), None)
        if parent == "attn" and name == "wo" and len(shape) >= 3:
            h, hd, d = shape[-3:]
            return (T(h), None, F(d))
        if parent == "attn" and name in ("bq", "bk", "bv") and len(shape) >= 2:
            h, hd = shape[-2:]
            return (T(h), None)
        if parent == "embed" and name == "table":
            v, d = shape[-2:]
            return (T(v), F(d))
        if parent == "unembed" and name == "w":
            d, v = shape[-2:]
            return (F(d), T(v))
        if parent == "experts" and len(shape) >= 3:
            e = shape[-3]
            if name == "down":                      # (E, f, d)
                return (T(e), None, F(shape[-1]))
            return (T(e), F(shape[-2]), None)       # gate/up (E, d, f)
        if name == "router":
            return tuple(None for _ in shape[-2:])
        if name == "w" and len(shape) >= 2:
            d_in, d_out = shape[-2:]
            if parent in ("down", "mlp_down", "out_proj"):
                return (T(d_in), F(d_out))          # contraction dim is TP
            return (F(d_in), T(d_out))              # gate/up/in_proj/...
        return None

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        parts = tuple(_path_str((k,)) for k in path)
        shape = tuple(leaf.shape)
        nd = len(shape)
        entries = rule(parts, shape)
        if entries is None:
            # fallback: FSDP the largest divisible dim, else replicate
            entries = [None] * nd
            if nd and fsdp is not None:
                order = sorted(range(nd), key=lambda i: -shape[i])
                for i in order:
                    if shape[i] and _guard(mesh, fsdp, shape[i]) is not None \
                            and shape[i] >= _axes_prod(mesh, fsdp):
                        entries[i] = fsdp
                        break
            entries = tuple(entries)
        else:
            entries = (None,) * (nd - len(entries)) + tuple(entries)
        if all(e is None for e in entries):
            entries = ()                        # canonical replicated spec
        specs.append(P(*entries))
    return jax.tree_util.tree_unflatten(treedef, specs)


# ---------------------------------------------------------------------------
# cache specs
# ---------------------------------------------------------------------------
def cache_spec_tree(caches, cfg: ModelConfig, mesh, shard: ShardCfg):
    """Decode-cache PartitionSpecs.

    Batch shards over the data axes; attention KV caches additionally
    shard the SEQUENCE dim over ``tp`` (flash-decode: each TP rank scans
    its slice of the context, combining partial softmax online), guarded
    on divisibility like everything else.  SSM/conv recurrent states are
    batch-sharded only — they are O(1) in sequence.
    """
    dp = shard.dp if shard.batch_sharded else None
    tp = shard.tp
    batch_axis = 0 if cfg.family == "ssm" else 1   # ssm caches lack the
    # leading stacked-layer axis (tuple-of-states)

    def spec(leaf):
        shape = tuple(leaf.shape)
        nd = len(shape)
        entries = [None] * nd
        if nd > batch_axis:
            entries[batch_axis] = _guard(mesh, dp, shape[batch_axis])
        is_kv = (nd == 5 and shape[3] == cfg.num_kv_heads
                 and shape[4] == cfg.head_dim)
        if is_kv and tp is not None:
            entries[2] = _guard(mesh, tp, shape[2])
        return P(*entries)

    return jax.tree.map(spec, caches)


# ---------------------------------------------------------------------------
# batch specs
# ---------------------------------------------------------------------------
def batch_spec_tree(batch, mesh, shard: ShardCfg):
    """Input-batch specs: leading (batch) dim over the data axes."""
    dp = shard.dp if shard.batch_sharded else None

    def spec(leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return P()
        return P(_guard(mesh, dp, leaf.shape[0]), *([None] * (nd - 1)))

    return jax.tree.map(spec, batch)


# ---------------------------------------------------------------------------
# ensemble / slot specs
# ---------------------------------------------------------------------------
def slot_spec(mesh, n_slots: int, axis: str = "data"):
    """Spec placing a leading ensemble *slot* axis over a data-parallel
    mesh axis (multi-device simulation farms: each device advances
    ``n_slots / |axis|`` resident simulations).  Guarded like every other
    rule: a non-divisible slot count stays replicated."""
    if axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no axis {axis!r}")
    return P(_guard(mesh, axis, n_slots))


def validate_decomposition(decomposition, n_axes: int, mesh_axis_names,
                           slot_axis: str | None = None) -> tuple:
    """Normalize + validate a grid decomposition: returns the
    ``((array_axis, mesh_axis), ...)`` pairs, raising on a duplicate
    array axis, an out-of-range array axis, an unknown mesh axis, or a
    grid axis decomposing over the slot axis.  Shared by the spec rule
    below and the farm's ``plan_decomposition`` so both layers enforce —
    and word — the contract identically."""
    pairs = tuple(decomposition.items() if isinstance(decomposition, dict)
                  else decomposition)
    if len({a for a, _ in pairs}) != len(pairs):
        raise ValueError(
            f"decomposition {pairs!r} maps some array axis more than "
            "once; each grid axis decomposes over at most one mesh axis")
    for a, name in pairs:
        if not 0 <= int(a) < n_axes:
            raise ValueError(
                f"decomposition names array axis {a}, but fields have "
                f"only {n_axes} grid axes")
        if name not in mesh_axis_names:
            raise ValueError(
                f"mesh {tuple(mesh_axis_names)} has no axis {name!r} "
                f"(decomposition of array axis {a})")
        if slot_axis is not None and name == slot_axis:
            raise ValueError(
                f"axis {name!r} is the slot axis; a grid axis cannot "
                "decompose over it")
    return pairs


def slot_field_spec(mesh, n_slots: int, shape: tuple, decomposition=(),
                    slot_axis: str = "slot"):
    """Spec for a slot-stacked grid field ``(n_slots, *shape)`` on a
    slots × shards farm mesh: ``P(slot_axis, <grid axes>)``.

    The two axes get different failure postures, deliberately:

    * the slot axis is *guarded* — slots never interact, so a slot count
      that does not divide over ``slot_axis`` runs replicated (correct,
      just not parallel), same as :func:`slot_spec`;
    * the grid axes *raise* — halo-exchange code inside the step ppermutes
      over the decomposition's mesh axes assuming true shards, so quietly
      replicating an indivisible grid axis would hand every device the
      full extent while the exchange still shifts it: mis-sharding, not a
      layout choice.
    """
    if slot_axis not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no slot axis "
                         f"{slot_axis!r}")
    pairs = validate_decomposition(decomposition, len(shape),
                                   mesh.axis_names, slot_axis=slot_axis)
    grid: list = [None] * len(shape)
    for a, name in pairs:
        a = int(a)
        if shape[a] % mesh.shape[name]:
            raise ValueError(
                f"grid extent {shape[a]} on array axis {a} is not "
                f"divisible by mesh axis {name!r} (size "
                f"{mesh.shape[name]}) — refusing to mis-shard")
        grid[a] = name
    return P(_guard(mesh, slot_axis, n_slots), *grid)


# ---------------------------------------------------------------------------
# NamedSharding lift
# ---------------------------------------------------------------------------
def named(specs, mesh):
    """Spec tree -> NamedSharding tree (device_put / jit shardings)."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
