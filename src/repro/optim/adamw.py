"""AdamW with dtype-configurable state — pure JAX (no optax dependency).

State dtypes matter at the kimi-k2 scale: 1T params × (4+4+4)B of fp32
master+m+v = 12 TB > 512 chips × 16 GB.  ``m_dtype/v_dtype=bf16`` and
bf16 params bring the optimizer residency to 1T × (2+2+2) = 6 TB, which
fits (see EXPERIMENTS.md §Dry-run).  Update math always runs in fp32;
states are cast on read/write (stochastic-rounding-free bf16 moments are
the standard large-scale compromise, cf. PaLM/LLaMA recipes).

Optimizer state inherits the param PartitionSpecs (ZeRO: each FSDP shard
updates only its slice — SPMD derives this from the shardings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    m_dtype: Any = jnp.float32
    v_dtype: Any = jnp.float32
    clip_norm: float | None = 1.0
    # decay mask: paths whose params skip weight decay (norms, biases)
    decay_filter: Callable[[str], bool] = staticmethod(
        lambda path: not any(s in path for s in ("norm", "scale", "bias",
                                                 "/b", "A_log", "dt_bias")))

    def init(self, params) -> AdamWState:
        return AdamWState(
            step=jnp.zeros((), jnp.int32),
            m=jax.tree.map(lambda p: jnp.zeros(p.shape, self.m_dtype), params),
            v=jax.tree.map(lambda p: jnp.zeros(p.shape, self.v_dtype), params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        """Returns (new_params, new_state, stats)."""
        step = state.step + 1
        gnorm = global_norm(grads)
        scale = jnp.ones((), jnp.float32)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12))

        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        g_flat = jax.tree.leaves(grads)
        m_flat = jax.tree.leaves(state.m)
        v_flat = jax.tree.leaves(state.v)
        new_p, new_m, new_v = [], [], []
        for (path, p), g, m, v in zip(flat, g_flat, m_flat, v_flat):
            gf = g.astype(jnp.float32) * scale
            mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
            upd = (mf / bc1) / (jnp.sqrt(vf / bc2) + self.eps)
            from repro.dist.sharding import _path_str
            if self.weight_decay and self.decay_filter(_path_str(path)):
                upd = upd + self.weight_decay * p.astype(jnp.float32)
            new_p.append((p.astype(jnp.float32) - lr * upd).astype(p.dtype))
            new_m.append(mf.astype(self.m_dtype))
            new_v.append(vf.astype(self.v_dtype))

        unflat = lambda leaves: jax.tree_util.tree_unflatten(treedef, leaves)
        return (unflat(new_p),
                AdamWState(step=step, m=unflat(new_m), v=unflat(new_v)),
                {"grad_norm": gnorm, "lr": lr,
                 "clip_scale": scale})

    def state_spec_tree(self, param_specs):
        """Optimizer-state PartitionSpecs mirror the param specs."""
        from jax.sharding import PartitionSpec as P

        return AdamWState(step=P(), m=param_specs, v=param_specs)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))
