"""Massively data-parallel stencil framework (CaCUDA on TPU) + LM stack."""
from repro import compat as _compat  # installs jax version shims on import

_compat.install()
