"""Massively data-parallel stencil framework (CaCUDA on TPU) + LM stack."""
from repro import compat as _compat  # installs jax version shims on import

_compat.install()


def __getattr__(name):
    # `from repro import api` without importing jax-heavy modules at
    # package import (repro.api pulls in the sim/cfd stacks)
    if name == "api":
        import importlib

        return importlib.import_module("repro.api")
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
