"""repro.jobs — durable job engine: checkpoint state *is* the job state.

`JobStore` persists simulation requests, status transitions, latest-
snapshot pointers, and process leases in one SQLite file (WAL,
``BEGIN IMMEDIATE``) beside atomic-rename checkpoint directories.  Wire
it in with ``RuntimeConfig(store=...)`` / ``runtime(..., store=...)``:
submits become durable before admission, every evict/harvest/terminal
transition lands in the store next to the snapshot write, a restarted
Runtime resumes incomplete simulations first, and two farm processes can
drain one queue via lease takeover.  With no store configured the farm
path is bitwise-identical to before (pinned by test).
"""
from __future__ import annotations

import os

from repro.jobs.codec import (PAYLOAD_VERSION, config_from_dict,
                              config_to_dict, decode_request, encode_request)
from repro.jobs.store import (DIVERGED, DONE, EVICTED, FAILED, INCOMPLETE,
                              QUEUED, RUNNING, SNAPSHOT_KINDS, STATUSES,
                              TERMINAL, Job, JobStore, default_owner)

__all__ = [
    "PAYLOAD_VERSION", "config_from_dict", "config_to_dict",
    "decode_request", "encode_request",
    "QUEUED", "RUNNING", "EVICTED", "DONE", "FAILED", "DIVERGED",
    "TERMINAL", "INCOMPLETE", "STATUSES", "SNAPSHOT_KINDS",
    "Job", "JobStore", "default_owner", "resolve_store",
]


def resolve_store(spec, ckpt_dir: str | None = None) -> JobStore | None:
    """Normalize a ``RuntimeConfig.store`` spec to a JobStore (or None).

    ``None``/``False`` → no store (the bitwise-identical in-memory path);
    a ``JobStore`` passes through; ``True`` → ``<ckpt_dir>/jobs.sqlite``
    (requires ``ckpt_dir``); a path string → a store at that file; a dict
    → ``JobStore(**spec)`` for tuned ttl/prune knobs.
    """
    if spec is None or spec is False:
        return None
    if isinstance(spec, JobStore):
        return spec
    if spec is True:
        if not ckpt_dir:
            raise ValueError(
                "store=True needs ckpt_dir to place jobs.sqlite; "
                "pass store='/path/to/jobs.sqlite' or set ckpt_dir")
        return JobStore(os.path.join(ckpt_dir, "jobs.sqlite"))
    if isinstance(spec, str):
        return JobStore(spec)
    if isinstance(spec, dict):
        return JobStore(**spec)
    raise TypeError(f"cannot resolve a job store from {spec!r}")
