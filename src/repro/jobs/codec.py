"""Durable wire format for simulation requests.

A :class:`~repro.sim.farm.SimRequest` splits into two halves with very
different shapes: the *description* (the :class:`~repro.cfd.ns3d.CFDConfig`
plus run knobs — small, structured, human-inspectable) and the optional
*initial fields* (numpy arrays, potentially megabytes).  The store keeps
the description as a JSON text column — queryable during incidents, exact
float round-trip via ``repr``-based JSON numbers — and the fields as one
npz blob, so a queued job survives a process crash byte-for-byte:
``decode_request(*encode_request(req))`` rebuilds a request whose config
compares equal and whose initial fields are bitwise the originals.

``sid`` is deliberately NOT part of the payload: it is per-process farm
bookkeeping, reassigned on every (re)admission, while the durable identity
is the store's ``job_id``.
"""
from __future__ import annotations

import dataclasses
import io
import json

import numpy as np

from repro.cfd.ns3d import CFDConfig

PAYLOAD_VERSION = 1


def config_to_dict(cfg: CFDConfig) -> dict:
    """JSON-ready dict of a CFDConfig (tuples become lists)."""
    return dataclasses.asdict(cfg)


def config_from_dict(d: dict) -> CFDConfig:
    """Rebuild a CFDConfig from its JSON form, restoring the tuple-typed
    fields (``shape``/``forcing``/``decomposition``) that JSON flattened
    to lists — a round-tripped config must compare ``==`` to the
    original, and hashable tuples are part of the static signature."""
    d = dict(d)
    d["shape"] = tuple(int(x) for x in d["shape"])
    d["forcing"] = tuple(float(x) for x in d["forcing"])
    d["decomposition"] = tuple(
        (int(axis), str(name)) for axis, name in d["decomposition"])
    return CFDConfig(**d)


def encode_request(req) -> tuple[str, bytes | None]:
    """``(payload_json, init_npz)`` of a SimRequest.

    ``init_npz`` is None when the request carries no initial fields (the
    scenario ICs them in-solver); otherwise a compressed npz archive with
    one entry per field.
    """
    payload = json.dumps({
        "version": PAYLOAD_VERSION,
        "config": config_to_dict(req.config),
        "steps": req.steps,
        "tag": req.tag,
        "steady_tol": req.steady_tol,
        "residual_tol": req.residual_tol,
        "priority": req.priority,
        "step0": req.step0,
    }, sort_keys=True)
    blob = None
    if req.init_state is not None:
        buf = io.BytesIO()
        np.savez_compressed(
            buf, **{k: np.asarray(v) for k, v in req.init_state.items()})
        blob = buf.getvalue()
    return payload, blob


def decode_request(payload: str, init_npz: bytes | None = None):
    """Rebuild the SimRequest a payload row describes (``sid=None`` —
    the farm assigns a fresh one at submission)."""
    from repro.sim.farm import SimRequest   # lazy: avoid import cycle

    doc = json.loads(payload)
    if doc.get("version") != PAYLOAD_VERSION:
        raise ValueError(
            f"unsupported job payload version {doc.get('version')!r} "
            f"(this build reads {PAYLOAD_VERSION})")
    init_state = None
    if init_npz is not None:
        with np.load(io.BytesIO(init_npz), allow_pickle=False) as data:
            init_state = {k: np.asarray(data[k]) for k in data.files}
    return SimRequest(
        config=config_from_dict(doc["config"]),
        steps=int(doc["steps"]),
        tag=str(doc.get("tag", "")),
        steady_tol=doc.get("steady_tol"),
        residual_tol=doc.get("residual_tol"),
        priority=int(doc.get("priority", 0)),
        init_state=init_state,
        step0=int(doc.get("step0", 0)),
    )
