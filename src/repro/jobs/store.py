"""repro.jobs.store — the durable job engine: checkpoint state IS the job state.

The farm's queue, slot table, and evict/readmit bookkeeping live in process
memory; one crash loses every queued and running request.  Cactus-lineage
frameworks treat checkpoint/recovery as a first-class service so petascale
runs survive node loss — this module is that service for the simulation
farm, following the conduit-core / flatagents pattern: **one SQLite file is
the single source of truth** for job rows, latest-snapshot pointers, and
lease locks, next to an atomic-rename :class:`~repro.ckpt.checkpointer.
Checkpointer` directory holding the field snapshots themselves.

Design points:

* **WAL + ``BEGIN IMMEDIATE``** — every mutation is one immediate
  transaction, so two farm processes sharing the file serialize on claims
  and can never double-claim a job; readers never block the writer.
* **Leases in the DB, not file locks** — each lease carries an owner
  identity (``host:pid:token``), an explicit TTL, and renew/release verbs;
  a crashed owner's lease simply expires and the next claimer *takes it
  over* (counted, audited in ``job_events``).
* **Snapshot pointers, not snapshot blobs** — field state stays in the
  checkpointer's npz-per-step layout (atomic directory rename); the store
  records ``(kind, dir, step_key, steps_done)`` per job so a restarted
  process resumes from the latest snapshot and pruning never orphans a
  directory (flight records included).
* **Terminal pruning on a schedule** — ``prune_terminal`` drops rows AND
  snapshot/flight directories for ``done/failed/diverged`` jobs older than
  a threshold (opportunistically after terminal transitions when
  ``prune_after_s`` is set), so durable farms don't leak disk.

The store is pure host-side bookkeeping: with no store configured the farm
path compiles and runs byte-for-byte unchanged (pinned by test, like
telemetry-off).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
import secrets
import socket
import sqlite3
import time

import numpy as np

from repro.jobs.codec import decode_request, encode_request

# job status vocabulary — matches the service's poll() statuses
QUEUED = "queued"
RUNNING = "running"
EVICTED = "evicted"
DONE = "done"
FAILED = "failed"
DIVERGED = "diverged"
TERMINAL = (DONE, FAILED, DIVERGED)
INCOMPLETE = (RUNNING, EVICTED)
STATUSES = (QUEUED,) + INCOMPLETE + TERMINAL

# snapshot kinds: "evict" is the resume pointer (latest mid-flight field
# state), "result" the terminal field state of a done job, "flight" a
# PR 9 flight record (frames + poisoned state) registered so restarts and
# pruning both resolve it
SNAPSHOT_KINDS = ("evict", "result", "flight")

_SCHEMA = """
CREATE TABLE IF NOT EXISTS jobs (
  job_id       INTEGER PRIMARY KEY AUTOINCREMENT,
  status       TEXT NOT NULL,
  signature    TEXT NOT NULL DEFAULT '',
  tag          TEXT NOT NULL DEFAULT '',
  priority     INTEGER NOT NULL DEFAULT 0,
  payload      TEXT NOT NULL,
  init_npz     BLOB,
  steps_done   INTEGER NOT NULL DEFAULT 0,
  terminated   TEXT,
  error        TEXT,
  submitted_at REAL NOT NULL,
  updated_at   REAL NOT NULL
);
CREATE INDEX IF NOT EXISTS jobs_by_status
  ON jobs (status, priority DESC, job_id);
CREATE TABLE IF NOT EXISTS snapshots (
  job_id     INTEGER NOT NULL,
  kind       TEXT NOT NULL,
  dir        TEXT NOT NULL,
  step_key   INTEGER NOT NULL,
  steps_done INTEGER NOT NULL DEFAULT 0,
  fields     TEXT,
  updated_at REAL NOT NULL,
  PRIMARY KEY (job_id, kind)
);
CREATE TABLE IF NOT EXISTS leases (
  job_id      INTEGER PRIMARY KEY,
  owner       TEXT NOT NULL,
  acquired_at REAL NOT NULL,
  expires_at  REAL NOT NULL
);
CREATE TABLE IF NOT EXISTS job_events (
  seq    INTEGER PRIMARY KEY AUTOINCREMENT,
  job_id INTEGER NOT NULL,
  event  TEXT NOT NULL,
  owner  TEXT NOT NULL,
  at     REAL NOT NULL,
  detail TEXT
);
"""


def default_owner() -> str:
    """``host:pid:token`` — the lease owner identity.  The random token
    distinguishes two stores (or two runtimes) inside one process and a
    recycled pid on one host."""
    return f"{socket.gethostname()}:{os.getpid()}:{secrets.token_hex(3)}"


@dataclasses.dataclass
class Job:
    """One durable job row (host-side view)."""

    job_id: int
    status: str
    signature: str
    tag: str
    priority: int
    payload: str
    init_npz: bytes | None
    steps_done: int
    terminated: str | None
    error: str | None

    def request(self):
        """The SimRequest this row describes (sid unassigned)."""
        return decode_request(self.payload, self.init_npz)


_JOB_COLS = ("job_id", "status", "signature", "tag", "priority", "payload",
             "init_npz", "steps_done", "terminated", "error")
_SELECT_JOB = f"SELECT {', '.join('j.' + c for c in _JOB_COLS)} FROM jobs j"


class JobStore:
    """SQLite-backed durable queue + lease table + snapshot registry.

    One instance per process per store file; safe to share the *file*
    across processes (WAL), not the instance across threads.  ``ttl_s``
    is the lease lifetime — an owner that neither renews nor releases for
    that long is presumed dead and its jobs become claimable.
    ``prune_after_s`` (when set) opportunistically prunes terminal rows
    older than that after each terminal transition.
    """

    def __init__(self, path: str, *, ttl_s: float = 30.0,
                 owner: str | None = None, prune_after_s: float | None = None,
                 keep_results: bool = True):
        self.path = os.path.abspath(path)
        self.dir = os.path.dirname(self.path)
        os.makedirs(self.dir, exist_ok=True)
        self.ttl_s = float(ttl_s)
        self.owner = owner if owner is not None else default_owner()
        self.prune_after_s = prune_after_s
        self.keep_results = keep_results
        self.takeovers = 0        # expired leases this instance took over
        self._ckpts: dict[str, object] = {}
        # autocommit mode: transactions are explicit BEGIN IMMEDIATE, so
        # two processes' claims serialize at BEGIN, not at first write
        self._conn = sqlite3.connect(self.path, timeout=30.0,
                                     isolation_level=None)
        self._conn.execute("PRAGMA journal_mode=WAL")
        self._conn.execute("PRAGMA synchronous=NORMAL")
        self._conn.executescript(_SCHEMA)

    # -- plumbing -------------------------------------------------------------
    @contextlib.contextmanager
    def _tx(self):
        self._conn.execute("BEGIN IMMEDIATE")
        try:
            yield self._conn
        except BaseException:
            self._conn.execute("ROLLBACK")
            raise
        self._conn.execute("COMMIT")

    def _event(self, c, job_id: int, event: str, detail: dict | None = None):
        c.execute(
            "INSERT INTO job_events (job_id, event, owner, at, detail) "
            "VALUES (?, ?, ?, ?, ?)",
            (job_id, event, self.owner, time.time(),
             json.dumps(detail, sort_keys=True) if detail else None))

    def _job(self, row) -> Job:
        return Job(**dict(zip(_JOB_COLS, row)))

    def snapshot_dir(self, kind: str) -> str:
        return os.path.join(self.dir, "snapshots", kind)

    def _ckpt(self, kind: str):
        """The store-owned checkpointer for one snapshot kind (evict /
        result).  Separate directories per kind, step key = job_id —
        globally unique, so two farm processes sharing the store never
        collide on a directory name."""
        if kind not in self._ckpts:
            from repro.ckpt.checkpointer import Checkpointer

            self._ckpts[kind] = Checkpointer(self.snapshot_dir(kind),
                                             keep_last=0)
        return self._ckpts[kind]

    def close(self):
        self._conn.close()

    # -- intake ---------------------------------------------------------------
    def submit(self, req, signature: str = "", *, lease: bool = False) -> int:
        """Persist one request as a ``queued`` row; returns its job_id.

        This is the durability point: the row is committed before the
        farm ever sees the request, so a crash one instruction later
        loses nothing.  ``lease=True`` additionally acquires this owner's
        lease in the same transaction — the submitting process intends to
        run the job itself (the Runtime's ``submit`` path), so a peer
        must not claim it unless this process dies.
        """
        payload, blob = encode_request(req)
        now = time.time()
        with self._tx() as c:
            cur = c.execute(
                "INSERT INTO jobs (status, signature, tag, priority, payload,"
                " init_npz, steps_done, submitted_at, updated_at) "
                "VALUES (?, ?, ?, ?, ?, ?, ?, ?, ?)",
                (QUEUED, signature, req.tag, int(req.priority), payload,
                 sqlite3.Binary(blob) if blob is not None else None,
                 int(req.step0), now, now))
            job_id = int(cur.lastrowid)
            if lease:
                c.execute(
                    "REPLACE INTO leases (job_id, owner, acquired_at,"
                    " expires_at) VALUES (?, ?, ?, ?)",
                    (job_id, self.owner, now, now + self.ttl_s))
            self._event(c, job_id, "submit",
                        {"tag": req.tag, "leased": bool(lease)})
        return job_id

    # -- views ----------------------------------------------------------------
    def get(self, job_id: int) -> Job | None:
        row = self._conn.execute(
            _SELECT_JOB + " WHERE j.job_id = ?", (job_id,)).fetchone()
        return self._job(row) if row is not None else None

    def jobs(self, status: str | tuple | None = None) -> list[Job]:
        q, args = _SELECT_JOB, ()
        if status is not None:
            statuses = (status,) if isinstance(status, str) else tuple(status)
            q += (" WHERE j.status IN ("
                  + ",".join("?" * len(statuses)) + ")")
            args = statuses
        q += " ORDER BY j.job_id"
        return [self._job(r) for r in self._conn.execute(q, args)]

    def counts(self) -> dict[str, int]:
        out = {s: 0 for s in STATUSES}
        for status, n in self._conn.execute(
                "SELECT status, COUNT(*) FROM jobs GROUP BY status"):
            out[status] = n
        return out

    def queue_depth(self) -> int:
        """Rows still waiting in the durable queue (status ``queued``)."""
        (n,) = self._conn.execute(
            "SELECT COUNT(*) FROM jobs WHERE status = ?", (QUEUED,)).fetchone()
        return int(n)

    def events(self, job_id: int | None = None,
               event: str | None = None, after_seq: int = 0) -> list[dict]:
        """The audit log, oldest first — who claimed/admitted/resolved
        what, when (the no-double-execution assertions read this)."""
        q = ("SELECT seq, job_id, event, owner, at, detail FROM job_events "
             "WHERE seq > ?")
        args: list = [after_seq]
        if job_id is not None:
            q += " AND job_id = ?"
            args.append(job_id)
        if event is not None:
            q += " AND event = ?"
            args.append(event)
        q += " ORDER BY seq"
        return [dict(zip(("seq", "job_id", "event", "owner", "at", "detail"),
                         r)) for r in self._conn.execute(q, args)]

    def last_seq(self) -> int:
        (n,) = self._conn.execute(
            "SELECT COALESCE(MAX(seq), 0) FROM job_events").fetchone()
        return int(n)

    # -- leases / claims ------------------------------------------------------
    def claim(self, limit: int = 1,
              statuses: tuple = (QUEUED,)) -> list[Job]:
        """Transactionally lease up to ``limit`` claimable jobs.

        Claimable: status in ``statuses`` AND no lease, an expired lease
        (dead owner -> *takeover*, counted), or this owner's own expired
        lease.  Ordered priority-descending then FIFO by job_id — the
        same admission order the in-memory SlotTable uses.  Two processes
        racing this method serialize on ``BEGIN IMMEDIATE``; a job can
        never be leased twice while a lease is live.
        """
        now = time.time()
        marks = ",".join("?" * len(statuses))
        cols = ", ".join("j." + c for c in _JOB_COLS)
        out: list[Job] = []
        with self._tx() as c:
            rows = c.execute(
                f"SELECT {cols}, l.owner, l.expires_at FROM jobs j"
                " LEFT JOIN leases l ON l.job_id = j.job_id"
                f" WHERE j.status IN ({marks})"
                " AND (l.job_id IS NULL OR l.expires_at <= ?)"
                " ORDER BY j.priority DESC, j.job_id LIMIT ?",
                (*statuses, now, int(limit))).fetchall()
            for row in rows:
                job = self._job(row[:len(_JOB_COLS)])
                prev_owner = row[len(_JOB_COLS)]
                takeover = (prev_owner is not None
                            and prev_owner != self.owner)
                if takeover:
                    self.takeovers += 1
                c.execute(
                    "REPLACE INTO leases (job_id, owner, acquired_at,"
                    " expires_at) VALUES (?, ?, ?, ?)",
                    (job.job_id, self.owner, now, now + self.ttl_s))
                self._event(c, job.job_id,
                            "takeover" if takeover else "claim",
                            {"from": prev_owner} if takeover else None)
                out.append(job)
        return out

    def claim_incomplete(self, limit: int = 64) -> list[Job]:
        """Claim orphaned in-flight work: ``running``/``evicted`` rows
        whose lease expired (their process died).  The restart contract —
        resume these FIRST, then claim queued work."""
        return self.claim(limit=limit, statuses=INCOMPLETE)

    def renew(self) -> int:
        """Extend every lease this owner holds; returns the count.  The
        service calls this from its heartbeat, so liveness is 'the farm
        is stepping', not a dedicated thread."""
        now = time.time()
        with self._tx() as c:
            cur = c.execute(
                "UPDATE leases SET expires_at = ? WHERE owner = ?",
                (now + self.ttl_s, self.owner))
            return cur.rowcount

    def release(self, job_id: int) -> bool:
        with self._tx() as c:
            cur = c.execute(
                "DELETE FROM leases WHERE job_id = ? AND owner = ?",
                (job_id, self.owner))
            return cur.rowcount > 0

    def lease_of(self, job_id: int) -> dict | None:
        row = self._conn.execute(
            "SELECT owner, acquired_at, expires_at FROM leases "
            "WHERE job_id = ?", (job_id,)).fetchone()
        if row is None:
            return None
        return dict(zip(("owner", "acquired_at", "expires_at"), row))

    # -- transitions ----------------------------------------------------------
    def transition(self, job_id: int, status: str, *,
                   steps_done: int | None = None,
                   terminated: str | None = None, error: str | None = None,
                   event: str | None = None):
        """One status transition, transactionally, with its audit event.
        Terminal transitions release the lease in the same transaction
        (the job needs no owner once resolved) and — when
        ``prune_after_s`` is configured — sweep old terminal rows after
        commit."""
        if status not in STATUSES:
            raise ValueError(f"unknown job status {status!r}")
        sets, args = ["status = ?", "updated_at = ?"], [status, time.time()]
        if steps_done is not None:
            sets.append("steps_done = ?")
            args.append(int(steps_done))
        if terminated is not None:
            sets.append("terminated = ?")
            args.append(terminated)
        if error is not None:
            sets.append("error = ?")
            args.append(error)
        with self._tx() as c:
            c.execute(f"UPDATE jobs SET {', '.join(sets)} WHERE job_id = ?",
                      (*args, job_id))
            if status in TERMINAL:
                c.execute("DELETE FROM leases WHERE job_id = ?", (job_id,))
            self._event(c, job_id, event or status,
                        {"status": status, "steps_done": steps_done})
        if status in TERMINAL and self.prune_after_s is not None:
            self.prune_terminal(self.prune_after_s)

    # -- snapshots ------------------------------------------------------------
    def record_snapshot(self, job_id: int, kind: str, directory: str,
                        step_key: int, steps_done: int = 0,
                        fields: list | None = None):
        """Register an externally written snapshot (e.g. a PR 9 flight
        record) so restarts can resolve it and pruning removes it with
        the job — nothing under a registered pointer is ever orphaned."""
        with self._tx() as c:
            c.execute(
                "REPLACE INTO snapshots (job_id, kind, dir, step_key,"
                " steps_done, fields, updated_at) VALUES (?, ?, ?, ?, ?, ?, ?)",
                (job_id, kind, os.path.abspath(directory), int(step_key),
                 int(steps_done),
                 json.dumps(fields) if fields is not None else None,
                 time.time()))
            self._event(c, job_id, "snapshot",
                        {"kind": kind, "steps_done": steps_done})

    def save_snapshot(self, job_id: int, state: dict, steps_done: int,
                      kind: str = "evict", status: str | None = None):
        """Write a field snapshot through the store's checkpointer
        (atomic rename, step key = job_id), then register the pointer —
        and optionally the status transition — in ONE transaction, so the
        job row and its resume pointer can never disagree.  A crash
        between the file write and the commit leaves only an unregistered
        directory, overwritten by the next save and swept by pruning."""
        host = {k: np.asarray(v) for k, v in state.items()}
        self._ckpt(kind).save(job_id, host, blocking=True)
        now = time.time()
        with self._tx() as c:
            c.execute(
                "REPLACE INTO snapshots (job_id, kind, dir, step_key,"
                " steps_done, fields, updated_at) VALUES (?, ?, ?, ?, ?, ?, ?)",
                (job_id, kind, self.snapshot_dir(kind), job_id,
                 int(steps_done), json.dumps(sorted(host)), now))
            if status is not None:
                c.execute(
                    "UPDATE jobs SET status = ?, steps_done = ?,"
                    " updated_at = ? WHERE job_id = ?",
                    (status, int(steps_done), now, job_id))
            self._event(c, job_id, "snapshot",
                        {"kind": kind, "steps_done": steps_done,
                         "status": status})

    def latest_snapshot(self, job_id: int, kind: str = "evict") -> dict | None:
        row = self._conn.execute(
            "SELECT dir, step_key, steps_done, fields, updated_at "
            "FROM snapshots WHERE job_id = ? AND kind = ?",
            (job_id, kind)).fetchone()
        if row is None:
            return None
        out = dict(zip(("dir", "step_key", "steps_done", "fields",
                        "updated_at"), row))
        if out["fields"] is not None:
            out["fields"] = json.loads(out["fields"])
        return out

    def load_snapshot(self, job_id: int,
                      kind: str = "evict") -> tuple[int, dict]:
        """``(steps_done, {field: np.ndarray})`` of a job's registered
        snapshot — template-free: the field names ride in the snapshot
        row, and dict trees flatten with keys sorted, so the npz leaves
        zip back against the sorted field list."""
        from repro.ckpt.checkpointer import Checkpointer

        snap = self.latest_snapshot(job_id, kind)
        if snap is None:
            raise KeyError(f"job {job_id} has no {kind!r} snapshot")
        fields = snap["fields"]
        if not fields:
            raise ValueError(f"job {job_id} {kind!r} snapshot registered "
                             "without a field list — cannot rebuild")
        _, leaves = Checkpointer(snap["dir"]).read_arrays(snap["step_key"])
        if len(leaves) != len(fields):
            raise ValueError(
                f"job {job_id} {kind!r} snapshot has {len(leaves)} leaves, "
                f"expected {len(fields)}")
        return int(snap["steps_done"]), dict(zip(sorted(fields), leaves))

    def load_result(self, job_id: int) -> dict:
        """The persisted final field state of a ``done`` job — readable
        from any process, long after the one that ran it exited."""
        return self.load_snapshot(job_id, kind="result")[1]

    # -- pruning --------------------------------------------------------------
    def prune_terminal(self, max_age_s: float = 0.0) -> int:
        """Drop terminal jobs (``done/failed/diverged``) untouched for
        ``max_age_s``: their snapshot/flight directories first (via
        ``Checkpointer.remove`` — self-healing order: a crash mid-prune
        leaves rows pointing at removed dirs, swept on the next pass),
        then their rows, leases, and events.  Returns the number of jobs
        pruned."""
        from repro.ckpt.checkpointer import Checkpointer

        cutoff = time.time() - max(max_age_s, 0.0)
        marks = ",".join("?" * len(TERMINAL))
        rows = self._conn.execute(
            f"SELECT job_id FROM jobs WHERE status IN ({marks})"
            " AND updated_at <= ?", (*TERMINAL, cutoff)).fetchall()
        ids = [r[0] for r in rows]
        if not ids:
            return 0
        idmarks = ",".join("?" * len(ids))
        snaps = self._conn.execute(
            f"SELECT dir, step_key FROM snapshots WHERE job_id IN ({idmarks})",
            ids).fetchall()
        by_dir: dict[str, list[int]] = {}
        for d, key in snaps:
            by_dir.setdefault(d, []).append(key)
        for d, keys in by_dir.items():
            ck = Checkpointer(d, keep_last=0)
            for key in keys:
                ck.remove(key)
        with self._tx() as c:
            c.execute(f"DELETE FROM snapshots WHERE job_id IN ({idmarks})",
                      ids)
            c.execute(f"DELETE FROM leases WHERE job_id IN ({idmarks})", ids)
            c.execute(f"DELETE FROM job_events WHERE job_id IN ({idmarks})",
                      ids)
            c.execute(f"DELETE FROM jobs WHERE job_id IN ({idmarks})", ids)
        return len(ids)
