"""Taylor-Green vortex: analytic validation of the full NS pipeline.

2D Taylor-Green (z-invariant in our 3D solver) on the periodic box
[0, 2*pi]^2:  u =  sin(x) cos(y) F(t),  v = -cos(x) sin(y) F(t),
F(t) = exp(-2 nu t).  The nonlinear terms are balanced by pressure, so the
numerical solution must track the analytic decay — this exercises advection,
diffusion, the Poisson solve, and projection at once, with a known answer.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.cfd.ns3d import CFDConfig, NavierStokes3D


def config(n: int = 32, nz: int = 4, nu: float = 0.1, dt: float | None = None,
           **kw) -> CFDConfig:
    h = 2.0 * math.pi / n
    dt = dt if dt is not None else min(0.25 * h, 0.2 * h * h / (6 * nu))
    kw.setdefault("jacobi_iters", 60)
    kw.setdefault("jacobi_omega", 1.0)
    return CFDConfig(
        shape=(n, n, nz), extent=2.0 * math.pi, nu=nu, dt=dt,
        case="taylor_green", **kw)


def sim_request(n: int = 32, nu: float = 0.1, *, steps: int = 50,
                tag: str = "", steady_tol: float | None = None,
                residual_tol: float | None = None, priority: int = 0, **kw):
    """A farm request for one Taylor-Green run (slot-parameterized setup).

    Heterogeneous ``nu`` across slots decays each vortex at its own rate
    under one compiled step; ``forcing`` may be set through ``kw`` to drive
    a sustained variant.  ``residual_tol``/``steady_tol``/``priority`` as
    in :func:`repro.cfd.cavity.sim_request`.
    """
    from repro.sim.farm import SimRequest  # lazy: cfd must not require sim

    cfg = config(n, nu=nu, **kw)
    return SimRequest(config=cfg, steps=steps,
                      tag=tag or f"tg-nu{nu:g}", steady_tol=steady_tol,
                      residual_tol=residual_tol, priority=priority)


def analytic(solver: NavierStokes3D, t: float):
    """vx, vy sampled at their staggered face positions."""
    x, y, _ = solver.driver.coords()
    h = solver.config.h
    f = math.exp(-2.0 * solver.config.nu * t)
    vx = jnp.sin(x + 0.5 * h) * jnp.cos(y) * f
    vy = -jnp.cos(x) * jnp.sin(y + 0.5 * h) * f
    return vx, vy


def run(n: int = 32, steps: int = 50, nu: float = 0.1, mesh=None, **kw):
    """Integrate and report errors vs the analytic solution."""
    cfg = config(n, nu=nu, **kw)
    solver = NavierStokes3D(cfg, mesh)
    state = solver.init_state()
    step = solver.make_step()
    for _ in range(steps):
        state = step(state)
    t = steps * cfg.dt
    ax, ay = analytic(solver, t)
    # one fused on-device report (div_linf + ke ride the health
    # diagnostics vector) plus one device_get for the analytic-error
    # reductions — a per-value float() here forces a host sync each,
    # which blocks dispatch when this runs as an ANALYSIS-bin call
    rep = solver.health_report(state)
    err_x, err_y, energy_exact = (float(v) for v in jax.device_get((
        jnp.abs(state["vx"] - ax).max(),
        jnp.abs(state["vy"] - ay).max(),
        0.5 * (jnp.mean(ax ** 2) + jnp.mean(ay ** 2)))))
    energy = rep["ke"]
    return {
        "t": t, "err_vx": err_x, "err_vy": err_y, "div_max": rep["div_linf"],
        "energy": energy, "energy_exact": energy_exact,
        "energy_rel_err": abs(energy - energy_exact) / energy_exact,
    }
