"""Lid-driven cavity at Re=100 — the paper's validation case (its Fig. 3).

The paper compares midsection centerline velocity against Ghia, Ghia & Shin
(1982).  We do the same: the 3D solver runs a z-periodic (quasi-2D) cavity,
and the x-velocity profile u(y) through the vertical centerline x=0.5 is
interpolated to Ghia's tabulated points.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.cfd.ns3d import CFDConfig, NavierStokes3D

# Ghia, Ghia & Shin (1982), Table I: u through the geometric center, Re=100.
# (y, u) — lid at y=1 moving with u=1.
GHIA_RE100_U = np.array([
    [0.0000, 0.00000],
    [0.0547, -0.03717],
    [0.0625, -0.04192],
    [0.0703, -0.04775],
    [0.1016, -0.06434],
    [0.1719, -0.10150],
    [0.2813, -0.15662],
    [0.4531, -0.21090],
    [0.5000, -0.20581],
    [0.6172, -0.13641],
    [0.7344, 0.00332],
    [0.8516, 0.23151],
    [0.9531, 0.68717],
    [0.9609, 0.73722],
    [0.9688, 0.78871],
    [0.9766, 0.84123],
    [1.0000, 1.00000],
])

# Ghia Table II: v through the horizontal centerline y=0.5, Re=100.
GHIA_RE100_V = np.array([
    [0.0000, 0.00000],
    [0.0625, 0.09233],
    [0.0703, 0.10091],
    [0.0781, 0.10890],
    [0.0938, 0.12317],
    [0.1563, 0.16077],
    [0.2266, 0.17507],
    [0.2344, 0.17527],
    [0.3125, 0.15662],
    [0.5000, 0.05454],
    [0.8047, -0.24533],
    [0.8594, -0.22445],
    [0.9063, -0.16914],
    [0.9453, -0.10313],
    [0.9531, -0.08864],
    [0.9609, -0.07391],
    [1.0000, 0.00000],
])


def config(n: int = 64, nz: int = 4, re: float = 100.0,
           lid_velocity: float = 1.0, **kw) -> CFDConfig:
    nu = 1.0 / re
    base = CFDConfig(shape=(n, n, nz), nu=nu)
    dt = kw.pop("dt", 0.8 * base.cfl(1.0))
    return CFDConfig(shape=(n, n, nz), extent=1.0, nu=nu, dt=dt,
                     case="cavity", lid_velocity=lid_velocity, **kw)


def sim_request(n: int = 32, re: float = 100.0, *, steps: int | None = None,
                t_end: float | None = None, tag: str = "",
                steady_tol: float | None = None,
                residual_tol: float | None = None, priority: int = 0, **kw):
    """A farm request for one cavity run (slot-parameterized setup).

    ``re``/``lid_velocity``/``forcing`` land in the per-slot scalar struct;
    grid and solver structure come from ``config(n, **kw)`` and must match
    the farm's static signature.  Give either ``steps`` or ``t_end``.
    ``residual_tol`` terminates at steady state on the residual norm
    ``||u^{n+1}-u^n||_inf / dt``; ``steady_tol`` is the legacy KE-drift
    heuristic.  ``priority`` orders farm admission (higher first).
    """
    from repro.sim.farm import SimRequest  # lazy: cfd must not require sim

    cfg = config(n, re=re, **kw)
    if steps is None:
        if t_end is None:
            raise ValueError("give either steps= or t_end=")
        steps = int(round(t_end / cfg.dt))
    return SimRequest(config=cfg, steps=steps,
                      tag=tag or f"cavity-re{re:g}", steady_tol=steady_tol,
                      residual_tol=residual_tol, priority=priority)


def centerline_u(solver: NavierStokes3D, state) -> tuple[np.ndarray, np.ndarray]:
    """u(y) at the vertical centerline x=0.5 (z-averaged)."""
    n = solver.config.shape[0]
    h = solver.config.h
    vx = np.asarray(state["vx"]).mean(axis=2)  # z average
    # vx[i, j] lives at x=(i+1)h, y=(j+.5)h; centerline x=0.5 -> i = n/2 - 1
    i = n // 2 - 1
    y = (np.arange(n) + 0.5) * h
    return y, vx[i, :]


def centerline_v(solver: NavierStokes3D, state) -> tuple[np.ndarray, np.ndarray]:
    """v(x) at the horizontal centerline y=0.5 (z-averaged)."""
    n = solver.config.shape[0]
    h = solver.config.h
    vy = np.asarray(state["vy"]).mean(axis=2)
    j = n // 2 - 1
    x = (np.arange(n) + 0.5) * h
    return x, vy[:, j]


def ghia_errors(solver: NavierStokes3D, state) -> dict:
    """RMS/max deviation from Ghia's tabulated centerline profiles."""
    y, u = centerline_u(solver, state)
    x, v = centerline_v(solver, state)
    ui = np.interp(GHIA_RE100_U[1:-1, 0], y, u)  # skip the wall/lid endpoints
    vi = np.interp(GHIA_RE100_V[1:-1, 0], x, v)
    du = ui - GHIA_RE100_U[1:-1, 1]
    dv = vi - GHIA_RE100_V[1:-1, 1]
    return {
        "u_rms": float(np.sqrt(np.mean(du ** 2))),
        "u_max": float(np.abs(du).max()),
        "v_rms": float(np.sqrt(np.mean(dv ** 2))),
        "v_max": float(np.abs(dv).max()),
    }


def run(n: int = 64, t_end: float = 20.0, mesh=None, progress=None, **kw):
    """Run the cavity to (near) steady state; return solver, state, errors."""
    cfg = config(n, **kw)
    solver = NavierStokes3D(cfg, mesh)
    state = solver.init_state()
    step = solver.make_step()
    steps = int(round(t_end / cfg.dt))
    for i in range(steps):
        state = step(state)
        if progress and i % progress == 0:
            ke = solver.kinetic_energy(state)
            print(f"  step {i:6d}/{steps} t={i*cfg.dt:7.3f} KE={ke:.6f}")
    return solver, state, ghia_errors(solver, state)
