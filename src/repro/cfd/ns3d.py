"""3D incompressible Navier-Stokes on a staggered MAC grid — the paper's §4.

Chorin/Hirt-Nichols explicit projection scheme, built entirely from the
framework's descriptor-generated kernels + driver-managed halo exchange:

  1. UPDATE_VELOCITY   u* = u + dt (-adv + nu lap + f)         [stencil kernel]
  2. wall masks        enforce zero wall-normal faces
  3. DIVERGENCE        rhs = div(u*)/dt                        [stencil kernel]
  4. JACOBI_PRESSURE   iterate lap p = rhs                     [stencil kernel]
                       (optionally the fused communication-avoiding smoother)
  5. PROJECT_VELOCITY  u = u* - dt grad p                      [stencil kernel]

Grid convention (see kernels/stencil3d.py): vx[i] at the right x-face of
cell i; the hi wall face is vx[N-1].  Cases: ``cavity`` (lid-driven, lid at
y-hi moving in +x; z periodic so the Ghia 2D profile is recovered) and
``taylor_green`` (triply periodic, analytic solution).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import AxisSpec, Domain, GridDriver, bc_dirichlet, bc_neumann
from repro.core.halo import exchange_pad, stencil_step_overlap
from repro.kernels import ops, ref
from repro.kernels.jacobi import jacobi_fused_ref


def bc_moving_wall(u_wall: float):
    """Tangential-velocity ghost across a wall moving at ``u_wall``:
    ghost = 2 u_wall - mirrored interior (wall value is the face average)."""

    def rule(strip, side):
        return 2.0 * u_wall - jnp.flip(strip, axis=rule.axis)

    return rule


@dataclasses.dataclass(frozen=True)
class CFDConfig:
    shape: tuple[int, int, int] = (64, 64, 4)
    extent: float = 1.0                      # cubic cells: h = extent/shape[0]
    nu: float = 0.01
    dt: float = 2.5e-3
    case: str = "cavity"                     # "cavity" | "taylor_green"
    lid_velocity: float = 1.0
    forcing: tuple[float, float, float] = (0.0, 0.0, 0.0)
    jacobi_iters: int = 40
    jacobi_omega: float = 1.0
    fused_sweeps: int = 1                    # >1: communication-avoiding smoother
    template: str | None = None              # None -> backend default
    interpret: bool = False                  # Pallas interpret mode (CPU 3DBLOCK)
    overlap: bool = True                     # interior/boundary split
    decomposition: tuple = ()                # e.g. ((0,"data"), (1,"model"))

    @property
    def h(self) -> float:
        return self.extent / self.shape[0]

    def cfl(self, umax: float = 1.0) -> float:
        """Stable dt bound: advective + viscous."""
        h = self.h
        return min(0.5 * h / max(umax, 1e-12), h * h / (6.0 * self.nu) * 0.9)


# The per-simulation runtime parameters: everything that may vary between
# ensemble members sharing one compiled step.  Grid geometry (shape, h) and
# solver structure (iterations, overlap, template) stay static — they select
# the compiled executable; these select the physics, as traced f32 scalars.
PARAM_KEYS = ("nu", "dt", "lid_velocity", "fx", "fy", "fz")


def params_from_config(c: CFDConfig) -> dict:
    """The per-simulation scalar struct for ``c`` (f32, like the fields).

    Both the single-run path (``make_step``) and the simulation farm thread
    these through the step, so a farm slot is bit-identical to a serial run
    of the same configuration.
    """
    fx, fy, fz = c.forcing
    vals = dict(nu=c.nu, dt=c.dt, lid_velocity=c.lid_velocity,
                fx=fx, fy=fy, fz=fz)
    return {k: jnp.float32(vals[k]) for k in PARAM_KEYS}


# Cases whose domain is fully periodic (no wall BCs, no wall masks).
# "kelvin_helmholtz" shares the solver structure of "taylor_green" — its
# shear-layer initial condition is owned by the scenario registry
# (repro.sim.scenarios), not by the solver.
PERIODIC_CASES = ("taylor_green", "kelvin_helmholtz")

# Physics columns of one in-situ health frame, in the order
# ``health_diagnostics`` stacks them.  ``obs.health.DIAG_COLUMNS`` is
# ``("step", *HEALTH_DIAGS)`` — duplicated (not imported) so the solver
# owes nothing to the obs package; a test pins the two tuples.
HEALTH_DIAGS = ("div_linf", "ke", "umax", "cfl", "finite")


class NavierStokes3D:
    """The CFD application object: owns the driver, BCs, and the step."""

    FIELDS = ("vx", "vy", "vz", "p")

    def __init__(self, config: CFDConfig, mesh: jax.sharding.Mesh | None = None):
        self.config = config
        periodic = config.case in PERIODIC_CASES
        self.domain = Domain(
            shape=config.shape,
            spacing=(config.h,) * 3,
            decomposition=dict(config.decomposition),
            periodic=(periodic, periodic, True),
        )
        self.driver = GridDriver(self.domain, mesh)
        self._health_jit = None   # lazy fused health_report executable
        self._build_bcs()

    @property
    def field_pspec(self):
        """PartitionSpec of one field under this solver's decomposition.

        The serial path shards state as ``field_pspec``; the simulation
        farm stacks a slot axis in front and shards as
        ``P(slot_axis, *field_pspec)`` (``dist.sharding.slot_field_spec``)
        — same grid placement, one more batch dimension.
        """
        return self.domain.pspec()

    # ------------------------------------------------------------------ BCs
    def _bcs_for(self, lid_velocity) -> dict:
        """BC rule table; ``lid_velocity`` may be a traced per-slot scalar."""
        c = self.config
        if c.case in PERIODIC_CASES:
            # fully periodic: no BC rules needed anywhere
            return {f: ((None,) * 3, (None,) * 3) for f in self.FIELDS}
        noslip = bc_moving_wall(0.0)
        lid = bc_moving_wall(lid_velocity)
        zero = bc_dirichlet(0.0)
        neum = bc_neumann()
        # (bc_lo per axis, bc_hi per axis); z is periodic via Domain.periodic
        return {
            # vx: normal to x walls (ghost faces 0), tangential in y (lid at hi)
            "vx": ((zero, noslip, None), (zero, lid, None)),
            # vy: tangential in x, normal to y walls
            "vy": ((noslip, zero, None), (noslip, zero, None)),
            # vz: tangential to x and y walls
            "vz": ((noslip, noslip, None), (noslip, noslip, None)),
            # p: homogeneous Neumann at all walls
            "p": ((neum, neum, None), (neum, neum, None)),
        }

    def _build_bcs(self):
        self.bc = self._bcs_for(self.config.lid_velocity)

    def _specs(self, field: str, bc: dict | None = None
               ) -> tuple[AxisSpec, AxisSpec, AxisSpec]:
        bc_lo, bc_hi = (bc or self.bc)[field]
        return self.driver.axis_specs(bc_lo=bc_lo, bc_hi=bc_hi)

    # --------------------------------------------------------------- fields
    def init_state(self) -> dict:
        c = self.config
        state = self.driver.allocate(self.FIELDS, 0.0)
        state["mask_vx"], state["mask_vy"], state["mask_vz"] = self._masks()
        if c.case == "taylor_green":
            x, y, z = self.driver.coords()
            h = c.h
            # face-centered sample positions (vx at x+(h/2), vy at y+(h/2))
            state["vx"] = jnp.sin(x + 0.5 * h) * jnp.cos(y)
            state["vy"] = -jnp.cos(x) * jnp.sin(y + 0.5 * h)
        return state

    def _masks(self):
        """Zero the wall-normal boundary faces (vx[N-1] on x, etc.)."""
        c = self.config
        sh = self.driver.sharding()
        ones = np.ones(c.shape, np.float32)
        mx, my, mz = ones.copy(), ones.copy(), ones.copy()
        if c.case not in PERIODIC_CASES:
            mx[-1, :, :] = 0.0
            my[:, -1, :] = 0.0
            # z periodic: no vz mask
        arrs = [jnp.asarray(m) for m in (mx, my, mz)]
        if sh is not None:
            arrs = [jax.device_put(a, sh) for a in arrs]
        return arrs

    # ----------------------------------------------------------------- step
    def _global_mean(self, x):
        # sequential per-axis sums, innermost first: the reduction order is
        # then identical with and without a leading slot axis (vmap), which
        # keeps farm slots bit-identical to serial runs
        m = x
        for _ in range(3):
            m = m.sum(axis=-1)
        m = m / np.prod(np.asarray(x.shape[-3:], np.float32))
        axes = tuple(self.domain.decomposition.values())
        if axes:
            m = lax.pmean(m, axes)
        return m

    def _step_local(self, state: dict, params: dict | None = None) -> dict:
        """One dt, operating on local blocks (runs inside shard_map).

        ``params`` is the per-simulation scalar struct (see ``PARAM_KEYS``);
        the farm vmaps this function over a slot axis with batched params,
        the single-run path passes ``params_from_config`` constants.

        Nothing here assumes the local block is the whole grid: ghost
        zones come from ``exchange_pad`` driven by the domain's AxisSpecs,
        so the same trace runs undecomposed (pure BC padding), decomposed
        under ``shard_map`` (ppermute per face), and decomposed *under
        vmap* on a slots × shards farm mesh — the collectives batch over
        the unnamed slot axis, keeping every slot bitwise equal to its
        serial decomposed run.
        """
        c = self.config
        if params is None:
            params = params_from_config(c)
        kw = dict(template=c.template or "JNP", interpret=c.interpret)
        if kw["template"] == "3DBLOCK":
            # chip-aware roofline tile, resolved per local interior and
            # memoized (autotune.tile_for) — serial and farm runs of the
            # same grid resolve the same tile, a bitwise-parity invariant
            kw["tile"] = "auto"
        h = c.h
        dt, nu = params["dt"], params["nu"]
        bc = self._bcs_for(params["lid_velocity"])
        specs = functools.partial(self._specs, bc=bc)
        vx, vy, vz, p = state["vx"], state["vy"], state["vz"], state["p"]
        mvx, mvy, mvz = state["mask_vx"], state["mask_vy"], state["mask_vz"]

        # -- 1. advection-diffusion (with comm/compute overlap if enabled)
        vel_params = dict(dt=dt, h=h, nu=nu, fx=params["fx"],
                          fy=params["fy"], fz=params["fz"])

        def upd_packed(padded):
            out = ops.update_velocity(padded[0], padded[1], padded[2],
                                      **vel_params, **kw)
            return jnp.stack(out)

        if c.overlap:
            # pack the components on a leading axis; the deep interior runs
            # without any ghost dependency (overlaps the ppermutes), shells
            # are computed from the exchanged pack.
            def pad_packed(pack):
                return jnp.stack([
                    exchange_pad(pack[i], (1, 1, 1), specs(f))
                    for i, f in enumerate(("vx", "vy", "vz"))
                ])

            packed = jnp.stack([vx, vy, vz])
            out = stencil_step_overlap(
                packed, (0, 1, 1, 1), specs=None, kernel=upd_packed,
                pad_fn=pad_packed)
            vx_s, vy_s, vz_s = out[0], out[1], out[2]
        else:
            pads = [exchange_pad(v, (1, 1, 1), specs(f))
                    for f, v in (("vx", vx), ("vy", vy), ("vz", vz))]
            vx_s, vy_s, vz_s = ops.update_velocity(*pads, **vel_params, **kw)

        vx_s, vy_s, vz_s = vx_s * mvx, vy_s * mvy, vz_s * mvz

        # -- 2. divergence rhs
        pads = [exchange_pad(v, ((1, 0),) * 3, specs(f))
                for f, v in (("vx", vx_s), ("vy", vy_s), ("vz", vz_s))]
        rhs = ops.divergence(*pads, h=h, **kw) / dt

        # -- 3. pressure Poisson (warm start from previous p)
        p_specs = specs("p")
        k = c.fused_sweeps

        def jacobi_body(_, pcur):
            if k <= 1:
                pp = exchange_pad(pcur, (1, 1, 1), p_specs)
                return ops.jacobi_pressure(pp, rhs, h=h, omega=c.jacobi_omega, **kw)
            pp = exchange_pad(pcur, (k, k, k), p_specs)
            rr = exchange_pad(rhs, (k, k, k), p_specs)
            return jacobi_fused_ref(pp, rr, h=h, omega=c.jacobi_omega, sweeps=k)

        iters = max(c.jacobi_iters // max(k, 1), 1)
        p_new = lax.fori_loop(0, iters, jacobi_body, p)
        p_new = p_new - self._global_mean(p_new)  # pin the Neumann null space

        # -- 4. projection
        pp = exchange_pad(p_new, ((0, 1),) * 3, p_specs)
        vx_n, vy_n, vz_n = ops.project_velocity(vx_s, vy_s, vz_s, pp,
                                                dt=dt, h=h, **kw)
        vx_n, vy_n, vz_n = vx_n * mvx, vy_n * mvy, vz_n * mvz

        return dict(state, vx=vx_n, vy=vy_n, vz=vz_n, p=p_new)

    def make_step(self) -> Callable[[dict], dict]:
        """Jitted global step (shard_map'd when a mesh decomposes the grid).

        The config's scalars are threaded as f32 traced values through the
        same parameterized step the simulation farm vmaps — on the 3DBLOCK
        (Pallas) template they ride the generator's scalar-table operand
        (scalar prefetch on real TPU) exactly like a farm slot's table row —
        so a serial run is the bitwise reference for a farm slot with the
        same parameters on every template.
        """
        c = self.config
        example = self.init_state()
        params = params_from_config(c)
        jstep = self.driver.sharded_step_tree(self._step_local, example, params)
        return lambda s: jstep(s, params)

    # ------------------------------------------------------------ analysis
    def divergence_of(self, state: dict) -> jnp.ndarray:
        def local(vx, vy, vz):
            pads = [exchange_pad(v, ((1, 0),) * 3, self._specs(f))
                    for f, v in (("vx", vx), ("vy", vy), ("vz", vz))]
            return ops.divergence(*pads, h=self.config.h, template="JNP")

        if self.driver.mesh is None:
            return local(state["vx"], state["vy"], state["vz"])
        spec = self.domain.pspec()
        f = jax.shard_map(local, mesh=self.driver.mesh,
                          in_specs=(spec, spec, spec), out_specs=spec,
                          check_vma=False)
        return f(state["vx"], state["vy"], state["vz"])

    def kinetic_energy(self, state: dict) -> float:
        return float(0.5 * sum(jnp.mean(state[f] ** 2)
                               for f in ("vx", "vy", "vz")))

    def health_diagnostics(self, state: dict,
                           params: dict | None = None) -> jnp.ndarray:
        """One fused ``(len(HEALTH_DIAGS),)`` f32 vector of in-situ health
        diagnostics: divergence L∞, kinetic energy, max|u|, CFL number,
        and a finite-fields sentinel (1.0 = no NaN/Inf in any dynamic
        field — the velocities and the pressure).

        Local-block semantics like ``_step_local``: the stencil is
        ghost-free (interior slicing) and reductions finish with
        ``pmax``/``pmin``/``pmean`` over the decomposition axes, so the
        same function runs serially, vmapped over farm slots, and inside
        ``shard_map`` — with zero halo traffic of its own.  Read-only
        (no state writes): compiling it alongside the step cannot
        perturb the step's numerics.
        """
        c = self.config
        if params is None:
            params = params_from_config(c)
        axes = tuple(self.domain.decomposition.values())

        def gmax(x):
            return lax.pmax(x, axes) if axes else x

        def seqmax(x):
            # sequential per-axis maxes: XLA:CPU lowers one multi-axis
            # (or flattened) NaN-propagating max-reduce to a scalar loop,
            # which is ~3x slower than chained single-axis reduces; this
            # runs inside every farm chunk, so the lowering matters
            for _ in range(3):
                x = x.max(axis=-1)
            return x

        # interior one-sided divergence: identical to the ghost-padded
        # stencil on every cell that has real (non-BC) neighbors, but it
        # is pure slicing — no padded field copies, no halo traffic, one
        # fused kernel.  A blow-up is a volume phenomenon; the skipped
        # boundary planes cannot hide one from the L-inf
        vx, vy, vz = state["vx"], state["vy"], state["vz"]
        div = ((vx[1:, 1:, 1:] - vx[:-1, 1:, 1:])
               + (vy[1:, 1:, 1:] - vy[1:, :-1, 1:])
               + (vz[1:, 1:, 1:] - vz[1:, 1:, :-1])) / c.h
        div_linf = gmax(seqmax(jnp.abs(div)))
        # max|u| as ONE volume reduce over the elementwise 3-field max
        # (equal to the max of per-field maxes, at a third of the reduce)
        umax = gmax(seqmax(jnp.maximum(jnp.maximum(jnp.abs(vx),
                                                   jnp.abs(vy)),
                                       jnp.abs(vz))))
        ke2 = vx * vx + vy * vy + vz * vz
        for _ in range(3):      # sequential per-axis sums like _global_mean
            ke2 = ke2.sum(axis=-1)
        ke = 0.5 * ke2 / np.prod(np.asarray(vx.shape[-3:], np.float32))
        if axes:
            ke = lax.pmean(ke, axes)
        cfl = umax * params["dt"] / c.h
        # sentinel without boolean volume reduces: NaN/Inf in any velocity
        # poisons umax or ke (max and sum both propagate non-finites); the
        # pressure — untouched by the three stats above — contributes one
        # cheap mean-of-field sum
        psum = state["p"]
        for _ in range(3):
            psum = psum.sum(axis=-1)
        finite = jnp.isfinite(div_linf + ke + umax + psum)
        finite = finite.astype(jnp.float32)
        if axes:
            finite = lax.pmin(finite, axes)
        return jnp.stack([div_linf, ke, umax, cfl, finite]
                         ).astype(jnp.float32)

    def health_report(self, state: dict) -> dict:
        """Named health diagnostics of ``state`` as plain floats — ONE
        fused dispatch and ONE host fetch, however many numbers come
        back (the lazy replacement for per-diagnostic ``float(...)``
        host syncs in analysis code)."""
        fields = [state[f] for f in self.FIELDS]
        if self._health_jit is None:
            def local(vx, vy, vz, p):
                return self.health_diagnostics(
                    {"vx": vx, "vy": vy, "vz": vz, "p": p})

            if self.driver.mesh is None:
                self._health_jit = jax.jit(local)
            else:
                from jax.sharding import PartitionSpec

                spec = self.domain.pspec()
                self._health_jit = jax.jit(jax.shard_map(
                    local, mesh=self.driver.mesh, in_specs=(spec,) * 4,
                    out_specs=PartitionSpec(), check_vma=False))
        vec = np.asarray(self._health_jit(*fields))
        return {k: float(v) for k, v in zip(HEALTH_DIAGS, vec)}
