"""Method of Lines time integrators (the Cactus MoL thorn analogue).

Explicit Runge-Kutta integrators over arbitrary pytrees of state, as provided
to Cactus applications by the MoL thorn.  ``rhs(y, t) -> dy/dt`` is supplied
by the application (e.g. the CFD momentum equation); integrators are pure and
jit-compatible.
"""
from __future__ import annotations

from typing import Callable, TypeVar

import jax
import jax.numpy as jnp

T = TypeVar("T")
RHS = Callable[[T, jnp.ndarray], T]

_tm = jax.tree_util.tree_map


def _axpy(a: float, x: T, y: T) -> T:
    return _tm(lambda xi, yi: a * xi + yi, x, y)


def euler(rhs: RHS, y: T, t, dt) -> T:
    return _axpy(dt, rhs(y, t), y)


def rk2(rhs: RHS, y: T, t, dt) -> T:
    """Heun's method (SSP-RK2)."""
    k1 = rhs(y, t)
    y1 = _axpy(dt, k1, y)
    k2 = rhs(y1, t + dt)
    return _tm(lambda yi, a, b: yi + 0.5 * dt * (a + b), y, k1, k2)


def rk3_ssp(rhs: RHS, y: T, t, dt) -> T:
    """Shu-Osher strong-stability-preserving RK3 (standard for advection)."""
    k1 = rhs(y, t)
    y1 = _axpy(dt, k1, y)
    k2 = rhs(y1, t + dt)
    y2 = _tm(lambda yi, y1i, ki: 0.75 * yi + 0.25 * (y1i + dt * ki), y, y1, k2)
    k3 = rhs(y2, t + 0.5 * dt)
    return _tm(
        lambda yi, y2i, ki: yi / 3.0 + (2.0 / 3.0) * (y2i + dt * ki), y, y2, k3
    )


def rk4(rhs: RHS, y: T, t, dt) -> T:
    k1 = rhs(y, t)
    k2 = rhs(_axpy(0.5 * dt, k1, y), t + 0.5 * dt)
    k3 = rhs(_axpy(0.5 * dt, k2, y), t + 0.5 * dt)
    k4 = rhs(_axpy(dt, k3, y), t + dt)
    return _tm(
        lambda yi, a, b, c, d: yi + (dt / 6.0) * (a + 2 * b + 2 * c + d),
        y, k1, k2, k3, k4,
    )


INTEGRATORS = {"euler": euler, "rk2": rk2, "rk3": rk3_ssp, "rk4": rk4}


def batched(integrator: Callable) -> Callable:
    """Vmap an integrator over a leading slot axis (the simulation farm).

    ``y`` leaves, ``t`` and ``dt`` all carry the slot axis, so every
    ensemble member advances with its own time and step size under one
    compiled step; ``rhs`` sees per-slot (unbatched) state, exactly as in a
    serial run — a farm slot therefore integrates identically to MoL alone.
    """

    def step(rhs: RHS, y: T, t, dt) -> T:
        return jax.vmap(lambda yi, ti, di: integrator(rhs, yi, ti, di))(
            y, t, dt)

    return step


BATCHED_INTEGRATORS = {k: batched(v) for k, v in INTEGRATORS.items()}
