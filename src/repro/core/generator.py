"""CaCUDA code generator, retargeted from CUDA templates to Pallas/XLA.

The paper's generator parses kernel descriptors and expands optimized CUDA
templates (shared-memory staging, 3D block tiling, axis streaming) so that
application authors write only the per-cell update.  Here the same descriptor
drives two templates:

* ``3DBLOCK`` — a ``pl.pallas_call`` whose BlockSpecs are derived from the
  descriptor: cached (``CACHED=YES``) read variables are staged HBM->VMEM as
  halo-expanded ``Element`` blocks (``tile + stencil``), outputs as bare
  ``tile`` blocks.  This is the TPU analogue of the paper's shared-memory
  tile staging; the MXU/VPU alignment rules replace CUDA warp rules.

* ``JNP`` — a fused pure-``jnp`` expansion of the same body (shifted slices
  of the padded array).  It is the oracle for kernel tests, the
  shape-polymorphic kernel used for boundary shells in overlap mode, and the
  XLA path on non-TPU backends.

The *kernel body* the user writes is a function ``body(ctx) -> dict`` where
``ctx[name]`` is a :class:`FieldView` supporting ``.at(dx, dy, dz)`` shifted
reads — the moral equivalent of the generated CUDA macros that CaCUDA emitted
for indexing shared memory.  The same body traces through both templates.

Runtime parameters split two ways in the 3DBLOCK template:

* **Python/numpy scalars** are baked into the kernel as trace-time literals
  (the original behavior — fine for geometry like ``h`` that is static per
  compiled executable).
* **Array-valued scalars** (``jax.Array`` or tracers, e.g. the per-simulation
  ``nu``/``dt`` the simulation farm threads through its vmapped step) are
  packed into a ``(rows, n_params)`` *scalar table* operand: row 0 for a
  single simulation, row ``s`` for slot ``s`` of a batched call.  On real TPU
  hardware the table rides the scalar-prefetch lane
  (``pltpu.PrefetchScalarGridSpec`` — SMEM, available before the grid body
  runs); in interpret mode / on other backends it is an ordinary leading
  operand whose BlockSpec selects the slot's row.  Either way ONE compiled
  kernel serves every scalar assignment — admitting a new parameter variant
  into the farm never recompiles, and a ``jax.vmap`` over the call (the
  ensemble executor's slot axis) dispatches to a slot-indexed batched grid
  via ``jax.custom_batching.custom_vmap``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # newer JAX: per-dim element indexing via Element block dims
    from jax._src.pallas.core import Element as _Element
except ImportError:  # older JAX: whole-spec Unblocked indexing mode
    _Element = None

# This JAX version ships optimization_barrier without a batching rule; the
# rule is the identity on batch dims (the barrier is shape-transparent), as
# added upstream in later releases.  Needed because interpret-mode kernels
# pin their operand/result boundary with a barrier (see _apply_pallas) and
# literal-parameter kernels batch through pallas's native vmap rule.
try:
    from jax.interpreters import batching as _batching
    _ob_p = jax._src.lax.lax.optimization_barrier_p
    if _ob_p not in _batching.primitive_batchers:
        def _ob_batcher(batched_args, batch_dims, **params):
            return _ob_p.bind(*batched_args, **params), batch_dims
        _batching.primitive_batchers[_ob_p] = _ob_batcher
except (ImportError, AttributeError):  # pragma: no cover - newer JAX has it
    pass

from repro.core.descriptor import Intent, StencilDescriptor


def _split_params(params: dict) -> tuple[dict, dict]:
    """Partition runtime parameters into (literal, array-valued).

    Array-valued covers concrete ``jax.Array``s AND tracers (jit/vmap);
    Python and numpy scalars stay literals, preserving the direct-call
    behavior of eager kernel invocations in tests and notebooks.
    """
    literal, arr = {}, {}
    for k, v in params.items():
        (arr if isinstance(v, jax.Array) else literal)[k] = v
    return literal, arr


def element_block_spec(block_shape, index_map) -> pl.BlockSpec:
    """BlockSpec whose ``index_map`` returns *element* offsets.

    This is how the 3DBLOCK template expresses halo-expanded overlapping
    windows (tile + stencil) staged into VMEM.  Newer JAX spells it with
    ``Element`` block dims; older JAX with the ``Unblocked`` indexing mode.
    Both take element offsets from the index map, so callers are agnostic.
    """
    if _Element is not None:
        return pl.BlockSpec(tuple(_Element(b) for b in block_shape), index_map)
    return pl.BlockSpec(tuple(block_shape), index_map,
                        indexing_mode=pl.Unblocked())


class FieldView:
    """Shifted-stencil accessor over a halo-padded array (or VMEM block)."""

    __slots__ = ("arr", "halo_lo", "halo_hi")

    def __init__(self, arr, halo_lo, halo_hi):
        self.arr = arr
        self.halo_lo = halo_lo
        self.halo_hi = halo_hi

    def at(self, dx: int = 0, dy: int = 0, dz: int = 0) -> jnp.ndarray:
        off = (dx, dy, dz)
        idx = []
        for a, o in enumerate(off):
            lo, hi = self.halo_lo[a], self.halo_hi[a]
            if not -lo <= o <= hi:
                raise ValueError(
                    f"stencil offset {off} exceeds declared radii "
                    f"(lo={self.halo_lo}, hi={self.halo_hi})"
                )
            stop = self.arr.shape[a] - hi + o
            idx.append(slice(lo + o, stop))
        return self.arr[tuple(idx)]

    @property
    def c(self) -> jnp.ndarray:
        return self.at(0, 0, 0)


class KernelContext(Mapping):
    """What the kernel body sees: field views + runtime parameters."""

    def __init__(self, views: dict[str, FieldView], params: dict[str, Any]):
        self._views = views
        self._params = params

    def __getitem__(self, name: str) -> FieldView:
        return self._views[name]

    def __iter__(self):
        return iter(self._views)

    def __len__(self):
        return len(self._views)

    def param(self, name: str):
        return self._params[name]


@dataclasses.dataclass
class GeneratedKernel:
    """A compiled-from-descriptor kernel, callable on padded input arrays.

    ``__call__(arrays, **params) -> dict[name, interior array]`` where
    ``arrays[name]`` for read variables is the *padded* local array
    (interior + stencil ghosts) and outputs are interior-shaped.
    """

    desc: StencilDescriptor
    body: Callable[[KernelContext], dict[str, jnp.ndarray]]
    template: str
    interpret: bool = False

    def __post_init__(self):
        self._halo_lo = self.desc.halo_lo
        self._halo_hi = self.desc.halo_hi
        # custom_vmap entry points, one per (literal params, array-param
        # names) signature — the vmap rule must be installed once per
        # callable, and the literal half of the params is baked into it
        self._entry_cache: dict[tuple, Any] = {}

    # ---- JNP template -----------------------------------------------------
    def _apply_jnp(self, arrays: dict[str, jnp.ndarray], params: dict[str, Any]):
        views = {}
        for name in self.desc.inputs:
            cached = name in self.desc.cached_inputs
            hl = self._halo_lo if cached else (0, 0, 0)
            hh = self._halo_hi if cached else (0, 0, 0)
            views[name] = FieldView(arrays[name], hl, hh)
        out = self.body(KernelContext(views, params))
        missing = set(self.desc.outputs) - set(out)
        if missing:
            raise ValueError(f"kernel body did not produce outputs: {sorted(missing)}")
        return {k: out[k] for k in self.desc.outputs}

    # ---- 3DBLOCK (Pallas) template ----------------------------------------
    def _scalar_table(self, arr: dict[str, Any], nslots: int | None):
        """Pack array-valued params into a ``(rows, n)`` table.

        Returns ``(names, dtypes, table)`` — column order follows the
        descriptor's parameter declaration; each column is cast to the
        promoted table dtype and cast back on read inside the kernel.
        Rows: 1 (unbatched) or ``nslots`` (one row per slot; shared
        scalars broadcast so every slot reads its own row).
        """
        declared = sorted((k for k in arr if k in self.desc.parameters),
                          key=self.desc.param_index)
        extra = sorted(set(arr) - set(declared))
        names = tuple(declared + extra)
        dtypes = tuple(jnp.asarray(arr[k]).dtype for k in names)
        tdt = jnp.result_type(*dtypes)
        cols = []
        for k in names:
            v = jnp.asarray(arr[k]).astype(tdt)
            cols.append(jnp.broadcast_to(v, (nslots,)) if nslots is not None
                        else jnp.reshape(v, ()))
        table = jnp.stack(cols, axis=-1)
        if nslots is None:
            table = table[None]                      # (1, n)
        return names, dtypes, table

    def _apply_pallas(self, arrays: dict[str, jnp.ndarray],
                      params: dict[str, Any], *, batched: bool = False):
        """The 3DBLOCK expansion; ``batched`` adds a leading slot axis to
        the grid and every BlockSpec so one ``pallas_call`` advances all
        resident simulations (the ensemble-executor form).

        Array-valued params ride a scalar table operand (row per slot when
        batched — scalar prefetch on real TPU, a leading SMEM-style operand
        in interpret mode); literal params are baked at trace time.
        """
        desc = self.desc
        literal, arr = _split_params(params)
        tx, ty, tz = desc.tile
        hl, hh = self._halo_lo, self._halo_hi
        first = arrays[desc.inputs[0]]
        nslots = first.shape[0] if batched else None
        space = first.shape[1:] if batched else first.shape
        interior = tuple(
            s - (lo + hi) for s, lo, hi in zip(space, hl, hh)
        ) if desc.inputs[0] in desc.cached_inputs else space
        nx, ny, nz = interior
        if nx % tx or ny % ty or nz % tz:
            raise ValueError(
                f"interior {interior} not divisible by tile {desc.tile}; "
                f"use the autotuner or the JNP template"
            )
        grid = (nx // tx, ny // ty, nz // tz)
        if batched:
            grid = (nslots,) + grid

        if arr:
            tab_names, tab_dtypes, table = self._scalar_table(arr, nslots)
        else:
            tab_names, tab_dtypes, table = (), (), None
        # real TPU hardware prefetches the table into SMEM ahead of the
        # grid body; everywhere else (interpret mode, CPU/GPU lowering)
        # it is a plain leading operand whose BlockSpec picks the row
        use_prefetch = (table is not None and not self.interpret
                        and jax.default_backend() == "tpu")

        def slotted(block, index_map, element):
            """Prepend the slot dim (block 1, offset = slot index).

            Index maps take ``*_`` so the scalar-prefetch grid spec —
            which appends the scalar refs to every index-map call — and
            the plain grid agree on one signature.
            """
            if not batched:
                return (element_block_spec(block, index_map) if element
                        else pl.BlockSpec(block, index_map))
            block = (1,) + block
            index_map = lambda b, *g, _m=index_map: (b,) + _m(*g)
            return (element_block_spec(block, index_map) if element
                    else pl.BlockSpec(block, index_map))

        in_specs = []
        in_arrays = []
        for name in desc.inputs:
            if name in desc.cached_inputs:
                # halo-expanded overlapping window staged into VMEM — the
                # shared-memory tile of the paper's 3DBLOCK template
                spec = slotted(
                    (tx + hl[0] + hh[0], ty + hl[1] + hh[1], tz + hl[2] + hh[2]),
                    lambda i, j, k, *_: (i * tx, j * ty, k * tz), element=True)
            else:
                spec = slotted((tx, ty, tz), lambda i, j, k, *_: (i, j, k),
                               element=False)
            in_specs.append(spec)
            in_arrays.append(arrays[name])

        out_spec = slotted((tx, ty, tz), lambda i, j, k, *_: (i, j, k),
                           element=False)
        out_names = desc.outputs
        out_shape = ((nslots,) + interior) if batched else interior
        out_shapes = [jax.ShapeDtypeStruct(out_shape, arrays[n].dtype
                                           if n in arrays else first.dtype)
                      for n in out_names]

        def pallas_body(*refs):
            if table is not None:
                tab_ref, refs = refs[0], refs[1:]
                # prefetch hands the WHOLE table to every grid instance
                # (slot row selected by program id); the operand fallback
                # already blocked it down to this slot's (1, n) row
                row = (pl.program_id(0) if (use_prefetch and batched) else 0)
                run = {k: tab_ref[row, i].astype(dt)
                       for i, (k, dt) in enumerate(zip(tab_names, tab_dtypes))}
            else:
                run = {}
            body_params = {**literal, **run}
            in_refs = refs[: len(in_arrays)]
            out_refs = refs[len(in_arrays):]
            views = {}
            for name, ref in zip(desc.inputs, in_refs):
                blk = ref[...]
                if batched:
                    blk = blk[0]  # drop the slot dim inside the block
                cached = name in desc.cached_inputs
                views[name] = FieldView(
                    blk, hl if cached else (0, 0, 0), hh if cached else (0, 0, 0)
                )
            out = self.body(KernelContext(views, body_params))
            for name, ref in zip(out_names, out_refs):
                val = out[name][None] if batched else out[name]
                ref[...] = val.astype(ref.dtype)

        if use_prefetch:
            from jax.experimental.pallas import tpu as pltpu

            call = pl.pallas_call(
                pallas_body,
                grid_spec=pltpu.PrefetchScalarGridSpec(
                    num_scalar_prefetch=1,
                    grid=grid,
                    in_specs=in_specs,
                    out_specs=[out_spec] * len(out_names),
                ),
                out_shape=out_shapes,
            )
            results = call(table, *in_arrays)
        else:
            operands = tuple(in_arrays)
            if table is not None:
                n = len(tab_names)
                tab_spec = pl.BlockSpec(
                    (1, n),
                    (lambda s, *_: (s, 0)) if batched
                    else (lambda *_: (0, 0)))
                in_specs = [tab_spec] + in_specs
                operands = (table,) + operands
            if self.interpret:
                # interpret mode inlines the kernel into the surrounding
                # XLA program, where fusion/FMA contraction may associate
                # differently with and without a leading slot axis.  A real
                # pallas_call is an opaque custom call; pinning the kernel
                # boundary restores that semantics, keeping batched (farm)
                # programs bitwise-identical to their serial counterparts.
                operands = jax.lax.optimization_barrier(operands)
            results = pl.pallas_call(
                pallas_body,
                grid=grid,
                in_specs=in_specs,
                out_specs=[out_spec] * len(out_names),
                out_shape=out_shapes,
                interpret=self.interpret,
            )(*operands)
            if self.interpret:
                results = jax.lax.optimization_barrier(results)
        if len(out_names) == 1:
            results = (results,) if not isinstance(results, (list, tuple)) else results
        return dict(zip(out_names, results))

    def _pallas_entry(self, lit_key: tuple, arr_names: tuple):
        """The vmappable 3DBLOCK entry point for one params signature.

        A ``custom_vmap``-wrapped call: unbatched it is the plain operand
        -table ``_apply_pallas``; under ``jax.vmap`` (the ensemble
        executor's slot axis) the rule re-expands to the slot-grid batched
        ``pallas_call`` with one scalar-table row per slot, instead of
        leaving pallas's generic batching rule to guess.  Cached per
        (literal params, array-param names) so the vmap rule is installed
        once per signature.
        """
        entry = self._entry_cache.get((lit_key, arr_names))
        if entry is not None:
            return entry
        literal = dict(lit_key)

        @jax.custom_batching.custom_vmap
        def call(arrays, aparams):
            return self._apply_pallas(arrays, {**literal, **aparams})

        @call.def_vmap
        def _rule(axis_size, in_batched, arrays, aparams):
            arr_b, par_b = in_batched
            arrays = {
                k: v if arr_b[k]
                else jnp.broadcast_to(v, (axis_size,) + v.shape)
                for k, v in arrays.items()}
            aparams = {
                k: v if par_b[k]
                else jnp.broadcast_to(v, (axis_size,) + jnp.shape(v))
                for k, v in aparams.items()}
            out = self._apply_pallas(arrays, {**literal, **aparams},
                                     batched=True)
            return out, {k: True for k in out}

        self._entry_cache[(lit_key, arr_names)] = call
        return call

    # ---- batched (slot-axis) templates ------------------------------------
    def _apply_jnp_batched(self, arrays, params, batched_params):
        batched = {k: v for k, v in params.items() if k in batched_params}
        static = {k: v for k, v in params.items() if k not in batched_params}

        def fn(a, bp):
            return self._apply_jnp(a, {**static, **bp})

        return jax.vmap(fn, in_axes=(0, 0))(arrays, batched)

    def apply_batched(self, arrays: dict[str, jnp.ndarray],
                      batched_params: frozenset | tuple = (), **params):
        """Apply the kernel over a leading slot (batch) axis of every array.

        ``batched_params`` names runtime parameters that also carry the slot
        axis (per-simulation scalars, e.g. viscosity); the rest are shared.
        The JNP template vmaps the fused expansion; the 3DBLOCK template adds
        the slot axis to its grid/BlockSpecs and routes per-slot scalars
        through the scalar table — one table row per slot (scalar prefetch
        on real TPU), so heterogeneous physics shares one compiled kernel.
        """
        for p in self.desc.parameters:
            if p not in params:
                raise ValueError(f"missing runtime parameter {p!r}")
        if self.template == "JNP":
            return self._apply_jnp_batched(arrays, params,
                                           frozenset(batched_params))
        bad = [k for k in batched_params
               if not isinstance(params.get(k), jax.Array)]
        if bad:
            raise ValueError(
                f"batched parameters must be array-valued with a leading "
                f"slot axis; got non-array values for {sorted(bad)}")
        return self._apply_pallas(arrays, params, batched=True)

    def __call__(self, arrays: dict[str, jnp.ndarray], **params):
        for p in self.desc.parameters:
            if p not in params:
                raise ValueError(f"missing runtime parameter {p!r}")
        if self.template == "JNP":
            return self._apply_jnp(arrays, params)
        literal, arr = _split_params(params)
        if not arr:
            # all-literal calls keep the original direct expansion (and
            # pallas's own batching rule under vmap, which interpret mode
            # executes identically to the slot-grid form)
            return self._apply_pallas(arrays, params)
        entry = self._pallas_entry(
            tuple(sorted(literal.items())), tuple(sorted(arr)))
        return entry(arrays, {k: jnp.asarray(v) for k, v in arr.items()})

    def describe(self) -> str:
        """Human-readable summary of the generated kernel (the 'emitted code')."""
        d = self.desc
        hx, hy, hz = d.halo_width
        lines = [
            f"kernel {d.name} [{self.template}] tile={d.tile} stencil={d.stencil}",
            f"  grid = interior / tile ; VMEM/block ~ {d.vmem_block_bytes()} B (f32)",
        ]
        for g in d.variables:
            stage = "VMEM halo-block" if (g.cached and g.intent.is_read) else "VMEM tile"
            lines.append(
                f"  {','.join(g.names):24s} intent={g.intent.value:13s} {stage}"
            )
        for p in d.parameters:
            lines.append(f"  {p:24s} runtime parameter "
                         f"(literal if Python scalar, scalar-table operand "
                         f"if traced/array)")
        return "\n".join(lines)


def generate(
    desc: StencilDescriptor,
    body: Callable[[KernelContext], dict[str, jnp.ndarray]],
    *,
    template: str | None = None,
    interpret: bool = False,
) -> GeneratedKernel:
    """Expand ``desc`` + ``body`` into an executable kernel.

    ``template=None`` uses the descriptor's TYPE (``3DBLOCK`` -> Pallas).
    ``interpret=True`` runs the Pallas template through the interpreter
    (CPU-correctness mode used by the test suite).
    """
    tmpl = template or desc.type
    if tmpl not in ("3DBLOCK", "JNP"):
        raise ValueError(f"unknown template {tmpl!r}")
    return GeneratedKernel(desc=desc, body=body, template=tmpl, interpret=interpret)


def generate_pair(desc, body):
    """(pallas_interpret, jnp_oracle) pair for validation tests."""
    return (
        generate(desc, body, template="3DBLOCK", interpret=True),
        generate(desc, body, template="JNP"),
    )
