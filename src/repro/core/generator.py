"""CaCUDA code generator, retargeted from CUDA templates to Pallas/XLA.

The paper's generator parses kernel descriptors and expands optimized CUDA
templates (shared-memory staging, 3D block tiling, axis streaming) so that
application authors write only the per-cell update.  Here the same descriptor
drives two templates:

* ``3DBLOCK`` — a ``pl.pallas_call`` whose BlockSpecs are derived from the
  descriptor: cached (``CACHED=YES``) read variables are staged HBM->VMEM as
  halo-expanded ``Element`` blocks (``tile + stencil``), outputs as bare
  ``tile`` blocks.  This is the TPU analogue of the paper's shared-memory
  tile staging; the MXU/VPU alignment rules replace CUDA warp rules.

* ``JNP`` — a fused pure-``jnp`` expansion of the same body (shifted slices
  of the padded array).  It is the oracle for kernel tests, the
  shape-polymorphic kernel used for boundary shells in overlap mode, and the
  XLA path on non-TPU backends.

The *kernel body* the user writes is a function ``body(ctx) -> dict`` where
``ctx[name]`` is a :class:`FieldView` supporting ``.at(dx, dy, dz)`` shifted
reads — the moral equivalent of the generated CUDA macros that CaCUDA emitted
for indexing shared memory.  The same body traces through both templates.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax._src.pallas.core import Element

from repro.core.descriptor import Intent, StencilDescriptor


class FieldView:
    """Shifted-stencil accessor over a halo-padded array (or VMEM block)."""

    __slots__ = ("arr", "halo_lo", "halo_hi")

    def __init__(self, arr, halo_lo, halo_hi):
        self.arr = arr
        self.halo_lo = halo_lo
        self.halo_hi = halo_hi

    def at(self, dx: int = 0, dy: int = 0, dz: int = 0) -> jnp.ndarray:
        off = (dx, dy, dz)
        idx = []
        for a, o in enumerate(off):
            lo, hi = self.halo_lo[a], self.halo_hi[a]
            if not -lo <= o <= hi:
                raise ValueError(
                    f"stencil offset {off} exceeds declared radii "
                    f"(lo={self.halo_lo}, hi={self.halo_hi})"
                )
            stop = self.arr.shape[a] - hi + o
            idx.append(slice(lo + o, stop))
        return self.arr[tuple(idx)]

    @property
    def c(self) -> jnp.ndarray:
        return self.at(0, 0, 0)


class KernelContext(Mapping):
    """What the kernel body sees: field views + runtime parameters."""

    def __init__(self, views: dict[str, FieldView], params: dict[str, Any]):
        self._views = views
        self._params = params

    def __getitem__(self, name: str) -> FieldView:
        return self._views[name]

    def __iter__(self):
        return iter(self._views)

    def __len__(self):
        return len(self._views)

    def param(self, name: str):
        return self._params[name]


@dataclasses.dataclass
class GeneratedKernel:
    """A compiled-from-descriptor kernel, callable on padded input arrays.

    ``__call__(arrays, **params) -> dict[name, interior array]`` where
    ``arrays[name]`` for read variables is the *padded* local array
    (interior + stencil ghosts) and outputs are interior-shaped.
    """

    desc: StencilDescriptor
    body: Callable[[KernelContext], dict[str, jnp.ndarray]]
    template: str
    interpret: bool = False

    def __post_init__(self):
        self._halo_lo = self.desc.halo_lo
        self._halo_hi = self.desc.halo_hi

    # ---- JNP template -----------------------------------------------------
    def _apply_jnp(self, arrays: dict[str, jnp.ndarray], params: dict[str, Any]):
        views = {}
        for name in self.desc.inputs:
            cached = name in self.desc.cached_inputs
            hl = self._halo_lo if cached else (0, 0, 0)
            hh = self._halo_hi if cached else (0, 0, 0)
            views[name] = FieldView(arrays[name], hl, hh)
        out = self.body(KernelContext(views, params))
        missing = set(self.desc.outputs) - set(out)
        if missing:
            raise ValueError(f"kernel body did not produce outputs: {sorted(missing)}")
        return {k: out[k] for k in self.desc.outputs}

    # ---- 3DBLOCK (Pallas) template ----------------------------------------
    def _apply_pallas(self, arrays: dict[str, jnp.ndarray], params: dict[str, Any]):
        desc = self.desc
        tx, ty, tz = desc.tile
        hl, hh = self._halo_lo, self._halo_hi
        first = arrays[desc.inputs[0]]
        interior = tuple(
            s - (lo + hi) for s, lo, hi in zip(first.shape, hl, hh)
        ) if desc.inputs[0] in desc.cached_inputs else first.shape
        nx, ny, nz = interior
        if nx % tx or ny % ty or nz % tz:
            raise ValueError(
                f"interior {interior} not divisible by tile {desc.tile}; "
                f"use the autotuner or the JNP template"
            )
        grid = (nx // tx, ny // ty, nz // tz)

        in_specs = []
        in_arrays = []
        for name in desc.inputs:
            if name in desc.cached_inputs:
                # halo-expanded overlapping window staged into VMEM — the
                # shared-memory tile of the paper's 3DBLOCK template
                block = (
                    Element(tx + hl[0] + hh[0]),
                    Element(ty + hl[1] + hh[1]),
                    Element(tz + hl[2] + hh[2]),
                )
                index_map = lambda i, j, k: (i * tx, j * ty, k * tz)
            else:
                block = (tx, ty, tz)
                index_map = lambda i, j, k: (i, j, k)
            in_specs.append(pl.BlockSpec(block, index_map))
            in_arrays.append(arrays[name])

        out_spec = pl.BlockSpec((tx, ty, tz), lambda i, j, k: (i, j, k))
        out_names = desc.outputs
        out_shapes = [jax.ShapeDtypeStruct(interior, arrays[n].dtype
                                           if n in arrays else first.dtype)
                      for n in out_names]

        def pallas_body(*refs):
            in_refs = refs[: len(in_arrays)]
            out_refs = refs[len(in_arrays):]
            views = {}
            for name, ref in zip(desc.inputs, in_refs):
                blk = ref[...]
                cached = name in desc.cached_inputs
                views[name] = FieldView(
                    blk, hl if cached else (0, 0, 0), hh if cached else (0, 0, 0)
                )
            out = self.body(KernelContext(views, params))
            for name, ref in zip(out_names, out_refs):
                ref[...] = out[name].astype(ref.dtype)

        results = pl.pallas_call(
            pallas_body,
            grid=grid,
            in_specs=in_specs,
            out_specs=[out_spec] * len(out_names),
            out_shape=out_shapes,
            interpret=self.interpret,
        )(*in_arrays)
        if len(out_names) == 1:
            results = (results,) if not isinstance(results, (list, tuple)) else results
        return dict(zip(out_names, results))

    def __call__(self, arrays: dict[str, jnp.ndarray], **params):
        for p in self.desc.parameters:
            if p not in params:
                raise ValueError(f"missing runtime parameter {p!r}")
        if self.template == "JNP":
            return self._apply_jnp(arrays, params)
        return self._apply_pallas(arrays, params)

    def describe(self) -> str:
        """Human-readable summary of the generated kernel (the 'emitted code')."""
        d = self.desc
        hx, hy, hz = d.halo_width
        lines = [
            f"kernel {d.name} [{self.template}] tile={d.tile} stencil={d.stencil}",
            f"  grid = interior / tile ; VMEM/block ~ {d.vmem_block_bytes()} B (f32)",
        ]
        for g in d.variables:
            stage = "VMEM halo-block" if (g.cached and g.intent.is_read) else "VMEM tile"
            lines.append(
                f"  {','.join(g.names):24s} intent={g.intent.value:13s} {stage}"
            )
        for p in d.parameters:
            lines.append(f"  {p:24s} runtime parameter (static at trace)")
        return "\n".join(lines)


def generate(
    desc: StencilDescriptor,
    body: Callable[[KernelContext], dict[str, jnp.ndarray]],
    *,
    template: str | None = None,
    interpret: bool = False,
) -> GeneratedKernel:
    """Expand ``desc`` + ``body`` into an executable kernel.

    ``template=None`` uses the descriptor's TYPE (``3DBLOCK`` -> Pallas).
    ``interpret=True`` runs the Pallas template through the interpreter
    (CPU-correctness mode used by the test suite).
    """
    tmpl = template or desc.type
    if tmpl not in ("3DBLOCK", "JNP"):
        raise ValueError(f"unknown template {tmpl!r}")
    return GeneratedKernel(desc=desc, body=body, template=tmpl, interpret=interpret)


def generate_pair(desc, body):
    """(pallas_interpret, jnp_oracle) pair for validation tests."""
    return (
        generate(desc, body, template="3DBLOCK", interpret=True),
        generate(desc, body, template="JNP"),
    )
