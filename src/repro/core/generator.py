"""CaCUDA code generator, retargeted from CUDA templates to Pallas/XLA.

The paper's generator parses kernel descriptors and expands optimized CUDA
templates (shared-memory staging, 3D block tiling, axis streaming) so that
application authors write only the per-cell update.  Here the same descriptor
drives two templates:

* ``3DBLOCK`` — a ``pl.pallas_call`` whose BlockSpecs are derived from the
  descriptor: cached (``CACHED=YES``) read variables are staged HBM->VMEM as
  halo-expanded ``Element`` blocks (``tile + stencil``), outputs as bare
  ``tile`` blocks.  This is the TPU analogue of the paper's shared-memory
  tile staging; the MXU/VPU alignment rules replace CUDA warp rules.

* ``JNP`` — a fused pure-``jnp`` expansion of the same body (shifted slices
  of the padded array).  It is the oracle for kernel tests, the
  shape-polymorphic kernel used for boundary shells in overlap mode, and the
  XLA path on non-TPU backends.

The *kernel body* the user writes is a function ``body(ctx) -> dict`` where
``ctx[name]`` is a :class:`FieldView` supporting ``.at(dx, dy, dz)`` shifted
reads — the moral equivalent of the generated CUDA macros that CaCUDA emitted
for indexing shared memory.  The same body traces through both templates.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # newer JAX: per-dim element indexing via Element block dims
    from jax._src.pallas.core import Element as _Element
except ImportError:  # older JAX: whole-spec Unblocked indexing mode
    _Element = None

from repro.core.descriptor import Intent, StencilDescriptor


def element_block_spec(block_shape, index_map) -> pl.BlockSpec:
    """BlockSpec whose ``index_map`` returns *element* offsets.

    This is how the 3DBLOCK template expresses halo-expanded overlapping
    windows (tile + stencil) staged into VMEM.  Newer JAX spells it with
    ``Element`` block dims; older JAX with the ``Unblocked`` indexing mode.
    Both take element offsets from the index map, so callers are agnostic.
    """
    if _Element is not None:
        return pl.BlockSpec(tuple(_Element(b) for b in block_shape), index_map)
    return pl.BlockSpec(tuple(block_shape), index_map,
                        indexing_mode=pl.Unblocked())


class FieldView:
    """Shifted-stencil accessor over a halo-padded array (or VMEM block)."""

    __slots__ = ("arr", "halo_lo", "halo_hi")

    def __init__(self, arr, halo_lo, halo_hi):
        self.arr = arr
        self.halo_lo = halo_lo
        self.halo_hi = halo_hi

    def at(self, dx: int = 0, dy: int = 0, dz: int = 0) -> jnp.ndarray:
        off = (dx, dy, dz)
        idx = []
        for a, o in enumerate(off):
            lo, hi = self.halo_lo[a], self.halo_hi[a]
            if not -lo <= o <= hi:
                raise ValueError(
                    f"stencil offset {off} exceeds declared radii "
                    f"(lo={self.halo_lo}, hi={self.halo_hi})"
                )
            stop = self.arr.shape[a] - hi + o
            idx.append(slice(lo + o, stop))
        return self.arr[tuple(idx)]

    @property
    def c(self) -> jnp.ndarray:
        return self.at(0, 0, 0)


class KernelContext(Mapping):
    """What the kernel body sees: field views + runtime parameters."""

    def __init__(self, views: dict[str, FieldView], params: dict[str, Any]):
        self._views = views
        self._params = params

    def __getitem__(self, name: str) -> FieldView:
        return self._views[name]

    def __iter__(self):
        return iter(self._views)

    def __len__(self):
        return len(self._views)

    def param(self, name: str):
        return self._params[name]


@dataclasses.dataclass
class GeneratedKernel:
    """A compiled-from-descriptor kernel, callable on padded input arrays.

    ``__call__(arrays, **params) -> dict[name, interior array]`` where
    ``arrays[name]`` for read variables is the *padded* local array
    (interior + stencil ghosts) and outputs are interior-shaped.
    """

    desc: StencilDescriptor
    body: Callable[[KernelContext], dict[str, jnp.ndarray]]
    template: str
    interpret: bool = False

    def __post_init__(self):
        self._halo_lo = self.desc.halo_lo
        self._halo_hi = self.desc.halo_hi

    # ---- JNP template -----------------------------------------------------
    def _apply_jnp(self, arrays: dict[str, jnp.ndarray], params: dict[str, Any]):
        views = {}
        for name in self.desc.inputs:
            cached = name in self.desc.cached_inputs
            hl = self._halo_lo if cached else (0, 0, 0)
            hh = self._halo_hi if cached else (0, 0, 0)
            views[name] = FieldView(arrays[name], hl, hh)
        out = self.body(KernelContext(views, params))
        missing = set(self.desc.outputs) - set(out)
        if missing:
            raise ValueError(f"kernel body did not produce outputs: {sorted(missing)}")
        return {k: out[k] for k in self.desc.outputs}

    # ---- 3DBLOCK (Pallas) template ----------------------------------------
    def _apply_pallas(self, arrays: dict[str, jnp.ndarray],
                      params: dict[str, Any], *, batched: bool = False):
        """The 3DBLOCK expansion; ``batched`` adds a leading slot axis to
        the grid and every BlockSpec so one ``pallas_call`` advances all
        resident simulations (the ensemble-executor form)."""
        desc = self.desc
        tx, ty, tz = desc.tile
        hl, hh = self._halo_lo, self._halo_hi
        first = arrays[desc.inputs[0]]
        nslots = first.shape[0] if batched else None
        space = first.shape[1:] if batched else first.shape
        interior = tuple(
            s - (lo + hi) for s, lo, hi in zip(space, hl, hh)
        ) if desc.inputs[0] in desc.cached_inputs else space
        nx, ny, nz = interior
        if nx % tx or ny % ty or nz % tz:
            raise ValueError(
                f"interior {interior} not divisible by tile {desc.tile}; "
                f"use the autotuner or the JNP template"
            )
        grid = (nx // tx, ny // ty, nz // tz)
        if batched:
            grid = (nslots,) + grid

        def slotted(block, index_map, element):
            """Prepend the slot dim (block 1, offset = slot index)."""
            if not batched:
                return (element_block_spec(block, index_map) if element
                        else pl.BlockSpec(block, index_map))
            block = (1,) + block
            index_map = lambda b, *g, _m=index_map: (b,) + _m(*g)
            return (element_block_spec(block, index_map) if element
                    else pl.BlockSpec(block, index_map))

        in_specs = []
        in_arrays = []
        for name in desc.inputs:
            if name in desc.cached_inputs:
                # halo-expanded overlapping window staged into VMEM — the
                # shared-memory tile of the paper's 3DBLOCK template
                spec = slotted(
                    (tx + hl[0] + hh[0], ty + hl[1] + hh[1], tz + hl[2] + hh[2]),
                    lambda i, j, k: (i * tx, j * ty, k * tz), element=True)
            else:
                spec = slotted((tx, ty, tz), lambda i, j, k: (i, j, k),
                               element=False)
            in_specs.append(spec)
            in_arrays.append(arrays[name])

        out_spec = slotted((tx, ty, tz), lambda i, j, k: (i, j, k),
                           element=False)
        out_names = desc.outputs
        out_shape = ((nslots,) + interior) if batched else interior
        out_shapes = [jax.ShapeDtypeStruct(out_shape, arrays[n].dtype
                                           if n in arrays else first.dtype)
                      for n in out_names]

        def pallas_body(*refs):
            in_refs = refs[: len(in_arrays)]
            out_refs = refs[len(in_arrays):]
            views = {}
            for name, ref in zip(desc.inputs, in_refs):
                blk = ref[...]
                if batched:
                    blk = blk[0]  # drop the slot dim inside the block
                cached = name in desc.cached_inputs
                views[name] = FieldView(
                    blk, hl if cached else (0, 0, 0), hh if cached else (0, 0, 0)
                )
            out = self.body(KernelContext(views, params))
            for name, ref in zip(out_names, out_refs):
                val = out[name][None] if batched else out[name]
                ref[...] = val.astype(ref.dtype)

        results = pl.pallas_call(
            pallas_body,
            grid=grid,
            in_specs=in_specs,
            out_specs=[out_spec] * len(out_names),
            out_shape=out_shapes,
            interpret=self.interpret,
        )(*in_arrays)
        if len(out_names) == 1:
            results = (results,) if not isinstance(results, (list, tuple)) else results
        return dict(zip(out_names, results))

    # ---- batched (slot-axis) templates ------------------------------------
    def _apply_jnp_batched(self, arrays, params, batched_params):
        batched = {k: v for k, v in params.items() if k in batched_params}
        static = {k: v for k, v in params.items() if k not in batched_params}

        def fn(a, bp):
            return self._apply_jnp(a, {**static, **bp})

        return jax.vmap(fn, in_axes=(0, 0))(arrays, batched)

    def apply_batched(self, arrays: dict[str, jnp.ndarray],
                      batched_params: frozenset | tuple = (), **params):
        """Apply the kernel over a leading slot (batch) axis of every array.

        ``batched_params`` names runtime parameters that also carry the slot
        axis (per-simulation scalars, e.g. viscosity); the rest are shared.
        The JNP template vmaps the fused expansion; the 3DBLOCK template adds
        the slot axis to its grid/BlockSpecs (shared scalars only — per-slot
        parameters would need scalar prefetch, which the JNP path covers).
        """
        for p in self.desc.parameters:
            if p not in params:
                raise ValueError(f"missing runtime parameter {p!r}")
        if self.template == "JNP":
            return self._apply_jnp_batched(arrays, params,
                                           frozenset(batched_params))
        if batched_params:
            raise NotImplementedError(
                "per-slot parameters require the JNP template")
        return self._apply_pallas(arrays, params, batched=True)

    def __call__(self, arrays: dict[str, jnp.ndarray], **params):
        for p in self.desc.parameters:
            if p not in params:
                raise ValueError(f"missing runtime parameter {p!r}")
        if self.template == "JNP":
            return self._apply_jnp(arrays, params)
        return self._apply_pallas(arrays, params)

    def describe(self) -> str:
        """Human-readable summary of the generated kernel (the 'emitted code')."""
        d = self.desc
        hx, hy, hz = d.halo_width
        lines = [
            f"kernel {d.name} [{self.template}] tile={d.tile} stencil={d.stencil}",
            f"  grid = interior / tile ; VMEM/block ~ {d.vmem_block_bytes()} B (f32)",
        ]
        for g in d.variables:
            stage = "VMEM halo-block" if (g.cached and g.intent.is_read) else "VMEM tile"
            lines.append(
                f"  {','.join(g.names):24s} intent={g.intent.value:13s} {stage}"
            )
        for p in d.parameters:
            lines.append(f"  {p:24s} runtime parameter (static at trace)")
        return "\n".join(lines)


def generate(
    desc: StencilDescriptor,
    body: Callable[[KernelContext], dict[str, jnp.ndarray]],
    *,
    template: str | None = None,
    interpret: bool = False,
) -> GeneratedKernel:
    """Expand ``desc`` + ``body`` into an executable kernel.

    ``template=None`` uses the descriptor's TYPE (``3DBLOCK`` -> Pallas).
    ``interpret=True`` runs the Pallas template through the interpreter
    (CPU-correctness mode used by the test suite).
    """
    tmpl = template or desc.type
    if tmpl not in ("3DBLOCK", "JNP"):
        raise ValueError(f"unknown template {tmpl!r}")
    return GeneratedKernel(desc=desc, body=body, template=tmpl, interpret=interpret)


def generate_pair(desc, body):
    """(pallas_interpret, jnp_oracle) pair for validation tests."""
    return (
        generate(desc, body, template="3DBLOCK", interpret=True),
        generate(desc, body, template="JNP"),
    )
