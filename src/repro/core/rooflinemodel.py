"""Roofline model: per-chip hardware constants + term computation.

Used by the tile autotuner (napkin math before lowering), the dry-run
analyzer (terms from compiled HLO), the perf accounting layer
(``repro.obs.perf``), and the benchmark harness.  Chips live in a small
registry so utilization is always reported against the peaks of the
hardware that actually ran — ``resolve_chip("auto")`` picks the entry
matching ``jax.devices()`` (a CI CPU lane reports against host-class
peaks, not TPU v5e ones).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Chip:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12   # FLOP/s
    peak_flops_fp32: float = 98.5e12  # MXU fp32 ~ half bf16
    hbm_bandwidth: float = 819e9      # B/s
    hbm_bytes: float = 16e9
    ici_link_bandwidth: float = 50e9  # B/s per link (~ per direction)
    ici_links: int = 4                # 2D torus: ±x, ±y
    vmem_bytes: float = 128 * 2**20

    def peak_flops(self, dtype: str = "bf16") -> float:
        return self.peak_flops_bf16 if dtype in ("bf16", "bfloat16") else self.peak_flops_fp32


V5E = Chip()

# Deliberately round host-class numbers (a few vector cores of XLA:CPU,
# dual-channel DDR, "interconnect" = shared memory between forced host
# devices): utilization on the CPU lane is then labeled against an honest
# same-order peak instead of a TPU's — the absolute percentages stay
# rough, but ratios across runs (what the regression gate compares) are
# meaningful.
CPU_HOST = Chip(
    name="cpu-host",
    peak_flops_bf16=2e11,
    peak_flops_fp32=2e11,
    hbm_bandwidth=3e10,
    hbm_bytes=8e9,
    ici_link_bandwidth=1e10,
    ici_links=1,
    vmem_bytes=32 * 2**20,     # L2/L3-class working set
)

# GPUs only appear through jax.default_backend() == "gpu"; an A100-class
# placeholder keeps "auto" total rather than precise.
GPU_GENERIC = Chip(
    name="gpu-generic",
    peak_flops_bf16=312e12,
    peak_flops_fp32=19.5e12,
    hbm_bandwidth=1.6e12,
    hbm_bytes=40e9,
    ici_link_bandwidth=100e9,
    ici_links=2,
    vmem_bytes=40 * 2**20,
)

CHIPS: dict[str, Chip] = {
    "tpu-v5e": V5E,
    "cpu-host": CPU_HOST,
    "gpu-generic": GPU_GENERIC,
}

_PLATFORM_CHIP = {"tpu": "tpu-v5e", "cpu": "cpu-host", "gpu": "gpu-generic",
                  "cuda": "gpu-generic", "rocm": "gpu-generic"}


def resolve_chip(spec: "Chip | str | None" = "auto") -> Chip:
    """Coerce a chip spec to hardware constants.

    Accepts a :class:`Chip` (passes through), a registry name
    (``"tpu-v5e"``, ``"cpu-host"``, ...), or ``"auto"``/``None`` — which
    resolves from the platform of ``jax.devices()[0]`` so CI CPU numbers
    are never reported against TPU peaks.
    """
    if isinstance(spec, Chip):
        return spec
    if spec is None or spec == "auto":
        import jax

        platform = jax.devices()[0].platform
        return CHIPS[_PLATFORM_CHIP.get(platform, "cpu-host")]
    if spec in CHIPS:
        return CHIPS[spec]
    raise KeyError(f"unknown chip {spec!r} (have {sorted(CHIPS)} or 'auto')")


@dataclasses.dataclass
class RooflineTerms:
    """Per-device seconds for each roofline term; bottleneck = max."""

    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time: perfectly overlapped terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Fraction of roofline: 1.0 = pure compute-bound at peak."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "roofline_fraction": self.compute_fraction,
        }


def terms_from_counts(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    collective_bytes_per_device: float,
    *,
    dtype: str = "bf16",
    chip: Chip = V5E,
) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_device / chip.peak_flops(dtype),
        memory_s=hbm_bytes_per_device / chip.hbm_bandwidth,
        collective_s=collective_bytes_per_device / chip.ici_link_bandwidth,
    )


def stencil_arithmetic_intensity(
    tile: tuple[int, int, int],
    halo: tuple[int, int, int],
    flops_per_cell: float,
    nvars_read: int,
    nvars_written: int,
    itemsize: int = 4,
) -> float:
    """FLOP/byte of one halo-expanded tile — drives tile autotuning.

    Larger tiles amortize the halo re-read; this is the TPU analogue of the
    paper's shared-memory tile-size tuning.
    """
    tx, ty, tz = tile
    hx, hy, hz = halo
    cells = tx * ty * tz
    read = (tx + 2 * hx) * (ty + 2 * hy) * (tz + 2 * hz) * nvars_read
    written = cells * nvars_written
    return (cells * flops_per_cell) / ((read + written) * itemsize)
