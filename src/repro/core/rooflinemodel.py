"""TPU v5e roofline model: hardware constants + term computation.

Used by the tile autotuner (napkin math before lowering), the dry-run
analyzer (terms from compiled HLO), and the benchmark harness.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Chip:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12   # FLOP/s
    peak_flops_fp32: float = 98.5e12  # MXU fp32 ~ half bf16
    hbm_bandwidth: float = 819e9      # B/s
    hbm_bytes: float = 16e9
    ici_link_bandwidth: float = 50e9  # B/s per link (~ per direction)
    ici_links: int = 4                # 2D torus: ±x, ±y
    vmem_bytes: float = 128 * 2**20

    def peak_flops(self, dtype: str = "bf16") -> float:
        return self.peak_flops_bf16 if dtype in ("bf16", "bfloat16") else self.peak_flops_fp32


V5E = Chip()


@dataclasses.dataclass
class RooflineTerms:
    """Per-device seconds for each roofline term; bottleneck = max."""

    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time: perfectly overlapped terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Fraction of roofline: 1.0 = pure compute-bound at peak."""
        t = self.step_time_s
        return self.compute_s / t if t > 0 else 0.0

    def as_dict(self) -> dict:
        return {
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "roofline_fraction": self.compute_fraction,
        }


def terms_from_counts(
    flops_per_device: float,
    hbm_bytes_per_device: float,
    collective_bytes_per_device: float,
    *,
    dtype: str = "bf16",
    chip: Chip = V5E,
) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops_per_device / chip.peak_flops(dtype),
        memory_s=hbm_bytes_per_device / chip.hbm_bandwidth,
        collective_s=collective_bytes_per_device / chip.ici_link_bandwidth,
    )


def stencil_arithmetic_intensity(
    tile: tuple[int, int, int],
    halo: tuple[int, int, int],
    flops_per_cell: float,
    nvars_read: int,
    nvars_written: int,
    itemsize: int = 4,
) -> float:
    """FLOP/byte of one halo-expanded tile — drives tile autotuning.

    Larger tiles amortize the halo re-read; this is the TPU analogue of the
    paper's shared-memory tile-size tuning.
    """
    tx, ty, tz = tile
    hx, hy, hz = halo
    cells = tx * ty * tz
    read = (tx + 2 * hx) * (ty + 2 * hy) * (tz + 2 * hz) * nvars_read
    written = cells * nvars_written
    return (cells * flops_per_cell) / ((read + written) * itemsize)
