"""Parser for the paper's ``cacuda.ccl`` declarative kernel syntax.

The paper's code generator is built on Piraha, a parsing-expression-grammar
engine; the grammar needed for ``cacuda.ccl`` is small enough that a
recursive-descent parser is clearer and dependency-free.  The accepted syntax
is exactly Listing 1 of the paper::

    CCTK_CUDA_KERNEL UPDATE_VELOCITY
      TYPE=3DBLOCK
      STENCIL="1,1,1,1,1,1"
      TILE="16,16,16"
    {
      CCTK_CUDA_KERNEL_VARIABLE CACHED=YES INTENT=SEPARATEINOUT
      {
        vx, vy, vz
      } "VELOCITY"
      CCTK_CUDA_KERNEL_PARAMETER
      {
        density
      } "DENSITY"
    }

Multiple kernels per file are allowed; ``#`` starts a comment.
"""
from __future__ import annotations

import re

from repro.core.descriptor import Intent, StencilDescriptor, VariableGroup

_TOKEN_RE = re.compile(
    r"""
    (?P<ws>\s+|\#[^\n]*)
  | (?P<string>"[^"]*")
  | (?P<punct>[{}=,])
  | (?P<word>[A-Za-z0-9_]+)
    """,
    re.VERBOSE,
)


class CCLSyntaxError(ValueError):
    pass


def _tokenize(text: str) -> list[str]:
    toks: list[str] = []
    pos = 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if m is None:
            raise CCLSyntaxError(f"unexpected character {text[pos]!r} at offset {pos}")
        pos = m.end()
        if m.lastgroup != "ws":
            toks.append(m.group())
    return toks


class _Cursor:
    def __init__(self, toks: list[str]):
        self.toks = toks
        self.i = 0

    def peek(self) -> str | None:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> str:
        tok = self.peek()
        if tok is None:
            raise CCLSyntaxError("unexpected end of input")
        self.i += 1
        return tok

    def expect(self, tok: str) -> None:
        got = self.next()
        if got != tok:
            raise CCLSyntaxError(f"expected {tok!r}, got {got!r}")


def _int_list(s: str) -> tuple[int, ...]:
    return tuple(int(x) for x in s.strip('"').split(","))


def _parse_attrs(cur: _Cursor) -> dict[str, str]:
    """KEY=VALUE pairs until a '{'."""
    attrs: dict[str, str] = {}
    while cur.peek() != "{":
        key = cur.next()
        cur.expect("=")
        attrs[key.upper()] = cur.next()
    return attrs


def _parse_name_list(cur: _Cursor) -> tuple[str, ...]:
    cur.expect("{")
    names: list[str] = []
    while cur.peek() != "}":
        tok = cur.next()
        if tok == ",":
            continue
        names.append(tok)
    cur.expect("}")
    return tuple(names)


def _parse_kernel(cur: _Cursor) -> StencilDescriptor:
    name = cur.next()
    attrs = _parse_attrs(cur)
    cur.expect("{")
    variables: list[VariableGroup] = []
    parameters: list[str] = []
    while cur.peek() != "}":
        tok = cur.next()
        if tok == "CCTK_CUDA_KERNEL_VARIABLE":
            vattrs = _parse_attrs(cur)
            names = _parse_name_list(cur)
            group = ""
            if cur.peek() and cur.peek().startswith('"'):
                group = cur.next().strip('"')
            variables.append(
                VariableGroup(
                    names=names,
                    intent=Intent(vattrs.get("INTENT", "IN").upper()),
                    cached=vattrs.get("CACHED", "YES").upper() == "YES",
                    group=group,
                )
            )
        elif tok == "CCTK_CUDA_KERNEL_PARAMETER":
            # parameters take no attributes in the paper's listing
            names = _parse_name_list(cur)
            parameters.extend(names)
            if cur.peek() and cur.peek().startswith('"'):
                cur.next()  # group label, unused for parameters
        else:
            raise CCLSyntaxError(f"unexpected token {tok!r} inside kernel body")
    cur.expect("}")

    return StencilDescriptor(
        name=name,
        variables=tuple(variables),
        stencil=_int_list(attrs.get("STENCIL", '"1,1,1,1,1,1"')),
        tile=_int_list(attrs.get("TILE", '"8,8,128"')),
        type=attrs.get("TYPE", "3DBLOCK").strip('"'),
        parameters=tuple(parameters),
    )


def parse_ccl(text: str) -> list[StencilDescriptor]:
    """Parse a cacuda.ccl document into kernel descriptors."""
    cur = _Cursor(_tokenize(text))
    kernels: list[StencilDescriptor] = []
    while cur.peek() is not None:
        cur.expect("CCTK_CUDA_KERNEL")
        kernels.append(_parse_kernel(cur))
    return kernels


def parse_ccl_file(path: str) -> list[StencilDescriptor]:
    with open(path) as f:
        return parse_ccl(f.read())
