"""Tile autotuner: pick the 3DBLOCK tile from the roofline model.

The paper auto-tunes data distribution and relies on hand-tuned TILE choices
in the descriptors.  On TPU we can do better: enumerate hardware-aligned
candidate tiles, keep those whose staged working set fits the VMEM budget,
and maximize arithmetic intensity (halo amortization).  Deterministic — no
on-device search — so it is usable at trace time and in the dry-run.

Budgets come from the PR 7 chip registry: ``chip="auto"`` (the default)
resolves via :func:`repro.core.rooflinemodel.resolve_chip` to the hardware
that actually runs — a CI CPU lane tunes against cpu-host working-set
budgets, never against TPU v5e VMEM.

:func:`tile_for` is the memoized production entry point: the solver's hot
path (``ops.apply_kernel(tile="auto")``) resolves one choice per
``(kernel, local_shape, dtype, chip)`` signature and the choice is cached
here — alongside the per-static-signature compile cache, since the tile
feeds the executable's cache key — with hit/miss counters the test suite
asserts on.
"""
from __future__ import annotations

import dataclasses

from repro.core.descriptor import Intent, StencilDescriptor
from repro.core.rooflinemodel import Chip, resolve_chip, \
    stencil_arithmetic_intensity

# VPU lanes/sublanes: last dim multiples of 128, second-to-last multiples of 8
_LANE = 128
_SUBLANE = 8


def _divisors(n: int, step: int) -> list[int]:
    return [d for d in range(step, n + 1, step) if n % d == 0]


@dataclasses.dataclass(frozen=True)
class TileChoice:
    tile: tuple[int, int, int]
    vmem_bytes: int
    intensity: float


def choose_tile(
    desc: StencilDescriptor,
    local_shape: tuple[int, int, int],
    *,
    itemsize: int = 4,
    flops_per_cell: float = 10.0,
    chip: Chip | str | None = "auto",
    vmem_fraction: float = 0.5,
) -> TileChoice:
    """Best aligned tile dividing ``local_shape`` that fits the VMEM budget.

    ``chip`` accepts a :class:`Chip`, a registry name, or ``"auto"`` (the
    default): budgets then match the hardware running the kernel.
    """
    chip = resolve_chip(chip)
    nx, ny, nz = local_shape
    budget = chip.vmem_bytes * vmem_fraction
    nread = len(desc.inputs)
    nwrite = len(desc.outputs)
    halo = desc.halo_width

    best: TileChoice | None = None
    zc = _divisors(nz, _LANE) or [nz]
    yc = _divisors(ny, _SUBLANE) or _divisors(ny, 1)
    xc = _divisors(nx, 1)
    for tz in zc:
        for ty in yc:
            for tx in xc:
                d2 = dataclasses.replace(desc, tile=(tx, ty, tz))
                vmem = d2.vmem_block_bytes(itemsize)
                if vmem > budget:
                    continue
                ai = stencil_arithmetic_intensity(
                    (tx, ty, tz), halo, flops_per_cell, nread, nwrite, itemsize
                )
                cand = TileChoice((tx, ty, tz), vmem, ai)
                if best is None or cand.intensity > best.intensity or (
                    cand.intensity == best.intensity and vmem < best.vmem_bytes
                ):
                    best = cand
    if best is None:
        raise ValueError(
            f"no tile of {local_shape} fits VMEM budget {budget:.0f}B "
            f"for kernel {desc.name}"
        )
    return best


def tuned(desc: StencilDescriptor, local_shape, **kw) -> StencilDescriptor:
    """Return the descriptor with its TILE replaced by the tuned choice."""
    return dataclasses.replace(desc, tile=choose_tile(desc, local_shape, **kw).tile)


# -- memoized production path ------------------------------------------------
# One tuned choice per (kernel, local interior, itemsize, chip) signature.
# Both the serial driver and the simulation farm resolve through here with
# the same local interior, so they always run the same tile — a requirement
# of the farm's bitwise-parity contract with serial runs.
_TILE_CACHE: dict[tuple, TileChoice] = {}
_TILE_STATS = {"hits": 0, "misses": 0}


def tile_for(desc: StencilDescriptor, local_shape: tuple[int, int, int],
             *, itemsize: int = 4, chip: Chip | str | None = "auto",
             **kw) -> TileChoice:
    """Memoized :func:`choose_tile` keyed on the tuning signature.

    The resolved tile flows into the kernel compile-cache key
    (``ops._kernel``), so the choice is effectively cached alongside the
    compiled executable: a farm admitting new scalar variants of a seen
    shape re-reads this cache and recompiles nothing.
    """
    chip = resolve_chip(chip)
    key = (desc.name, desc.stencil, tuple(local_shape), itemsize, chip.name,
           tuple(sorted(kw.items())))
    hit = _TILE_CACHE.get(key)
    if hit is not None:
        _TILE_STATS["hits"] += 1
        return hit
    _TILE_STATS["misses"] += 1
    choice = choose_tile(desc, tuple(local_shape), itemsize=itemsize,
                         chip=chip, **kw)
    _TILE_CACHE[key] = choice
    return choice


def tile_cache_stats() -> dict:
    return {**_TILE_STATS, "entries": len(_TILE_CACHE)}


def reset_tile_cache():
    _TILE_CACHE.clear()
    _TILE_STATS.update(hits=0, misses=0)
