"""Tile autotuner: pick the 3DBLOCK tile from the roofline model.

The paper auto-tunes data distribution and relies on hand-tuned TILE choices
in the descriptors.  On TPU we can do better: enumerate hardware-aligned
candidate tiles, keep those whose staged working set fits the VMEM budget,
and maximize arithmetic intensity (halo amortization).  Deterministic — no
on-device search — so it is usable at trace time and in the dry-run.
"""
from __future__ import annotations

import dataclasses

from repro.core.descriptor import Intent, StencilDescriptor
from repro.core.rooflinemodel import V5E, Chip, stencil_arithmetic_intensity

# VPU lanes/sublanes: last dim multiples of 128, second-to-last multiples of 8
_LANE = 128
_SUBLANE = 8


def _divisors(n: int, step: int) -> list[int]:
    return [d for d in range(step, n + 1, step) if n % d == 0]


@dataclasses.dataclass(frozen=True)
class TileChoice:
    tile: tuple[int, int, int]
    vmem_bytes: int
    intensity: float


def choose_tile(
    desc: StencilDescriptor,
    local_shape: tuple[int, int, int],
    *,
    itemsize: int = 4,
    flops_per_cell: float = 10.0,
    chip: Chip = V5E,
    vmem_fraction: float = 0.5,
) -> TileChoice:
    """Best aligned tile dividing ``local_shape`` that fits the VMEM budget."""
    nx, ny, nz = local_shape
    budget = chip.vmem_bytes * vmem_fraction
    nread = len(desc.inputs)
    nwrite = len(desc.outputs)
    halo = desc.halo_width

    best: TileChoice | None = None
    zc = _divisors(nz, _LANE) or [nz]
    yc = _divisors(ny, _SUBLANE) or _divisors(ny, 1)
    xc = _divisors(nx, 1)
    for tz in zc:
        for ty in yc:
            for tx in xc:
                d2 = dataclasses.replace(desc, tile=(tx, ty, tz))
                vmem = d2.vmem_block_bytes(itemsize)
                if vmem > budget:
                    continue
                ai = stencil_arithmetic_intensity(
                    (tx, ty, tz), halo, flops_per_cell, nread, nwrite, itemsize
                )
                cand = TileChoice((tx, ty, tz), vmem, ai)
                if best is None or cand.intensity > best.intensity or (
                    cand.intensity == best.intensity and vmem < best.vmem_bytes
                ):
                    best = cand
    if best is None:
        raise ValueError(
            f"no tile of {local_shape} fits VMEM budget {budget:.0f}B "
            f"for kernel {desc.name}"
        )
    return best


def tuned(desc: StencilDescriptor, local_shape, **kw) -> StencilDescriptor:
    """Return the descriptor with its TILE replaced by the tuned choice."""
    return dataclasses.replace(desc, tile=choose_tile(desc, local_shape, **kw).tile)
