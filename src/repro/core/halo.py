"""Driver-managed ghost-zone (halo) exchange — the paper's §1.1/§2 on TPU.

In Cactus, the driver partitions the grid over MPI ranks and fills each
rank's *ghost region* from its neighbors before stencil kernels run.  On a
TPU mesh the same pattern is a ``jax.lax.ppermute`` (collective-permute —
nearest-neighbor ICI traffic) per face, executed inside ``jax.shard_map``.

Fields are stored globally **unpadded**; the halo is materialized transiently
per kernel application (``exchange_pad``), exactly mirroring the MPI
send/recv into ghost buffers.  Physical boundaries are filled by boundary
condition rules on the edge shards.

Communication/computation overlap (the paper's §1.2 headline optimization) is
provided by :func:`stencil_step_overlap`: the interior update is data-
independent of the exchanged strips, so XLA's latency-hiding scheduler can
run the ``collective-permute`` concurrently with the interior compute — the
TPU analogue of CUDA async copy + concurrent execution.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
from jax import lax

# A BC rule maps (strip, side) -> ghost strip, where ``strip`` is the
# ``width``-wide slab of interior cells adjacent to the physical boundary
# (ordered as stored, i.e. strip[0] is closest to the domain for side "lo"
# ... strip[-1] closest for side "hi").
BCRule = Callable[[jnp.ndarray, str], jnp.ndarray]


def bc_dirichlet(value: float) -> BCRule:
    def rule(strip: jnp.ndarray, side: str) -> jnp.ndarray:
        return jnp.full_like(strip, value)

    return rule


def bc_neumann() -> BCRule:
    """Zero-gradient: mirror the adjacent interior cells."""

    def rule(strip: jnp.ndarray, side: str) -> jnp.ndarray:
        return jnp.flip(strip, axis=rule.axis)  # axis injected by _pad_axis

    return rule


def bc_mirror(sign: float = -1.0) -> BCRule:
    """Reflection BC: ghost = sign * mirrored interior (no-slip walls)."""

    def rule(strip: jnp.ndarray, side: str) -> jnp.ndarray:
        return sign * jnp.flip(strip, axis=rule.axis)

    return rule


@dataclasses.dataclass(frozen=True)
class AxisSpec:
    """How one array axis is decomposed and bounded.

    ``mesh_axis=None`` means the axis is not decomposed (single shard); the
    exchange then degenerates to pure boundary-condition padding, which is
    also the single-device test path.
    """

    array_axis: int
    mesh_axis: str | None = None
    periodic: bool = False
    bc_lo: BCRule | None = None
    bc_hi: BCRule | None = None


def _shift_perm(n: int, shift: int, periodic: bool) -> list[tuple[int, int]]:
    if periodic:
        return [(i, (i + shift) % n) for i in range(n)]
    return [(i, i + shift) for i in range(n) if 0 <= i + shift < n]


def _norm_width(w) -> tuple[int, int]:
    """Width spec: int (symmetric) or (lo, hi) one-sided ghost widths."""
    if isinstance(w, int):
        return (w, w)
    lo, hi = w
    return (int(lo), int(hi))


def _pad_axis(u: jnp.ndarray, width, spec: AxisSpec) -> jnp.ndarray:
    """Fill ghosts along one axis: neighbor exchange + physical BCs."""
    wlo, whi = _norm_width(width)
    if wlo == 0 and whi == 0:
        return u
    ax = spec.array_axis
    size = u.shape[ax]
    if size < max(wlo, whi):
        raise ValueError(
            f"local extent {size} on axis {ax} smaller than halo width {(wlo, whi)}"
        )

    def apply_bc(rule: BCRule | None, strip: jnp.ndarray, side: str) -> jnp.ndarray:
        if rule is None:
            return jnp.zeros_like(strip)
        rule.axis = ax  # let flip-based rules know the axis
        return rule(strip, side)

    parts = [u]
    if wlo:
        strip_hi_lo = lax.slice_in_dim(u, size - wlo, size, axis=ax)  # sent right
        my_lo = lax.slice_in_dim(u, 0, wlo, axis=ax)
        if spec.mesh_axis is None:
            ghost_lo = strip_hi_lo if spec.periodic else apply_bc(spec.bc_lo, my_lo, "lo")
        else:
            n = lax.axis_size(spec.mesh_axis)
            ghost_lo = lax.ppermute(
                strip_hi_lo, spec.mesh_axis, _shift_perm(n, +1, spec.periodic))
            if not spec.periodic:
                idx = lax.axis_index(spec.mesh_axis)
                ghost_lo = jnp.where(idx == 0, apply_bc(spec.bc_lo, my_lo, "lo"), ghost_lo)
        parts.insert(0, ghost_lo)
    if whi:
        strip_lo_hi = lax.slice_in_dim(u, 0, whi, axis=ax)  # sent left
        my_hi = lax.slice_in_dim(u, size - whi, size, axis=ax)
        if spec.mesh_axis is None:
            ghost_hi = strip_lo_hi if spec.periodic else apply_bc(spec.bc_hi, my_hi, "hi")
        else:
            n = lax.axis_size(spec.mesh_axis)
            ghost_hi = lax.ppermute(
                strip_lo_hi, spec.mesh_axis, _shift_perm(n, -1, spec.periodic))
            if not spec.periodic:
                idx = lax.axis_index(spec.mesh_axis)
                ghost_hi = jnp.where(
                    idx == n - 1, apply_bc(spec.bc_hi, my_hi, "hi"), ghost_hi)
        parts.append(ghost_hi)
    return jnp.concatenate(parts, axis=ax) if len(parts) > 1 else u


def exchange_pad(
    u: jnp.ndarray, widths: Sequence, specs: Sequence[AxisSpec]
) -> jnp.ndarray:
    """Materialize the ghost region: pad ``u`` by ``widths[i]`` along each spec.

    Each width is an int (symmetric) or a ``(lo, hi)`` pair for one-sided
    stencils.  Must run inside ``shard_map`` when any spec names a mesh axis.
    Corner ghosts are produced correctly because later axes exchange the
    already-padded earlier axes (the standard two-phase corner trick).
    """
    if len(widths) != len(specs):
        raise ValueError("widths and specs length mismatch")
    for w, spec in zip(widths, specs):
        u = _pad_axis(u, w, spec)
    return u


def stencil_step_overlap(
    u: jnp.ndarray,
    widths: Sequence[int],
    specs: Sequence[AxisSpec],
    kernel: Callable[[jnp.ndarray], jnp.ndarray],
    kernel_deep: Callable[[jnp.ndarray], jnp.ndarray] | None = None,
    pad_fn: Callable[[jnp.ndarray], jnp.ndarray] | None = None,
) -> jnp.ndarray:
    """Apply ``kernel`` (padded -> interior) with comm/compute overlap.

    This is the paper's headline optimization (async copy + concurrent
    execution), restructured for XLA: the *deep interior* of the local block
    needs no ghost data, so ``kernel(u)`` — which has no data dependency on
    the ``ppermute`` results — runs concurrently with the exchange under
    XLA's latency-hiding scheduler.  Only thin boundary *shells* (width =
    halo, per face) are computed from the exchanged array afterwards.

    ``kernel`` must be shape-polymorphic (maps an array padded by ``widths``
    to its interior); ``kernel_deep``, if given, is used for the large
    aligned interior block (e.g. the Pallas 3DBLOCK kernel) while ``kernel``
    handles the thin shells (the fused-jnp template).

    Result equals ``kernel(exchange_pad(u, widths, specs))`` (tested); the
    difference is the dataflow graph's schedulability and ~zero recompute.
    """
    if len(widths) != len(u.shape):
        raise ValueError("widths must cover every array axis (use 0 to skip)")
    ws = [_norm_width(w) for w in widths]
    # issue the exchange FIRST; pad_fn lets callers pad packed multi-field
    # arrays with per-field BC rules (must produce ghosts matching `widths`)
    padded = pad_fn(u) if pad_fn is not None else exchange_pad(u, widths, specs)
    deep = (kernel_deep or kernel)(u)  # no ghost dependency -> overlappable

    # Assemble per axis, peeling lo/hi shells computed from the padded array.
    # Output rows [a, b) on an axis with ghosts (lo, hi) need padded rows
    # [a, b + lo + hi).
    def shell(axis: int, side: str, row_lo: list[int], row_hi: list[int]):
        """kernel() over the slab producing the (lo|hi) shell of `axis`."""
        lo, hi = ws[axis]
        sl = []
        for a, ((la, ha), na) in enumerate(zip(ws, u.shape)):
            if a < axis:
                sl.append(slice(row_lo[a], row_hi[a] + la + ha))
            elif a == axis:
                sl.append(slice(0, 2 * lo + hi) if side == "lo"
                          else slice(na - hi, na + lo + hi))
            else:
                sl.append(slice(None))  # full padded extent
        return kernel(padded[tuple(sl)])

    # innermost: deep block; wrap outwards in reverse axis order
    out = deep
    row_lo = [lo for lo, _ in ws]
    row_hi = [n - hi for n, (_, hi) in zip(u.shape, ws)]
    for axis in reversed(range(len(ws))):
        lo, hi = ws[axis]
        if lo == 0 and hi == 0:
            continue
        pieces = []
        if lo:
            pieces.append(shell(axis, "lo", row_lo, row_hi))
        pieces.append(out)
        if hi:
            pieces.append(shell(axis, "hi", row_lo, row_hi))
        row_lo[axis] = 0
        row_hi[axis] = u.shape[axis]
        out = jnp.concatenate(pieces, axis=axis) if len(pieces) > 1 else out
    return out


def make_sharded_step(
    step_local: Callable,
    mesh: jax.sharding.Mesh,
    in_specs,
    out_specs,
    check_vma: bool = False,
):
    """Wrap a per-shard step (which uses exchange_pad/ppermute) via shard_map."""
    return jax.shard_map(
        step_local, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=check_vma,
    )
