"""The driver component: domain decomposition over the TPU mesh.

In Cactus the *driver thorn* (PUGH/Carpet) sets up storage, partitions the
grid between processes, and owns inter-process communication.  Here the
driver owns the named JAX mesh, builds the halo AxisSpecs for stencil
kernels, allocates sharded fields, and wraps local step functions in
``shard_map`` so that application code (the CFD solver) is written purely in
terms of local blocks + ghost zones — as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.halo import AxisSpec, BCRule, exchange_pad


@dataclasses.dataclass(frozen=True)
class Domain:
    """Global regular grid: extent, spacing, decomposition, boundaries."""

    shape: tuple[int, int, int]
    spacing: tuple[float, float, float] = (1.0, 1.0, 1.0)
    origin: tuple[float, float, float] = (0.0, 0.0, 0.0)
    # array axis -> mesh axis name (axes absent are not decomposed)
    decomposition: Mapping[int, str] = dataclasses.field(default_factory=dict)
    periodic: tuple[bool, bool, bool] = (False, False, False)

    def pspec(self) -> P:
        parts = [self.decomposition.get(a) for a in range(3)]
        return P(*parts)


class GridDriver:
    """Owns mesh + domain; hands out shardings, axis specs, sharded steps."""

    def __init__(self, domain: Domain, mesh: jax.sharding.Mesh | None = None):
        self.domain = domain
        self.mesh = mesh
        if mesh is not None:
            for a, name in domain.decomposition.items():
                if name not in mesh.axis_names:
                    raise ValueError(f"mesh has no axis {name!r} for array axis {a}")
                if domain.shape[a] % mesh.shape[name]:
                    raise ValueError(
                        f"global extent {domain.shape[a]} on axis {a} not divisible "
                        f"by mesh axis {name!r} (size {mesh.shape[name]})"
                    )
        elif domain.decomposition:
            raise ValueError("decomposed domain requires a mesh")

    # -- geometry ------------------------------------------------------------
    @property
    def local_shape(self) -> tuple[int, int, int]:
        s = list(self.domain.shape)
        if self.mesh is not None:
            for a, name in self.domain.decomposition.items():
                s[a] //= self.mesh.shape[name]
        return tuple(s)

    def sharding(self) -> jax.sharding.Sharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.domain.pspec())

    def axis_specs(
        self,
        bc_lo: Sequence[BCRule | None] = (None, None, None),
        bc_hi: Sequence[BCRule | None] = (None, None, None),
    ) -> tuple[AxisSpec, AxisSpec, AxisSpec]:
        """Halo AxisSpecs for the three array axes (for exchange_pad)."""
        return tuple(
            AxisSpec(
                array_axis=a,
                mesh_axis=self.domain.decomposition.get(a),
                periodic=self.domain.periodic[a],
                bc_lo=bc_lo[a],
                bc_hi=bc_hi[a],
            )
            for a in range(3)
        )

    # -- storage ------------------------------------------------------------
    def coords(self) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
        """Global cell-center coordinate arrays (sharded like fields)."""
        axes = [
            self.domain.origin[a] + (np.arange(self.domain.shape[a]) + 0.5) * self.domain.spacing[a]
            for a in range(3)
        ]
        grids = jnp.meshgrid(*[jnp.asarray(x) for x in axes], indexing="ij")
        if self.mesh is not None:
            grids = [jax.device_put(g, self.sharding()) for g in grids]
        return tuple(grids)

    def allocate(self, names: Sequence[str], init=0.0, dtype=jnp.float32) -> dict:
        sh = self.sharding()
        out = {}
        for n in names:
            arr = jnp.full(self.domain.shape, init, dtype=dtype)
            out[n] = jax.device_put(arr, sh) if sh is not None else arr
        return out

    # -- execution ----------------------------------------------------------
    def sharded_step(self, step_local: Callable, n_fields_out: int | None = None):
        """Wrap a per-shard ``state -> state`` function with shard_map + jit.

        ``step_local`` sees local blocks and may call ``exchange_pad`` /
        ``stencil_step_overlap`` with this driver's axis specs.  Without a
        mesh it is jitted directly (single-device path used by unit tests).
        """
        if self.mesh is None:
            return jax.jit(step_local)
        spec = self.domain.pspec()
        mapped = jax.shard_map(
            step_local,
            mesh=self.mesh,
            in_specs=spec,
            out_specs=spec,
            check_vma=False,
        )
        return jax.jit(mapped)

    def sharded_step_tree(self, step_local: Callable, example_state,
                          example_params=None) -> Callable:
        """Like sharded_step but for a pytree state (dict of fields).

        ``example_params``: optional pytree of replicated scalars passed as a
        second *traced* argument (``step(state, params)``).  Keeping runtime
        parameters out of the closure means the compiled code is identical to
        the ensemble farm's vmapped step, where they are batched arguments.
        """
        if self.mesh is None:
            return jax.jit(step_local)
        spec = self.domain.pspec()
        tree_spec = jax.tree_util.tree_map(lambda _: spec, example_state)
        in_specs = (tree_spec,)
        if example_params is not None:
            in_specs += (jax.tree_util.tree_map(lambda _: P(), example_params),)
        mapped = jax.shard_map(
            step_local,
            mesh=self.mesh,
            in_specs=in_specs,
            out_specs=tree_spec,
            check_vma=False,
        )
        return jax.jit(mapped)
