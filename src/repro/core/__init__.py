"""repro.core — the paper's contribution: a massively data-parallel stencil
computation framework (Cactus/CaCUDA) retargeted to JAX on TPU pods.

Public surface:
  descriptor.StencilDescriptor / descriptor()  — CaCUDA kernel descriptors
  ccl.parse_ccl                                — the cacuda.ccl text syntax
  generator.generate                           — descriptor -> Pallas/JNP kernel
  halo.exchange_pad / stencil_step_overlap     — ghost-zone exchange + overlap
  driver.GridDriver / Domain                   — domain decomposition driver
  mol                                          — Method of Lines integrators
  schedule.Schedule                            — schedule tree
  autotune.choose_tile / tile_for              — roofline-driven TILE tuning
"""
from repro.core.descriptor import Intent, StencilDescriptor, VariableGroup, descriptor
from repro.core.ccl import parse_ccl, parse_ccl_file
from repro.core.generator import FieldView, GeneratedKernel, KernelContext, generate, generate_pair
from repro.core.halo import (
    AxisSpec,
    bc_dirichlet,
    bc_mirror,
    bc_neumann,
    exchange_pad,
    stencil_step_overlap,
)
from repro.core.driver import Domain, GridDriver
from repro.core import mol
from repro.core.schedule import Schedule
from repro.core.autotune import (
    choose_tile, reset_tile_cache, tile_cache_stats, tile_for, tuned,
)
from repro.core.rooflinemodel import (
    CHIPS, CPU_HOST, V5E, Chip, RooflineTerms, resolve_chip,
    terms_from_counts,
)
