"""Schedule tree — the Cactus ``schedule.ccl`` analogue.

Cactus applications register routines into named schedule bins (INITIAL,
PRESTEP, EVOL, POSTSTEP, ANALYSIS) with BEFORE/AFTER ordering constraints;
the flesh topologically sorts and runs them.  Here a schedule composes pure
state->state functions, so the whole sorted bin can be jitted as one step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

State = dict  # pytree of fields

BINS = ("INITIAL", "PRESTEP", "EVOL", "POSTSTEP", "ANALYSIS")

# Accepted spellings for callers that use Cactus's long bin names (the
# scenario registry registers into INITIAL/EVOLVE/ANALYSIS).
BIN_ALIASES = {"EVOLVE": "EVOL", "POST": "POSTSTEP", "PRE": "PRESTEP"}


def canonical_bin(bin: str) -> str:
    """Resolve a bin name or alias to its canonical BINS entry."""
    name = BIN_ALIASES.get(bin, bin)
    if name not in BINS:
        raise ScheduleError(
            f"unknown schedule bin {bin!r} (have {BINS}, "
            f"aliases {tuple(BIN_ALIASES)})")
    return name


@dataclasses.dataclass
class _Entry:
    name: str
    fn: Callable[[State], State]
    before: tuple[str, ...]
    after: tuple[str, ...]


class ScheduleError(RuntimeError):
    pass


class Schedule:
    def __init__(self):
        self._bins: dict[str, list[_Entry]] = {b: [] for b in BINS}

    def register(
        self,
        bin: str,
        name: str | None = None,
        *,
        before: tuple[str, ...] = (),
        after: tuple[str, ...] = (),
    ):
        """Decorator: schedule ``fn`` in ``bin`` with ordering constraints."""
        bin = canonical_bin(bin)

        def deco(fn):
            self._bins[bin].append(
                _Entry(name or fn.__name__, fn, tuple(before), tuple(after))
            )
            return fn

        return deco

    def _sorted(self, bin: str) -> list[_Entry]:
        entries = self._bins[canonical_bin(bin)]
        names = {e.name for e in entries}
        # build edges: after=X means X -> self ; before=Y means self -> Y
        edges: dict[str, set[str]] = {e.name: set() for e in entries}
        for e in entries:
            for a in e.after:
                if a in names:
                    edges[e.name].add(a)
            for b in e.before:
                if b in names:
                    edges[b].add(e.name)
        order: list[str] = []
        mark: dict[str, int] = {}

        def visit(n: str):
            if mark.get(n) == 1:
                raise ScheduleError(f"cycle through {n!r} in bin {bin}")
            if mark.get(n) == 2:
                return
            mark[n] = 1
            for d in sorted(edges[n]):
                visit(d)
            mark[n] = 2
            order.append(n)

        # preserve registration order among unconstrained entries
        for e in entries:
            visit(e.name)
        by_name = {e.name: e for e in entries}
        return [by_name[n] for n in order]

    def compile_bin(self, bin: str,
                    telemetry=None) -> Callable[[State], State]:
        """Compose the bin's routines (topologically sorted) into one fn.

        With an *enabled* :class:`repro.obs.Telemetry`, the composed
        runner is the Cactus-instrumented one: the bin and each routine
        get hierarchical wall-clock timer sections (fenced with
        ``block_until_ready`` so async dispatch is charged to the routine
        that issued it) plus ``jax.named_scope`` annotations so bins show
        up in XLA profiles.  Telemetry ``None``/disabled returns exactly
        the uninstrumented composition — the zero-telemetry path has no
        fences, no clocks, and identical numerics.
        """
        entries = self._sorted(bin)

        if telemetry is None or not telemetry.enabled:
            def run(state: State) -> State:
                for e in entries:
                    state = e.fn(state)
                return state

            run.__name__ = f"schedule_{bin}"
            return run

        tel, bname = telemetry, canonical_bin(bin)
        # ANALYSIS routines may return lazy device scalars (the health-
        # diagnostics contract: build on device, fetch once at the end) —
        # fencing after every entry would serialize their dispatch, so
        # the ANALYSIS bin fences once when the whole bin is composed
        per_entry_fence = bname != "ANALYSIS"

        def run(state: State) -> State:
            with tel.section(f"schedule.{bname}"):
                for e in entries:
                    with tel.section(e.name), \
                            tel.named_scope(f"{bname}.{e.name}"):
                        state = e.fn(state)
                        if per_entry_fence:
                            tel.fence(state)
                if not per_entry_fence:
                    tel.fence(state)
            return state

        run.__name__ = f"schedule_{bname}"
        return run

    def names(self, bin: str) -> list[str]:
        return [e.name for e in self._sorted(bin)]
