"""CaCUDA kernel descriptors, adapted for TPU/Pallas.

The paper's CaCUDA abstraction declares, per kernel: the grid variables it
touches, their intents, whether they are staged through fast on-chip memory
(CACHED), the stencil radii, and the tile shape.  The descriptor is consumed
by :mod:`repro.core.generator`, which expands it against an optimized template
(the TPU analogue of the paper's ``3DBLOCK`` CUDA template) into a
``pl.pallas_call`` with explicit BlockSpec VMEM tiling, or into a fused
pure-``jnp`` kernel (the oracle / XLA path).

Descriptors can be constructed programmatically or parsed from the paper's
``cacuda.ccl`` declarative syntax (see :mod:`repro.core.ccl`).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Sequence


class Intent(enum.Enum):
    """Variable intents, exactly the CaCUDA set."""

    IN = "IN"
    OUT = "OUT"
    INOUT = "INOUT"
    # Read from one buffer, write to a separate one (double buffering).  The
    # generated kernel reads ``name`` and produces a fresh output array.
    SEPARATEINOUT = "SEPARATEINOUT"

    @property
    def is_read(self) -> bool:
        return self in (Intent.IN, Intent.INOUT, Intent.SEPARATEINOUT)

    @property
    def is_write(self) -> bool:
        return self in (Intent.OUT, Intent.INOUT, Intent.SEPARATEINOUT)


@dataclasses.dataclass(frozen=True)
class VariableGroup:
    """A CCTK_CUDA_KERNEL_VARIABLE block: names sharing intent/caching."""

    names: tuple[str, ...]
    intent: Intent
    cached: bool = True
    group: str = ""

    def __post_init__(self):
        if not self.names:
            raise ValueError("variable group must name at least one variable")


@dataclasses.dataclass(frozen=True)
class StencilDescriptor:
    """The CaCUDA kernel descriptor (Listing 1 of the paper).

    ``stencil`` is the 6-tuple of one-sided radii ``(xl, xh, yl, yh, zl, zh)``
    exactly as in the paper's ``STENCIL="1,1,1,1,1,1"``.  ``tile`` is the
    output tile owned by one kernel instance (the paper's ``TILE="16,16,16"``).
    On TPU the tile maps to the Pallas BlockSpec block shape; cached inputs are
    staged into VMEM as ``tile + stencil`` halo-expanded blocks.

    ``parameters`` declares the kernel's runtime scalars — and, for the
    3DBLOCK template, the *scalar-prefetch contract*: declaration order is
    the column order of the generated kernel's scalar table
    (:meth:`param_index`), the operand that carries array-valued/per-slot
    parameter values (``pltpu.PrefetchScalarGridSpec`` on real TPU, a
    leading row-indexed operand in interpret mode).  Values passed as
    Python scalars are instead baked as trace-time literals.
    """

    name: str
    variables: tuple[VariableGroup, ...]
    stencil: tuple[int, int, int, int, int, int] = (1, 1, 1, 1, 1, 1)
    tile: tuple[int, ...] = (8, 8, 128)
    type: str = "3DBLOCK"
    parameters: tuple[str, ...] = ()

    def __post_init__(self):
        if len(self.stencil) != 6:
            raise ValueError(f"stencil must have 6 radii, got {self.stencil}")
        if any(r < 0 for r in self.stencil):
            raise ValueError(f"stencil radii must be >= 0: {self.stencil}")
        if self.type not in ("3DBLOCK", "JNP"):
            raise ValueError(f"unknown kernel type {self.type!r}")
        if len(self.tile) != 3:
            raise ValueError(f"tile must be rank 3, got {self.tile}")
        seen: set[str] = set()
        for g in self.variables:
            for n in g.names:
                if n in seen:
                    raise ValueError(f"variable {n!r} declared twice")
                seen.add(n)

    # -- derived geometry ---------------------------------------------------
    @property
    def halo_lo(self) -> tuple[int, int, int]:
        return (self.stencil[0], self.stencil[2], self.stencil[4])

    @property
    def halo_hi(self) -> tuple[int, int, int]:
        return (self.stencil[1], self.stencil[3], self.stencil[5])

    @property
    def halo_width(self) -> tuple[int, int, int]:
        """Symmetric ghost width needed per axis (max of lo/hi radius)."""
        return tuple(
            max(self.stencil[2 * a], self.stencil[2 * a + 1]) for a in range(3)
        )

    # -- variable classification --------------------------------------------
    def _names(self, pred) -> tuple[str, ...]:
        out: list[str] = []
        for g in self.variables:
            if pred(g):
                out.extend(g.names)
        return tuple(out)

    @property
    def inputs(self) -> tuple[str, ...]:
        """All variables the kernel reads, in declaration order."""
        return self._names(lambda g: g.intent.is_read)

    @property
    def outputs(self) -> tuple[str, ...]:
        """All variables the kernel writes, in declaration order."""
        return self._names(lambda g: g.intent.is_write)

    @property
    def cached_inputs(self) -> frozenset[str]:
        return frozenset(self._names(lambda g: g.intent.is_read and g.cached))

    def group_of(self, name: str) -> VariableGroup:
        for g in self.variables:
            if name in g.names:
                return g
        raise KeyError(name)

    def param_index(self, name: str) -> int:
        """Scalar-table column of parameter ``name`` (declaration order).

        The generator packs array-valued runtime parameters into the
        3DBLOCK scalar-prefetch table in exactly this order, restricted to
        the parameters that are array-valued at the call site.
        """
        try:
            return self.parameters.index(name)
        except ValueError:
            raise KeyError(
                f"{name!r} is not a declared parameter of kernel "
                f"{self.name} (have {self.parameters})") from None

    def vmem_block_bytes(self, itemsize: int = 4) -> int:
        """VMEM working-set estimate for one kernel instance.

        Mirrors the shared-memory budget check the CaCUDA templates perform:
        each cached input costs a halo-expanded tile, outputs and uncached
        inputs cost a bare tile.
        """
        hx, hy, hz = self.halo_width
        tx, ty, tz = self.tile
        halo_block = (tx + 2 * hx) * (ty + 2 * hy) * (tz + 2 * hz)
        tile_block = tx * ty * tz
        total = 0
        for g in self.variables:
            per_var = halo_block if (g.cached and g.intent.is_read) else tile_block
            if g.intent is Intent.SEPARATEINOUT:
                per_var += tile_block  # separate output buffer
            total += per_var * len(g.names)
        return total * itemsize


def descriptor(
    name: str,
    *,
    stencil: Sequence[int] = (1, 1, 1, 1, 1, 1),
    tile: Sequence[int] = (8, 8, 128),
    type: str = "3DBLOCK",
    parameters: Sequence[str] = (),
    **groups: dict,
) -> StencilDescriptor:
    """Convenience constructor.

    Example::

        update_velocity = descriptor(
            "UPDATE_VELOCITY", stencil=(1, 1, 1, 1, 1, 1), tile=(16, 16, 16),
            velocity=dict(names=("vx", "vy", "vz"), intent="SEPARATEINOUT"),
            pressure=dict(names=("p",), intent="IN"),
            parameters=("density",),
        )
    """
    vgs = []
    for gname, spec in groups.items():
        vgs.append(
            VariableGroup(
                names=tuple(spec["names"]),
                intent=Intent(spec.get("intent", "IN")),
                cached=bool(spec.get("cached", True)),
                group=gname.upper(),
            )
        )
    return StencilDescriptor(
        name=name,
        variables=tuple(vgs),
        stencil=tuple(stencil),
        tile=tuple(tile),
        type=type,
        parameters=tuple(parameters),
    )
