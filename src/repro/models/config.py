"""ModelConfig — the single dataclass describing every assigned architecture.

One instance fully determines parameter shapes, block composition and the
train/prefill/decode computation.  ``src/repro/configs/<arch>.py`` files are
thin constructors of this dataclass with the published dimensions.

``ShardCfg`` carries the distribution decisions (mesh + axis names + per-
family strategy knobs) into the model code.  ``ShardCfg(None)`` is the
single-device path used by smoke tests: every collective degenerates to a
no-op and no sharding constraint is emitted.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    qkv_bias: bool = False
    rope_theta: float = 500_000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # --- MoE ---------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    router_z_coef: float = 1e-3

    # --- SSM / Mamba2 (hybrid) ----------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_width: int = 4
    ssm_chunk: int = 128
    attn_every: int = 0            # hybrid: shared attn+MLP block period

    # --- xLSTM ---------------------------------------------------------------
    slstm_indices: tuple = ()      # layer indices that are sLSTM (rest mLSTM)
    mlstm_proj_factor: float = 2.0
    # BPTT unroll: recurrent weights stay VMEM-resident across k unrolled
    # steps (divides the per-step weight re-read by k) at k× HLO body size
    slstm_unroll: int = 1

    # --- modality stubs -------------------------------------------------------
    num_codebooks: int = 0         # audio (musicgen): EnCodec streams
    num_prefix_tokens: int = 0     # vlm (paligemma): SigLIP patch embeddings

    # --- numerics / memory -----------------------------------------------------
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: str = "block"           # none | block | dots
    q_chunk: int = 1024
    # kv_chunk = full sequence: ONE kv pass per q-chunk, so the online-
    # softmax accumulator never round-trips HBM as a scan carry — the same
    # HBM traffic as the Pallas flash kernel (which holds acc in VMEM and
    # streams kv in hardware-sized blocks).  Finite values model kernels
    # that spill the accumulator; used in ablations.
    kv_chunk: int = 1 << 30
    scan_layers: bool = True

    # --- capability flags -------------------------------------------------------
    subquadratic: bool = False     # can run long_500k decode (O(1)/O(S) state)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ---- derived dims -----------------------------------------------------
    @property
    def d_inner(self) -> int:               # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def conv_dim(self) -> int:              # channels fed through causal conv
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    def param_count(self) -> int:
        """Total parameters (used for 6·N·D MODEL_FLOPS and docs)."""
        import math

        import repro.models.model as m

        shapes = jax.eval_shape(lambda: m.init_params(self, jax.random.PRNGKey(0)))
        return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        import math

        total = self.param_count()
        if not self.num_experts:
            return total
        import repro.models.model as m

        shapes = jax.eval_shape(lambda: m.init_params(self, jax.random.PRNGKey(0)))
        expert_total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
            if any("experts" == getattr(k, "key", None) for k in path):
                expert_total += math.prod(leaf.shape)
        active_frac = (self.num_experts_per_tok / self.num_experts)
        return total - expert_total + int(expert_total * active_frac)


@dataclasses.dataclass(frozen=True)
class ShardCfg:
    """Distribution decisions, threaded through the model code.

    mesh=None is the single-device path (tests): constraints and collectives
    are skipped.  ``dp``/``tp`` are mesh-axis names (dp may be a tuple, e.g.
    ("pod", "data") on the multi-pod mesh).  ``moe_mode``:
      local — no collectives, every device computes all experts (tests)
      tp    — experts sharded over ``tp``; activations replicated on ``tp``;
              combine via psum (baseline; collective = 1 all-reduce/layer)
      a2a   — tokens sequence-sharded over ``tp``; all_to_all dispatch
              (optimized; see EXPERIMENTS.md §Perf)
    ``ssm_sp``: sequence-shard Mamba2/conv over ``tp`` with halo exchange +
    chunk-state relay (the paper's ghost-zone pattern on the sequence axis).
    """

    mesh: Any = None
    dp: Any = "data"
    tp: str | None = "model"
    moe_mode: str = "local"
    ssm_sp: bool = False
    batch_sharded: bool = True     # False when global batch < |dp| (long_500k)
    replicate_params: bool = False # small models: pure DP, one grad AR/step

    @property
    def dp_axes(self) -> tuple:
        return self.dp if isinstance(self.dp, tuple) else (self.dp,)

    def act_spec(self, *trailing):
        """PartitionSpec for (B, ...) activations."""
        from jax.sharding import PartitionSpec as P

        if self.mesh is None:
            return None
        batch = self.dp if self.batch_sharded else None
        return P(batch, *trailing)

    def constrain(self, x, spec):
        if self.mesh is None or spec is None:
            return x
        from jax.sharding import NamedSharding

        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def constrain_act(self, x, *trailing):
        return self.constrain(x, self.act_spec(*trailing))


LOCAL = ShardCfg(mesh=None, moe_mode="local")
