"""Mamba2 (SSD) block — chunked state-space dual for train/prefill, O(1)
recurrent decode, and a sequence-parallel mode built on the paper's
ghost-zone machinery.

The chunked SSD algorithm is itself the paper's 3DBLOCK idea on the time
axis: tile the sequence into chunks, compute the quadratic intra-chunk part
locally (the "interior"), and pass a tiny carried state between chunks (the
"ghost cell").  Sequence parallelism (``ssm_sp``) extends the same pattern
across mesh shards: the causal-conv halo is exchanged with
``core.halo.exchange_pad`` (width = conv_width - 1, one-sided) and the SSD
chunk state is relayed with an all-gather + local prefix product — a 1-cell
ghost region on the sequence axis.

Layout: x (B, S, G, R, P) with H = G·R heads (G = ``ssm_groups`` share one
(B̄, C̄) pair, as in Mamba2).  All SSD math runs in fp32.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import layers
from repro.models.config import ModelConfig, ShardCfg


class Mamba2State(NamedTuple):
    conv: jnp.ndarray   # (B, W-1, conv_dim)
    ssm: jnp.ndarray    # (B, G, R, N, P) fp32


def init_mamba2(key, cfg: ModelConfig) -> dict:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    w = cfg.conv_width
    dt = cfg.param_dtype
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in_proj = 2 * di + 2 * g * n + h
    # dt_bias: inverse-softplus of dt ~ U[1e-3, 1e-1] (mamba2 init)
    u = jax.random.uniform(k4, (h,), jnp.float32, np.log(1e-3), np.log(1e-1))
    dt0 = jnp.exp(u)
    return {
        "in_proj": layers.init_dense(k1, d, d_in_proj, dt),
        "conv_w": layers.truncated_normal(k2, (w, cfg.conv_dim),
                                          1.0 / np.sqrt(w), jnp.float32),
        "conv_b": jnp.zeros((cfg.conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt0 + jnp.log(-jnp.expm1(-dt0)),  # softplus^-1(dt0)
        "norm": layers.init_rmsnorm(di),
        "out_proj": layers.init_dense(k3, di, d, dt),
    }


def _causal_conv(xbc: jnp.ndarray, conv_w, conv_b,
                 prefix: jnp.ndarray | None) -> jnp.ndarray:
    """Depthwise causal conv, width W.  ``prefix``: (B, W-1, C) carried
    context (zeros at sequence start; previous shard's tail under SP)."""
    b, s, c = xbc.shape
    w = conv_w.shape[0]
    if prefix is None:
        prefix = jnp.zeros((b, w - 1, c), xbc.dtype)
    xpad = jnp.concatenate([prefix.astype(xbc.dtype), xbc], axis=1)
    y = sum(xpad[:, i:i + s].astype(jnp.float32) * conv_w[i]
            for i in range(w))
    return jax.nn.silu(y + conv_b).astype(xbc.dtype)


def _split_proj(cfg: ModelConfig, zxbcdt: jnp.ndarray):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + cfg.conv_dim]
    dt = zxbcdt[..., di + cfg.conv_dim:]
    assert dt.shape[-1] == h
    return z, xbc, dt


def _gr(cfg: ModelConfig):
    g = cfg.ssm_groups
    return g, cfg.ssm_heads // g


def ssd_chunked(x, dt, a, b_, c_, chunk: int, init_state=None,
                states_only: bool = False):
    """Chunked SSD.  x (B,S,G,R,P) fp32, dt (B,S,G,R) fp32 (post-softplus),
    a (G,R) fp32 (negative), b_/c_ (B,S,G,N) fp32.

    Returns (y (B,S,G,R,P), final_state (B,G,R,N,P)).  With
    ``states_only=True`` skips the quadratic intra-chunk work and returns
    (None, final_state) — the cheap first pass of the sequence-parallel
    scheme.
    """
    return ssd_core(x, dt * a, dt, b_, c_, chunk, init_state, states_only)


def ssd_core(x, log_decay, in_scale, b_, c_, chunk: int, init_state=None,
             states_only: bool = False):
    """Chunked linear-recurrence core shared by Mamba2 SSD and mLSTM.

    State recursion  S_t = exp(log_decay_t) S_{t-1} + in_scale_t B_t (x) x_t
    with output      y_t = C_t^T S_t.
    Mamba2 passes (log_decay, in_scale) = (dt*a, dt); the mLSTM passes
    (log sigmoid(f̃), exp(ĩ)) — decay and input gate decoupled.
    Shapes: x (B,S,G,R,P), log_decay/in_scale (B,S,G,R), b_/c_ (B,S,G,N).
    """
    bsz, s, g, r, p = x.shape
    n = b_.shape[-1]
    l = min(chunk, s)
    pad = (-s) % l
    if pad:
        x, log_decay, in_scale, b_, c_ = (
            jnp.pad(v, [(0, 0), (0, pad)] + [(0, 0)] * (v.ndim - 2))
            for v in (x, log_decay, in_scale, b_, c_))
    nc = (s + pad) // l
    xc = x.reshape(bsz, nc, l, g, r, p)
    dtc = in_scale.reshape(bsz, nc, l, g, r)
    bc = b_.reshape(bsz, nc, l, g, n)
    cc = c_.reshape(bsz, nc, l, g, n)

    da = log_decay.reshape(bsz, nc, l, g, r)       # (B,nc,L,G,R)  negative
    cum = jnp.cumsum(da, axis=2)                   # within-chunk cumulative

    # chunk-end states: S_c = sum_j exp(cum_end - cum_j) dt_j B_j (x) x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :, :] - cum)        # (B,nc,L,G,R)
    sc = jnp.einsum("bclgn,bclgr,bclgrp->bcgrnp",
                    bc, decay_to_end * dtc, xc)               # (B,nc,G,R,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1])                      # (B,nc,G,R)

    s0 = (jnp.zeros((bsz, g, r, n, p), jnp.float32)
          if init_state is None else init_state.astype(jnp.float32))

    def body(carry, inp):
        st, dec = inp
        nxt = carry * dec[..., None, None] + st
        return nxt, carry                                      # emit incoming

    final, s_in = lax.scan(
        body, s0, (jnp.moveaxis(sc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    if states_only:
        return None, final
    s_in = jnp.moveaxis(s_in, 0, 1)                            # (B,nc,G,R,N,P)

    # intra-chunk quadratic + inter-chunk contribution: ships as the Pallas
    # SSD kernel on TPU (kernels/ssd.py — the (L,L) decay/score temporaries
    # stay in VMEM); the tagged jnp path below is the same math and is
    # priced as that kernel by the roofline (DESIGN.md §6).
    with jax.named_scope("__kernel__ssd"):
        from repro.kernels.ssd import ssd_intra_reference

        y = ssd_intra_reference(xc, da, dtc, bc, cc, s_in)
    y = y.reshape(bsz, nc * l, g, r, p)[:, :s]
    return y, final


def _prep_ssm_inputs(params, cfg: ModelConfig, xbc, dt_raw):
    """Split conv output into (x, B̄, C̄) and finalize dt/A in fp32."""
    di, g, n = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    g_, r = _gr(cfg)
    xs = xbc[..., :di]
    b_ = xbc[..., di:di + g * n].reshape(*xbc.shape[:-1], g, n)
    c_ = xbc[..., di + g * n:].reshape(*xbc.shape[:-1], g, n)
    shp = xs.shape[:-1]
    xs = xs.reshape(*shp, g_, r, cfg.ssm_head_dim).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"]).reshape(*shp, g_, r)
    a = -jnp.exp(params["A_log"]).reshape(g_, r)
    return xs, b_.astype(jnp.float32), c_.astype(jnp.float32), dt, a


def _finish(params, cfg: ModelConfig, y, xs, z):
    """D-skip, gated RMSNorm, out-projection."""
    d_skip = params["D"].reshape(*_gr(cfg))
    y = y + d_skip[..., None] * xs
    y = y.reshape(*y.shape[:-3], cfg.d_inner)
    y = layers.rmsnorm(params["norm"], y.astype(cfg.compute_dtype),
                       cfg.norm_eps) * jax.nn.silu(z.astype(cfg.compute_dtype))
    return layers.dense(params["out_proj"], y)


def mamba2_seq(params, cfg: ModelConfig, x: jnp.ndarray,
               shard: ShardCfg, state: Mamba2State | None = None,
               return_state: bool = False):
    """Full-sequence Mamba2: train / prefill.  x (B, S, d_model)."""
    if shard.ssm_sp and shard.mesh is not None and shard.tp:
        return _mamba2_seq_sp(params, cfg, x, shard, return_state)
    zxbcdt = layers.dense(params["in_proj"], x.astype(cfg.compute_dtype))
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    conv_prefix = state.conv if state is not None else None
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_prefix)
    xs, b_, c_, dt, a = _prep_ssm_inputs(params, cfg, xbc, dt_raw)
    init = state.ssm if state is not None else None
    y, final = ssd_chunked(xs, dt, a, b_, c_, cfg.ssm_chunk, init)
    out = _finish(params, cfg, y, xs, z)
    if not return_state:
        return out, None
    # conv state must be the PRE-activation xbc tail; recompute cheaply
    zx2 = _split_proj(cfg, zxbcdt)[1]
    w = cfg.conv_width
    new_state = Mamba2State(conv=zx2[:, -(w - 1):, :].astype(jnp.float32),
                            ssm=final)
    return out, new_state


def _mamba2_seq_sp(params, cfg: ModelConfig, x, shard: ShardCfg,
                   return_state: bool):
    """Sequence-parallel Mamba2 over the ``tp`` axis.

    Halo pattern (the paper's ghost region, on the sequence axis):
      conv:  (W-1)-wide one-sided halo via core.halo.exchange_pad/ppermute
      SSD:   two-pass chunk-state relay — local states_only pass, all-gather
             of (chunk_decay_total, final_state), local prefix product gives
             each shard its incoming state, then the exact local SSD.
    """
    from jax.sharding import PartitionSpec as P

    from repro.core.halo import AxisSpec, exchange_pad

    mesh, tp = shard.mesh, shard.tp
    batch = shard.dp if shard.batch_sharded else None
    w = cfg.conv_width

    def local(x_l, prm):
        zxbcdt = layers.dense(prm["in_proj"], x_l.astype(cfg.compute_dtype))
        z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
        spec = AxisSpec(array_axis=1, mesh_axis=tp)  # zero-BC == causal start
        xbc_h = exchange_pad(xbc, [(w - 1, 0)], [spec])
        prefix, xbc_body = xbc_h[:, :w - 1], xbc_h[:, w - 1:]
        xbc_c = _causal_conv(xbc_body, prm["conv_w"], prm["conv_b"], prefix)
        xs, b_, c_, dt, a = _prep_ssm_inputs(prm, cfg, xbc_c, dt_raw)

        # pass 1: local chunk states only (cheap — no quadratic part)
        _, final_local = ssd_chunked(xs, dt, a, b_, c_, cfg.ssm_chunk,
                                     states_only=True)
        decay_total = jnp.exp(jnp.sum(dt * a, axis=1))          # (B,G,R)
        finals = lax.all_gather(final_local, tp)                # (ep,B,G,R,N,P)
        decays = lax.all_gather(decay_total, tp)                # (ep,B,G,R)
        ep = finals.shape[0]
        rank = lax.axis_index(tp)

        def prefix_body(carry, i):
            s_acc = carry
            emit = s_acc
            s_acc = s_acc * decays[i][..., None, None] + finals[i]
            return s_acc, emit

        _, s_in_all = lax.scan(prefix_body,
                               jnp.zeros_like(final_local), jnp.arange(ep))
        s0 = s_in_all[rank]                                     # (B,G,R,N,P)

        # pass 2: exact local SSD with the relayed incoming state
        y, final = ssd_chunked(xs, dt, a, b_, c_, cfg.ssm_chunk, s0)
        out = _finish(prm, cfg, y, xs, z)
        return out

    pspec = jax.tree.map(lambda _: P(), params)
    fn = jax.shard_map(local, mesh=mesh,
                       in_specs=(P(batch, tp, None), pspec),
                       out_specs=P(batch, tp, None), check_vma=False)
    return fn(x, params), None


def mamba2_init_state(cfg: ModelConfig, batch: int) -> Mamba2State:
    g, r = _gr(cfg)
    return Mamba2State(
        conv=jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_dim), jnp.float32),
        ssm=jnp.zeros((batch, g, r, cfg.ssm_state, cfg.ssm_head_dim),
                      jnp.float32))


def mamba2_step(params, cfg: ModelConfig, x_t: jnp.ndarray,
                state: Mamba2State):
    """Single-token decode.  x_t (B, d_model) -> (y (B, d_model), state)."""
    zxbcdt = layers.dense(params["in_proj"], x_t.astype(cfg.compute_dtype))
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    # rolling conv window
    window = jnp.concatenate(
        [state.conv, xbc[:, None, :].astype(jnp.float32)], axis=1)  # (B,W,C)
    y_conv = jnp.einsum("bwc,wc->bc", window, params["conv_w"]) + params["conv_b"]
    xbc_c = jax.nn.silu(y_conv).astype(cfg.compute_dtype)
    new_conv = window[:, 1:]

    xs, b_, c_, dt, a = _prep_ssm_inputs(params, cfg, xbc_c, dt_raw)
    # xs (B,G,R,P), b_/c_ (B,G,N), dt (B,G,R)
    da = jnp.exp(dt * a)                                        # (B,G,R)
    upd = jnp.einsum("bgn,bgr,bgrp->bgrnp", b_, dt, xs)
    ssm = state.ssm * da[..., None, None] + upd
    y = jnp.einsum("bgn,bgrnp->bgrp", c_, ssm)
    out = _finish(params, cfg, y, xs, z)
    return out, Mamba2State(conv=new_conv, ssm=ssm)


def mamba2_flops_per_token(cfg: ModelConfig, seq: int) -> int:
    """Approx fwd FLOPs/token of one block (projections dominate)."""
    d, di = cfg.d_model, cfg.d_inner
    proj = 2 * d * (2 * di + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads)
    out = 2 * di * d
    ssd = 2 * cfg.ssm_chunk * (cfg.ssm_heads * cfg.ssm_head_dim
                               + cfg.ssm_groups * cfg.ssm_state * cfg.ssm_head_dim)
    return proj + out + ssd
