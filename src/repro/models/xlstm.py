"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, parallelizable)
and sLSTM (scalar memory, strictly sequential) — the ``ssm`` family arch.

The mLSTM is a linear-attention-style recurrence

    C_t = f_t C_{t-1} + i_t k_t v_t^T      n_t = f_t n_{t-1} + i_t k_t
    h_t = o_t * (C_t q_t) / max(|n_t q_t|, 1)

which is exactly the :func:`repro.models.mamba2.ssd_core` recursion with
decoupled (decay, input-scale) = (sigmoid(f̃), exp(ĩ)); the normalizer n is
carried as one extra value-channel (x augmented with a ones column), so
train/prefill reuse the chunked SSD machinery — the paper's "tile the time
axis, carry a tiny ghost state between chunks" pattern.  Stabilization
deviation from the reference implementation is documented in DESIGN.md:
the input-gate logit is soft-capped (±8) instead of carrying the running
max-state m_t through the parallel form; fp32 throughout the cell.

The sLSTM has per-head block-diagonal *recurrent* gate connections
(gates at t see h_{t-1}), which makes it non-parallelizable over time —
implemented as a ``lax.scan`` (the paper's own characterization).

Decode is O(1)-state for both cell types, so xlstm-125m runs ``long_500k``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import layers
from repro.models.config import ModelConfig, ShardCfg
from repro.models.mamba2 import ssd_core

_GATE_CAP = 8.0  # soft-cap on the mLSTM input-gate logit (stabilization)


class MLSTMState(NamedTuple):
    c: jnp.ndarray      # (B, H, N, P) matrix memory, fp32
    n: jnp.ndarray      # (B, H, N)    normalizer, fp32
    conv: jnp.ndarray   # (B, W-1, d_inner) causal-conv tail, fp32


class SLSTMState(NamedTuple):
    c: jnp.ndarray      # (B, H, P) cell, fp32
    n: jnp.ndarray      # (B, H, P) normalizer, fp32
    m: jnp.ndarray      # (B, H, P) max-state (log-space stabilizer), fp32
    h: jnp.ndarray      # (B, H, P) previous output (recurrent input), fp32


def _dims(cfg: ModelConfig):
    h = cfg.num_heads
    d_inner = int(cfg.d_model * cfg.mlstm_proj_factor)
    d_inner = -(-d_inner // h) * h                    # round up to head mult
    return h, d_inner, d_inner // h


# ---------------------------------------------------------------------------
# mLSTM block: ln -> up-proj (u, z) -> conv(u) -> q,k | v -> mLSTM cell
#              -> group-norm -> *silu(z) -> down-proj -> residual
# ---------------------------------------------------------------------------
def init_mlstm(key, cfg: ModelConfig) -> dict:
    h, di, p = _dims(cfg)
    dt = cfg.param_dtype
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    w = cfg.conv_width
    return {
        "up": layers.init_dense(k1, cfg.d_model, 2 * di, dt),
        "conv_w": layers.truncated_normal(k2, (w, di), 1.0 / np.sqrt(w),
                                          jnp.float32),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "wq": layers.init_dense(k3, di, di, dt),
        "wk": layers.init_dense(k4, di, di, dt),
        "wv": layers.init_dense(k5, di, di, dt),
        # gates are scalar per head, computed from the block input
        "wi": layers.init_dense(jax.random.fold_in(key, 7), cfg.d_model, h,
                                jnp.float32),
        "wf": layers.init_dense(jax.random.fold_in(key, 8), cfg.d_model, h,
                                jnp.float32),
        # forget bias init positive => long memory at init (paper's init)
        "bf": jnp.full((h,), 3.0, jnp.float32),
        "bi": jnp.full((h,), -2.0, jnp.float32),
        "norm": layers.init_rmsnorm(di),
        "down": layers.init_dense(k6, di, cfg.d_model, dt,
                                  stddev=1.0 / np.sqrt(di)),
    }


def mlstm_init_state(cfg: ModelConfig, batch: int) -> MLSTMState:
    h, di, p = _dims(cfg)
    return MLSTMState(
        c=jnp.zeros((batch, h, p, p + 1), jnp.float32),
        n=jnp.zeros((batch, h, p), jnp.float32),   # kept for API symmetry
        conv=jnp.zeros((batch, cfg.conv_width - 1, di), jnp.float32))


def _mlstm_gates(params, x, h):
    """(B,S,H) fp32 (log_decay, in_scale) from the block input."""
    xf = x.astype(jnp.float32)
    f_logit = layers.dense(params["wf"], xf) + params["bf"]
    i_logit = layers.dense(params["wi"], xf) + params["bi"]
    i_logit = _GATE_CAP * jnp.tanh(i_logit / _GATE_CAP)      # soft-cap
    log_decay = jax.nn.log_sigmoid(f_logit)                  # (B,S,H) <= 0
    in_scale = jnp.exp(i_logit)
    return log_decay, in_scale


def _mlstm_qkv(params, cfg, x, conv_prefix):
    """Up-project, causal-conv, and split into q,k,v,z.  Returns fp32 qkv."""
    h, di, p = _dims(cfg)
    up = layers.dense(params["up"], x.astype(cfg.compute_dtype))
    u, z = up[..., :di], up[..., di:]
    w = cfg.conv_width
    b, s, _ = u.shape
    if conv_prefix is None:
        conv_prefix = jnp.zeros((b, w - 1, di), u.dtype)
    upad = jnp.concatenate([conv_prefix.astype(u.dtype), u], axis=1)
    uc = sum(upad[:, i:i + s].astype(jnp.float32) * params["conv_w"][i]
             for i in range(w))
    uc = jax.nn.silu(uc + params["conv_b"])
    q = layers.dense(params["wq"], uc.astype(cfg.compute_dtype))
    k = layers.dense(params["wk"], uc.astype(cfg.compute_dtype))
    v = layers.dense(params["wv"], u)                        # v skips the conv
    split = lambda t: t.reshape(b, s, h, p).astype(jnp.float32)
    new_prefix = jnp.concatenate([conv_prefix.astype(u.dtype), u],
                                 axis=1)[:, -(w - 1):]
    return split(q), split(k), split(v), z, new_prefix


def _mlstm_out(params, cfg, hval, z, x):
    h, di, p = _dims(cfg)
    b, s = hval.shape[:2]
    y = hval.reshape(b, s, di).astype(cfg.compute_dtype)
    y = layers.rmsnorm(params["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    return x + layers.dense(params["down"], y).astype(x.dtype)


def mlstm_seq(params, cfg: ModelConfig, x, state: MLSTMState | None = None,
              return_state: bool = False):
    """Full-sequence mLSTM block (train/prefill).  x (B,S,d_model)."""
    h, di, p = _dims(cfg)
    b, s, _ = x.shape
    q, k, v, z, new_conv = _mlstm_qkv(
        params, cfg, x, state.conv if state is not None else None)
    log_decay, in_scale = _mlstm_gates(params, x, h)
    # ssd_core layout: G=H heads, R=1; n_t carried as extra value channel
    scale = 1.0 / np.sqrt(p)
    v_aug = jnp.concatenate([v, jnp.ones((b, s, h, 1), jnp.float32)], -1)
    y_aug, final = ssd_core(
        v_aug[:, :, :, None, :],                 # x    (B,S,H,1,P+1)
        log_decay[..., None],                    # (B,S,H,1)
        in_scale[..., None],
        k * scale,                               # b_ (B,S,H,N)
        q,                                       # c_ (B,S,H,N)
        cfg.ssm_chunk,
        state.c[:, :, None] if state is not None else None)
    y_aug = y_aug[:, :, :, 0]                    # (B,S,H,P+1)
    hval = y_aug[..., :p] / jnp.maximum(jnp.abs(y_aug[..., p:]), 1.0)
    out = _mlstm_out(params, cfg, hval, z, x)
    if not return_state:
        return out, None
    return out, MLSTMState(c=final[:, :, 0], n=final[:, :, 0, :, p],
                           conv=new_conv.astype(jnp.float32))


def mlstm_step(params, cfg: ModelConfig, x_t, state: MLSTMState):
    """Single-token decode.  x_t (B, d_model) -> (y, state).  O(1) state."""
    h, di, p = _dims(cfg)
    b = x_t.shape[0]
    x1 = x_t[:, None, :]
    up = layers.dense(params["up"], x1.astype(cfg.compute_dtype))
    u, z = up[..., :di], up[..., di:]
    window = jnp.concatenate([state.conv, u.astype(jnp.float32)], axis=1)
    uc = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, params["conv_w"])
                     + params["conv_b"])[:, None]
    q = layers.dense(params["wq"], uc.astype(cfg.compute_dtype))
    k = layers.dense(params["wk"], uc.astype(cfg.compute_dtype))
    v = layers.dense(params["wv"], u)
    rs = lambda t: t.reshape(b, h, p).astype(jnp.float32)
    q, k, v = rs(q), rs(k), rs(v)
    log_decay, in_scale = _mlstm_gates(params, x1, h)
    f = jnp.exp(log_decay[:, 0])[..., None, None]            # (B,H,1,1)
    i = in_scale[:, 0][..., None, None]
    k = k / np.sqrt(p)
    v_aug = jnp.concatenate([v, jnp.ones((b, h, 1), jnp.float32)], -1)
    c_new = f * state.c + i * k[..., :, None] * v_aug[..., None, :]
    y_aug = jnp.einsum("bhn,bhnp->bhp", q, c_new)            # (B,H,P+1)
    hval = y_aug[..., :p] / jnp.maximum(jnp.abs(y_aug[..., p:]), 1.0)
    out = _mlstm_out(params, cfg, hval[:, None], z, x1)[:, 0]
    new_state = MLSTMState(c=c_new, n=c_new[..., p], conv=window[:, 1:])
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM block: ln -> sLSTM cell (recurrent gates, scan) -> group norm
#              -> GeLU MLP (pf 4/3) -> residual
# ---------------------------------------------------------------------------
def init_slstm(key, cfg: ModelConfig) -> dict:
    h = cfg.num_heads
    p = cfg.d_model // h
    dt = cfg.param_dtype
    ks = jax.random.split(key, 8)
    d_up = int(cfg.d_model * 4 / 3)
    gate = lambda k: layers.init_dense(k, cfg.d_model, h * p, jnp.float32)
    # recurrent block-diagonal per-head matrices (H, P, P)
    rec = lambda k: layers.truncated_normal(k, (h, p, p), 1.0 / np.sqrt(p),
                                            jnp.float32)
    return {
        "wz": gate(ks[0]), "wi": gate(ks[1]), "wf": gate(ks[2]), "wo": gate(ks[3]),
        "rz": rec(ks[4]), "ri": rec(jax.random.fold_in(key, 10)),
        "rf": rec(jax.random.fold_in(key, 11)), "ro": rec(jax.random.fold_in(key, 12)),
        "bz": jnp.zeros((h, p), jnp.float32),
        "bi": jnp.zeros((h, p), jnp.float32),
        "bf": jnp.full((h, p), 3.0, jnp.float32),
        "bo": jnp.zeros((h, p), jnp.float32),
        "norm": layers.init_rmsnorm(cfg.d_model),
        "mlp_up": layers.init_dense(ks[5], cfg.d_model, d_up, dt),
        "mlp_down": layers.init_dense(ks[6], d_up, cfg.d_model, dt,
                                      stddev=1.0 / np.sqrt(d_up)),
    }


def slstm_init_state(cfg: ModelConfig, batch: int) -> SLSTMState:
    h = cfg.num_heads
    p = cfg.d_model // h
    z = jnp.zeros((batch, h, p), jnp.float32)
    return SLSTMState(c=z, n=z, m=jnp.full_like(z, -1e30), h=z)


def _slstm_cell(params, gates_x, state: SLSTMState):
    """One stabilized sLSTM step.  gates_x: dict of (B,H,P) pre-activations
    from the input path; recurrent contributions added here."""
    hp = state.h
    rec = lambda r: jnp.einsum("bhp,hpq->bhq", hp, params[r])
    z = jnp.tanh(gates_x["z"] + rec("rz") + params["bz"])
    i_log = gates_x["i"] + rec("ri") + params["bi"]
    f_log = jax.nn.log_sigmoid(gates_x["f"] + rec("rf") + params["bf"])
    o = jax.nn.sigmoid(gates_x["o"] + rec("ro") + params["bo"])
    m_new = jnp.maximum(f_log + state.m, i_log)
    i_s = jnp.exp(i_log - m_new)
    f_s = jnp.exp(f_log + state.m - m_new)
    c = f_s * state.c + i_s * z
    n = f_s * state.n + i_s
    h_new = o * c / jnp.maximum(n, 1.0)
    return SLSTMState(c=c, n=n, m=m_new, h=h_new)


def _slstm_gates_x(params, cfg, x):
    """Input-path gate pre-activations: (B,S,H,P) each, fp32."""
    h = cfg.num_heads
    p = cfg.d_model // h
    xf = x.astype(jnp.float32)
    g = lambda w: layers.dense(params[w], xf).reshape(*x.shape[:-1], h, p)
    return {"z": g("wz"), "i": g("wi"), "f": g("wf"), "o": g("wo")}


def slstm_seq(params, cfg: ModelConfig, x, state: SLSTMState | None = None,
              return_state: bool = False):
    """Full-sequence sLSTM (sequential lax.scan over time).  x (B,S,d)."""
    b, s, d = x.shape
    h = cfg.num_heads
    p = d // h
    gx = _slstm_gates_x(params, cfg, x)
    s0 = state if state is not None else slstm_init_state(cfg, b)

    def body(st, g_t):
        st = _slstm_cell(params, g_t, st)
        return st, st.h

    gx_t = jax.tree.map(lambda t: jnp.moveaxis(t, 1, 0), gx)   # (S,B,H,P)
    final, hs = lax.scan(body, s0, gx_t,
                         unroll=min(cfg.slstm_unroll, s))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, s, d)                # (B,S,d)
    out = _slstm_mlp(params, cfg, y, x)
    return out, (final if return_state else None)


def slstm_step(params, cfg: ModelConfig, x_t, state: SLSTMState):
    """Single-token decode.  x_t (B, d)."""
    gx = _slstm_gates_x(params, cfg, x_t[:, None])
    st = _slstm_cell(params, jax.tree.map(lambda t: t[:, 0], gx), state)
    y = st.h.reshape(x_t.shape)
    return _slstm_mlp(params, cfg, y[:, None], x_t[:, None])[:, 0], st


def _slstm_mlp(params, cfg, y, x):
    y = layers.rmsnorm(params["norm"], y.astype(cfg.compute_dtype),
                       cfg.norm_eps)
    y = layers.dense(params["mlp_down"],
                     jax.nn.gelu(layers.dense(params["mlp_up"], y)))
    return x + y.astype(x.dtype)


def xlstm_flops_per_token(cfg: ModelConfig) -> int:
    """Approx fwd FLOPs/token of one mLSTM block (projections dominate)."""
    h, di, p = _dims(cfg)
    d = cfg.d_model
    proj = 2 * d * 2 * di + 3 * 2 * di * di + 2 * di * d
    cell = 2 * cfg.ssm_chunk * h * p * (p + 1) * 2
    return proj + cell
