"""Modality frontend STUBS for the [audio]/[vlm] archs.

Per the assignment, these archs specify the transformer BACKBONE only; the
modality frontend provides *precomputed* embeddings:

  musicgen-large — EnCodec frame embeddings: the real system runs a frozen
    EnCodec encoder producing K codebook streams; the backbone consumes the
    summed codebook embeddings per frame.  Stub: deterministic pseudo-
    embeddings (B, S, d_model) from a hashed PRNG — shape/dtype-exact.

  paligemma-3b — SigLIP patch embeddings: a 224px/14 ViT gives 256 patch
    tokens projected to d_model.  Stub: (B, 256, d_model) pseudo-embeddings
    consumed as a bidirectional prefix (prefix-LM masking).

Both stubs are pure functions of (key, shape) so the data pipeline, smoke
tests and benchmarks produce identical streams; ``input_specs()`` in
``launch/dryrun.py`` passes ShapeDtypeStructs of the same shapes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def frame_embeddings(key, cfg: ModelConfig, batch: int, seq: int,
                     dtype=None) -> jnp.ndarray:
    """musicgen: precomputed EnCodec frame embeddings (B, S, d_model)."""
    dtype = dtype or cfg.compute_dtype
    return (jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32)
            / jnp.sqrt(cfg.d_model)).astype(dtype)


def patch_embeddings(key, cfg: ModelConfig, batch: int,
                     dtype=None) -> jnp.ndarray:
    """paligemma: precomputed SigLIP patch embeddings (B, P, d_model)."""
    dtype = dtype or cfg.compute_dtype
    p = cfg.num_prefix_tokens
    return (jax.random.normal(key, (batch, p, cfg.d_model), jnp.float32)
            / jnp.sqrt(cfg.d_model)).astype(dtype)
