"""Unified model API over all assigned architectures.

    params = init_params(cfg, key)
    loss, metrics       = loss_fn(params, cfg, batch, shard)        # train
    logits, caches      = prefill(params, cfg, batch, caches, shard)
    logits, caches      = decode_step(params, cfg, token, caches, t, shard)

``batch`` is a dict:
    tokens        (B, S)  int32    — all archs except pure-embeds input
    targets       (B, S)  int32    — train only (next-token labels)
    embeds        (B, S, d) bf16   — musicgen stub frame embeddings (optional
                                     replacement for tokens)
    prefix_embeds (B, P, d) bf16   — paligemma stub patch embeddings

The loss is computed **chunked over the sequence** (``loss_chunk``
positions at a time, rematerialized): the (B, S, V) logits tensor for the
151k–256k vocabularies never exists in full — only (B, chunk, V) transients
(sharded over ``tp`` on V).  This is what lets the 256k-vocab archs fit the
dry-run memory budget.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import layers, transformer
from repro.models.attention import MaskSpec
from repro.models.config import LOCAL, ModelConfig, ShardCfg

LOSS_CHUNK = 512


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_params(cfg: ModelConfig, key) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "embed": layers.init_embedding(k1, cfg.vocab_size, cfg.d_model,
                                       cfg.param_dtype),
        "stack": transformer.init_layer_stack(k2, cfg),
        "final_norm": layers.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = layers.init_dense(
            k3, cfg.d_model, cfg.vocab_size, cfg.param_dtype)
    return p


def _unembed_w(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"]["table"].T       # (d, V)
    return params["unembed"]["w"]


# ---------------------------------------------------------------------------
# input embedding (modality-aware)
# ---------------------------------------------------------------------------
def embed_inputs(params, cfg: ModelConfig, batch: dict, shard: ShardCfg):
    """Returns (x (B, S_total, d), prefix_len)."""
    if "embeds" in batch:                       # musicgen stub frontend
        x = batch["embeds"].astype(cfg.compute_dtype)
    else:
        x = layers.embed(params["embed"], batch["tokens"], cfg.compute_dtype)
    prefix_len = 0
    if "prefix_embeds" in batch:                # paligemma stub frontend
        pre = batch["prefix_embeds"].astype(cfg.compute_dtype)
        x = jnp.concatenate([pre, x], axis=1)
        prefix_len = pre.shape[1]
    return shard.constrain_act(x, None, None), prefix_len


# ---------------------------------------------------------------------------
# chunked cross-entropy head
# ---------------------------------------------------------------------------
def _xent_chunk(w, hx, tgt, shard: ShardCfg):
    """hx (B,c,d), tgt (B,c) -> (sum_loss, sum_correct)."""
    logits = hx @ w                                       # (B,c,V)
    logits = shard.constrain(logits, shard.act_spec(None, shard.tp))
    logits = logits.astype(jnp.float32)
    m = logits.max(axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(tgt, logits.shape[-1], dtype=jnp.float32)
    tgt_logit = jnp.einsum("bcv,bcv->bc", logits, onehot)
    valid = (tgt >= 0).astype(jnp.float32)
    loss = jnp.sum((lse - tgt_logit) * valid)
    correct = jnp.sum((jnp.argmax(logits, -1) == tgt) * valid)
    return loss, correct, jnp.sum(valid)


def chunked_xent(params, cfg: ModelConfig, hidden, targets,
                 shard: ShardCfg, chunk: int = LOSS_CHUNK):
    """Mean next-token CE over (B,S,d) hidden vs (B,S) targets.

    targets < 0 are masked out.  Chunked + rematerialized over S so the
    full-vocab logits tensor never materializes.
    """
    b, s, d = hidden.shape
    w = _unembed_w(params, cfg).astype(cfg.compute_dtype)
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    nc = (s + pad) // chunk
    hc = hidden.reshape(b, nc, chunk, d)
    tc = targets.reshape(b, nc, chunk)

    body = jax.checkpoint(
        lambda carry, xs: (jax.tree.map(
            jnp.add, carry, _xent_chunk(w, xs[0], xs[1], shard)), None))
    z = jnp.zeros((), jnp.float32)
    (loss, correct, count), _ = lax.scan(
        body, (z, z, z), (jnp.moveaxis(hc, 1, 0), jnp.moveaxis(tc, 1, 0)))
    count = jnp.maximum(count, 1.0)
    return loss / count, correct / count


# ---------------------------------------------------------------------------
# train / prefill / decode
# ---------------------------------------------------------------------------
def loss_fn(params, cfg: ModelConfig, batch: dict, shard: ShardCfg = LOCAL):
    x, prefix_len = embed_inputs(params, cfg, batch, shard)
    s_total = x.shape[1]
    positions = jnp.arange(s_total)
    mask = MaskSpec(causal=True, prefix_len=prefix_len)
    x, _, met = transformer.stack_seq(params["stack"], cfg, x, shard,
                                      positions=positions, mask=mask,
                                      mode="train")
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if prefix_len:
        x = x[:, prefix_len:]                  # loss over the text segment
    loss, acc = chunked_xent(params, cfg, x, batch["targets"], shard)
    total = loss + met.moe_aux + met.moe_z
    return total, {"ce": loss, "acc": acc, "moe_aux": met.moe_aux,
                   "moe_z": met.moe_z, "moe_dropped": met.moe_dropped}


def prefill(params, cfg: ModelConfig, batch: dict, caches, shard: ShardCfg):
    """Fill caches from a prompt; returns (last-position logits, caches)."""
    x, prefix_len = embed_inputs(params, cfg, batch, shard)
    positions = jnp.arange(x.shape[1])
    mask = MaskSpec(causal=True, prefix_len=prefix_len)
    x, caches, _ = transformer.stack_seq(params["stack"], cfg, x, shard,
                                         positions=positions, mask=mask,
                                         caches=caches, mode="prefill")
    x = layers.rmsnorm(params["final_norm"], x[:, -1:], cfg.norm_eps)
    logits = x @ _unembed_w(params, cfg).astype(x.dtype)
    return logits, caches


def decode_step(params, cfg: ModelConfig, token, caches, cache_len,
                shard: ShardCfg = LOCAL):
    """One decode step.  token (B, 1) int32; cache_len: filled length."""
    x = layers.embed(params["embed"], token, cfg.compute_dtype)
    x = shard.constrain_act(x, None, None)
    x, caches = transformer.stack_step(params["stack"], cfg, x, shard,
                                       caches=caches, cache_len=cache_len)
    x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = x @ _unembed_w(params, cfg).astype(x.dtype)
    logits = shard.constrain(logits, shard.act_spec(None, shard.tp))
    return logits, caches


init_caches = transformer.init_caches


def model_flops_per_step(cfg: ModelConfig, batch: int, seq: int,
                         training: bool = True) -> float:
    """MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (fwd)."""
    n = cfg.active_param_count()
    mult = 6 if training else 2
    return float(mult) * n * batch * seq
