"""Mixture-of-Experts layer: top-k router + capacity-bucketed sort dispatch.

The paper's driver idea (decompose, exchange only what neighbors need,
overlap) maps onto MoE as expert-parallel dispatch.  Three modes (ShardCfg):

* ``local`` — every device holds and computes all experts (single-device
  tests and the pjit fallback; no collectives).
* ``tp``    — baseline EP: experts sharded over the ``tp`` mesh axis,
  activations replicated on ``tp`` (they already are, in the FSDP x TP
  layout), each rank dispatches to its local expert slice and the outputs
  combine with ONE ``psum`` per layer — the same collective cost as a TP
  MLP.  This is the paper-faithful "driver" scheme: no token leaves its
  data shard; only the reduced output is exchanged.
* ``a2a``   — optimized EP (see EXPERIMENTS.md §Perf): tokens are split
  over ``tp`` before routing, dispatch buffers travel through
  ``all_to_all`` to their expert's rank and back.  Moves k/|tp| of the
  psum's bytes when k < |tp|.

Dispatch uses the sort-based capacity bucket trick (argsort by expert id,
prefix-offset gather) — O(T·k log) with NO (T, E, C) one-hot tensor, so it
lowers at the kimi-k2 scale (384 experts, 1M tokens).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import layers
from repro.models.config import ModelConfig, ShardCfg


class MoEMetrics(NamedTuple):
    aux_loss: jnp.ndarray          # load-balance loss (scalar)
    z_loss: jnp.ndarray            # router logit z-loss (scalar)
    dropped_frac: jnp.ndarray      # fraction of assignments over capacity


def init_moe(key, cfg: ModelConfig) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    std_in, std_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    dt = cfg.param_dtype
    p = {
        "router": layers.truncated_normal(kr, (d, e), std_in, jnp.float32),
        "experts": {
            "gate": layers.truncated_normal(kg, (e, d, f), std_in, dt),
            "up": layers.truncated_normal(ku, (e, d, f), std_in, dt),
            "down": layers.truncated_normal(kd, (e, f, d), std_out, dt),
        },
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.init_mlp(
            ks, d, f * cfg.num_shared_experts, dt)
    return p


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    cap = int(np.ceil(tokens * cfg.num_experts_per_tok / cfg.num_experts
                      * cfg.capacity_factor))
    return max(8, -(-cap // 8) * 8)  # round up to 8 for TPU-friendly shapes


def _dispatch_indices(expert_ids: jnp.ndarray, num_experts: int, capacity: int):
    """Sort-based capacity bucketing.

    expert_ids: (A,) int32 in [0, num_experts]  (== num_experts -> masked out)
    Returns (assign, valid): for each buffer slot (e, c) flattened to (E*C,),
    ``assign`` indexes into the (A,) assignment list, ``valid`` marks live
    slots.  Assignments beyond an expert's capacity are dropped (standard
    GShard semantics; the dropped fraction is reported in metrics).
    """
    a = expert_ids.shape[0]
    order = jnp.argsort(expert_ids)                     # stable; masked at end
    counts = jnp.bincount(expert_ids, length=num_experts + 1)[:num_experts]
    starts = jnp.concatenate(
        [jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    slot_e = jnp.repeat(jnp.arange(num_experts), capacity)
    slot_c = jnp.tile(jnp.arange(capacity), num_experts)
    valid = slot_c < counts[slot_e]
    src = jnp.where(valid, starts[slot_e] + slot_c, 0)
    assign = order[jnp.minimum(src, a - 1)]
    dropped = 1.0 - jnp.sum(jnp.minimum(counts, capacity)) / jnp.maximum(
        jnp.sum(counts), 1)
    return assign, valid, dropped


def _expert_ffn(experts: dict, xin: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    """Grouped SwiGLU over the dispatch buffer xin (E, C, d)."""
    dt = compute_dtype
    g = jnp.einsum("ecd,edf->ecf", xin, experts["gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xin, experts["up"].astype(dt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                      experts["down"].astype(dt))


def _route(params, cfg: ModelConfig, x2d: jnp.ndarray):
    """Router: returns (top-k ids (T,k), renormalized gates (T,k), metrics)."""
    logits = x2d.astype(jnp.float32) @ params["router"]        # (T, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = lax.top_k(probs, cfg.num_experts_per_tok)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # load-balance: E * sum_e mean(one_hot assignments)_e * mean(probs)_e
    pe = probs.mean(axis=0)                                     # (E,)
    fe = jnp.zeros_like(pe).at[ids.reshape(-1)].add(
        1.0 / (ids.size))                                       # (E,)
    aux = cfg.num_experts * jnp.sum(fe * pe) * cfg.router_aux_coef
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * cfg.router_z_coef
    return ids.astype(jnp.int32), gates.astype(jnp.float32), aux, z


def _local_moe(params, cfg: ModelConfig, x2d, ids, gates,
               e_start: int, e_count: int, capacity: int, compute_dtype):
    """Dispatch/compute/combine for the expert slice [e_start, e_start+e_count).

    x2d (T, d) -> (T, d) partial output (only this slice's contribution).
    """
    t, d = x2d.shape
    k = cfg.num_experts_per_tok
    flat_ids = ids.reshape(-1)                                   # (T*k,)
    local = flat_ids - e_start
    local = jnp.where((local >= 0) & (local < e_count), local, e_count)
    assign, valid, dropped = _dispatch_indices(local, e_count, capacity)
    tok = assign // k                                            # (e_count*C,)
    xin = x2d[tok] * valid[:, None].astype(x2d.dtype)
    xin = xin.reshape(e_count, capacity, d)
    y = _expert_ffn(_slice_experts(params["experts"], e_start, e_count),
                    xin, compute_dtype)
    y = y.reshape(e_count * capacity, d)
    w = gates.reshape(-1)[assign] * valid                        # (E*C,)
    out = jnp.zeros((t, d), y.dtype).at[tok].add(y * w[:, None].astype(y.dtype))
    return out, dropped


def _slice_experts(experts: dict, e_start: int, e_count: int) -> dict:
    if e_start == 0 and e_count == experts["gate"].shape[0]:
        return experts
    return {k: lax.dynamic_slice_in_dim(v, e_start, e_count, axis=0)
            for k, v in experts.items()}


def moe_apply(params, cfg: ModelConfig, x: jnp.ndarray,
              shard: ShardCfg) -> tuple[jnp.ndarray, MoEMetrics]:
    """x: (B, S, d) -> (B, S, d).  Shared experts (if any) are always-on."""
    b, s, d = x.shape
    cdt = cfg.compute_dtype
    x2d = x.reshape(b * s, d)

    if shard.mesh is not None and shard.moe_mode == "a2a" and shard.tp:
        out, aux, z, dropped = _a2a_moe(params, cfg, x, shard)
    else:
        ids, gates, aux, z = _route(params, cfg, x2d)
        if shard.mesh is None or shard.moe_mode == "local" or shard.tp is None:
            cap = _capacity(b * s, cfg)
            out, dropped = _local_moe(params, cfg, x2d, ids, gates,
                                      0, cfg.num_experts, cap, cdt)
        elif shard.moe_mode == "tp":
            out, dropped = _tp_moe(params, cfg, x2d, ids, gates, shard)
        else:
            raise ValueError(f"unknown moe_mode {shard.moe_mode}")

    if "shared" in params:
        out = out + layers.mlp(params["shared"], x2d.astype(cdt))
    return out.reshape(b, s, d).astype(x.dtype), MoEMetrics(aux, z, dropped)


# ---------------------------------------------------------------------------
# tp mode: experts sharded over `tp`; activations replicated on `tp`;
# each rank computes its slice, combine = one psum (baseline EP).
# ---------------------------------------------------------------------------
def _tp_moe(params, cfg: ModelConfig, x2d, ids, gates, shard: ShardCfg):
    mesh = shard.mesh
    tp = shard.tp
    ep = mesh.shape[tp]
    assert cfg.num_experts % ep == 0, (cfg.num_experts, ep)
    e_local = cfg.num_experts // ep
    t = x2d.shape[0]
    t_local = t // int(np.prod([mesh.shape[a] for a in shard.dp_axes])) \
        if shard.batch_sharded else t
    cap = _capacity(t_local, cfg)

    from jax.sharding import PartitionSpec as P

    batch = shard.dp if shard.batch_sharded else None

    def local_shifted(x2d_l, ids_l, gates_l, experts_l):
        rank = lax.axis_index(tp)
        e_start = rank * e_local
        lids = ids_l - e_start
        lids_flat = jnp.where((lids >= 0) & (lids < e_local), lids, e_local)
        assign, valid, dropped = _dispatch_indices(
            lids_flat.reshape(-1), e_local, cap)
        tok = assign // cfg.num_experts_per_tok
        xin = x2d_l[tok] * valid[:, None].astype(x2d_l.dtype)
        xin = xin.reshape(e_local, cap, x2d_l.shape[-1])
        y = _expert_ffn(experts_l, xin, cfg.compute_dtype)
        y = y.reshape(e_local * cap, -1)
        w = gates_l.reshape(-1)[assign] * valid
        out = jnp.zeros_like(x2d_l, dtype=y.dtype).at[tok].add(
            y * w[:, None].astype(y.dtype))
        return lax.psum(out, tp), lax.pmean(dropped, tp)

    fn = jax.shard_map(
        local_shifted, mesh=mesh,
        in_specs=(P(batch, None), P(batch, None), P(batch, None),
                  jax.tree.map(lambda _: P(tp, None, None), params["experts"])),
        out_specs=(P(batch, None), P()),
        check_vma=False)
    return fn(x2d, ids, gates, params["experts"])


# ---------------------------------------------------------------------------
# a2a mode: tokens split over `tp` as well (sequence split of the flat token
# list); dispatch buffers all_to_all to the owning rank and back.  Each rank
# routes only its token slice; collective volume ~ 2 * T_local*k/ep * d per
# direction vs psum's 2 * T_local * d.
# ---------------------------------------------------------------------------
def _a2a_moe(params, cfg: ModelConfig, x, shard: ShardCfg):
    mesh = shard.mesh
    tp = shard.tp
    ep = mesh.shape[tp]
    assert cfg.num_experts % ep == 0
    e_local = cfg.num_experts // ep
    b, s, d = x.shape
    k = cfg.num_experts_per_tok

    from jax.sharding import PartitionSpec as P

    batch = shard.dp if shard.batch_sharded else None

    def local(x_l, experts_l, router):
        # x_l: (b_l, s_l, d) — sequence additionally split over tp
        bl, sl, _ = x_l.shape
        tl = bl * sl
        x2d = x_l.reshape(tl, d)
        ids, gates, aux, z = _route({"router": router}, cfg, x2d)
        # capacity per (source rank, dest expert)
        cap = _capacity(tl, cfg)
        flat = ids.reshape(-1)
        assign, valid, dropped = _dispatch_indices(flat, cfg.num_experts, cap)
        tok = assign // k
        xin = (x2d[tok] * valid[:, None].astype(x2d.dtype))
        xin = xin.reshape(ep, e_local * cap, d)       # group by dest rank
        xin = lax.all_to_all(xin, tp, split_axis=0, concat_axis=0, tiled=False)
        # now (ep, e_local*cap, d): source-rank major, my experts only
        y = _expert_ffn(experts_l,
                        xin.reshape(ep * e_local, cap, d).reshape(
                            ep, e_local, cap, d).transpose(1, 0, 2, 3)
                        .reshape(e_local, ep * cap, d),
                        cfg.compute_dtype)
        y = y.reshape(e_local, ep, cap, d).transpose(1, 0, 2, 3) \
             .reshape(ep, e_local * cap, d)
        y = lax.all_to_all(y, tp, split_axis=0, concat_axis=0, tiled=False)
        y = y.reshape(cfg.num_experts * cap, d)
        w = gates.reshape(-1)[assign] * valid
        out = jnp.zeros((tl, d), y.dtype).at[tok].add(
            y * w[:, None].astype(y.dtype))
        return (out.reshape(bl, sl, d), aux[None], z[None], dropped[None])

    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(batch, tp, None),
                  jax.tree.map(lambda _: P(tp, None, None), params["experts"]),
                  P(None, None)),
        out_specs=(P(batch, tp, None), P(tp), P(tp), P(tp)),
        check_vma=False)
    out, aux, z, dropped = fn(x, params["experts"], params["router"])
    return (out.reshape(b * s, d), aux.mean(), z.mean(), dropped.mean())


def moe_flops_per_token(cfg: ModelConfig) -> int:
    """Forward FLOPs/token of one MoE layer (routed active + shared)."""
    active = cfg.num_experts_per_tok + cfg.num_shared_experts
    return 2 * 3 * cfg.d_model * cfg.d_ff * active + 2 * cfg.d_model * cfg.num_experts
