"""The decoder layer stack: family-specific block composition under one
scan-over-layers driver.

Families (ModelConfig.family):
  dense / audio / vlm  — GQA attention + SwiGLU MLP (pre-norm residual)
  moe                  — GQA attention + top-k MoE FFN (+ shared experts)
  hybrid               — Mamba2 blocks with ONE weight-tied shared
                         attention+MLP block applied every ``attn_every``
                         layers (zamba2)
  ssm                  — xLSTM: mLSTM blocks with sLSTM at
                         ``slstm_indices`` (unrolled; 12 layers)

``scan_layers=True`` stacks identical layers into one ``lax.scan`` body —
one lowered layer in the HLO (compile time at 94 layers) and the natural
attachment point for ``jax.checkpoint`` (remat policy).  Heterogeneous
stacks (hybrid flags, xlstm mixing) handle per-layer structure with
``lax.cond`` flags / unrolled composition.

Caches (decode/prefill) are stacked along a leading layer axis so they
thread through the same scan as xs/ys.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models import layers, mamba2, moe, xlstm
from repro.models.attention import MaskSpec
from repro.models.blocks import KVCache, attention, init_attention
from repro.models.config import ModelConfig, ShardCfg


class StackMetrics(NamedTuple):
    moe_aux: jnp.ndarray
    moe_z: jnp.ndarray
    moe_dropped: jnp.ndarray

    @staticmethod
    def zero():
        z = jnp.zeros((), jnp.float32)
        return StackMetrics(z, z, z)


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)          # "block": save only layer boundaries


def n_attn_layers(cfg: ModelConfig) -> int:
    """hybrid: number of applications of the shared attention block.

    The stack is organized as ``G = L / attn_every`` uniform groups
    [shared-attn, mamba × attn_every] so the layer scan has no data-
    dependent control flow (exact cost attribution in the lowered HLO).
    """
    if cfg.family != "hybrid" or not cfg.attn_every:
        return 0
    assert cfg.num_layers % cfg.attn_every == 0, (
        "hybrid stacks require attn_every | num_layers", cfg.num_layers,
        cfg.attn_every)
    return cfg.num_layers // cfg.attn_every


def _group(cfg: ModelConfig, tree):
    """Reshape stacked (L, ...) leaves to (G, attn_every, ...)."""
    g = n_attn_layers(cfg)
    return jax.tree.map(
        lambda t: t.reshape(g, cfg.attn_every, *t.shape[1:]), tree)


def _ungroup(tree):
    return jax.tree.map(
        lambda t: t.reshape(t.shape[0] * t.shape[1], *t.shape[2:]), tree)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_attn_block(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.init_rmsnorm(cfg.d_model),
        "attn": init_attention(k1, cfg.d_model, cfg.num_heads,
                               cfg.num_kv_heads, cfg.head_dim,
                               cfg.param_dtype, cfg.qkv_bias),
        "ln2": layers.init_rmsnorm(cfg.d_model),
        "ffn": (moe.init_moe(k2, cfg) if cfg.family == "moe"
                else layers.init_mlp(k2, cfg.d_model, cfg.d_ff,
                                     cfg.param_dtype)),
    }


def init_layer_stack(key, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, cfg.num_layers)
    if cfg.family in ("dense", "audio", "vlm", "moe"):
        return {"layers": jax.vmap(
            functools.partial(_init_attn_block, cfg=cfg))(ks)}
    if cfg.family == "hybrid":
        stacked = jax.vmap(lambda k: {
            "ln": layers.init_rmsnorm(cfg.d_model),
            "mamba": mamba2.init_mamba2(k, cfg)})(ks)
        return {"layers": stacked,
                "shared_attn": _init_attn_block(
                    jax.random.fold_in(key, 1), cfg)}
    if cfg.family == "ssm":
        per_layer = tuple(
            xlstm.init_slstm(ks[i], cfg) if i in cfg.slstm_indices
            else xlstm.init_mlstm(ks[i], cfg)
            for i in range(cfg.num_layers))
        return {"layers": per_layer}
    raise ValueError(cfg.family)


def init_caches(cfg: ModelConfig, batch: int, max_seq: int,
                cache_dtype=jnp.bfloat16) -> Any:
    """Decode-time state for the whole stack (family-specific pytree)."""
    kv = lambda n: KVCache(
        k=jnp.zeros((n, batch, max_seq, cfg.num_kv_heads, cfg.head_dim),
                    cache_dtype),
        v=jnp.zeros((n, batch, max_seq, cfg.num_kv_heads, cfg.head_dim),
                    cache_dtype))
    if cfg.family in ("dense", "audio", "vlm", "moe"):
        return kv(cfg.num_layers)
    if cfg.family == "hybrid":
        st = mamba2.mamba2_init_state(cfg, batch)
        stacked = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.num_layers,) + x.shape), st)
        return {"mamba": stacked, "attn": kv(n_attn_layers(cfg))}
    if cfg.family == "ssm":
        states = []
        for i in range(cfg.num_layers):
            states.append(xlstm.slstm_init_state(cfg, batch)
                          if i in cfg.slstm_indices
                          else xlstm.mlstm_init_state(cfg, batch))
        return tuple(states)
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# one attention block (dense/moe families + the hybrid shared block)
# ---------------------------------------------------------------------------
def _attn_block(p, cfg: ModelConfig, x, shard: ShardCfg, *, positions,
                mask: MaskSpec, cache=None, cache_len=None):
    h, new_cache = attention(
        p["attn"], layers.rmsnorm(p["ln1"], x, cfg.norm_eps),
        rope_theta=cfg.rope_theta, positions=positions, mask=mask,
        cache=cache, cache_len=cache_len,
        q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    x = shard.constrain_act(x + h, None, None)
    y = layers.rmsnorm(p["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        y, metrics = moe.moe_apply(p["ffn"], cfg, y, shard)
    else:
        y = layers.mlp(p["ffn"], y)
        metrics = StackMetrics.zero()
    if isinstance(metrics, moe.MoEMetrics):
        metrics = StackMetrics(metrics.aux_loss, metrics.z_loss,
                               metrics.dropped_frac)
    x = shard.constrain_act(x + y.astype(x.dtype), None, None)
    return x, new_cache, metrics


# ---------------------------------------------------------------------------
# sequence mode (train / prefill)
# ---------------------------------------------------------------------------
def stack_seq(params, cfg: ModelConfig, x, shard: ShardCfg, *, positions,
              mask: MaskSpec, caches=None, mode: str = "train"):
    """x (B,S,d) -> (x, new_caches, metrics).  mode: train | prefill."""
    if cfg.family in ("dense", "audio", "vlm", "moe"):
        return _seq_attn_stack(params, cfg, x, shard, positions=positions,
                               mask=mask, caches=caches, mode=mode)
    if cfg.family == "hybrid":
        return _seq_hybrid_stack(params, cfg, x, shard, positions=positions,
                                 mask=mask, caches=caches, mode=mode)
    if cfg.family == "ssm":
        return _seq_xlstm_stack(params, cfg, x, caches=caches, mode=mode)
    raise ValueError(cfg.family)


def _seq_attn_stack(params, cfg, x, shard, *, positions, mask, caches, mode):
    stacked = params["layers"]

    def body(x, layer_in):
        lp, cache = layer_in
        x, new_cache, met = _attn_block(lp, cfg, x, shard,
                                        positions=positions, mask=mask,
                                        cache=cache)
        return x, (new_cache, met)

    body = _remat(body, cfg)
    if mode == "train":
        xs = (stacked, None)
        body_nc = lambda c, lp: (lambda r: (r[0], r[1][1]))(body(c, (lp, None)))
        if cfg.scan_layers:
            x, mets = lax.scan(body_nc, x, stacked)
        else:
            mets = []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda t: t[i], stacked)
                x, met = body_nc(x, lp)
                mets.append(met)
            mets = jax.tree.map(lambda *ts: jnp.stack(ts), *mets)
        return x, None, jax.tree.map(jnp.sum, mets)
    # prefill: thread caches as xs/ys
    if cfg.scan_layers:
        x, (new_caches, mets) = lax.scan(body, x, (stacked, caches))
    else:
        ncs, mets = [], []
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda t: t[i], stacked)
            cache = jax.tree.map(lambda t: t[i], caches)
            x, (nc, met) = body(x, (lp, cache))
            ncs.append(nc)
            mets.append(met)
        new_caches = jax.tree.map(lambda *ts: jnp.stack(ts), *ncs)
        mets = jax.tree.map(lambda *ts: jnp.stack(ts), *mets)
    return x, new_caches, jax.tree.map(jnp.sum, mets)


def _seq_hybrid_stack(params, cfg, x, shard, *, positions, mask, caches,
                      mode):
    """Group scan: each iteration = shared attn block + attn_every mamba
    layers.  Caches: attn KV stacked (G, ...) as scan xs/ys; mamba states
    stacked (L, ...) regrouped to (G, E, ...)."""
    grouped = _group(cfg, params["layers"])
    shared = params["shared_attn"]
    attn_caches = caches["attn"] if caches is not None else None
    mamba_states = (_group(cfg, caches["mamba"])
                    if caches is not None else None)
    with_caches = caches is not None

    def one_group(x, gp, acache, mstates):
        x, new_acache, _ = _attn_block(shared, cfg, x, shard,
                                       positions=positions, mask=mask,
                                       cache=acache)
        new_ms = []
        for e in range(cfg.attn_every):
            lp = jax.tree.map(lambda t: t[e], gp)
            ms = (jax.tree.map(lambda t: t[e], mstates)
                  if mstates is not None else None)
            h, nm = mamba2.mamba2_seq(
                lp["mamba"], cfg, layers.rmsnorm(lp["ln"], x, cfg.norm_eps),
                shard, state=ms, return_state=with_caches)
            x = shard.constrain_act(x + h.astype(x.dtype), None, None)
            new_ms.append(nm)
        new_mstates = (jax.tree.map(lambda *ts: jnp.stack(ts), *new_ms)
                       if with_caches else None)
        return x, new_acache, new_mstates

    def body(x, group_in):
        gp, acache, mstates = group_in
        x, new_acache, new_mstates = one_group(x, gp, acache, mstates)
        return x, (new_acache, new_mstates)

    body = _remat(body, cfg)
    if cfg.scan_layers:
        x, (new_attn, new_mamba) = lax.scan(
            body, x, (grouped, attn_caches, mamba_states))
    else:
        nas, nms = [], []
        g = n_attn_layers(cfg)
        for i in range(g):
            gp = jax.tree.map(lambda t: t[i], grouped)
            ac = (jax.tree.map(lambda t: t[i], attn_caches)
                  if attn_caches is not None else None)
            ms = (jax.tree.map(lambda t: t[i], mamba_states)
                  if mamba_states is not None else None)
            x, (na, nm) = body(x, (gp, ac, ms))
            nas.append(na)
            nms.append(nm)
        stack = lambda ts: (jax.tree.map(lambda *xs: jnp.stack(xs), *ts)
                            if with_caches else None)
        new_attn, new_mamba = stack(nas), stack(nms)
    new_caches = ({"mamba": _ungroup(new_mamba), "attn": new_attn}
                  if with_caches else None)
    return x, new_caches, StackMetrics.zero()


def _seq_xlstm_stack(params, cfg, x, *, caches, mode):
    new_states = []
    want_state = caches is not None
    for i, lp in enumerate(params["layers"]):
        st = caches[i] if caches is not None else None
        fn = (xlstm.slstm_seq if i in cfg.slstm_indices else xlstm.mlstm_seq)
        x, ns = fn(lp, cfg, x, state=st, return_state=want_state)
        new_states.append(ns)
    return x, (tuple(new_states) if want_state else None), StackMetrics.zero()


# ---------------------------------------------------------------------------
# step mode (single-token decode)
# ---------------------------------------------------------------------------
def stack_step(params, cfg: ModelConfig, x, shard: ShardCfg, *, caches,
               cache_len):
    """x (B,1,d), caches filled to cache_len -> (x, new_caches).

    ``cache_len`` is a scalar (uniform batch) or a (B,) vector (continuous
    batching: per-slot fill levels and rope positions)."""
    if getattr(cache_len, "ndim", 0) >= 1:
        positions = cache_len.reshape(-1, 1)     # (B, 1) per-slot rope
    else:
        positions = jnp.atleast_1d(cache_len)
    mask = MaskSpec(causal=True, q_offset=0)
    if cfg.family in ("dense", "audio", "vlm", "moe"):
        stacked = params["layers"]

        def body(x, layer_in):
            lp, cache = layer_in
            x, new_cache, _ = _attn_block(lp, cfg, x, shard,
                                          positions=positions, mask=mask,
                                          cache=cache, cache_len=cache_len)
            return x, new_cache

        if cfg.scan_layers:
            x, new_caches = lax.scan(body, x, (stacked, caches))
        else:
            ncs = []
            for i in range(cfg.num_layers):
                lp = jax.tree.map(lambda t: t[i], stacked)
                cache = jax.tree.map(lambda t: t[i], caches)
                x, nc = body(x, (lp, cache))
                ncs.append(nc)
            new_caches = jax.tree.map(lambda *ts: jnp.stack(ts), *ncs)
        return x, new_caches

    if cfg.family == "hybrid":
        grouped = _group(cfg, params["layers"])
        shared = params["shared_attn"]
        mamba_states = _group(cfg, caches["mamba"])

        def body(x, group_in):
            gp, acache, mstates = group_in
            x, new_acache, _ = _attn_block(
                shared, cfg, x, shard, positions=positions, mask=mask,
                cache=acache, cache_len=cache_len)
            new_ms = []
            for e in range(cfg.attn_every):
                lp = jax.tree.map(lambda t: t[e], gp)
                ms = jax.tree.map(lambda t: t[e], mstates)
                h, nm = mamba2.mamba2_step(
                    lp["mamba"], cfg,
                    layers.rmsnorm(lp["ln"], x[:, 0], cfg.norm_eps), ms)
                x = x + h[:, None].astype(x.dtype)
                new_ms.append(nm)
            new_mstates = jax.tree.map(lambda *ts: jnp.stack(ts), *new_ms)
            return x, (new_acache, new_mstates)

        x, (new_attn, new_mamba) = lax.scan(
            body, x, (grouped, caches["attn"], mamba_states))
        return x, {"mamba": _ungroup(new_mamba), "attn": new_attn}

    if cfg.family == "ssm":
        new_states = []
        xt = x[:, 0]
        for i, lp in enumerate(params["layers"]):
            if i in cfg.slstm_indices:
                xt, ns = xlstm.slstm_step(lp, cfg, xt, caches[i])
            else:
                xt, ns = xlstm.mlstm_step(lp, cfg, xt, caches[i])
            new_states.append(ns)
        return xt[:, None], tuple(new_states)
    raise ValueError(cfg.family)
