"""Shared neural-net layers, pure JAX (no flax): param-dict modules.

Every layer is a pair of functions: ``init_*`` building a param pytree from
a PRNG key (usable under ``jax.eval_shape`` for the allocation-free dry-run)
and an apply function.  Weights are stored in ``param_dtype`` (fp32 masters
by default; bf16 for the very largest configs) and cast to ``compute_dtype``
at use — the standard mixed-precision scheme.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal(key, shape, stddev, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(d: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings (rotate-half convention)
# ---------------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_frequencies(x.shape[-1], theta)           # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                           # (..., S, 1, D/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / embeddings
# ---------------------------------------------------------------------------
def init_dense(key, d_in: int, d_out: int, dtype, *, bias: bool = False,
               stddev: float | None = None) -> dict:
    stddev = stddev if stddev is not None else 1.0 / np.sqrt(d_in)
    p = {"w": truncated_normal(key, (d_in, d_out), stddev, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(params: dict, x: jnp.ndarray, compute_dtype=None) -> jnp.ndarray:
    dt = compute_dtype or x.dtype
    y = x.astype(dt) @ params["w"].astype(dt)
    if "b" in params:
        y = y + params["b"].astype(dt)
    return y


def init_embedding(key, vocab: int, d: int, dtype) -> dict:
    # 1/sqrt(d): keeps tied-unembedding logits O(1) at init; the pre-stack
    # rmsnorm-free residual entry is fine because blocks pre-norm.
    return {"table": truncated_normal(key, (vocab, d), 1.0 / np.sqrt(d), dtype)}


def embed(params: dict, ids: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    return jnp.take(params["table"], ids, axis=0).astype(compute_dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, d, d_ff, dtype),
        "up": init_dense(k2, d, d_ff, dtype),
        "down": init_dense(k3, d_ff, d, dtype, stddev=1.0 / np.sqrt(d_ff)),
    }


def mlp(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    g = dense(params["gate"], x)
    u = dense(params["up"], x)
    return dense(params["down"], jax.nn.silu(g) * u)


def swiglu_ffn_flops(d: int, d_ff: int) -> int:
    return 2 * d * d_ff * 3  # per token, fwd
