"""Transformer building blocks: GQA attention (train/prefill/decode) and the
pre-norm residual block composition."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.attention import MaskSpec, chunked_mha, decode_mha, full_mha


class KVCache(NamedTuple):
    k: jnp.ndarray  # (B, Smax, KH, D)
    v: jnp.ndarray
    # length is tracked by the serving engine (one scalar for the batch)


def init_attention(key, d_model: int, num_heads: int, num_kv_heads: int,
                   head_dim: int, dtype, qkv_bias: bool = False) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    import numpy as np

    std = 1.0 / np.sqrt(d_model)
    p = {
        "wq": layers.truncated_normal(kq, (d_model, num_heads, head_dim), std, dtype),
        "wk": layers.truncated_normal(kk, (d_model, num_kv_heads, head_dim), std, dtype),
        "wv": layers.truncated_normal(kv, (d_model, num_kv_heads, head_dim), std, dtype),
        "wo": layers.truncated_normal(
            ko, (num_heads, head_dim, d_model), 1.0 / np.sqrt(num_heads * head_dim), dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((num_heads, head_dim), dtype)
        p["bk"] = jnp.zeros((num_kv_heads, head_dim), dtype)
        p["bv"] = jnp.zeros((num_kv_heads, head_dim), dtype)
    return p


def attention(
    params: dict,
    x: jnp.ndarray,                  # (B, S, D)
    *,
    rope_theta: float,
    positions: jnp.ndarray,          # (S,) absolute positions
    mask: MaskSpec,
    cache: KVCache | None = None,
    cache_len=None,                  # filled prefix length (decode/prefill)
    impl: str = "chunked",
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
):
    """Returns (y, new_cache).  Modes:
      train:    cache=None                    -> causal self-attention
      prefill:  cache empty, cache_len=None   -> fill cache[0:S]
      decode:   cache filled, cache_len=t     -> append at t, attend to [0:t]
    """
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    q = layers.apply_rope(q, positions, rope_theta)
    k = layers.apply_rope(k, positions, rope_theta)

    new_cache = cache
    if cache is not None:
        if cache_len is None:  # prefill: write [0:S]
            kc = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k.astype(cache.k.dtype), 0, axis=1)
            vc = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v.astype(cache.v.dtype), 0, axis=1)
            new_cache = KVCache(kc, vc)
            attn_k, attn_v = k, v
            valid = None
        else:  # decode: append one token at cache_len (scalar or (B,))
            if getattr(cache_len, "ndim", 0) >= 1:  # per-slot positions
                upd = lambda c, u, i: jax.lax.dynamic_update_slice_in_dim(
                    c, u, i, axis=0)
                kc = jax.vmap(upd)(cache.k, k.astype(cache.k.dtype),
                                   cache_len)
                vc = jax.vmap(upd)(cache.v, v.astype(cache.v.dtype),
                                   cache_len)
            else:
                kc = jax.lax.dynamic_update_slice_in_dim(
                    cache.k, k.astype(cache.k.dtype), cache_len, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(
                    cache.v, v.astype(cache.v.dtype), cache_len, axis=1)
            new_cache = KVCache(kc, vc)
            out = decode_mha(q, kc.astype(dt), vc.astype(dt),
                             cache_len + q.shape[1])
            y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
            return y, new_cache
    else:
        attn_k, attn_v = k, v
        valid = None

    if impl == "full":
        out = full_mha(q, attn_k, attn_v, mask, kv_valid_len=valid)
    else:
        out = chunked_mha(q, attn_k, attn_v, mask, q_chunk=q_chunk,
                          kv_chunk=kv_chunk, kv_valid_len=valid)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(dt))
    return y, new_cache
