"""Attention: chunked-flash (pure XLA) + GQA module with KV cache.

``chunked_mha`` is the memory-safe O(S) attention used for training and
prefill on every backend (the Pallas flash kernel in ``repro.kernels`` is
the TPU fast path; both implement the same online-softmax algorithm and are
cross-validated in tests).  Layout is BSHD: q (B, Sq, H, D), k/v
(B, Skv, KH, D), H = KH * rep (GQA).

Masking supports causal, causal-with-offset (decode), and prefix-LM
(PaliGemma: bidirectional prefix + causal suffix).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

_NEG_INF = -1e30


class MaskSpec(NamedTuple):
    causal: bool = True
    q_offset: int = 0          # absolute position of q[0]
    prefix_len: int = 0        # positions < prefix_len attend bidirectionally


def _mask(qpos, kpos, spec: MaskSpec, kv_valid_len=None):
    """(Sq, Sk) boolean mask (True = attend)."""
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    if spec.causal:
        causal = kpos[None, :] <= (qpos[:, None] + spec.q_offset)
        if spec.prefix_len:
            causal = causal | (kpos[None, :] < spec.prefix_len)
        m = m & causal
    if kv_valid_len is not None:
        m = m & (kpos[None, :] < kv_valid_len)
    return m


def full_mha(q, k, v, spec: MaskSpec = MaskSpec(), kv_valid_len=None,
             scale=None):
    """O(S^2)-memory attention (small-sequence / oracle / decode path).

    The ``__kernel__`` scope marks the region as shipping as one fused
    Pallas kernel on TPU (kernels/attention.py): the roofline's HBM-traffic
    model charges only region inputs/outputs — logits/probabilities stay
    in VMEM (see launch/hlo_cost.py).
    """
    with jax.named_scope("__kernel__attention"):
        b, sq, h, d = q.shape
        _, sk, kh, _ = k.shape
        rep = h // kh
        scale = scale if scale is not None else 1.0 / (d ** 0.5)
        qf = q.reshape(b, sq, kh, rep, d).astype(jnp.float32)
        logits = jnp.einsum("bqhrd,bkhd->bhrqk", qf,
                            k.astype(jnp.float32)) * scale
        per_batch = (kv_valid_len is not None
                     and getattr(kv_valid_len, "ndim", 0) >= 1)
        mask = _mask(jnp.arange(sq), jnp.arange(sk), spec,
                     None if per_batch else kv_valid_len)
        logits = jnp.where(mask[None, None, None], logits, _NEG_INF)
        if per_batch:  # continuous batching: per-slot valid length
            kmask = (jnp.arange(sk)[None, :]
                     < kv_valid_len.reshape(b, 1))       # (B, Sk)
            logits = jnp.where(kmask[:, None, None, None, :], logits,
                               _NEG_INF)
        w = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhrqk,bkhd->bqhrd", w, v.astype(jnp.float32))
        return out.reshape(b, sq, h, d).astype(q.dtype)


def chunked_mha(q, k, v, spec: MaskSpec = MaskSpec(), *, q_chunk: int = 1024,
                kv_chunk: int = 1024, kv_valid_len=None, scale=None):
    """Online-softmax attention: O(chunk^2) transient memory.

    Outer ``lax.map`` over q chunks, inner ``lax.scan`` over kv chunks —
    the XLA analogue of the flash-attention tiling (and of the paper's
    stream-along-one-axis 3DBLOCK template).
    """
    b, sq, h, d = q.shape
    _, sk, kh, _ = k.shape
    rep = h // kh
    scale = scale if scale is not None else 1.0 / (d ** 0.5)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    nq, nk = -(-sq // q_chunk), -(-sk // kv_chunk)
    # pad to chunk multiples
    sq_p, sk_p = nq * q_chunk, nk * kv_chunk
    qp = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, sk_p - sk), (0, 0), (0, 0)))
    valid = jnp.minimum(kv_valid_len if kv_valid_len is not None else sk, sk)

    kb = kp.reshape(b, nk, kv_chunk, kh, d)
    vb = vp.reshape(b, nk, kv_chunk, kh, d)

    @jax.named_scope("__kernel__attention")
    def one_q_chunk(qi):
        qs = lax.dynamic_slice_in_dim(qp, qi * q_chunk, q_chunk, axis=1)
        qs = qs.reshape(b, q_chunk, kh, rep, d).astype(jnp.float32)
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def body(carry, inputs):
            m_prev, l_prev, acc = carry
            kj, (kc, vc) = inputs
            kc = kc.astype(jnp.float32)
            logits = jnp.einsum("bqhrd,bkhd->bqhrk", qs, kc) * scale
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            msk = _mask(qpos, kpos, spec, valid)           # (q_chunk, kv_chunk)
            logits = jnp.where(msk[None, :, None, None, :], logits, _NEG_INF)
            m_cur = jnp.maximum(m_prev, logits.max(axis=-1))
            p = jnp.exp(logits - m_cur[..., None])
            alpha = jnp.exp(m_prev - m_cur)
            l_cur = l_prev * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bqhrk,bkhd->bqhrd", p, vc.astype(jnp.float32))
            return (m_cur, l_cur, acc), None

        init = (
            jnp.full((b, q_chunk, kh, rep), _NEG_INF, jnp.float32),
            jnp.zeros((b, q_chunk, kh, rep), jnp.float32),
            jnp.zeros((b, q_chunk, kh, rep, d), jnp.float32),
        )
        (m, l, acc), _ = lax.scan(
            body, init,
            (jnp.arange(nk), (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0))))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(b, q_chunk, h, d).astype(q.dtype)

    # checkpoint: backward recomputes each q-chunk's online-softmax pass
    # instead of saving per-chunk masks/probabilities as residuals (the
    # flash-attention backward; cuts train-time attention residency from
    # O(S^2 / nq) to O(chunk^2) transients)
    out = lax.map(jax.checkpoint(one_q_chunk), jnp.arange(nq))
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq_p, h, d)   # (nq,B,qc,H,D)
    return out[:, :sq]


def decode_mha(q, k_cache, v_cache, cache_len, scale=None):
    """Single-step decode: q (B, 1, H, D) against a (B, S, KH, D) cache.

    Positions >= cache_len are masked.  Small enough to run unchunked; the
    contraction is sharded by pjit (seq-sharded cache => psum combine, the
    flash-decode pattern, chosen automatically by SPMD).
    """
    return full_mha(q, k_cache, v_cache,
                    MaskSpec(causal=False), kv_valid_len=cache_len,
                    scale=scale)
