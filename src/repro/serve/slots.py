"""Fixed-slot occupancy table + FIFO admission queue.

The continuous-batching pattern shared by the LM serving engine
(:mod:`repro.serve.engine`) and the CFD simulation farm
(:mod:`repro.sim.farm`): a fixed device batch of ``n_slots`` resident
items, a host-side FIFO of waiting work, and slot reclamation — whenever a
slot frees, the next queued item is admitted into it and the whole batch
keeps stepping.  The table owns only host-side bookkeeping; callers own the
device-side state keyed by slot index.
"""
from __future__ import annotations

import collections
from typing import Any, Iterator


class SlotTable:
    """Host bookkeeping for a fixed pool of device slots."""

    def __init__(self, n_slots: int):
        if n_slots < 1:
            raise ValueError(f"need at least one slot, got {n_slots}")
        self.n_slots = n_slots
        self._entries: list[Any | None] = [None] * n_slots
        # admission queue, split by priority level: pop always serves the
        # highest level first and is FIFO *within* a level, so urgent work
        # (an interactive request, a readmission) jumps the backlog without
        # reordering peers.  Level 0 is the default; the common case is a
        # single-level FIFO, exactly the old behaviour.
        self._queues: dict[int, collections.deque] = collections.defaultdict(
            collections.deque)

    # -- intake ---------------------------------------------------------------
    def submit(self, item: Any, priority: int = 0) -> None:
        """Queue ``item`` for admission when a slot frees.

        Higher ``priority`` levels admit first; ties admit in submission
        order (FIFO within a level).
        """
        self._queues[int(priority)].append(item)

    # -- admission ------------------------------------------------------------
    def _pop_next(self) -> Any | None:
        for prio in sorted(self._queues, reverse=True):
            q = self._queues[prio]
            if q:
                return q.popleft()
        return None

    def admit_next(self) -> tuple[int, Any] | None:
        """Pop the next queued item into the first free slot.

        Returns ``(slot, item)``, or ``None`` when there is no free slot or
        nothing is queued.  Call repeatedly to fill every free slot.
        """
        slot = next(self.free_slots(), None)
        if slot is None:
            return None
        item = self._pop_next()
        if item is None:
            return None
        self._entries[slot] = item
        return slot, item

    # -- occupancy ------------------------------------------------------------
    def get(self, slot: int) -> Any | None:
        return self._entries[slot]

    def replace(self, slot: int, item: Any) -> None:
        """Swap the occupant of ``slot`` (e.g. queued request -> live entry)."""
        if self._entries[slot] is None:
            raise ValueError(f"slot {slot} is free; admit into it instead")
        self._entries[slot] = item

    def release(self, slot: int) -> Any:
        """Free ``slot``; returns the item that occupied it."""
        item = self._entries[slot]
        if item is None:
            raise ValueError(f"slot {slot} is already free")
        self._entries[slot] = None
        return item

    def free_slots(self) -> Iterator[int]:
        return (s for s, e in enumerate(self._entries) if e is None)

    def slots(self) -> tuple:
        """Fixed-order occupancy view: one element per slot, ``None`` for
        a free slot — what a dashboard renders (``occupied()`` skips free
        slots, which a live per-slot view must not)."""
        return tuple(self._entries)

    def occupied(self) -> Iterator[tuple[int, Any]]:
        return ((s, e) for s, e in enumerate(self._entries) if e is not None)

    @property
    def n_active(self) -> int:
        return sum(1 for e in self._entries if e is not None)

    @property
    def n_queued(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def queued_items(self) -> Iterator[Any]:
        """Every waiting item in admission order — priority levels high
        to low, FIFO within a level: the order ``admit_next`` would pop
        them.  A durable job store walks this to mirror the in-memory
        queue without disturbing it."""
        for prio in sorted(self._queues, reverse=True):
            yield from self._queues[prio]

    def queue_depths(self) -> dict[int, int]:
        """Waiting-item count per priority level.  Every level that ever
        held work is reported (emptied levels at 0), so a gauge fed from
        this view decays to zero instead of freezing at the last depth."""
        return {p: len(q) for p, q in self._queues.items()}

    @property
    def idle(self) -> bool:
        """Nothing resident and nothing waiting."""
        return self.n_active == 0 and self.n_queued == 0
