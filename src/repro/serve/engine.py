"""Batched serving engine: continuous batching over a fixed-slot KV cache.

The production pattern (vLLM-style, sized down): a fixed decode batch of
``slots``, each slot holding one request's KV/SSM state at a fixed
``max_seq`` budget.  Requests queue up; whenever a slot frees (EOS or
length budget), the next request is prefilled into that slot and decoding
continues for the whole batch every step.  Per-slot position/length
bookkeeping lives on the host; the device step is one jitted
``decode_step`` over the full slot batch (slots beyond their length emit
garbage that is masked on the host — the standard padding-decode trade).

Single-slot prefill uses a per-request jitted prefill over a length-
bucketed prompt (bucketing avoids a compile per prompt length).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model
from repro.models.config import LOCAL, ModelConfig, ShardCfg
from repro.serve.slots import SlotTable


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (P,) int32
    max_new_tokens: int = 32
    eos_id: int | None = None
    # filled by the engine
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return -(-n // 2048) * 2048


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 512, shard: ShardCfg = LOCAL,
                 greedy: bool = True):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.shard = shard
        self.table = SlotTable(slots)
        self.finished: list[Request] = []
        self.lengths = np.zeros((slots,), np.int32)   # filled tokens per slot
        self.budgets = np.zeros((slots,), np.int32)
        self.caches = model.init_caches(cfg, slots, max_seq, jnp.float32)
        self.last_token = np.zeros((slots, 1), np.int32)
        self.steps = 0
        # exact per-leaf batch axis: the axis whose extent tracks the batch
        a = jax.eval_shape(lambda: model.init_caches(cfg, slots, max_seq,
                                                     jnp.float32))
        b = jax.eval_shape(lambda: model.init_caches(cfg, slots + 1, max_seq,
                                                     jnp.float32))
        self._batch_axes = jax.tree.map(
            lambda x, y: int(next(i for i, (u, v) in
                                  enumerate(zip(x.shape, y.shape)) if u != v)),
            a, b)

        self._decode = jax.jit(
            lambda p, t, c, l: model.decode_step(p, cfg, t, c, l, shard))
        self._prefill_cache = {}

    # -- request intake ---------------------------------------------------------
    def submit(self, req: Request):
        self.table.submit(req)

    @property
    def active(self) -> list[Request | None]:
        return [self.table.get(s) for s in range(self.slots)]

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill_cache:
            def fn(p, tokens, caches):
                # single-request prefill into slot-0 of a 1-batch cache view
                return model.prefill(p, self.cfg, {"tokens": tokens}, caches,
                                     self.shard)

            self._prefill_cache[plen] = jax.jit(fn)
        return self._prefill_cache[plen]

    def _admit(self):
        while True:
            admitted = self.table.admit_next()
            if admitted is None:
                return
            s, req = admitted
            plen = len(req.prompt)
            b = _bucket(plen)
            toks = np.full((1, b), 0, np.int32)
            toks[0, :plen] = req.prompt
            toks = jnp.asarray(toks)
            one_cache = model.init_caches(self.cfg, 1, self.max_seq,
                                          jnp.float32)
            logits, one_cache = self._prefill_fn(b)(self.params, toks,
                                                    one_cache)
            # bucketing pads the prompt; recompute last real-token logits by
            # decoding nothing — we take argmax at position plen-1 via the
            # cache, i.e. accept one wasted pad region (documented trade)
            self.caches = jax.tree.map(
                lambda full, one, ax: jax.lax.dynamic_update_index_in_dim(
                    full, jnp.take(one, 0, axis=ax), s, ax),
                self.caches, one_cache, self._batch_axes)
            # re-decode the last real prompt token: its KV rewrite at
            # position plen-1 is idempotent and yields the first new token
            # without a per-length prefill compile (bucketed pads beyond
            # plen are masked by the per-slot valid length)
            self.lengths[s] = plen - 1
            self.budgets[s] = req.max_new_tokens
            self.last_token[s, 0] = int(req.prompt[-1])

    # -- one engine step -------------------------------------------------------
    def step(self):
        self._admit()
        if self.table.n_active == 0:
            return False
        cache_len = jnp.asarray(self.lengths)        # (slots,) per-slot fill
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.last_token), self.caches, cache_len)
        toks = np.asarray(jnp.argmax(logits[:, -1], axis=-1), np.int32)
        self.steps += 1
        for s, req in list(self.table.occupied()):
            t = int(toks[s])
            req.output.append(t)
            self.last_token[s, 0] = t
            self.lengths[s] += 1
            self.budgets[s] -= 1
            if ((req.eos_id is not None and t == req.eos_id)
                    or self.budgets[s] <= 0
                    or self.lengths[s] >= self.max_seq - 1):
                req.done = True
                self.finished.append(req)
                self.table.release(s)
                self.lengths[s] = 0
        return True

    def run_until_drained(self, max_steps: int = 10_000):
        while self.steps < max_steps:
            if not self.step():
                if self.table.idle:
                    break
        return self.finished


