"""JAX version compatibility shims.

The codebase is written against the current jax API surface; this module
back-fills the handful of names that older releases (>= 0.4.3x) spell
differently, so one tree runs on both:

  * ``jax.shard_map``            — older jax has ``jax.experimental.shard_map``
                                   with ``check_rep`` instead of ``check_vma``.
  * ``jax.sharding.AxisType``    — absent on older jax; meshes are untyped.
  * ``jax.make_mesh(axis_types=...)`` — older signature lacks the kwarg.

``install()`` is idempotent and only patches what is missing, so on a
current jax it is a no-op.  It runs from ``repro/__init__`` so every entry
point (tests, launchers, subprocess snippets) sees a uniform API.
"""
from __future__ import annotations

import enum
import functools

import jax


def install() -> None:
    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                      check_vma=None, **kw):
            if check_vma is not None:
                kw.setdefault("check_rep", check_vma)
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map

    if not hasattr(jax.lax, "axis_size"):
        def axis_size(axis_name):
            # psum of a literal over a named axis constant-folds to the
            # (static, python-int) axis size at trace time
            return jax.lax.psum(1, axis_name)

        jax.lax.axis_size = axis_size

    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
            del axis_types  # untyped meshes on this jax
            return _make_mesh(axis_shapes, axis_names, **kw)

        jax.make_mesh = make_mesh


install()
