"""Deterministic sharded data pipeline.

Production posture without external deps: a synthetic-corpus tokenizer-free
source (seeded Zipf mixture with Markov structure so the LM loss actually
falls), document packing into fixed-length sequences with next-token
targets, deterministic *restartable* iteration (step -> batch is a pure
function of (seed, step) — resuming from a checkpoint replays the exact
stream with no state files), and per-host sharding (each data-parallel
host materializes only its slice — the multi-host pattern).

A background prefetch thread hides generation latency behind the train
step (the paper's copy/compute overlap at the input layer).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import jax
import numpy as np

from repro.models import multimodal
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab_size: int = 32_000
    seq_len: int = 1024
    global_batch: int = 8
    # synthetic corpus knobs
    zipf_a: float = 1.2
    markov_order: int = 1
    n_states: int = 64
    doc_len_mean: int = 512


class SyntheticCorpus:
    """Seeded Markov-Zipf token source: documents with learnable structure.

    Each Markov state owns a Zipf-permuted slice of the vocab; transitions
    are sparse.  A 1-layer model reaches ~2-3 nats on this stream, so
    convergence tests have signal (pure-uniform streams don't train).
    """

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v, s = cfg.vocab_size, cfg.n_states
        # per-state emission: Zipf weights over a state-specific permutation
        ranks = np.arange(1, v + 1, dtype=np.float64) ** (-cfg.zipf_a)
        self.emit_p = ranks / ranks.sum()
        self.perms = np.stack([rng.permutation(v) for _ in range(s)])
        # sparse transitions: each state -> 4 successors
        self.next_states = rng.integers(0, s, size=(s, 4))

    def document(self, doc_id: int) -> np.ndarray:
        rng = np.random.default_rng((self.cfg.seed, 1, doc_id))
        length = max(16, int(rng.exponential(self.cfg.doc_len_mean)))
        state = int(rng.integers(self.cfg.n_states))
        out = np.empty((length,), np.int32)
        # vectorized-ish: emit in chunks per state run
        i = 0
        while i < length:
            run = int(rng.integers(8, 64))
            n = min(run, length - i)
            toks = rng.choice(self.cfg.vocab_size, size=n, p=self.emit_p)
            out[i:i + n] = self.perms[state][toks]
            i += n
            state = int(self.next_states[state, rng.integers(4)])
        return out


class PackedLMDataset:
    """Deterministic (seed, step, shard) -> batch packing.

    ``batch(step, shard_idx, num_shards)`` returns that host's slice of the
    global batch: dict(tokens (b,S) int32, targets (b,S) int32).  Document
    boundaries insert target masking (-1) for the first token of each doc.
    """

    def __init__(self, cfg: DataConfig, model_cfg: ModelConfig | None = None):
        self.cfg = cfg
        self.corpus = SyntheticCorpus(cfg)
        self.model_cfg = model_cfg

    def _sequence(self, seq_id: int) -> tuple[np.ndarray, np.ndarray]:
        """Pack documents into one (seq_len+1,) stream, then split x/y."""
        need = self.cfg.seq_len + 1
        rng = np.random.default_rng((self.cfg.seed, 2, seq_id))
        doc_id = int(rng.integers(2 ** 31)) + seq_id * 1000
        toks, bounds = [], []
        total = 0
        while total < need:
            d = self.corpus.document(doc_id)
            bounds.append(total)
            toks.append(d)
            total += len(d)
            doc_id += 1
        stream = np.concatenate(toks)[:need]
        x = stream[:-1].astype(np.int32)
        y = stream[1:].astype(np.int32).copy()
        for b in bounds:  # no cross-document prediction
            if 0 <= b - 1 < self.cfg.seq_len:
                y[b - 1] = -1
        return x, y

    def batch(self, step: int, shard_idx: int = 0, num_shards: int = 1) -> dict:
        gb = self.cfg.global_batch
        assert gb % num_shards == 0
        b = gb // num_shards
        xs, ys = [], []
        for i in range(b):
            seq_id = step * gb + shard_idx * b + i
            x, y = self._sequence(seq_id)
            xs.append(x)
            ys.append(y)
        out = {"tokens": np.stack(xs), "targets": np.stack(ys)}
        mc = self.model_cfg
        if mc is not None and mc.family == "audio":
            key = jax.random.PRNGKey(hash((self.cfg.seed, step, shard_idx))
                                     % (2 ** 31))
            out["embeds"] = np.asarray(multimodal.frame_embeddings(
                key, mc, b, self.cfg.seq_len))
            del out["tokens"]
        if mc is not None and mc.family == "vlm":
            key = jax.random.PRNGKey(hash((self.cfg.seed, 3, step, shard_idx))
                                     % (2 ** 31))
            out["prefix_embeds"] = np.asarray(
                multimodal.patch_embeddings(key, mc, b))
        return out

    def iterate(self, start_step: int = 0, shard_idx: int = 0,
                num_shards: int = 1) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step, shard_idx, num_shards)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-N queue) over a batch iterator."""

    def __init__(self, it: Iterator[dict], depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            for item in it:
                if self._stop.is_set():
                    return
                self._q.put(item)
            self._q.put(None)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
