"""Train / prefill / serve step factories — the functions the launcher
jits (and the dry-run lowers) with explicit in/out shardings.

``make_train_step`` supports gradient accumulation (microbatching): the
global batch is split into ``grad_accum`` microbatches scanned
sequentially, gradients accumulated in fp32 — the standard way to hold
global batch 256×4096 tokens without activation OOM.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import model
from repro.models.config import ModelConfig, ShardCfg
from repro.optim.adamw import AdamW, AdamWState


def make_loss_fn(cfg: ModelConfig, shard: ShardCfg):
    def lfn(params, batch):
        return model.loss_fn(params, cfg, batch, shard)

    return lfn


def make_train_step(cfg: ModelConfig, shard: ShardCfg, opt: AdamW,
                    grad_accum: int = 1):
    """Standard pjit train step (FSDP×TP — SPMD places the collectives).

    When ``shard.replicate_params`` (small-model pure-DP posture), the
    loss+grad is computed under an explicit shard_map with ONE final
    gradient pmean: SPMD cannot hoist all-reduces out of ``while`` loops,
    so recurrent archs (sLSTM BPTT) would otherwise all-reduce the
    weight-grad partials EVERY timestep (measured: 8,209 ARs/step on
    xlstm train_4k — see EXPERIMENTS.md §Perf-xlstm).
    """
    if shard.mesh is not None and shard.replicate_params:
        return _make_dp_train_step(cfg, shard, opt, grad_accum)
    lfn = make_loss_fn(cfg, shard)

    def train_step(params, opt_state: AdamWState, batch):
        if grad_accum == 1:
            (loss, met), grads = jax.value_and_grad(
                lfn, has_aux=True)(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % grad_accum == 0, (b, grad_accum)
                return x.reshape(grad_accum, b // grad_accum, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                loss_acc, met_acc, g_acc = carry
                (l, m), g = jax.value_and_grad(lfn, has_aux=True)(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                met_acc = jax.tree.map(jnp.add, met_acc, m)
                return (loss_acc + l, met_acc, g_acc), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zero_m = {"ce": 0.0, "acc": 0.0, "moe_aux": 0.0, "moe_z": 0.0,
                      "moe_dropped": 0.0}
            zero_m = jax.tree.map(lambda x: jnp.zeros((), jnp.float32), zero_m)
            (loss, met, grads), _ = lax.scan(
                body, (jnp.zeros((), jnp.float32), zero_m, zero_g), micro)
            inv = 1.0 / grad_accum
            loss = loss * inv
            met = jax.tree.map(lambda x: x * inv, met)
            grads = jax.tree.map(lambda g: g * inv, grads)

        params, opt_state, stats = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, **met, **stats}

    return train_step


def _make_dp_train_step(cfg: ModelConfig, shard: ShardCfg, opt: AdamW,
                        grad_accum: int = 1, compress_pod_grads: bool = False):
    """pmap-style DP: per-shard local autodiff (no collectives inside the
    model), one pmean of the grad tree, replicated optimizer update.

    ``compress_pod_grads``: reduce at full precision within a pod (ICI),
    then int8 error-feedback all-reduce across the ``pod`` axis (DCN-class
    links) — 4× fewer inter-pod wire bytes; the EF residual threads through
    the step as a third state argument (dist/compression.py).
    """
    from jax.sharding import PartitionSpec as P

    from repro.models.config import ShardCfg as SC

    local_shard = SC(mesh=None, moe_mode="local")   # pure-local math
    lfn = make_loss_fn(cfg, local_shard)
    axes = tuple(shard.dp_axes)
    pod_axes = tuple(a for a in axes if a == "pod")
    intra_axes = tuple(a for a in axes if a != "pod")

    n_pod = (shard.mesh.shape["pod"]
             if (compress_pod_grads and "pod" in shard.mesh.axis_names)
             else 0)

    def train_step(params, opt_state: AdamWState, batch, ef_err=None):
        """ef_err (compression only): pytree with a leading (n_pod,) axis —
        per-pod error-feedback residuals (values differ across pods, so
        they carry an explicit axis rather than a replicated spec)."""
        def local(params, batch, ef_err):
            if grad_accum == 1:
                (loss, met), grads = jax.value_and_grad(
                    lfn, has_aux=True)(params, batch)
            else:
                def split(x):
                    b = x.shape[0]
                    return x.reshape(grad_accum, b // grad_accum,
                                     *x.shape[1:])

                def body(carry, mb):
                    l_acc, g_acc = carry
                    (l, m), g = jax.value_and_grad(
                        lfn, has_aux=True)(params, mb)
                    return (l_acc + l, jax.tree.map(
                        lambda a, b: a + b.astype(jnp.float32), g_acc, g)), m

                zero_g = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params)
                (loss, grads), mets = lax.scan(
                    body, (jnp.zeros((), jnp.float32), zero_g),
                    jax.tree.map(split, batch))
                loss = loss / grad_accum
                grads = jax.tree.map(lambda g: g / grad_accum, grads)
                met = jax.tree.map(lambda x: x[-1], mets)
            if n_pod:
                from repro.dist.compression import ef_allreduce_mean

                if intra_axes:      # full-precision reduce inside the pod
                    grads = jax.tree.map(
                        lambda g: lax.pmean(g, intra_axes), grads)
                flat_g, tdef = jax.tree_util.tree_flatten(grads)
                flat_e = jax.tree.leaves(ef_err)
                out_g, out_e = [], []
                for g, e in zip(flat_g, flat_e):
                    gm, ne = ef_allreduce_mean(g.astype(jnp.float32), e[0],
                                               "pod")
                    out_g.append(gm)
                    out_e.append(ne[None])          # keep the pod axis
                grads = jax.tree_util.tree_unflatten(tdef, out_g)
                new_ef = jax.tree_util.tree_unflatten(tdef, out_e)
            else:
                grads = lax.pmean(grads, axes)      # THE one collective
                new_ef = ef_err
            loss = lax.pmean(loss, axes)
            met = jax.tree.map(lambda x: lax.pmean(x, axes), met)
            return loss, met, grads, new_ef

        bspecs = jax.tree.map(
            lambda _: P(shard.dp if shard.batch_sharded else None), batch)
        pspec = jax.tree.map(lambda _: P(), params)
        mspec = jax.tree.map(lambda _: P(), {
            "ce": 0, "acc": 0, "moe_aux": 0, "moe_z": 0, "moe_dropped": 0})
        if n_pod:
            if ef_err is None:
                ef_err = jax.tree.map(
                    lambda p: jnp.zeros((n_pod,) + p.shape, jnp.float32),
                    params)
            ef_spec = jax.tree.map(lambda _: P("pod"), params)
            fn = jax.shard_map(
                local, mesh=shard.mesh,
                in_specs=(pspec, bspecs, ef_spec),
                out_specs=(P(), mspec, pspec, ef_spec),
                check_vma=False)
            loss, met, grads, new_ef = fn(params, batch, ef_err)
        else:
            fn = jax.shard_map(
                lambda p, b: local(p, b, None)[:3], mesh=shard.mesh,
                in_specs=(pspec, bspecs),
                out_specs=(P(), mspec, pspec),
                check_vma=False)
            loss, met, grads = fn(params, batch)
            new_ef = None
        params, opt_state, stats = opt.update(grads, opt_state, params)
        out = {"loss": loss, **met, **stats}
        if n_pod:
            out["ef_err"] = new_ef
        return params, opt_state, out

    return train_step


def make_prefill_step(cfg: ModelConfig, shard: ShardCfg):
    def prefill_step(params, batch, caches):
        return model.prefill(params, cfg, batch, caches, shard)

    return prefill_step


def make_serve_step(cfg: ModelConfig, shard: ShardCfg, *, greedy: bool = True,
                    temperature: float = 1.0):
    """One decode step: token -> (next_token, logits, caches)."""

    def serve_step(params, token, caches, cache_len, rng=None):
        logits, caches = model.decode_step(params, cfg, token, caches,
                                           cache_len, shard)
        lg = logits[:, -1].astype(jnp.float32)
        if greedy or rng is None:
            nxt = jnp.argmax(lg, axis=-1)
        else:
            nxt = jax.random.categorical(rng, lg / temperature, axis=-1)
        return nxt.astype(jnp.int32)[:, None], logits, caches

    return serve_step
