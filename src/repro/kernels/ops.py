"""jit'd public wrappers around the kernels in this package.

Each op dispatches between the Pallas 3DBLOCK template (TPU; interpret mode
for CPU validation) and the fused-jnp template (the XLA path used on CPU and
inside boundary shells).  The CFD solver and the LM stack call these — never
``pallas_call`` directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.generator import generate
from repro.kernels import stencil3d
from repro.kernels.attention import flash_attention
from repro.kernels.jacobi import jacobi_fused, jacobi_fused_ref


def default_template() -> str:
    """3DBLOCK on TPU, JNP elsewhere (dry-run/CPU/test default)."""
    return "3DBLOCK" if jax.default_backend() == "tpu" else "JNP"


@functools.lru_cache(maxsize=None)
def _kernel(name: str, template: str, interpret: bool, tile: tuple | None):
    desc = stencil3d.DESCRIPTORS[name]
    if tile is not None:
        import dataclasses

        desc = dataclasses.replace(desc, tile=tile)
    return generate(desc, stencil3d.BODIES[name], template=template,
                    interpret=interpret)


def _auto_tile(name: str, arrays: dict) -> tuple:
    """Roofline-autotuned tile for this kernel's local interior.

    Resolved from the (vmap-invisible) local array shapes, so the farm's
    slot-batched call and a serial run of the same grid tune identically —
    the memoized choice lives in ``autotune._TILE_CACHE`` and feeds the
    ``_kernel`` compile-cache key.
    """
    from repro.core import autotune

    desc = stencil3d.DESCRIPTORS[name]
    first = arrays[desc.inputs[0]]
    space = tuple(first.shape)
    if desc.inputs[0] in desc.cached_inputs:
        space = tuple(s - lo - hi for s, lo, hi in
                      zip(space, desc.halo_lo, desc.halo_hi))
    itemsize = jnp.dtype(first.dtype).itemsize
    return autotune.tile_for(desc, space, itemsize=itemsize).tile


def apply_kernel(name: str, arrays: dict, *, template: str | None = None,
                 interpret: bool = False, tile: tuple | str | None = None,
                 **params):
    """Run one descriptor kernel. ``tile`` overrides the descriptor TILE:
    a concrete 3-tuple, or ``"auto"`` for the chip-aware roofline choice
    (ignored on the JNP template, which has no tiles)."""
    tmpl = template or default_template()
    if tile == "auto":
        tile = _auto_tile(name, arrays) if tmpl == "3DBLOCK" else None
    return _kernel(name, tmpl, interpret, tile)(arrays, **params)


# -- convenience wrappers (the public op surface) ---------------------------
def update_velocity(vx, vy, vz, *, dt, h, nu, fx=0.0, fy=0.0, fz=0.0, **kw):
    out = apply_kernel(
        "UPDATE_VELOCITY", {"vx": vx, "vy": vy, "vz": vz},
        dt=dt, h=h, nu=nu, fx=fx, fy=fy, fz=fz, **kw)
    return out["vx"], out["vy"], out["vz"]


def divergence(vx, vy, vz, *, h, **kw):
    return apply_kernel("DIVERGENCE", {"vx": vx, "vy": vy, "vz": vz}, h=h, **kw)["div"]


def jacobi_pressure(p, rhs, *, h, omega=1.0, **kw):
    return apply_kernel("JACOBI_PRESSURE", {"p": p, "rhs": rhs},
                        h=h, omega=omega, **kw)["p"]


def project_velocity(vx, vy, vz, p, *, dt, h, **kw):
    out = apply_kernel(
        "PROJECT_VELOCITY", {"vx": vx, "vy": vy, "vz": vz, "p": p},
        dt=dt, h=h, **kw)
    return out["vx"], out["vy"], out["vz"]


def jacobi_smooth(p, rhs, *, h, omega=1.0, sweeps=1, template=None,
                  interpret=False, tile=(8, 8, 8)):
    """Communication-avoiding fused smoother; inputs padded by ``sweeps``."""
    tmpl = template or default_template()
    if tmpl == "JNP":
        return jacobi_fused_ref(p, rhs, h=h, omega=omega, sweeps=sweeps)
    return jacobi_fused(p, rhs, h=h, omega=omega, sweeps=sweeps, tile=tile,
                        interpret=interpret)


def mha(q, k, v, *, causal=True, q_offset=0, template=None, interpret=False,
        block_q=128, block_k=128):
    """Attention hot-spot: Pallas flash kernel on TPU, else chunked XLA.

    q: (H, Sq, D); k/v: (Hkv, Sk, D).
    """
    tmpl = template or default_template()
    if tmpl == "3DBLOCK":
        return flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    from repro.models.attention import chunked_mha  # lazy: avoid cycle

    return chunked_mha(q, k, v, causal=causal, q_offset=q_offset,
                       chunk=block_k)
