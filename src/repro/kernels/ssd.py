"""Intra-chunk SSD (Mamba2 / mLSTM) as a Pallas TPU kernel.

The chunked linear-recurrence core (`models/mamba2.ssd_core`) splits into
a cheap inter-chunk state relay and a *quadratic intra-chunk* part that
materializes (L, L) decay/score matrices per (batch, chunk, group).  In
pure XLA those temporaries round-trip HBM; this kernel computes one
(batch·chunk, group) tile entirely in VMEM:

    cum   = cumsum(log_decay)                       (L, R)
    S     = (C @ B^T)                               (L, L)
    for r: y[:, r] = (S * exp(cum_r_i - cum_r_j) * mask * dt_r) @ x[:, r]
    plus the inter-chunk contribution  y += (C @ state_r) * exp(cum_r)

Grid: (B·nc, G); blocks sized (L, R, P) — L=chunk (128 default), R heads
per group, P head_dim: VMEM ≈ L·R·P·4B ≈ 2 MB per operand at the zamba2
shapes.  MXU work is the (L,L)@(L,P) matmul per head.

Validated in interpret mode against the pure-jnp oracle
(`ssd_intra_reference` == the ssd_core intra-chunk math) over
shape/dtype sweeps in tests/test_kernels_ssd.py.  The model code tags the
jnp path with ``jax.named_scope("__kernel__ssd")`` so the dry-run roofline
prices it as this kernel (see DESIGN.md §6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_intra_kernel(x_ref, ld_ref, dt_ref, b_ref, c_ref, s0_ref, y_ref):
    """One (batch·chunk, group) tile.

    x  (1, L, 1, R, P)   values
    ld (1, L, 1, R)      log decay
    dt (1, L, 1, R)      input scale
    b  (1, L, 1, N)      input projection
    c  (1, L, 1, N)      output projection
    s0 (1, 1, R, N, P)   incoming chunk state
    y  (1, L, 1, R, P)   output
    """
    x = x_ref[0, :, 0].astype(jnp.float32)        # (L, R, P)
    ld = ld_ref[0, :, 0].astype(jnp.float32)      # (L, R)
    dt = dt_ref[0, :, 0].astype(jnp.float32)      # (L, R)
    b = b_ref[0, :, 0].astype(jnp.float32)        # (L, N)
    c = c_ref[0, :, 0].astype(jnp.float32)        # (L, N)
    s0 = s0_ref[0, 0].astype(jnp.float32)         # (R, N, P)
    l = x.shape[0]
    r = x.shape[1]

    cum = jnp.cumsum(ld, axis=0)                  # (L, R)
    scores = c @ b.T                              # (L, L)  MXU
    mask = jnp.tril(jnp.ones((l, l), jnp.bool_))

    def head(i, y):
        cr = cum[:, i]
        diff = cr[:, None] - cr[None, :]          # (L, L)
        w = jnp.where(mask, jnp.exp(diff), 0.0) * scores * dt[None, :, i]
        yi = w @ x[:, i]                          # (L, P)  MXU
        yi = yi + jnp.exp(cr)[:, None] * (c @ s0[i])
        return y.at[:, i].set(yi)

    y = jax.lax.fori_loop(0, r, head, jnp.zeros_like(x))
    y_ref[0, :, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_pallas(x, log_decay, in_scale, b_, c_, s_in, *,
                     interpret: bool = False):
    """x (B,nc,L,G,R,P), gates (B,nc,L,G,R), b_/c_ (B,nc,L,G,N),
    s_in (B,nc,G,R,N,P) -> y (B,nc,L,G,R,P)."""
    bsz, nc, l, g, r, p = x.shape
    n = b_.shape[-1]
    bc = bsz * nc
    rs = lambda t, *tail: t.reshape(bc, *tail)
    x2 = rs(x, l, g, r, p)
    ld2 = rs(log_decay, l, g, r)
    dt2 = rs(in_scale, l, g, r)
    b2 = rs(b_, l, g, n)
    c2 = rs(c_, l, g, n)
    s2 = rs(s_in, 1, g, r, n, p)[:, 0]            # (bc, g, r, n, p)

    grid = (bc, g)
    out = pl.pallas_call(
        _ssd_intra_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, l, 1, r, p), lambda i, j: (i, 0, j, 0, 0)),
            pl.BlockSpec((1, l, 1, r), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, l, 1, r), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, l, 1, n), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, l, 1, n), lambda i, j: (i, 0, j, 0)),
            pl.BlockSpec((1, 1, r, n, p), lambda i, j: (i, j, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, l, 1, r, p), lambda i, j: (i, 0, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bc, l, g, r, p), jnp.float32),
        interpret=interpret,
    )(x2, ld2, dt2, b2, c2, s2)
    return out.reshape(bsz, nc, l, g, r, p)


def ssd_intra_reference(x, log_decay, in_scale, b_, c_, s_in):
    """Pure-jnp oracle — the exact intra-chunk math of ssd_core."""
    cum = jnp.cumsum(log_decay, axis=2)
    l = x.shape[2]
    diff = cum[:, :, :, None, :, :] - cum[:, :, None, :, :, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    lmat = jnp.where(mask[None, None, :, :, None, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bclgn,bcmgn->bclmg", c_, b_)
    attw = scores[..., None] * lmat * in_scale[:, :, None, :, :, :]
    y = jnp.einsum("bclmgr,bcmgrp->bclgrp", attw, x)
    y = y + jnp.einsum("bclgn,bcgrnp->bclgrp", c_, s_in) \
        * jnp.exp(cum)[..., None]
    return y
