"""Communication-avoiding fused Jacobi smoother (beyond-paper optimization).

The paper's CFD code spends most of its time in the pressure Poisson solve,
and its scalability section identifies boundary exchange as the cost to
minimize.  A TPU-native improvement over exchanging every sweep: widen the
ghost region to ``k`` cells and fuse ``k`` Jacobi sweeps into one kernel
launch — each sweep consumes one ghost ring, so one halo exchange (width k)
feeds k sweeps.  This divides the collective *count* (latency) by k and cuts
exchanged bytes for k>2, at the cost of O(k·ring) redundant flops — the
classic communication-avoiding smoother trade, which favors TPU's
compute-rich/ICI-bound balance.

Both a Pallas 3DBLOCK version (VMEM-resident tile across all k sweeps — the
intermediate sweeps never touch HBM) and a shape-polymorphic jnp version
(oracle + boundary-shell path) are provided.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.generator import element_block_spec


def _sweep(p, rhs, h2, omega):
    """One weighted-Jacobi sweep; p padded by 1 relative to output, rhs
    padded to match p (its outer ring is unused)."""
    nbr = (p[2:, 1:-1, 1:-1] + p[:-2, 1:-1, 1:-1]
           + p[1:-1, 2:, 1:-1] + p[1:-1, :-2, 1:-1]
           + p[1:-1, 1:-1, 2:] + p[1:-1, 1:-1, :-2])
    jac = (nbr - h2 * rhs[1:-1, 1:-1, 1:-1]) / 6.0
    return (1.0 - omega) * p[1:-1, 1:-1, 1:-1] + omega * jac


def jacobi_fused_ref(p, rhs, *, h, omega=1.0, sweeps=1):
    """jnp oracle: k fused sweeps; p and rhs padded by ``sweeps`` cells."""
    h2 = h * h
    for _ in range(sweeps):
        p = _sweep(p, rhs, h2, omega)
        rhs = rhs[1:-1, 1:-1, 1:-1]
    return p


def _fused_body(p_ref, rhs_ref, o_ref, *, h2, omega, sweeps):
    p = p_ref[...].astype(jnp.float32)
    rhs = rhs_ref[...].astype(jnp.float32)
    for _ in range(sweeps):
        p = _sweep(p, rhs, h2, omega)
        rhs = rhs[1:-1, 1:-1, 1:-1]
    o_ref[...] = p.astype(o_ref.dtype)


def jacobi_fused(
    p: jnp.ndarray,
    rhs: jnp.ndarray,
    *,
    h: float,
    omega: float = 1.0,
    sweeps: int = 1,
    tile: tuple[int, int, int] = (8, 8, 8),
    interpret: bool = False,
) -> jnp.ndarray:
    """Pallas: k sweeps, one launch; inputs padded by ``sweeps`` per axis."""
    k = sweeps
    interior = tuple(s - 2 * k for s in p.shape)
    tx, ty, tz = (min(t, n) for t, n in zip(tile, interior))
    if any(n % t for n, t in zip(interior, (tx, ty, tz))):
        raise ValueError(f"interior {interior} not divisible by tile {(tx, ty, tz)}")
    grid = (interior[0] // tx, interior[1] // ty, interior[2] // tz)
    halo_spec = element_block_spec(
        (tx + 2 * k, ty + 2 * k, tz + 2 * k),
        lambda i, j, l: (i * tx, j * ty, l * tz),
    )
    out_spec = pl.BlockSpec((tx, ty, tz), lambda i, j, l: (i, j, l))
    body = functools.partial(_fused_body, h2=h * h, omega=omega, sweeps=k)
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[halo_spec, halo_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct(interior, p.dtype),
        interpret=interpret,
    )(p, rhs)
