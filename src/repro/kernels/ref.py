"""Pure-jnp oracles for every kernel in this package.

These are written independently of the descriptor/generator machinery (plain
shifted slices of padded arrays) so kernel tests compare two separate
implementations: ``pallas_call`` (interpret mode) vs these references.

Conventions match stencil3d.py: inputs are halo-padded by the declared
stencil radii; outputs are interior-shaped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _i(p, lo=(1, 1, 1), hi=(1, 1, 1), off=(0, 0, 0)):
    """Interior view of padded array ``p`` shifted by ``off``."""
    sl = tuple(
        slice(l + o, p.shape[a] - h + o) for a, (l, h, o) in enumerate(zip(lo, hi, off))
    )
    return p[sl]


def laplacian(u, h):
    """7-point Laplacian of a symmetric-padded (1,1,1) array."""
    c = lambda *o: _i(u, off=o)
    return (c(1, 0, 0) + c(-1, 0, 0) + c(0, 1, 0) + c(0, -1, 0)
            + c(0, 0, 1) + c(0, 0, -1) - 6.0 * c(0, 0, 0)) / (h * h)


def update_velocity(vx, vy, vz, *, dt, h, nu, fx=0.0, fy=0.0, fz=0.0):
    """MAC advection-diffusion; inputs padded (1,1,1) symmetric."""
    ih = 1.0 / h

    def a(f, o1, o2):
        return 0.5 * (_i(f, off=o1) + _i(f, off=o2))

    def lap(f):
        return laplacian(f, h)

    # x-momentum
    uc_r = a(vx, (0, 0, 0), (1, 0, 0)); uc_l = a(vx, (-1, 0, 0), (0, 0, 0))
    duu = (uc_r ** 2 - uc_l ** 2) * ih
    duv = (a(vx, (0, 0, 0), (0, 1, 0)) * a(vy, (0, 0, 0), (1, 0, 0))
           - a(vx, (0, -1, 0), (0, 0, 0)) * a(vy, (0, -1, 0), (1, -1, 0))) * ih
    duw = (a(vx, (0, 0, 0), (0, 0, 1)) * a(vz, (0, 0, 0), (1, 0, 0))
           - a(vx, (0, 0, -1), (0, 0, 0)) * a(vz, (0, 0, -1), (1, 0, -1))) * ih
    nvx = _i(vx) + dt * (-(duu + duv + duw) + nu * lap(vx) + fx)

    # y-momentum
    vc_r = a(vy, (0, 0, 0), (0, 1, 0)); vc_l = a(vy, (0, -1, 0), (0, 0, 0))
    dvv = (vc_r ** 2 - vc_l ** 2) * ih
    dvu = (a(vy, (0, 0, 0), (1, 0, 0)) * a(vx, (0, 0, 0), (0, 1, 0))
           - a(vy, (-1, 0, 0), (0, 0, 0)) * a(vx, (-1, 0, 0), (-1, 1, 0))) * ih
    dvw = (a(vy, (0, 0, 0), (0, 0, 1)) * a(vz, (0, 0, 0), (0, 1, 0))
           - a(vy, (0, 0, -1), (0, 0, 0)) * a(vz, (0, 0, -1), (0, 1, -1))) * ih
    nvy = _i(vy) + dt * (-(dvu + dvv + dvw) + nu * lap(vy) + fy)

    # z-momentum
    wc_r = a(vz, (0, 0, 0), (0, 0, 1)); wc_l = a(vz, (0, 0, -1), (0, 0, 0))
    dww = (wc_r ** 2 - wc_l ** 2) * ih
    dwu = (a(vz, (0, 0, 0), (1, 0, 0)) * a(vx, (0, 0, 0), (0, 0, 1))
           - a(vz, (-1, 0, 0), (0, 0, 0)) * a(vx, (-1, 0, 0), (-1, 0, 1))) * ih
    dwv = (a(vz, (0, 0, 0), (0, 1, 0)) * a(vy, (0, 0, 0), (0, 0, 1))
           - a(vz, (0, -1, 0), (0, 0, 0)) * a(vy, (0, -1, 0), (0, -1, 1))) * ih
    nvz = _i(vz) + dt * (-(dwu + dwv + dww) + nu * lap(vz) + fz)
    return nvx, nvy, nvz


def divergence(vx, vy, vz, *, h):
    """Cell divergence; velocity inputs padded (1,0) per axis (lo side)."""
    lo, hi = (1, 1, 1), (0, 0, 0)
    c = lambda f, *o: _i(f, lo, hi, o or (0, 0, 0))
    return ((c(vx) - c(vx, -1, 0, 0)) + (c(vy) - c(vy, 0, -1, 0))
            + (c(vz) - c(vz, 0, 0, -1))) / h


def jacobi_pressure(p, rhs, *, h, omega=1.0):
    """One weighted-Jacobi sweep; p padded (1,1,1), rhs interior-shaped."""
    c = lambda *o: _i(p, off=o)
    nbr = (c(1, 0, 0) + c(-1, 0, 0) + c(0, 1, 0) + c(0, -1, 0)
           + c(0, 0, 1) + c(0, 0, -1))
    jac = (nbr - h * h * rhs) / 6.0
    return (1.0 - omega) * _i(p) + omega * jac


def project_velocity(vx, vy, vz, p, *, dt, h):
    """Projection correction; velocities interior, p padded (0,1) per axis."""
    lo, hi = (0, 0, 0), (1, 1, 1)
    pc = lambda *o: _i(p, lo, hi, o or (0, 0, 0))
    s = dt / h
    return (vx - s * (pc(1, 0, 0) - pc()),
            vy - s * (pc(0, 1, 0) - pc()),
            vz - s * (pc(0, 0, 1) - pc()))


# ---------------------------------------------------------------------------
# attention oracle (for kernels/attention.py)
# ---------------------------------------------------------------------------
def mha_reference(q, k, v, *, causal=True, scale=None, q_offset=0):
    """O(S^2)-memory reference attention.

    q: (Sq, H, D), k/v: (Sk, Hkv, D) with H a multiple of Hkv (GQA).
    ``q_offset``: absolute position of q[0] (for decode/causal masking).
    """
    sq, h, d = q.shape
    sk, hkv, _ = k.shape
    rep = h // hkv
    kf = jnp.repeat(k, rep, axis=1)
    vf = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    logits = jnp.einsum("qhd,khd->hqk", q.astype(jnp.float32),
                        kf.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("hqk,khd->qhd", w, vf.astype(jnp.float32)).astype(q.dtype)
