"""Flash attention as a Pallas TPU kernel (beyond-paper LM hot-spot).

The paper's kernel-level contribution is the 3DBLOCK stencil template; the
assigned LM architectures add one more compute hot-spot the same VMEM-tiling
philosophy applies to: attention.  This kernel is the TPU-native online-
softmax tiling (Q blocks resident in VMEM, K/V streamed block-by-block over
the grid's inner dimension), with GQA head grouping.

Validated in interpret mode against ``ref.mha_reference`` (tests sweep
shapes/dtypes); the pure-XLA chunked path in ``models/attention.py`` is the
CPU/dry-run implementation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _flash_body(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                *, scale, causal, block_q, block_k, kv_len, q_offset):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)  # (block_q, d)
    k = k_ref[0].astype(jnp.float32)  # (block_k, d)
    v = v_ref[0].astype(jnp.float32)

    s = jnp.dot(q, k.T) * scale  # (block_q, block_k)
    if causal:
        qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + q_offset
        kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos <= qpos, s, _NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_cur)
    alpha = jnp.exp(m_prev - m_cur)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
    m_ref[...] = m_cur

    @pl.when(kj == (kv_len // block_k) - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray,  # (H, Sq, D)
    k: jnp.ndarray,  # (Hkv, Sk, D)
    v: jnp.ndarray,  # (Hkv, Sk, D)
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> jnp.ndarray:
    """Online-softmax attention with explicit VMEM tiling (one head-group/step).

    Heads are the outermost grid dim; GQA is expressed by mapping ``rep``
    query heads onto each KV head via the index map (no KV duplication in
    HBM — the repeat happens through block re-reads, which the paper's
    halo-overlap blocks do for stencils).
    """
    h, sq, d = q.shape
    hkv, sk, _ = k.shape
    assert h % hkv == 0, (h, hkv)
    rep = h // hkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    scale = scale if scale is not None else 1.0 / (d ** 0.5)

    grid = (h, sq // block_q, sk // block_k)

    q_spec = pl.BlockSpec((1, block_q, d), lambda hh, i, j: (hh, i, 0))
    kv_spec = pl.BlockSpec((1, block_k, d), lambda hh, i, j: (hh // rep, j, 0))
    o_spec = pl.BlockSpec((1, block_q, d), lambda hh, i, j: (hh, i, 0))

    body = functools.partial(
        _flash_body, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, kv_len=sk, q_offset=q_offset)

    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
        ],
        interpret=interpret,
    )(q, k, v)
