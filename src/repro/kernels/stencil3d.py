"""The paper's CFD hot-spot kernels, declared as CaCUDA descriptors and
expanded by the generator into Pallas ``3DBLOCK`` kernels.

These are the TPU analogues of the kernels the paper's CaCUDA templates
generate for the Navier-Stokes code (Listing 1 declares UPDATE_VELOCITY):

  UPDATE_VELOCITY   advection (MAC staggered, central) + viscous diffusion
  DIVERGENCE        cell-centered divergence of the face velocity field
  JACOBI_PRESSURE   one weighted-Jacobi sweep of the pressure Poisson eq.
  PROJECT_VELOCITY  pressure-gradient correction of the face velocities

Grid convention (staggered MAC):
  p[i,j,k]  at cell center ((i+.5)h, (j+.5)h, (k+.5)h)
  vx[i,j,k] at x-face     ((i+1 )h, (j+.5)h, (k+.5)h)   (right face of cell i)
  vy[i,j,k] at y-face     ((i+.5)h, (j+1 )h, (k+.5)h)
  vz[i,j,k] at z-face     ((i+.5)h, (j+.5)h, (k+1 )h)

All kernels read halo-padded arrays (ghosts filled by the driver's exchange,
exactly as in Cactus) and write interior arrays.  Runtime parameters passed
as Python scalars (grid geometry like ``h``) are baked as trace-time
literals, mirroring CaCUDA's compile-time parameters; parameters passed as
jax arrays/tracers (the per-simulation ``dt``, ``nu``, forcing the farm
threads through its vmapped step) ride the generator's scalar-table operand
— scalar prefetch on real TPU — in descriptor-declared column order.
"""
from __future__ import annotations

from repro.core import descriptor
from repro.core.generator import KernelContext


# --------------------------------------------------------------------------
# descriptors (the cacuda.ccl declarations)
# --------------------------------------------------------------------------
UPDATE_VELOCITY = descriptor(
    "UPDATE_VELOCITY",
    stencil=(1, 1, 1, 1, 1, 1),
    tile=(8, 8, 8),
    velocity=dict(names=("vx", "vy", "vz"), intent="SEPARATEINOUT", cached=True),
    parameters=("dt", "h", "nu", "fx", "fy", "fz"),
)

DIVERGENCE = descriptor(
    "DIVERGENCE",
    stencil=(1, 0, 1, 0, 1, 0),
    tile=(8, 8, 8),
    velocity=dict(names=("vx", "vy", "vz"), intent="IN", cached=True),
    div=dict(names=("div",), intent="OUT"),
    parameters=("h",),
)

JACOBI_PRESSURE = descriptor(
    "JACOBI_PRESSURE",
    stencil=(1, 1, 1, 1, 1, 1),
    tile=(8, 8, 8),
    pressure=dict(names=("p",), intent="SEPARATEINOUT", cached=True),
    rhs=dict(names=("rhs",), intent="IN", cached=False),
    parameters=("h", "omega"),
)

PROJECT_VELOCITY = descriptor(
    "PROJECT_VELOCITY",
    stencil=(0, 1, 0, 1, 0, 1),
    tile=(8, 8, 8),
    velocity=dict(names=("vx", "vy", "vz"), intent="SEPARATEINOUT", cached=False),
    pressure=dict(names=("p",), intent="IN", cached=True),
    parameters=("dt", "h"),
)


# --------------------------------------------------------------------------
# kernel bodies (what the application author writes; CaCUDA generates the rest)
# --------------------------------------------------------------------------
def update_velocity_body(ctx: KernelContext) -> dict:
    """Explicit advection-diffusion update for the three face velocities.

    Central (NASA-VOF2D style, donor-cell blending left to the solver layer)
    flux-form advection on the MAC grid + 7-point viscous Laplacian.
    """
    vx, vy, vz = ctx["vx"], ctx["vy"], ctx["vz"]
    dt, h, nu = ctx.param("dt"), ctx.param("h"), ctx.param("nu")
    fx, fy, fz = ctx.param("fx"), ctx.param("fy"), ctx.param("fz")
    ih = 1.0 / h

    def lap(f):
        return (
            f.at(1, 0, 0) + f.at(-1, 0, 0) + f.at(0, 1, 0) + f.at(0, -1, 0)
            + f.at(0, 0, 1) + f.at(0, 0, -1) - 6.0 * f.c
        ) * (ih * ih)

    def avg(f, o1, o2):
        return 0.5 * (f.at(*o1) + f.at(*o2))

    # ---- x-momentum at x-face (i+1)h ------------------------------------
    # d(u^2)/dx: u^2 at cell centers i and i+1
    uc_r = avg(vx, (0, 0, 0), (1, 0, 0))   # u at center of cell i+1
    uc_l = avg(vx, (-1, 0, 0), (0, 0, 0))  # u at center of cell i
    duu = (uc_r * uc_r - uc_l * uc_l) * ih
    # d(uv)/dy: corner fluxes at y = jh and (j+1)h on the x-face line
    u_yh = avg(vx, (0, 0, 0), (0, 1, 0))   # u at corner y=(j+1)h
    u_yl = avg(vx, (0, -1, 0), (0, 0, 0))  # u at corner y=jh
    v_yh = avg(vy, (0, 0, 0), (1, 0, 0))   # v at corner y=(j+1)h (avg in x)
    v_yl = avg(vy, (0, -1, 0), (1, -1, 0))
    duv = (u_yh * v_yh - u_yl * v_yl) * ih
    # d(uw)/dz
    u_zh = avg(vx, (0, 0, 0), (0, 0, 1))
    u_zl = avg(vx, (0, 0, -1), (0, 0, 0))
    w_zh = avg(vz, (0, 0, 0), (1, 0, 0))
    w_zl = avg(vz, (0, 0, -1), (1, 0, -1))
    duw = (u_zh * w_zh - u_zl * w_zl) * ih
    new_vx = vx.c + dt * (-(duu + duv + duw) + nu * lap(vx) + fx)

    # ---- y-momentum at y-face (j+1)h ------------------------------------
    vc_r = avg(vy, (0, 0, 0), (0, 1, 0))
    vc_l = avg(vy, (0, -1, 0), (0, 0, 0))
    dvv = (vc_r * vc_r - vc_l * vc_l) * ih
    v_xh = avg(vy, (0, 0, 0), (1, 0, 0))
    v_xl = avg(vy, (-1, 0, 0), (0, 0, 0))
    u_xh = avg(vx, (0, 0, 0), (0, 1, 0))
    u_xl = avg(vx, (-1, 0, 0), (-1, 1, 0))
    dvu = (v_xh * u_xh - v_xl * u_xl) * ih
    v_zh = avg(vy, (0, 0, 0), (0, 0, 1))
    v_zl = avg(vy, (0, 0, -1), (0, 0, 0))
    w_zh_y = avg(vz, (0, 0, 0), (0, 1, 0))
    w_zl_y = avg(vz, (0, 0, -1), (0, 1, -1))
    dvw = (v_zh * w_zh_y - v_zl * w_zl_y) * ih
    new_vy = vy.c + dt * (-(dvu + dvv + dvw) + nu * lap(vy) + fy)

    # ---- z-momentum at z-face (k+1)h ------------------------------------
    wc_r = avg(vz, (0, 0, 0), (0, 0, 1))
    wc_l = avg(vz, (0, 0, -1), (0, 0, 0))
    dww = (wc_r * wc_r - wc_l * wc_l) * ih
    w_xh = avg(vz, (0, 0, 0), (1, 0, 0))
    w_xl = avg(vz, (-1, 0, 0), (0, 0, 0))
    u_xh_z = avg(vx, (0, 0, 0), (0, 0, 1))
    u_xl_z = avg(vx, (-1, 0, 0), (-1, 0, 1))
    dwu = (w_xh * u_xh_z - w_xl * u_xl_z) * ih
    w_yh = avg(vz, (0, 0, 0), (0, 1, 0))
    w_yl = avg(vz, (0, -1, 0), (0, 0, 0))
    v_yh_z = avg(vy, (0, 0, 0), (0, 0, 1))
    v_yl_z = avg(vy, (0, -1, 0), (0, -1, 1))
    dwv = (w_yh * v_yh_z - w_yl * v_yl_z) * ih
    new_vz = vz.c + dt * (-(dwu + dwv + dww) + nu * lap(vz) + fz)

    return {"vx": new_vx, "vy": new_vy, "vz": new_vz}


def divergence_body(ctx: KernelContext) -> dict:
    vx, vy, vz = ctx["vx"], ctx["vy"], ctx["vz"]
    ih = 1.0 / ctx.param("h")
    div = (
        (vx.c - vx.at(-1, 0, 0))
        + (vy.c - vy.at(0, -1, 0))
        + (vz.c - vz.at(0, 0, -1))
    ) * ih
    return {"div": div}


def jacobi_pressure_body(ctx: KernelContext) -> dict:
    """Weighted Jacobi sweep: p' = (1-w) p + w (Σ nbr - h² rhs) / 6."""
    p, rhs = ctx["p"], ctx["rhs"]
    h, omega = ctx.param("h"), ctx.param("omega")
    nbr = (
        p.at(1, 0, 0) + p.at(-1, 0, 0) + p.at(0, 1, 0) + p.at(0, -1, 0)
        + p.at(0, 0, 1) + p.at(0, 0, -1)
    )
    jac = (nbr - h * h * rhs.c) / 6.0
    return {"p": (1.0 - omega) * p.c + omega * jac}


def project_velocity_body(ctx: KernelContext) -> dict:
    """u <- u - dt grad(p) at the faces (the Chorin projection correction)."""
    vx, vy, vz, p = ctx["vx"], ctx["vy"], ctx["vz"], ctx["p"]
    s = ctx.param("dt") / ctx.param("h")
    return {
        "vx": vx.c - s * (p.at(1, 0, 0) - p.c),
        "vy": vy.c - s * (p.at(0, 1, 0) - p.c),
        "vz": vz.c - s * (p.at(0, 0, 1) - p.c),
    }


BODIES = {
    "UPDATE_VELOCITY": update_velocity_body,
    "DIVERGENCE": divergence_body,
    "JACOBI_PRESSURE": jacobi_pressure_body,
    "PROJECT_VELOCITY": project_velocity_body,
}
DESCRIPTORS = {
    "UPDATE_VELOCITY": UPDATE_VELOCITY,
    "DIVERGENCE": DIVERGENCE,
    "JACOBI_PRESSURE": JACOBI_PRESSURE,
    "PROJECT_VELOCITY": PROJECT_VELOCITY,
}
