"""Sharded, async, atomic checkpointing with elastic restore.

Layout (no external deps — npz per leaf-group + JSON manifest):

    <dir>/step_000100.tmp-<nonce>/     # written here first
        manifest.json                  # tree structure, shapes, dtypes, step
        arrays.npz                     # one entry per flattened leaf
    <dir>/step_000100/                 # atomic os.replace on completion

Design points for 1000+-node operation, scaled to this harness:
  * atomicity — a checkpoint is visible iff its directory rename completed;
    a crash mid-write leaves only .tmp-* junk that cleanup() removes.
  * async     — ``save_async`` snapshots to host RAM (device_get) and
    writes on a background thread; the train loop blocks only for the
    device->host copy (the paper's copy/compute overlap applied to I/O).
  * elastic   — restore() rebuilds arrays on ANY mesh/sharding: arrays are
    saved unsharded (gathered) and re-sharded by ``jax.device_put`` against
    the target sharding, so N->M device restarts work (the multi-host
    version writes per-shard files + reshards on read; the gather here is
    the single-host analogue).
  * retention — keep_last prunes old steps after a successful save.
"""
from __future__ import annotations

import json
import os
import secrets
import threading
import time

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, keep_last: int = 3,
                 cleanup_max_age_s: float | None = 3600.0):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        # startup sweep of crash debris, age-guarded so a directory shared
        # by live processes never loses an in-flight .tmp-* write; None
        # skips the sweep entirely
        if cleanup_max_age_s is not None:
            self.cleanup(max_age_s=cleanup_max_age_s)

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and ".tmp-" not in name:
                try:
                    out.append(int(name.split("_")[1]))
                except (IndexError, ValueError):
                    continue
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree, *, blocking: bool = True):
        """Snapshot now; write async unless blocking."""
        self.wait()  # one outstanding save at a time
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def write():
            try:
                self._write(step, host)
            except BaseException as e:  # pragma: no cover
                self._error = e

        if blocking:
            write()
            self._raise_if_failed()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def save_async(self, step: int, tree):
        self.save(step, tree, blocking=False)

    def _write(self, step: int, host_tree):
        leaves, treedef = _flatten(host_tree)
        nonce = secrets.token_hex(4)
        tmp = self._step_dir(step) + f".tmp-{nonce}"
        os.makedirs(tmp, exist_ok=True)
        arrays = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "n_leaves": len(leaves),
            "treedef": str(treedef),
            "shapes": [list(np.shape(x)) for x in leaves],
            "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = self._step_dir(step)
        if os.path.exists(final):  # overwrite-same-step (restart race)
            import shutil

            shutil.rmtree(final)
        os.replace(tmp, final)
        self._prune()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError("async checkpoint failed") from e

    def _prune(self):
        steps = self.steps()
        for s in steps[: -self.keep_last] if self.keep_last else []:
            import shutil

            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def cleanup(self, max_age_s: float | None = None):
        """Remove interrupted .tmp-* writes (crash debris).

        ``max_age_s`` only removes debris whose mtime is at least that
        old — the safe mode for directories shared by live processes
        (another writer's in-flight tmp dir is seconds old, a crashed
        write's is not).  ``None`` removes all debris unconditionally.
        """
        import shutil

        now = time.time()
        for name in os.listdir(self.dir):
            if ".tmp-" not in name:
                continue
            path = os.path.join(self.dir, name)
            if max_age_s is not None:
                try:
                    if now - os.path.getmtime(path) < max_age_s:
                        continue
                except OSError:
                    continue
            shutil.rmtree(path, ignore_errors=True)

    def remove(self, step: int) -> bool:
        """Drop one saved step's directory (terminal-state pruning for
        the job store: a done/failed/diverged job's snapshots need not
        outlive its row).  Returns whether anything was removed."""
        import shutil

        d = self._step_dir(step)
        if not os.path.isdir(d):
            return False
        shutil.rmtree(d, ignore_errors=True)
        return True

    # -- restore ---------------------------------------------------------------
    def read_arrays(self, step: int) -> tuple[dict, list[np.ndarray]]:
        """``(manifest, leaves)`` of a saved step, raw — host arrays in
        flattened-tree order, no target template required.  For readers
        that rebuild structure from their own sidecar metadata (e.g. the
        health flight recorder) instead of a live solver tree."""
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        return manifest, [data[f"leaf_{i}"]
                          for i in range(manifest["n_leaves"])]

    def restore(self, step: int, target_tree, shardings=None):
        """Restore into the structure of ``target_tree`` (shapes/dtypes
        validated).  ``shardings``: optional pytree of Shardings — arrays
        are placed per-sharding (elastic N->M reshard).  A single
        ``Sharding`` broadcasts to every leaf (all-same-layout trees,
        e.g. a set of grid fields)."""
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves, treedef = _flatten(target_tree)
        if manifest["n_leaves"] != len(leaves):
            raise ValueError(
                f"checkpoint has {manifest['n_leaves']} leaves; target has "
                f"{len(leaves)} — incompatible trees")
        out = []
        if shardings is None:
            shard_leaves = [None] * len(leaves)
        elif isinstance(shardings, jax.sharding.Sharding):
            shard_leaves = [shardings] * len(leaves)
        else:
            shard_leaves = jax.tree_util.tree_flatten(shardings)[0]
        if len(shard_leaves) != len(leaves):
            raise ValueError(
                f"shardings has {len(shard_leaves)} leaves; target has "
                f"{len(leaves)} — pass one Sharding to broadcast")
        for i, (ref, sh) in enumerate(zip(leaves, shard_leaves)):
            arr = data[f"leaf_{i}"]
            if tuple(arr.shape) != tuple(np.shape(ref)):
                raise ValueError(
                    f"leaf {i}: checkpoint shape {arr.shape} != target "
                    f"{np.shape(ref)}")
            arr = arr.astype(np.asarray(ref).dtype if not hasattr(ref, "dtype")
                             else ref.dtype)
            out.append(jax.device_put(arr, sh) if sh is not None
                       else jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest(self, target_tree, shardings=None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, target_tree, shardings)
