"""Scenario registry — the Cactus "thorn list" for the simulation runtime.

Cactus applications are assemblies: physics *thorns* declare their grid
functions, parameters, and schedule-bin routines, and the flesh derives
everything else (storage, halo exchange, placement, execution order).  A
:class:`Scenario` is this repo's thorn descriptor: it names a problem
(config builder + parameter schema), optionally supplies an initial-condition
routine and analysis routines, and wires them into the
:class:`repro.core.schedule.Schedule` bins —

    INITIAL    allocate fields + apply the scenario's IC
    EVOLVE     the solver step (alias of the Cactus EVOL bin)
    ANALYSIS   diagnostics computed on demand over a finished state

``@register_scenario`` puts a scenario into the process-wide registry so
:mod:`repro.api` can resolve it by name; third-party code registers its own
scenarios exactly the way the built-ins below do (``kelvin_helmholtz`` is
deliberately written as such a "third-party" thorn: the solver knows only
its periodicity, the scenario owns the shear-layer IC).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Mapping

import jax.numpy as jnp
import numpy as np

from repro.cfd.ns3d import CFDConfig, NavierStokes3D
from repro.core.schedule import Schedule


class UnknownScenarioError(KeyError):
    """Raised when resolving a scenario name that was never registered."""


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """One entry of a scenario's parameter schema (PARAM_KEYS-style):
    a default plus a one-line doc, so the front door can list and
    validate per-run parameters without knowing any physics."""

    default: float
    doc: str = ""


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A registered problem: config builder, parameter schema, IC, analyses.

    ``builder(n, **kw)`` returns the :class:`CFDConfig`; runtime parameters
    (``params`` schema — Reynolds number, viscosity, lid velocity, ...)
    are builder keyword arguments, while ``ic_params`` shape only the
    initial condition (``init_fields``) and never enter the config.
    ``analyses`` maps a diagnostic name to ``fn(solver, state, ctx)``
    where ``ctx`` carries ``{"t", "steps"}``.
    """

    name: str
    description: str
    builder: Callable[..., CFDConfig]
    params: Mapping[str, ParamSpec] = dataclasses.field(default_factory=dict)
    ic_params: Mapping[str, ParamSpec] = dataclasses.field(
        default_factory=dict)
    init_fields: Callable[..., dict] | None = None
    analyses: Mapping[str, Callable] = dataclasses.field(default_factory=dict)

    # -- parameter plumbing ---------------------------------------------------
    def split_kwargs(self, kw: Mapping[str, Any]) -> tuple[dict, dict]:
        """Split mixed per-run kwargs into ``(builder_kw, ic_kw)``.

        IC-schema keys go to ``init_fields`` (with defaults filled in);
        everything else — runtime parameters and static solver knobs
        (``jacobi_iters``, ``dt``, ...) — flows to the builder, whose
        :class:`CFDConfig` constructor rejects unknown names.
        """
        kw = dict(kw)
        ic = {k: v.default for k, v in self.ic_params.items()}
        for k in list(kw):
            if k in self.ic_params:
                ic[k] = kw.pop(k)
        return kw, ic

    def config(self, n: int = 32, **kw) -> CFDConfig:
        """The scenario's :class:`CFDConfig` at resolution ``n``."""
        builder_kw, _ = self.split_kwargs(kw)
        return self.builder(n, **builder_kw)

    # -- schedule wiring ------------------------------------------------------
    def initial_state(self, solver: NavierStokes3D, **ic_kw) -> dict:
        """INITIAL bin, as a plain call: allocate + scenario IC."""
        return self.schedule(solver, ic=ic_kw).compile_bin("INITIAL")({})

    def schedule(self, solver: NavierStokes3D, step_fn: Callable | None = None,
                 ic: Mapping[str, Any] | None = None) -> Schedule:
        """The scenario's schedule tree against a concrete solver.

        INITIAL composes field allocation with the scenario IC (ordered
        AFTER allocation); EVOLVE holds the solver step (``step_fn``
        defaults to ``solver.make_step()`` — pass the farm's step to share
        a compiled executable); ANALYSIS entries accumulate diagnostics
        into ``state["diagnostics"]`` reading run context from
        ``state["_ctx"]``.
        """
        _, ic_kw = self.split_kwargs(dict(ic or {}))
        sched = Schedule()
        sched.register("INITIAL", "allocate_fields")(
            lambda _state: solver.init_state())
        if self.init_fields is not None:
            sched.register("INITIAL", f"ic_{self.name}",
                           after=("allocate_fields",))(
                lambda state: self.init_fields(solver, state, **ic_kw))
        if step_fn is None:
            # build the jitted step on first use, so running only the
            # INITIAL or ANALYSIS bin never pays for an EVOLVE trace
            cache: list = []

            def step_fn(state):
                if not cache:
                    cache.append(solver.make_step())
                return cache[0](state)
        sched.register("EVOLVE", "ns3d_step")(step_fn)
        for diag_name, fn in self.analyses.items():
            def entry(state, fn=fn, diag_name=diag_name):
                diags = dict(state.get("diagnostics", {}))
                diags[diag_name] = fn(solver, state, state.get("_ctx", {}))
                return dict(state, diagnostics=diags)
            sched.register("ANALYSIS", diag_name)(entry)
        return sched

    def analyze(self, solver: NavierStokes3D, state: dict,
                ctx: Mapping[str, Any] | None = None) -> dict:
        """Run the ANALYSIS bin over ``state``; returns the diagnostics."""
        st = dict(state, _ctx=dict(ctx or {}), diagnostics={})
        return self.schedule(solver).compile_bin("ANALYSIS")(st)["diagnostics"]

    # -- farm intake ----------------------------------------------------------
    def request(self, n: int = 32, *, steps: int | None = None,
                t_end: float | None = None, tag: str = "",
                steady_tol: float | None = None,
                residual_tol: float | None = None, priority: int = 0,
                config: CFDConfig | None = None, **kw):
        """A :class:`~repro.sim.farm.SimRequest` for one run of this
        scenario.  When the scenario owns an IC, the initial fields are
        built host-side and ride in ``init_state`` (per-request ICs under
        one compiled step — a decomposed farm scatters them at admission).

        ``config`` short-circuits the builder with an already-resolved
        CFDConfig (the Runtime passes its fully-configured one, so step
        counts and the executed config can never drift apart); only
        IC-schema kwargs are honoured alongside it.
        """
        from repro.sim.farm import SimRequest   # lazy: avoid import cycle

        builder_kw, ic_kw = self.split_kwargs(kw)
        cfg = config if config is not None else self.builder(n, **builder_kw)
        if steps is None:
            if t_end is None:
                raise ValueError("give either steps= or t_end=")
            steps = int(round(t_end / cfg.dt))
        init_state = None
        if self.init_fields is not None:
            # the IC is built on an undecomposed host solver: admission
            # owns the scatter, so one request serves laptop and pod
            solver = NavierStokes3D(
                dataclasses.replace(cfg, decomposition=()))
            state = self.init_fields(solver, solver.init_state(), **ic_kw)
            init_state = {k: np.asarray(v) for k, v in state.items()}
        return SimRequest(config=cfg, steps=steps,
                          tag=tag or f"{self.name}-{n}",
                          steady_tol=steady_tol, residual_tol=residual_tol,
                          priority=priority, init_state=init_state)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, Scenario] = {}


def register_scenario(obj=None, *, replace: bool = False):
    """Register a :class:`Scenario` — as a plain call, or as a decorator
    over a zero-argument factory function (the factory is invoked once at
    registration; the decorator returns the Scenario)."""
    def _register(scenario: Scenario) -> Scenario:
        if callable(scenario) and not isinstance(scenario, Scenario):
            scenario = scenario()
        if not isinstance(scenario, Scenario):
            raise TypeError(f"expected a Scenario, got {type(scenario)!r}")
        if scenario.name in _REGISTRY and not replace:
            raise ValueError(
                f"scenario {scenario.name!r} is already registered "
                "(pass replace=True to override)")
        _REGISTRY[scenario.name] = scenario
        return scenario

    if obj is None:             # @register_scenario(replace=...)
        return _register
    return _register(obj)       # @register_scenario / register_scenario(s)


def unregister_scenario(name: str) -> None:
    """Remove a registered scenario (tests / plugin teardown)."""
    _REGISTRY.pop(name, None)


def get_scenario(name) -> Scenario:
    """Resolve a scenario by name (a Scenario passes through unchanged)."""
    if isinstance(name, Scenario):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownScenarioError(
            f"unknown scenario {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def scenario_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# ---------------------------------------------------------------------------
# built-in scenarios
# ---------------------------------------------------------------------------
def _cavity_builder(n: int = 32, **kw) -> CFDConfig:
    from repro.cfd import cavity

    return cavity.config(n, **kw)


def _cavity_ghia(solver, state, ctx):
    from repro.cfd import cavity

    return cavity.ghia_errors(solver, state)


def _cavity_centerline_u(solver, state, ctx):
    from repro.cfd import cavity

    return cavity.centerline_u(solver, state)


def _kinetic_energy(solver, state, ctx):
    return solver.kinetic_energy(state)


register_scenario(Scenario(
    name="cavity",
    description="Lid-driven cavity (z-periodic quasi-2D), validated "
                "against Ghia et al. (1982) centerline profiles",
    builder=_cavity_builder,
    params={"re": ParamSpec(100.0, "Reynolds number (sets nu = 1/re)"),
            "lid_velocity": ParamSpec(1.0, "lid speed in +x at the y-hi "
                                           "wall")},
    analyses={"ghia": _cavity_ghia,
              "centerline_u": _cavity_centerline_u,
              "kinetic_energy": _kinetic_energy},
))


def _tg_builder(n: int = 32, **kw) -> CFDConfig:
    from repro.cfd import taylor_green

    return taylor_green.config(n, **kw)


def _tg_error(solver, state, ctx):
    import jax

    from repro.cfd import taylor_green

    t = float(ctx.get("t", 0.0))
    ax, ay = taylor_green.analytic(solver, t)
    # both reductions in one fetch — per-value float() syncs twice and
    # blocks the ANALYSIS bin's dispatch
    ex, ey = jax.device_get((jnp.abs(state["vx"] - ax).max(),
                             jnp.abs(state["vy"] - ay).max()))
    return {"t": t, "err_vx": float(ex), "err_vy": float(ey)}


register_scenario(Scenario(
    name="taylor_green",
    description="Periodic Taylor-Green vortex with analytic decay "
                "(end-to-end solver validation)",
    builder=_tg_builder,
    params={"nu": ParamSpec(0.1, "kinematic viscosity (decay rate)")},
    analyses={"analytic_error": _tg_error,
              "kinetic_energy": _kinetic_energy},
))


# -- Kelvin-Helmholtz: the "third-party thorn" --------------------------------
def _kh_builder(n: int = 32, nz: int = 4, nu: float = 2e-3,
                dt: float | None = None, **kw) -> CFDConfig:
    h = 2.0 * math.pi / n
    dt = dt if dt is not None else min(0.2 * h, 0.2 * h * h / (6 * nu))
    kw.setdefault("jacobi_iters", 60)
    return CFDConfig(shape=(n, n, nz), extent=2.0 * math.pi, nu=nu, dt=dt,
                     case="kelvin_helmholtz", **kw)


def _kh_init(solver, state, *, delta: float, eps: float) -> dict:
    """Double shear layer on the periodic box [0, 2pi]^2 (z-invariant):
    vx = tanh across two interfaces at y = pi/2 and y = 3pi/2, seeded with
    a sinusoidal vy perturbation that triggers the roll-up.  Fields are
    sampled at their staggered face positions (see taylor_green.analytic).
    """
    x, y, _ = solver.driver.coords()
    vx = jnp.where(y < math.pi,
                   jnp.tanh((y - 0.5 * math.pi) / delta),
                   jnp.tanh((1.5 * math.pi - y) / delta))
    vy = eps * jnp.sin(x)
    return dict(state, vx=vx.astype(jnp.float32), vy=vy.astype(jnp.float32))


def _kh_amplitude(solver, state, ctx):
    """max |vy|: the instability amplitude (grows through roll-up)."""
    return float(jnp.abs(state["vy"]).max())


@register_scenario
def kelvin_helmholtz() -> Scenario:
    return Scenario(
        name="kelvin_helmholtz",
        description="Double shear layer on the periodic box: "
                    "Kelvin-Helmholtz roll-up from a seeded perturbation",
        builder=_kh_builder,
        params={"nu": ParamSpec(2e-3, "kinematic viscosity")},
        ic_params={"delta": ParamSpec(math.pi / 15, "shear layer width"),
                   "eps": ParamSpec(0.05, "vy perturbation amplitude")},
        init_fields=_kh_init,
        analyses={"amplitude": _kh_amplitude,
                  "kinetic_energy": _kinetic_energy},
    )
