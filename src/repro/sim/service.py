"""Service front-end for the simulation farm: submit / poll / result.

The multi-tenant surface: callers hold a ``sid`` ticket, the service drives
the farm and answers status queries.  Long-running simulations can be
*evicted* — their slot state is pulled to host memory (and spilled to disk
through :class:`repro.ckpt.checkpointer.Checkpointer` when a directory is
configured, reusing its atomic-rename layout) so the slot serves other
traffic — and later *readmitted* to continue exactly where they stopped:
the saved fields re-enter a slot bit-identically, so an evicted+readmitted
run equals an uninterrupted one.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.cfd.ns3d import CFDConfig
from repro.ckpt.checkpointer import Checkpointer
from repro.sim.farm import SimRequest, SimResult, SimulationFarm


@dataclasses.dataclass
class _Evicted:
    req: SimRequest
    steps_done: int
    state: dict | None       # host state, or None when spilled to disk


class SimulationService:
    """submit/poll/result over a SimulationFarm, with eviction hooks."""

    def __init__(self, base_config: CFDConfig, n_slots: int = 8,
                 ckpt_dir: str | None = None, check_steady_every: int = 16,
                 mesh=None, slot_axis: str = "data"):
        self.farm = SimulationFarm(base_config, n_slots,
                                   check_steady_every=check_steady_every,
                                   mesh=mesh, slot_axis=slot_axis)
        self._evicted: dict[int, _Evicted] = {}
        self._requeued_progress: dict[int, int] = {}  # readmitted, waiting
        self._ckpt = Checkpointer(ckpt_dir, keep_last=0) if ckpt_dir else None

    # -- intake ---------------------------------------------------------------
    def submit(self, req: SimRequest) -> int:
        return self.farm.submit(req)

    # -- status ---------------------------------------------------------------
    def poll(self, sid: int) -> dict:
        """{"status": queued|running|evicted|done|failed, "steps_done": int}.

        A failed simulation (admission or compiled step raised) reports
        ``status="failed"`` with the captured ``error`` string."""
        if sid in self.farm.results:
            res = self.farm.results[sid]
            if res.terminated == "failed":
                return {"status": "failed", "steps_done": res.steps_done,
                        "error": res.error}
            return {"status": "done", "steps_done": res.steps_done}
        if sid in self._evicted:
            return {"status": "evicted",
                    "steps_done": self._evicted[sid].steps_done}
        running = self.farm.steps_done(sid)
        if running is not None:
            self._requeued_progress.pop(sid, None)
            return {"status": "running", "steps_done": running}
        if self.farm.known(sid):
            # a readmitted sim waiting for a slot keeps its saved progress
            return {"status": "queued",
                    "steps_done": self._requeued_progress.get(sid, 0)}
        raise KeyError(f"unknown simulation id {sid}")

    # -- driving --------------------------------------------------------------
    def run(self, device_steps: int) -> int:
        """Advance the farm up to ``device_steps``; returns steps taken."""
        return self.farm.run(device_steps)

    def result(self, sid: int, block: bool = True,
               max_device_steps: int = 100_000) -> SimResult:
        """The finished simulation; drives the farm to completion if needed."""
        if block and sid not in self.farm.results:
            if sid in self._evicted:
                self.readmit(sid)
            self.farm.run(max_device_steps,
                          until=lambda: sid in self.farm.results)
        if sid not in self.farm.results:
            raise KeyError(f"simulation {sid} has not finished "
                           f"(status: {self.poll(sid)['status']})")
        res = self.farm.results[sid]
        if res.terminated == "failed":
            raise RuntimeError(
                f"simulation {sid} ({res.tag or 'untagged'}) failed after "
                f"{res.steps_done} steps: {res.error}")
        return res

    # -- eviction / readmission ------------------------------------------------
    def evict(self, sid: int) -> bool:
        """Move a resident simulation's state off-device, freeing its slot.

        With a checkpoint directory configured the fields spill to disk via
        the atomic checkpointer (sid doubles as the step id); otherwise they
        stay in host RAM.
        """
        pulled = self.farm.evict(sid)
        if pulled is None:
            return False
        req, state, steps_done = pulled
        if self._ckpt is not None:
            self._ckpt.save(sid, state, blocking=True)
            state = None
        self._evicted[sid] = _Evicted(req=req, steps_done=steps_done,
                                      state=state)
        return True

    def readmit(self, sid: int) -> bool:
        """Re-queue an evicted simulation; it resumes at its exact step.

        The restored fields stay HOST-side while the request waits in the
        queue (readmission frees no slot by itself, and pinning a full
        state on-device would re-take the memory eviction just released);
        on a decomposed (slots × shards) farm ``write_slot`` scatters them
        to the shard layout at admission time.
        """
        ev = self._evicted.get(sid)
        if ev is None:
            return False
        state = ev.state
        if state is None:
            state = self._ckpt.restore(sid, self.farm.exec.state_template())
            state = {k: np.asarray(v) for k, v in state.items()}
        req = dataclasses.replace(ev.req, init_state=state,
                                  step0=ev.steps_done, sid=sid)
        self.farm.submit(req)
        # only now is the sim safely requeued — a failed restore above must
        # leave the eviction record intact for another attempt
        del self._evicted[sid]
        self._requeued_progress[sid] = ev.steps_done
        return True

    def drain(self, max_device_steps: int = 100_000) -> dict[int, SimResult]:
        """Readmit everything evicted, then run the farm dry.

        Always terminates with every submitted sid resolved: a sim whose
        slot config raises at admission or compile time is returned as a
        ``terminated="failed"`` result (with the error string) instead of
        wedging the drive loop — callers inspect ``result.terminated``
        rather than waiting on a sim that can never finish.
        """
        for sid in list(self._evicted):
            self.readmit(sid)
        return self.farm.run_until_drained(max_device_steps)
