"""Service front-end for the simulation farm: submit / poll / result.

The multi-tenant surface: callers hold a ``sid`` ticket, the service drives
the farm and answers status queries.  Long-running simulations can be
*evicted* — their slot state is pulled to host memory (and spilled to disk
through :class:`repro.ckpt.checkpointer.Checkpointer` when a directory is
configured, reusing its atomic-rename layout) so the slot serves other
traffic — and later *readmitted* to continue exactly where they stopped:
the saved fields re-enter a slot bit-identically, so an evicted+readmitted
run equals an uninterrupted one.

With telemetry enabled the service also runs the :mod:`repro.ft.watchdog`
machinery: every poll and every farm step-chunk is a *heartbeat* (touching
the ``heartbeat_path`` liveness file for an external orchestrator, when
configured), a gap between consecutive beats longer than the configured
deadline counts a ``service.watchdog_stalls`` metric + trace event, and a
:class:`~repro.ft.watchdog.StepWatchdog` EWMA over chunk wall-times flags
slow/hung chunks (``service.watchdog_events{kind}``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import numpy as np

from repro import obs
from repro.cfd.ns3d import CFDConfig
from repro.ckpt.checkpointer import Checkpointer
from repro.ft.watchdog import Heartbeat, StepWatchdog
from repro.sim.farm import SimRequest, SimResult, SimulationFarm, static_key


@dataclasses.dataclass
class _Evicted:
    req: SimRequest
    steps_done: int
    state: dict | None       # host state, or None when spilled to disk


class SimulationService:
    """submit/poll/result over a SimulationFarm, with eviction hooks."""

    def __init__(self, base_config: CFDConfig, n_slots: int = 8,
                 ckpt_dir: str | None = None, check_steady_every: int = 16,
                 mesh=None, slot_axis: str = "data", telemetry=None,
                 farm_id: str | None = None, health=None, store=None):
        self.tel = obs.resolve(telemetry)
        self.farm = SimulationFarm(base_config, n_slots,
                                   check_steady_every=check_steady_every,
                                   mesh=mesh, slot_axis=slot_axis,
                                   telemetry=self.tel, farm_id=farm_id,
                                   health=health)
        self._evicted: dict[int, _Evicted] = {}
        self._requeued_progress: dict[int, int] = {}  # readmitted, waiting
        self._ckpt = Checkpointer(ckpt_dir, keep_last=0) if ckpt_dir else None
        self.store = store               # repro.jobs.JobStore or None
        self._job_of: dict[int, int] = {}  # farm sid -> durable job_id
        self._last_renew = 0.0
        self._last_beat: float | None = None
        self._hb_file: Heartbeat | None = None
        self.watchdog: StepWatchdog | None = None
        if self.tel.enabled:
            cfg = self.tel.config
            if cfg.heartbeat_path is not None:
                self._hb_file = Heartbeat(cfg.heartbeat_path,
                                          interval_s=cfg.heartbeat_interval_s)
            self.watchdog = StepWatchdog()
        if self.tel.enabled or self.store is not None:
            # the farm beats on every step-chunk (with the chunk's wall
            # time); poll/result beat with no observation.  The store
            # rides the same beat for lease renewal — liveness is 'the
            # farm is stepping', no renewal thread.
            self.farm.heartbeat = self._beat
        if self.store is not None:
            self.farm.on_transition = self._store_transition

    # -- watchdog --------------------------------------------------------------
    def _beat(self, chunk_wall_s: float | None = None):
        """One liveness heartbeat (poll or step-chunk).

        Touches the liveness file, feeds the chunk time to the step
        watchdog, and — when consecutive beats are further apart than
        ``heartbeat_deadline_s`` — records a stall: the service was
        wedged (compile storm, device hang, host GC) between beats.
        """
        if self.store is not None:
            # rate-limited lease renewal: well inside the TTL, without a
            # store transaction on every chunk
            now_w = time.monotonic()
            if now_w - self._last_renew >= self.store.ttl_s / 3:
                self.store.renew()
                self._last_renew = now_w
        if not self.tel.enabled:
            return
        now = time.perf_counter()
        last, self._last_beat = self._last_beat, now
        if self._hb_file is not None:
            self._hb_file.beat()
        deadline = self.tel.config.heartbeat_deadline_s
        if last is not None and now - last > deadline:
            self.tel.metrics.inc("service.watchdog_stalls")
            self.tel.trace.emit("watchdog_stall", gap_s=now - last,
                                deadline_s=deadline)
            self._mark_unhealthy("watchdog_stall", gap_s=now - last)
        if chunk_wall_s is not None and self.watchdog is not None:
            for ev in self.watchdog.observe(self.farm.device_steps,
                                            chunk_wall_s):
                self.tel.metrics.inc("service.watchdog_events", kind=ev.kind)
                self.tel.trace.emit("watchdog_" + ev.kind, step=ev.step,
                                    step_time_s=ev.step_time,
                                    threshold_s=ev.threshold)
                if ev.kind in ("slow_step", "hang"):
                    self._mark_unhealthy("watchdog_" + ev.kind,
                                         step_time_s=ev.step_time)

    def _mark_unhealthy(self, cause: str, **detail):
        """Watchdog -> health vocabulary: a stall/slow/hang observation
        marks every resident sim ``warning`` in the health state machine,
        emitting the same ``kind="health"`` trace-event schema as
        quarantine — one timeline explains both hangs and divergences.
        Healthy frames at a later drain clear the warning."""
        monitor = self.farm.monitor
        if monitor is None:
            return
        from repro.obs.health import WARNING

        for _, entry in self.farm.table.occupied():
            monitor.mark(entry.req.sid, WARNING, cause=cause, **detail)

    # -- intake ---------------------------------------------------------------
    def submit(self, req: SimRequest, job_id: int | None = None) -> int:
        """Queue a simulation; returns its sid.

        With a job store configured the request is made durable FIRST —
        committed as a ``queued`` row, leased to this process — and only
        then admitted, so a crash between the two loses nothing (the row
        is claimable).  ``job_id`` hands in an already-claimed store row
        (the Runtime's claim/resume path) instead of inserting a new one.
        A farm-side submit failure transitions the row to ``failed``
        rather than leaving a leased orphan.
        """
        from repro import jobs

        if self.store is not None and job_id is None:
            job_id = self.store.submit(
                req, signature=str(static_key(req.config, self.farm.n_slots)),
                lease=True)
        try:
            sid = self.farm.submit(req)
        except Exception as e:
            if self.store is not None and job_id is not None:
                self.store.transition(job_id, jobs.FAILED,
                                      error=f"{type(e).__name__}: {e}",
                                      event="result")
            raise
        if self.store is not None and job_id is not None:
            self._job_of[sid] = job_id
            if self.tel.enabled:
                self.tel.trace.emit("job_submit", sid=sid, job_id=job_id,
                                    tag=req.tag)
        return sid

    def job_of(self, sid: int) -> int | None:
        """The durable job_id behind a farm sid (None without a store)."""
        return self._job_of.get(sid)

    # -- durable transitions ---------------------------------------------------
    def _store_transition(self, kind: str, req: SimRequest, result, **info):
        """Farm ``on_transition`` hook -> store rows, fired where the
        state change happens: admission marks the job ``running``;
        terminal resolutions persist the final field state (``result``
        snapshot, done jobs), register the flight record (diverged jobs),
        and transition the row — releasing the lease — in the same breath
        as the in-memory result."""
        from repro import jobs

        job_id = self._job_of.get(req.sid)
        if job_id is None:
            return
        if kind == "running":
            self.store.transition(job_id, jobs.RUNNING,
                                  steps_done=req.step0, event="admit")
        elif kind == "done":
            if self.store.keep_results:
                with self.tel.section("service.result_snapshot"):
                    self.store.save_snapshot(job_id, result.state,
                                             result.steps_done, kind="result")
            self.store.transition(job_id, jobs.DONE,
                                  steps_done=result.steps_done,
                                  terminated=result.terminated, event="result")
        elif kind in ("failed", "diverged"):
            if kind == "diverged" and info.get("flight_path"):
                # the flight record is pruned with the job and resolvable
                # from any process via the store row (dir + sid key)
                self.store.record_snapshot(
                    job_id, "flight", self.farm.flight.directory,
                    step_key=req.sid, steps_done=result.steps_done)
            self.store.transition(job_id, getattr(jobs, kind.upper()),
                                  steps_done=result.steps_done,
                                  terminated=result.terminated,
                                  error=result.error, event="result")
        if self.tel.enabled:
            self.tel.trace.emit("job", sid=req.sid, job_id=job_id,
                                transition=kind)
            self.tel.metrics.set("jobs.store_queue_depth",
                                 self.store.queue_depth())

    # -- status ---------------------------------------------------------------
    def poll(self, sid: int) -> dict:
        """{"status": queued|running|evicted|done|failed|diverged,
        "steps_done": int}.

        A failed simulation (admission or compiled step raised) reports
        ``status="failed"`` with the captured ``error`` string; a
        health-quarantined one reports ``status="diverged"`` (its
        post-mortem result — final state, flight-record path in
        ``error`` — still returns through ``result``).  On a
        health-monitored farm a *running* sim additionally carries its
        latest drained health frame under ``"health"`` (state, cause,
        step, div_linf, ke, umax, cfl, finite) — the streamed
        intermediate analysis."""
        if self.tel.enabled or self.store is not None:
            self._beat()
        if sid in self.farm.results:
            res = self.farm.results[sid]
            if res.terminated in ("failed", "diverged"):
                return {"status": res.terminated,
                        "steps_done": res.steps_done, "error": res.error}
            return {"status": "done", "steps_done": res.steps_done}
        if sid in self._evicted:
            return {"status": "evicted",
                    "steps_done": self._evicted[sid].steps_done}
        running = self.farm.steps_done(sid)
        if running is not None:
            self._requeued_progress.pop(sid, None)
            out = {"status": "running", "steps_done": running}
            if self.farm.monitor is not None:
                frame = self.farm.monitor.frame_of(sid)
                if frame is not None:
                    out["health"] = frame
            return out
        if self.farm.known(sid):
            # a readmitted sim waiting for a slot keeps its saved progress
            return {"status": "queued",
                    "steps_done": self._requeued_progress.get(sid, 0)}
        raise KeyError(f"unknown simulation id {sid}")

    # -- driving --------------------------------------------------------------
    def run(self, device_steps: int) -> int:
        """Advance the farm up to ``device_steps``; returns steps taken."""
        return self.farm.run(device_steps)

    def result(self, sid: int, block: bool = True,
               max_device_steps: int = 100_000) -> SimResult:
        """The finished simulation; drives the farm to completion if needed."""
        if block and sid not in self.farm.results:
            if sid in self._evicted:
                self.readmit(sid)
            self.farm.run(max_device_steps,
                          until=lambda: sid in self.farm.results)
        if sid not in self.farm.results:
            raise KeyError(f"simulation {sid} has not finished "
                           f"(status: {self.poll(sid)['status']})")
        res = self.farm.results[sid]
        if res.terminated == "failed":
            raise RuntimeError(
                f"simulation {sid} ({res.tag or 'untagged'}) failed after "
                f"{res.steps_done} steps: {res.error}")
        return res

    # -- eviction / readmission ------------------------------------------------
    def evict(self, sid: int) -> bool:
        """Move a resident simulation's state off-device, freeing its slot.

        With a checkpoint directory configured the fields spill to disk via
        the atomic checkpointer (sid doubles as the step id); otherwise they
        stay in host RAM.
        """
        pulled = self.farm.evict(sid)
        if pulled is None:
            return False
        req, state, steps_done = pulled
        job_id = self._job_of.get(sid)
        if self.store is not None and job_id is not None:
            # durable spill: snapshot write + (status=evicted, resume
            # pointer) land in one store transaction — a restarted process
            # claims this job and resumes it from exactly here.  The
            # legacy per-service spill directory is skipped: the store
            # owns the bytes, keyed by the globally-unique job_id.
            from repro import jobs

            with self.tel.section("service.evict_spill"):
                self.store.save_snapshot(job_id, state, steps_done,
                                         kind="evict", status=jobs.EVICTED)
            state = None
        elif self._ckpt is not None:
            with self.tel.section("service.evict_spill"):
                self._ckpt.save(sid, state, blocking=True)
            state = None
        self._evicted[sid] = _Evicted(req=req, steps_done=steps_done,
                                      state=state)
        return True

    def readmit(self, sid: int) -> bool:
        """Re-queue an evicted simulation; it resumes at its exact step.

        The restored fields stay HOST-side while the request waits in the
        queue (readmission frees no slot by itself, and pinning a full
        state on-device would re-take the memory eviction just released);
        on a decomposed (slots × shards) farm ``write_slot`` scatters them
        to the shard layout at admission time.
        """
        ev = self._evicted.get(sid)
        if ev is None:
            return False
        state = ev.state
        job_id = self._job_of.get(sid)
        if state is None and self.store is not None and job_id is not None:
            with self.tel.section("service.readmit_restore"):
                _, state = self.store.load_snapshot(job_id, kind="evict")
        elif state is None:
            with self.tel.section("service.readmit_restore"):
                state = self._ckpt.restore(sid,
                                           self.farm.exec.state_template())
                state = {k: np.asarray(v) for k, v in state.items()}
        req = dataclasses.replace(ev.req, init_state=state,
                                  step0=ev.steps_done, sid=sid)
        self.farm.submit(req)
        # only now is the sim safely requeued — a failed restore above must
        # leave the eviction record intact for another attempt
        del self._evicted[sid]
        self._requeued_progress[sid] = ev.steps_done
        return True

    def prometheus_text(self, perf: bool = False, chip="auto") -> str:
        """Prometheus text-exposition scrape of this service's telemetry
        registry (``Registry.to_prometheus``).  ``perf=True`` first
        mirrors the cost-model accounting of the farm's compiled step into
        ``repro_perf_*`` gauges (utilization, roofline seconds, predicted
        FLOPs / HBM / wire bytes per invocation) so an external scraper
        sees prediction and measurement side by side.  Disabled telemetry
        scrapes empty rather than raising."""
        if perf and self.tel.enabled:
            from repro.obs import perf as _perf

            chunk_s, _ = _perf._find_sections(self.tel.timers.snapshot(),
                                              "farm.step_chunk")
            per_step = (chunk_s / self.farm.device_steps
                        if chunk_s and self.farm.device_steps else None)
            row = _perf.farm_cost_row(self, measured_s=per_step)
            _perf.PerfReport([row], chip=chip).export_gauges(self.tel.metrics)
        return self.tel.metrics.to_prometheus()

    def drain(self, max_device_steps: int = 100_000) -> dict[int, SimResult]:
        """Readmit everything evicted, then run the farm dry.

        Always terminates with every submitted sid resolved: a sim whose
        slot config raises at admission or compile time is returned as a
        ``terminated="failed"`` result (with the error string) instead of
        wedging the drive loop — callers inspect ``result.terminated``
        rather than waiting on a sim that can never finish.
        """
        for sid in list(self._evicted):
            self.readmit(sid)
        return self.farm.run_until_drained(max_device_steps)
