"""Simulation farm: continuous-batching ensemble runtime for CFD workloads.

The serving pattern of :mod:`repro.serve.engine` (fixed device slots +
continuous batching) applied to stencil simulations: many independent
parameter variants of one case resident on a slot axis, advanced by a single
jitted vmapped step, with host-side admission/reclamation and a compile
cache so new work of an already-seen shape never recompiles.

    ensemble.py   the device layer — slot-stacked state, one step for all
    farm.py       the scheduler — queue, slots, termination, compile cache
    service.py    the front-end — submit/poll/result + evict/readmit
    scenarios.py  the registry — declarative problem specs (repro.api)

New code should reach this subsystem through :mod:`repro.api` (the
runtime front door); the constructors below remain public for one release
as the migration shim.
"""
from repro.sim.ensemble import EnsembleExecutor, stack_trees
from repro.sim.farm import (
    SimRequest, SimResult, SimulationFarm, compile_cache_stats,
    reset_compile_cache,
)
from repro.sim.scenarios import (
    ParamSpec, Scenario, UnknownScenarioError, get_scenario,
    register_scenario, scenario_names, unregister_scenario,
)
from repro.sim.service import SimulationService

__all__ = [
    "EnsembleExecutor", "ParamSpec", "Scenario", "SimRequest", "SimResult",
    "SimulationFarm", "SimulationService", "UnknownScenarioError",
    "compile_cache_stats", "get_scenario", "register_scenario",
    "reset_compile_cache", "scenario_names", "stack_trees",
    "unregister_scenario",
]
