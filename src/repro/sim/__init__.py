"""Simulation farm: continuous-batching ensemble runtime for CFD workloads.

The serving pattern of :mod:`repro.serve.engine` (fixed device slots +
continuous batching) applied to stencil simulations: many independent
parameter variants of one case resident on a slot axis, advanced by a single
jitted vmapped step, with host-side admission/reclamation and a compile
cache so new work of an already-seen shape never recompiles.

    ensemble.py   the device layer — slot-stacked state, one step for all
    farm.py       the scheduler — queue, slots, termination, compile cache
    service.py    the front-end — submit/poll/result + evict/readmit
"""
from repro.sim.ensemble import EnsembleExecutor, stack_trees
from repro.sim.farm import (
    SimRequest, SimResult, SimulationFarm, compile_cache_stats,
    reset_compile_cache,
)
from repro.sim.service import SimulationService

__all__ = [
    "EnsembleExecutor", "SimRequest", "SimResult", "SimulationFarm",
    "SimulationService", "compile_cache_stats", "reset_compile_cache",
    "stack_trees",
]
