"""Ensemble executor: one jitted step advances every resident simulation.

The device half of the simulation farm.  All ensemble members share one
compiled executable: the solver's parameterized local step is vmapped over a
leading *slot* axis of both the field state and the per-simulation scalar
struct (``ns3d.PARAM_KEYS`` — viscosity, dt, lid velocity, forcing), exactly
as the LM engine decodes its whole slot batch each step.  Because the serial
path (``NavierStokes3D.make_step``) threads the same f32 scalars through the
same traced step, a farm slot reproduces a serial run bit-for-bit — and so
does *chunked* stepping, a ``fori_loop`` of that step with a dynamic trip
count, which is how the farm amortizes host dispatch when no slot is due to
finish (the analogue of multi-token speculation windows in LM serving).

The descriptor-generated kernels batch the same way one level down:
``GeneratedKernel.apply_batched`` vmaps the JNP template and gives the
3DBLOCK Pallas template a leading batch axis in its grid/BlockSpecs; the
solver-level vmap used here subsumes both for the full CFD step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.cfd.ns3d import PARAM_KEYS, CFDConfig, NavierStokes3D


def stack_trees(trees):
    """Stack a list of identically-structured pytrees on a new slot axis 0."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def make_ensemble_step(solver: NavierStokes3D, *, mesh=None,
                       slot_axis: str = "data", n_slots: int | None = None):
    """The compiled ensemble executable for ``solver``'s configuration:
    ``run_k(state, params, k)`` advances the whole slot batch ``k`` steps
    (``k`` is a traced scalar — one compile covers every chunk size).

    With ``mesh``, the slot axis is placed over the ``slot_axis``
    data-parallel mesh axis (vmap × shard_map): each device advances its
    slice of the resident simulations, and because slots never interact,
    the distributed batch is bitwise-identical to the single-device one.
    """
    vstep = jax.vmap(solver._step_local)

    def run_k(state, params, k):
        return lax.fori_loop(0, k, lambda _, s: vstep(s, params), state)

    if mesh is None:
        return jax.jit(run_k)

    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import slot_spec

    # divisibility-guarded like every substrate rule: a slot count that
    # does not divide over the axis runs replicated (correct, just not
    # parallel) rather than erroring
    sp = slot_spec(mesh, n_slots if n_slots is not None
                   else mesh.shape[slot_axis], axis=slot_axis)
    fn = jax.shard_map(run_k, mesh=mesh, in_specs=(sp, sp, P()),
                       out_specs=sp, check_vma=False)
    return jax.jit(fn)


class EnsembleExecutor:
    """Slot-stacked state + the single jitted step that advances it.

    Owns no scheduling policy: slots are written/read by index, every step
    advances all of them (idle slots compute garbage that the farm masks on
    the host — the standard padding-batch trade from LM serving).
    """

    def __init__(self, config: CFDConfig, n_slots: int,
                 solver: NavierStokes3D | None = None, run_k=None,
                 mesh=None, slot_axis: str = "data"):
        if config.decomposition:
            raise NotImplementedError(
                "the ensemble executor batches over slots on one device "
                "mesh; per-slot grid decomposition is not supported")
        self.config = config
        self.n_slots = n_slots
        self.mesh = mesh
        self.solver = solver if solver is not None else NavierStokes3D(config)
        self._run_k = run_k if run_k is not None else make_ensemble_step(
            self.solver, mesh=mesh, slot_axis=slot_axis, n_slots=n_slots)
        fresh = self.solver.init_state()
        self._fresh = fresh            # per-slot initial state (unbatched)
        self.state = stack_trees([fresh] * n_slots)
        # per-slot scalars: host-authoritative (like the engine's slot
        # lengths), mirrored to a device struct only when admission dirties
        # them — steps between admissions ship nothing host->device
        self.params = {k: np.zeros((n_slots,), np.float32) for k in PARAM_KEYS}
        self.params["dt"][:] = np.float32(config.dt)   # idle slots stay finite
        self._params_dev = None
        self._ke = jax.jit(jax.vmap(
            lambda st: 0.5 * sum(jnp.mean(st[f] ** 2)
                                 for f in ("vx", "vy", "vz"))))

    # -- slot I/O -------------------------------------------------------------
    def write_slot(self, slot: int, params: dict, state: dict | None = None):
        """Admit a simulation: install its parameters and (re)set its fields.

        ``state=None`` writes the case's fresh initial state (new run);
        passing a host state dict readmits an evicted simulation.
        """
        src = self._fresh if state is None else {
            k: jnp.asarray(v) for k, v in state.items()}
        self.state = jax.tree_util.tree_map(
            lambda full, one: lax.dynamic_update_index_in_dim(
                full, one.astype(full.dtype), slot, 0),
            self.state, dict(src))
        for k in PARAM_KEYS:
            self.params[k][slot] = np.float32(params[k])
        self._params_dev = None

    def read_slot(self, slot: int) -> dict:
        """Host copy of one simulation's fields."""
        return {k: np.asarray(v[slot]) for k, v in self.state.items()}

    def clear_slot(self, slot: int):
        """Park a freed slot on benign parameters (finite garbage compute)."""
        for k in PARAM_KEYS:
            self.params[k][slot] = np.float32(
                self.config.dt if k == "dt" else 0.0)
        self._params_dev = None

    # -- stepping -------------------------------------------------------------
    def _device_params(self) -> dict:
        if self._params_dev is None:
            self._params_dev = {k: jnp.asarray(v)
                                for k, v in self.params.items()}
        return self._params_dev

    def step_many(self, k: int):
        """Advance the whole slot batch ``k`` device steps in one dispatch."""
        self.state = self._run_k(self.state, self._device_params(),
                                 jnp.int32(k))

    def step(self):
        """One device step for the whole slot batch."""
        self.step_many(1)

    def kinetic_energy(self) -> np.ndarray:
        """(n_slots,) per-slot kinetic energy (steady-state detection)."""
        return np.asarray(self._ke(self.state))
