"""Ensemble executor: one jitted step advances every resident simulation.

The device half of the simulation farm.  All ensemble members share one
compiled executable: the solver's parameterized local step is vmapped over a
leading *slot* axis of both the field state and the per-simulation scalar
struct (``ns3d.PARAM_KEYS`` — viscosity, dt, lid velocity, forcing), exactly
as the LM engine decodes its whole slot batch each step.  Because the serial
path (``NavierStokes3D.make_step``) threads the same f32 scalars through the
same traced step, a farm slot reproduces a serial run bit-for-bit — and so
does *chunked* stepping, a ``fori_loop`` of that step with a dynamic trip
count, which is how the farm amortizes host dispatch when no slot is due to
finish (the analogue of multi-token speculation windows in LM serving).

Two mesh placements compose (the farm's slots × shards story):

* **slot parallelism** — the slot axis spreads over a data-parallel mesh
  axis (``dist.sharding.slot_spec``); slots never interact, so the
  distributed batch is bitwise the single-device one.
* **per-slot grid decomposition** — with ``config.decomposition`` set,
  each slot's grid additionally decomposes over the named mesh axes
  (``dist.sharding.slot_field_spec``), and the vmapped step runs the
  driver's halo machinery (``exchange_pad`` / ``stencil_step_overlap``
  ppermuting over those axes) inside the same ``shard_map``.  One large
  simulation can then outgrow a single device while the farm keeps
  batching across slots.

The descriptor-generated kernels batch the same way one level down:
``GeneratedKernel.apply_batched`` vmaps the JNP template and gives the
3DBLOCK Pallas template a leading slot axis in its grid/BlockSpecs with
per-slot scalars routed through the scalar-table operand (scalar prefetch
on real TPU) — the solver-level vmap used here dispatches to exactly that
batched expansion via the generator's ``custom_vmap`` rule, so one
compiled Pallas kernel serves every resident simulation.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.cfd.ns3d import PARAM_KEYS, CFDConfig, NavierStokes3D


def stack_trees(trees):
    """Stack a list of identically-structured pytrees on a new slot axis 0."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def plan_decomposition(config: CFDConfig, mesh,
                       slot_axis: str | None = None
                       ) -> tuple[CFDConfig, dict]:
    """Resolve ``config.decomposition`` against the farm mesh.

    Returns ``(solver_config, active)`` where ``active`` maps array axis ->
    mesh axis for every decomposed axis whose mesh extent is > 1, and
    ``solver_config`` is ``config`` with exactly that decomposition.  Axes
    of extent 1 are dropped: a 1-shard mesh degrades to the plain
    slot-parallel fast path (same executable shape as an undecomposed
    farm) instead of threading no-op collectives through the step.

    Raises ``ValueError`` when a decomposition is requested without a
    mesh, or fails ``dist.sharding.validate_decomposition`` (duplicate /
    out-of-range array axis, unknown mesh axis, decomposing over the slot
    axis).  All validation runs BEFORE the extent-1 filter, so a
    mis-assembled config fails identically on a 1-shard laptop mesh and a
    real pod.
    """
    if not config.decomposition:
        return config, {}
    if mesh is None:
        raise ValueError(
            f"config.decomposition={tuple(config.decomposition)!r} asks for "
            "per-slot grid decomposition, which needs a farm mesh naming "
            "those axes (SimulationFarm(..., mesh=make_mesh((slots, shards), "
            "('slot', 'shard')))); got mesh=None")
    from repro.dist.sharding import validate_decomposition

    pairs = validate_decomposition(config.decomposition, len(config.shape),
                                   mesh.axis_names, slot_axis=slot_axis)
    active = {a: n for a, n in pairs if mesh.shape[n] > 1}
    solver_cfg = dataclasses.replace(
        config, decomposition=tuple(sorted(active.items())))
    return solver_cfg, active


def make_ensemble_step(solver: NavierStokes3D, *, mesh=None,
                       slot_axis: str = "data", n_slots: int | None = None):
    """The compiled ensemble executable for ``solver``'s configuration:
    ``run_k(state, params, k)`` advances the whole slot batch ``k`` steps
    (``k`` is a traced scalar — one compile covers every chunk size).

    With ``mesh``, the slot axis is placed over the ``slot_axis``
    data-parallel mesh axis (vmap × shard_map): each device advances its
    slice of the resident simulations, and because slots never interact,
    the distributed batch is bitwise-identical to the single-device one.

    When the solver's domain is decomposed (slots × shards), each field is
    additionally sharded over the decomposition's mesh axes and the
    vmapped step exchanges ghost zones over them; the result is bitwise
    the serial ``GridDriver`` run of the same decomposition.
    """
    vstep = jax.vmap(solver._step_local)

    def run_k(state, params, k):
        return lax.fori_loop(0, k, lambda _, s: vstep(s, params), state)

    if mesh is None:
        return jax.jit(run_k)

    from repro.dist.sharding import slot_field_spec, slot_spec

    # divisibility-guarded like every substrate rule: a slot count that
    # does not divide over the axis runs replicated (correct, just not
    # parallel) rather than erroring
    n = n_slots if n_slots is not None else mesh.shape[slot_axis]
    sp = slot_spec(mesh, n, axis=slot_axis)
    decomp = dict(solver.domain.decomposition)
    if decomp:
        state_spec = slot_field_spec(mesh, n, solver.config.shape, decomp,
                                     slot_axis=slot_axis)
    else:
        state_spec = sp
    fn = jax.shard_map(run_k, mesh=mesh, in_specs=(state_spec, sp, P()),
                       out_specs=state_spec, check_vma=False)
    return jax.jit(fn)


class EnsembleExecutor:
    """Slot-stacked state + the single jitted step that advances it.

    Owns no scheduling policy: slots are written/read by index, every step
    advances all of them (idle slots compute garbage that the farm masks on
    the host — the standard padding-batch trade from LM serving).
    """

    def __init__(self, config: CFDConfig, n_slots: int,
                 solver: NavierStokes3D | None = None, run_k=None,
                 mesh=None, slot_axis: str = "data", telemetry=None):
        from repro import obs

        self.tel = obs.resolve(telemetry)
        solver_cfg, decomp = plan_decomposition(config, mesh,
                                                slot_axis=slot_axis)
        self.config = config
        self.decomposition = decomp    # active per-slot grid decomposition
        self.n_slots = n_slots
        self.mesh = mesh
        self.slot_axis = slot_axis
        self.solver = solver if solver is not None else NavierStokes3D(
            solver_cfg, mesh if decomp else None)
        self._run_k = run_k if run_k is not None else make_ensemble_step(
            self.solver, mesh=mesh, slot_axis=slot_axis, n_slots=n_slots)
        fresh = self.solver.init_state()
        self._fresh = fresh            # per-slot initial state (unbatched)
        self.state = stack_trees([fresh] * n_slots)
        if mesh is not None:
            # pin the resident batch to its farm layout up front: slot axis
            # over `slot_axis`, grid axes over the active decomposition —
            # admissions then scatter into place instead of re-laying-out
            from repro.dist.sharding import slot_field_spec, slot_spec

            spec = (slot_field_spec(mesh, n_slots, solver_cfg.shape, decomp,
                                    slot_axis=slot_axis)
                    if decomp else slot_spec(mesh, n_slots, axis=slot_axis))
            self.state = jax.device_put(self.state,
                                        NamedSharding(mesh, spec))
        # per-slot scalars: host-authoritative (like the engine's slot
        # lengths), mirrored to a device struct only when admission dirties
        # them — steps between admissions ship nothing host->device
        self.params = {k: np.zeros((n_slots,), np.float32) for k in PARAM_KEYS}
        self.params["dt"][:] = np.float32(config.dt)   # idle slots stay finite
        self._params_dev = None
        self._ke = jax.jit(jax.vmap(
            lambda st: 0.5 * sum(jnp.mean(st[f] ** 2)
                                 for f in ("vx", "vy", "vz"))))

        # residual norm between two consecutive states: per-slot
        # ||u^{n+1} - u^n||_inf / dt over the velocity fields.  Runs OUTSIDE
        # the compiled ensemble step (on two state snapshots) so enabling
        # residual-based termination cannot perturb the step's numerics —
        # under jit on sharded inputs the max reduces globally across
        # shards without any explicit collective.
        def _resid(new, old, dt):
            per_slot = jnp.stack([
                jnp.max(jnp.abs(new[f] - old[f]),
                        axis=tuple(range(1, new[f].ndim)))
                for f in ("vx", "vy", "vz")])
            return jnp.max(per_slot, axis=0) / jnp.maximum(dt, 1e-30)

        self._resid = jax.jit(_resid)

    # -- slot I/O -------------------------------------------------------------
    def state_template(self) -> dict:
        """Host zeros with one slot's field shapes/dtypes — the restore
        template for spilled-to-disk evictions (no device gather: only
        metadata of the fresh per-slot state is read)."""
        return {k: np.zeros(v.shape, v.dtype)
                for k, v in self._fresh.items()}

    def slot_sharding(self) -> jax.sharding.Sharding | None:
        """Sharding of ONE slot's fields (grid axes only) on a decomposed
        farm — what evict must gather from and readmit must scatter back
        to; None when slots are not grid-decomposed."""
        if self.mesh is None or not self.decomposition:
            return None
        return NamedSharding(self.mesh, self.solver.field_pspec)

    def write_slot(self, slot: int, params: dict, state: dict | None = None):
        """Admit a simulation: install its parameters and (re)set its fields.

        ``state=None`` writes the case's fresh initial state (new run);
        passing a host state dict readmits an evicted simulation — on a
        decomposed farm the host fields are scattered to the slot's shard
        layout before entering the resident batch.
        """
        sh = self.slot_sharding()
        # host -> shards directly (device_put scatters a numpy array
        # per-shard); staging through jnp.asarray would first materialize
        # the FULL field on the default device — the one thing a
        # decomposed slot must never need
        place = ((lambda v: v if isinstance(v, jax.Array)
                  else jax.device_put(np.asarray(v), sh))
                 if sh is not None else jnp.asarray)
        src = self._fresh if state is None else {
            k: place(v) for k, v in state.items()}
        with self.tel.section("ensemble.write_slot"):
            self.state = jax.tree_util.tree_map(
                lambda full, one: lax.dynamic_update_index_in_dim(
                    full, one.astype(full.dtype), slot, 0),
                self.state, dict(src))
            self.tel.fence(self.state)
        for k in PARAM_KEYS:
            self.params[k][slot] = np.float32(params[k])
        self._params_dev = None

    def read_slot(self, slot: int) -> dict:
        """Host copy of one simulation's fields."""
        with self.tel.section("ensemble.read_slot"):
            return {k: np.asarray(v[slot]) for k, v in self.state.items()}

    def clear_slot(self, slot: int):
        """Park a freed slot on benign parameters (finite garbage compute)."""
        for k in PARAM_KEYS:
            self.params[k][slot] = np.float32(
                self.config.dt if k == "dt" else 0.0)
        self._params_dev = None

    # -- stepping -------------------------------------------------------------
    def _device_params(self) -> dict:
        if self._params_dev is None:
            self._params_dev = {k: jnp.asarray(v)
                                for k, v in self.params.items()}
        return self._params_dev

    def step_many(self, k: int):
        """Advance the whole slot batch ``k`` device steps in one dispatch."""
        self.state = self._run_k(self.state, self._device_params(),
                                 jnp.int32(k))

    def step(self):
        """One device step for the whole slot batch."""
        self.step_many(1)

    def kinetic_energy(self) -> np.ndarray:
        """(n_slots,) per-slot kinetic energy (steady-state detection)."""
        return np.asarray(self._ke(self.state))

    def residuals(self, prev_state) -> np.ndarray:
        """(n_slots,) per-slot ``||u_now - u_prev||_inf / dt`` — the
        steady-state residual of the resident batch relative to the
        ``prev_state`` snapshot (normally the state one device step ago)."""
        return np.asarray(self._resid(self.state, prev_state,
                                      self._device_params()["dt"]))
