"""Ensemble executor: one jitted step advances every resident simulation.

The device half of the simulation farm.  All ensemble members share one
compiled executable: the solver's parameterized local step is vmapped over a
leading *slot* axis of both the field state and the per-simulation scalar
struct (``ns3d.PARAM_KEYS`` — viscosity, dt, lid velocity, forcing), exactly
as the LM engine decodes its whole slot batch each step.  Because the serial
path (``NavierStokes3D.make_step``) threads the same f32 scalars through the
same traced step, a farm slot reproduces a serial run bit-for-bit — and so
does *chunked* stepping, a ``fori_loop`` of that step with a dynamic trip
count, which is how the farm amortizes host dispatch when no slot is due to
finish (the analogue of multi-token speculation windows in LM serving).

Two mesh placements compose (the farm's slots × shards story):

* **slot parallelism** — the slot axis spreads over a data-parallel mesh
  axis (``dist.sharding.slot_spec``); slots never interact, so the
  distributed batch is bitwise the single-device one.
* **per-slot grid decomposition** — with ``config.decomposition`` set,
  each slot's grid additionally decomposes over the named mesh axes
  (``dist.sharding.slot_field_spec``), and the vmapped step runs the
  driver's halo machinery (``exchange_pad`` / ``stencil_step_overlap``
  ppermuting over those axes) inside the same ``shard_map``.  One large
  simulation can then outgrow a single device while the farm keeps
  batching across slots.

The descriptor-generated kernels batch the same way one level down:
``GeneratedKernel.apply_batched`` vmaps the JNP template and gives the
3DBLOCK Pallas template a leading slot axis in its grid/BlockSpecs with
per-slot scalars routed through the scalar-table operand (scalar prefetch
on real TPU) — the solver-level vmap used here dispatches to exactly that
batched expansion via the generator's ``custom_vmap`` rule, so one
compiled Pallas kernel serves every resident simulation.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.cfd.ns3d import PARAM_KEYS, CFDConfig, NavierStokes3D
from repro.obs.health import N_DIAG


def stack_trees(trees):
    """Stack a list of identically-structured pytrees on a new slot axis 0."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def plan_decomposition(config: CFDConfig, mesh,
                       slot_axis: str | None = None
                       ) -> tuple[CFDConfig, dict]:
    """Resolve ``config.decomposition`` against the farm mesh.

    Returns ``(solver_config, active)`` where ``active`` maps array axis ->
    mesh axis for every decomposed axis whose mesh extent is > 1, and
    ``solver_config`` is ``config`` with exactly that decomposition.  Axes
    of extent 1 are dropped: a 1-shard mesh degrades to the plain
    slot-parallel fast path (same executable shape as an undecomposed
    farm) instead of threading no-op collectives through the step.

    Raises ``ValueError`` when a decomposition is requested without a
    mesh, or fails ``dist.sharding.validate_decomposition`` (duplicate /
    out-of-range array axis, unknown mesh axis, decomposing over the slot
    axis).  All validation runs BEFORE the extent-1 filter, so a
    mis-assembled config fails identically on a 1-shard laptop mesh and a
    real pod.
    """
    if not config.decomposition:
        return config, {}
    if mesh is None:
        raise ValueError(
            f"config.decomposition={tuple(config.decomposition)!r} asks for "
            "per-slot grid decomposition, which needs a farm mesh naming "
            "those axes (SimulationFarm(..., mesh=make_mesh((slots, shards), "
            "('slot', 'shard')))); got mesh=None")
    from repro.dist.sharding import validate_decomposition

    pairs = validate_decomposition(config.decomposition, len(config.shape),
                                   mesh.axis_names, slot_axis=slot_axis)
    active = {a: n for a, n in pairs if mesh.shape[n] > 1}
    solver_cfg = dataclasses.replace(
        config, decomposition=tuple(sorted(active.items())))
    return solver_cfg, active


def make_ensemble_step(solver: NavierStokes3D, *, mesh=None,
                       slot_axis: str = "data", n_slots: int | None = None,
                       health_window: int = 0):
    """The compiled ensemble executable for ``solver``'s configuration:
    ``run_k(state, params, k)`` advances the whole slot batch ``k`` steps
    (``k`` is a traced scalar — one compile covers every chunk size).

    With ``health_window=K > 0`` the executable becomes
    ``run_k(state, params, ring, k) -> (state, ring)``: after the ``k``
    inner steps the solver's fused ``health_diagnostics`` run ONCE on
    the chunk's final slot batch and shift into the device-side
    ``(slots, K, N_DIAG)`` ring buffer as its newest row (the oldest
    rolls off; frame column 0 is a sentinel the executor stamps with
    the absolute device step host-side when the ring is read, so the
    device carries no step counter and the dispatch ships no extra
    scalars).  Sampling per chunk — not per step — is
    what keeps the monitor's steady-state cost a vanishing fraction of
    the chunk: NaN/Inf and divergence persist in the fields, and the
    farm only acts on frames at its harvest boundaries anyway, so a
    chunk-end sample detects exactly what a per-step sample would.  The
    diagnostics are read-only reductions on the *output* of the step —
    they feed nothing back into the fields — so health-on state
    trajectories are bitwise the health-off ones, and the ring rides to
    the host only when the farm drains it at a harvest boundary (zero
    extra steady-state syncs).

    With ``mesh``, the slot axis is placed over the ``slot_axis``
    data-parallel mesh axis (vmap × shard_map): each device advances its
    slice of the resident simulations, and because slots never interact,
    the distributed batch is bitwise-identical to the single-device one.

    When the solver's domain is decomposed (slots × shards), each field is
    additionally sharded over the decomposition's mesh axes and the
    vmapped step exchanges ghost zones over them; the result is bitwise
    the serial ``GridDriver`` run of the same decomposition.
    """
    vstep = jax.vmap(solver._step_local)

    if health_window:
        vdiag = jax.vmap(solver.health_diagnostics)
        K = int(health_window)

        def run_k(state, params, ring, k):
            state = lax.fori_loop(
                0, k, lambda _, s: vstep(s, params), state)
            d = vdiag(state, params)              # (slots, N_DIAG - 1)
            # column 0 is the step stamp — written host-side on read;
            # on device it only needs to be "not the -1 blank sentinel"
            col = jnp.zeros((d.shape[0], 1), d.dtype)
            row = jnp.concatenate([col, d], axis=1)[:, None, :]
            # shift-append: newest frame last, oldest rolls off — no
            # cursor operand, rows arrive at the host already ordered
            ring = jnp.concatenate([ring[:, 1:], row.astype(ring.dtype)],
                                   axis=1)
            return state, ring
    else:
        def run_k(state, params, k):
            return lax.fori_loop(0, k, lambda _, s: vstep(s, params), state)

    if mesh is None:
        return jax.jit(run_k)

    from repro.dist.sharding import slot_field_spec, slot_spec

    # divisibility-guarded like every substrate rule: a slot count that
    # does not divide over the axis runs replicated (correct, just not
    # parallel) rather than erroring
    n = n_slots if n_slots is not None else mesh.shape[slot_axis]
    sp = slot_spec(mesh, n, axis=slot_axis)
    decomp = dict(solver.domain.decomposition)
    if decomp:
        state_spec = slot_field_spec(mesh, n, solver.config.shape, decomp,
                                     slot_axis=slot_axis)
    else:
        state_spec = sp
    if health_window:
        # the ring partitions its leading slot axis exactly like params
        in_specs = (state_spec, sp, sp, P())
        out_specs = (state_spec, sp)
    else:
        in_specs = (state_spec, sp, P())
        out_specs = state_spec
    fn = jax.shard_map(run_k, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


class EnsembleExecutor:
    """Slot-stacked state + the single jitted step that advances it.

    Owns no scheduling policy: slots are written/read by index, every step
    advances all of them (idle slots compute garbage that the farm masks on
    the host — the standard padding-batch trade from LM serving).
    """

    def __init__(self, config: CFDConfig, n_slots: int,
                 solver: NavierStokes3D | None = None, run_k=None,
                 mesh=None, slot_axis: str = "data", telemetry=None,
                 health_window: int = 0):
        from repro import obs

        self.tel = obs.resolve(telemetry)
        solver_cfg, decomp = plan_decomposition(config, mesh,
                                                slot_axis=slot_axis)
        self.config = config
        self.decomposition = decomp    # active per-slot grid decomposition
        self.n_slots = n_slots
        self.mesh = mesh
        self.slot_axis = slot_axis
        self.health_window = int(health_window)
        self.solver = solver if solver is not None else NavierStokes3D(
            solver_cfg, mesh if decomp else None)
        self._run_k = run_k if run_k is not None else make_ensemble_step(
            self.solver, mesh=mesh, slot_axis=slot_axis, n_slots=n_slots,
            health_window=self.health_window)
        fresh = self.solver.init_state()
        self._fresh = fresh            # per-slot initial state (unbatched)
        self.state = stack_trees([fresh] * n_slots)
        if mesh is not None:
            # pin the resident batch to its farm layout up front: slot axis
            # over `slot_axis`, grid axes over the active decomposition —
            # admissions then scatter into place instead of re-laying-out
            from repro.dist.sharding import slot_field_spec, slot_spec

            spec = (slot_field_spec(mesh, n_slots, solver_cfg.shape, decomp,
                                    slot_axis=slot_axis)
                    if decomp else slot_spec(mesh, n_slots, axis=slot_axis))
            self.state = jax.device_put(self.state,
                                        NamedSharding(mesh, spec))
        # device-side health ring: (slots, K, N_DIAG), shift-append (row
        # K-1 is the newest frame).  Column 0 is the device-step stamp:
        # -1 = blank sentinel on device; `read_health` overwrites it from
        # `_ring_steps`, the host-side record of each write's chunk-end
        # step — the device ships no step counter at all.  The ring
        # shards over the slot axis exactly like params.
        self.health_ring = None
        self.steps_taken = 0
        self._ring_steps: deque | None = None
        if self.health_window:
            K = self.health_window
            # step column -1 = "no frame recorded yet" sentinel
            blank = jnp.zeros((K, N_DIAG), jnp.float32).at[:, 0].set(-1.0)
            ring = jnp.broadcast_to(blank, (n_slots, K, N_DIAG))
            if mesh is not None:
                from repro.dist.sharding import slot_spec

                ring = jax.device_put(ring, NamedSharding(
                    mesh, slot_spec(mesh, n_slots, axis=slot_axis)))
            self.health_ring = ring
            self._ring_steps = deque(maxlen=K)
        # per-slot scalars: host-authoritative (like the engine's slot
        # lengths), mirrored to a device struct only when admission dirties
        # them — steps between admissions ship nothing host->device
        self.params = {k: np.zeros((n_slots,), np.float32) for k in PARAM_KEYS}
        self.params["dt"][:] = np.float32(config.dt)   # idle slots stay finite
        self._params_dev = None
        self._ke = jax.jit(jax.vmap(
            lambda st: 0.5 * sum(jnp.mean(st[f] ** 2)
                                 for f in ("vx", "vy", "vz"))))

        # residual norm between two consecutive states: per-slot
        # ||u^{n+1} - u^n||_inf / dt over the velocity fields.  Runs OUTSIDE
        # the compiled ensemble step (on two state snapshots) so enabling
        # residual-based termination cannot perturb the step's numerics —
        # under jit on sharded inputs the max reduces globally across
        # shards without any explicit collective.
        def _resid(new, old, dt):
            per_slot = jnp.stack([
                jnp.max(jnp.abs(new[f] - old[f]),
                        axis=tuple(range(1, new[f].ndim)))
                for f in ("vx", "vy", "vz")])
            return jnp.max(per_slot, axis=0) / jnp.maximum(dt, 1e-30)

        self._resid = jax.jit(_resid)

    # -- slot I/O -------------------------------------------------------------
    def state_template(self) -> dict:
        """Host zeros with one slot's field shapes/dtypes — the restore
        template for spilled-to-disk evictions (no device gather: only
        metadata of the fresh per-slot state is read)."""
        return {k: np.zeros(v.shape, v.dtype)
                for k, v in self._fresh.items()}

    def slot_sharding(self) -> jax.sharding.Sharding | None:
        """Sharding of ONE slot's fields (grid axes only) on a decomposed
        farm — what evict must gather from and readmit must scatter back
        to; None when slots are not grid-decomposed."""
        if self.mesh is None or not self.decomposition:
            return None
        return NamedSharding(self.mesh, self.solver.field_pspec)

    def write_slot(self, slot: int, params: dict, state: dict | None = None):
        """Admit a simulation: install its parameters and (re)set its fields.

        ``state=None`` writes the case's fresh initial state (new run);
        passing a host state dict readmits an evicted simulation — on a
        decomposed farm the host fields are scattered to the slot's shard
        layout before entering the resident batch.
        """
        sh = self.slot_sharding()
        # host -> shards directly (device_put scatters a numpy array
        # per-shard); staging through jnp.asarray would first materialize
        # the FULL field on the default device — the one thing a
        # decomposed slot must never need
        place = ((lambda v: v if isinstance(v, jax.Array)
                  else jax.device_put(np.asarray(v), sh))
                 if sh is not None else jnp.asarray)
        src = self._fresh if state is None else {
            k: place(v) for k, v in state.items()}
        with self.tel.section("ensemble.write_slot"):
            self.state = jax.tree_util.tree_map(
                lambda full, one: lax.dynamic_update_index_in_dim(
                    full, one.astype(full.dtype), slot, 0),
                self.state, dict(src))
            # the health ring is deliberately NOT reset here: its step
            # column is the executor's monotonic step counter, so the
            # monitor filters a previous occupant's rows by admit-time
            # device step — admission stays a single state update
            self.tel.fence(self.state)
        for k in PARAM_KEYS:
            self.params[k][slot] = np.float32(params[k])
        self._params_dev = None

    def read_slot(self, slot: int) -> dict:
        """Host copy of one simulation's fields."""
        with self.tel.section("ensemble.read_slot"):
            return {k: np.asarray(v[slot]) for k, v in self.state.items()}

    def clear_slot(self, slot: int):
        """Park a freed slot on benign parameters (finite garbage compute)."""
        for k in PARAM_KEYS:
            self.params[k][slot] = np.float32(
                self.config.dt if k == "dt" else 0.0)
        self._params_dev = None

    # -- stepping -------------------------------------------------------------
    def _device_params(self) -> dict:
        if self._params_dev is None:
            self._params_dev = {k: jnp.asarray(v)
                                for k, v in self.params.items()}
        return self._params_dev

    def step_args(self, k: int = 1) -> tuple:
        """The exact argument tuple ``_run_k`` is dispatched with — the
        perf layer lowers ``_run_k(*step_args(1))`` to cost-model the
        farm step whatever the health signature."""
        if self.health_ring is not None:
            return (self.state, self._device_params(), self.health_ring,
                    jnp.int32(k))
        return (self.state, self._device_params(), jnp.int32(k))

    def step_many(self, k: int):
        """Advance the whole slot batch ``k`` device steps in one dispatch."""
        out = self._run_k(*self.step_args(k))
        if self.health_ring is not None:
            self.state, self.health_ring = out
            # the frame sampled this dispatch is the chunk-end step
            self._ring_steps.append(self.steps_taken + int(k) - 1)
        else:
            self.state = out
        self.steps_taken += int(k)

    def read_health(self) -> np.ndarray:
        """Host copy of the ``(slots, K, N_DIAG)`` health ring — THE one
        device->host sync of the health path, issued by the farm only at
        ``check_steady_every`` harvest boundaries.  Column 0 of the last
        ``len(_ring_steps)`` rows is stamped with each frame's absolute
        device step from the host-side write record; older rows keep the
        -1 blank sentinel."""
        # np.array (not asarray): the zero-copy view of a CPU jax array
        # is read-only, and the step stamp writes into column 0
        rings = np.array(self.health_ring)
        if self._ring_steps:
            rings[:, -len(self._ring_steps):, 0] = np.asarray(
                self._ring_steps, np.float32)
        return rings

    def step(self):
        """One device step for the whole slot batch."""
        self.step_many(1)

    def kinetic_energy(self) -> np.ndarray:
        """(n_slots,) per-slot kinetic energy (steady-state detection)."""
        return np.asarray(self._ke(self.state))

    def residuals(self, prev_state) -> np.ndarray:
        """(n_slots,) per-slot ``||u_now - u_prev||_inf / dt`` — the
        steady-state residual of the resident batch relative to the
        ``prev_state`` snapshot (normally the state one device step ago)."""
        return np.asarray(self._resid(self.state, prev_state,
                                      self._device_params()["dt"]))
