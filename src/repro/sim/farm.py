"""The simulation farm: continuous batching of CFD runs over fixed slots.

Scheduling policy for the :class:`~repro.sim.ensemble.EnsembleExecutor`:
requests queue up host-side; whenever a slot frees (target step count hit or
steady state detected), the next request is admitted into it and the whole
batch keeps stepping — the vLLM pattern with CFD steps in place of token
decodes.  Admission writes the case's initial fields (or an evicted
simulation's saved fields) into the slot and installs its per-simulation
scalars; nothing ever recompiles, because the compiled ensemble step depends
only on the *static* configuration (case, grid shape, tile/template, solver
structure, slot count).

Those compiled steps live in a process-wide cache keyed by that static
signature, so a second farm — or a farm restarted after drain — of an
already-seen shape reuses the executable (hit/miss counters exposed via
:func:`compile_cache_stats` and asserted by the test suite).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax

from repro import obs
from repro.cfd.ns3d import CFDConfig, NavierStokes3D, params_from_config
from repro.serve.slots import SlotTable
from repro.sim.ensemble import (
    EnsembleExecutor, make_ensemble_step, plan_decomposition,
)


# -- compile cache -----------------------------------------------------------
# The executable cache stays process-wide on purpose (a restarted farm of a
# seen shape reuses the compiled step); the hit/miss COUNTERS are metrics:
# each farm scopes them to its own telemetry registry, so back-to-back
# runtimes no longer report each other's hits.  ``_FACADE_METRICS`` backs
# the legacy module-level ``compile_cache_stats()`` facade, which keeps its
# process-global semantics for compatibility.
_STEP_CACHE: dict[tuple, tuple[NavierStokes3D, Any]] = {}
_FACADE_METRICS = obs.Registry()

CACHE_METRIC = "farm.compile_cache"


def _count_cache(result: str, metrics=None):
    _FACADE_METRICS.inc(CACHE_METRIC, result=result)
    if metrics is not None and metrics is not _FACADE_METRICS:
        metrics.inc(CACHE_METRIC, result=result)


def static_key(config: CFDConfig, n_slots: int) -> tuple:
    """The compile signature: everything that selects the executable.

    Per-simulation physics (nu, dt, lid velocity, forcing) is deliberately
    absent — it is threaded through the step as traced scalars, so admitting
    a new parameter variant of a seen shape never recompiles.
    """
    return (
        config.case, config.shape, config.extent, config.jacobi_iters,
        config.jacobi_omega, config.fused_sweeps, config.template,
        config.interpret, config.overlap, config.decomposition, n_slots,
    )


def compiled_ensemble_step(config: CFDConfig, n_slots: int, mesh=None,
                           slot_axis: str = "data", metrics=None,
                           health_window: int = 0):
    """(solver, jitted chunked ensemble step) for the static signature.

    ``mesh`` extends the signature (a Mesh is hashable): multi-device
    farms cache separately from single-device ones of the same shape.
    With ``config.decomposition`` set, the solver is built against the
    farm mesh so each slot's grid decomposes over the named axes (the
    slots × shards path); a mesh whose decomposed axes all have extent 1
    degrades to the plain slot-parallel executable.

    ``health_window`` also extends the cache key — the in-situ health
    ring changes the executable's signature — but NOT ``static_key``
    itself: request admission matches on the physics signature alone, so
    the same requests run on health-on and health-off farms unchanged.

    ``metrics`` (an :class:`repro.obs.Registry`) additionally receives
    the ``farm.compile_cache{result=hit|miss}`` counters, scoping cache
    stats to the caller's telemetry instead of only the process facade.
    """
    key = static_key(config, n_slots) + (mesh, slot_axis if mesh else None,
                                         health_window)
    hit = _STEP_CACHE.get(key)
    if hit is not None:
        _count_cache("hit", metrics)
        return hit
    _count_cache("miss", metrics)
    solver_cfg, decomp = plan_decomposition(
        config, mesh, slot_axis=slot_axis if mesh is not None else None)
    solver = NavierStokes3D(solver_cfg, mesh if decomp else None)
    _STEP_CACHE[key] = (solver, make_ensemble_step(
        solver, mesh=mesh, slot_axis=slot_axis, n_slots=n_slots,
        health_window=health_window))
    return _STEP_CACHE[key]


def compile_cache_stats(metrics=None) -> dict:
    """Hit/miss/entry counts — process-wide by default (the legacy
    facade), or scoped to a telemetry registry when one is passed."""
    reg = metrics if metrics is not None else _FACADE_METRICS
    return {"hits": reg.get(CACHE_METRIC, result="hit") or 0,
            "misses": reg.get(CACHE_METRIC, result="miss") or 0,
            "entries": len(_STEP_CACHE)}


def reset_compile_cache():
    _STEP_CACHE.clear()
    _FACADE_METRICS.reset()


# -- requests / results ------------------------------------------------------
@dataclasses.dataclass
class SimRequest:
    """One simulation: a full per-run config + how long to run it.

    The config's static part must match the farm's; its scalar part (nu, dt,
    lid velocity, forcing) is what makes this run *this* run.  ``steps`` is
    the target device-step count.  Two early-termination criteria compose
    (first hit wins): ``residual_tol`` stops once the steady-state residual
    ``||u^{n+1} - u^n||_inf / dt`` falls below it (the physical criterion);
    ``steady_tol`` is the legacy relative kinetic-energy-drift heuristic.
    Both are evaluated on the farm's global ``check_steady_every`` cadence
    (not per-sim step counts), so a sim admitted off a check boundary may
    terminate at a different step than a serial run of the same request —
    admissions into an idle farm are boundary-aligned and match exactly.
    ``priority`` orders admission: higher levels leave the queue first,
    FIFO within a level.  ``init_state``/``step0`` readmit an evicted
    simulation mid-flight.
    """

    config: CFDConfig
    steps: int
    tag: str = ""
    steady_tol: float | None = None
    residual_tol: float | None = None
    priority: int = 0
    init_state: dict | None = None
    step0: int = 0
    sid: int | None = None   # assigned by the farm


@dataclasses.dataclass
class SimResult:
    sid: int
    tag: str
    steps_done: int
    terminated: str    # "steps" | "steady" | "residual" | "failed" | "diverged"
    state: dict              # host arrays: vx, vy, vz, p (+ masks)
    config: CFDConfig
    error: str | None = None   # set iff terminated is "failed" / "diverged"


class _SlotEntry:
    """Host bookkeeping for one resident simulation."""

    __slots__ = ("req", "steps_done", "ke_prev", "started")

    def __init__(self, req: SimRequest):
        self.req = req
        self.steps_done = req.step0
        self.ke_prev: float | None = None
        self.started = False           # first step-chunk already traced?


class SimulationFarm:
    """Queue + slots + termination around one compiled ensemble step.

    ``telemetry`` (any :func:`repro.obs.resolve` spec) instruments the
    farm: hierarchical timers around the admit / step-chunk / harvest
    phases, ``farm.*`` / ``sim.*`` metrics, and per-sim lifecycle trace
    events.  Disabled (the default) every hook is a no-op — results are
    bitwise those of an uninstrumented farm, with no extra device syncs.
    ``farm_id`` tags this farm's trace events when several farms share
    one telemetry handle (the Runtime's one-service-per-signature case).

    ``health`` (any :func:`repro.obs.health.resolve_health` spec) turns
    on in-situ health monitoring: the compiled step accumulates per-sim
    physics diagnostics into a device ring buffer, drained at the same
    ``check_steady_every`` boundary the steady checks use (zero extra
    steady-state host syncs), and a NaN/diverged sim is quarantined —
    evicted with ``terminated="diverged"`` and flight-recorded — while
    the remaining slots keep stepping bitwise-identically to a farm that
    never admitted it.  Health is independent of ``telemetry``:
    quarantine is functional behavior; events/metrics simply no-op when
    telemetry is off.
    """

    def __init__(self, base_config: CFDConfig, n_slots: int = 8,
                 check_steady_every: int = 16, mesh=None,
                 slot_axis: str = "data", telemetry=None,
                 farm_id: str | None = None, health=None):
        from repro.obs.health import (
            FlightRecorder, HealthMonitor, resolve_health,
        )

        self.base_config = base_config
        self.n_slots = n_slots
        self.check_steady_every = check_steady_every
        self.tel = obs.resolve(telemetry)
        self.farm_id = farm_id if farm_id is not None else base_config.case
        self.health = resolve_health(health)
        hw = self.health.window if self.health is not None else 0
        solver, run_k = compiled_ensemble_step(base_config, n_slots,
                                               mesh=mesh,
                                               slot_axis=slot_axis,
                                               metrics=self.tel.metrics,
                                               health_window=hw)
        self.exec = EnsembleExecutor(base_config, n_slots,
                                     solver=solver, run_k=run_k, mesh=mesh,
                                     slot_axis=slot_axis,
                                     telemetry=self.tel,
                                     health_window=hw)
        self.monitor = (HealthMonitor(self.health, telemetry=self.tel,
                                      farm_id=self.farm_id)
                        if self.health is not None else None)
        self.flight = (FlightRecorder(self.health.flight_dir)
                       if self.health is not None
                       and self.health.flight_dir else None)
        self.table = SlotTable(n_slots)
        self.results: dict[int, SimResult] = {}
        self.device_steps = 0
        self._next_sid = 0
        self._live: set[int] = set()   # queued or resident sids
        self._submit_ts: dict[int, float] = {}   # sid -> submit wall time
        self.heartbeat = None          # service-installed: fn(chunk_wall_s)
        # service-installed durable-store hook: fn(kind, req, result, **info)
        # fired at admission ("running") and at every terminal resolution
        # ("done"/"failed"/"diverged"), so each lifecycle transition lands
        # in the job store right where the state change happens.  None (the
        # default) keeps the in-memory path bitwise-untouched.
        self.on_transition = None

    def _gauge_load(self):
        """Refresh the occupancy/queue-depth gauges (telemetry only)."""
        if not self.tel.enabled:
            return
        self.tel.metrics.set("farm.slot_occupancy", self.table.n_active)
        for prio, depth in self.table.queue_depths().items():
            self.tel.metrics.set("farm.queue_depth", depth, priority=prio)

    # -- intake ---------------------------------------------------------------
    def submit(self, req: SimRequest) -> int:
        """Queue a simulation; returns its sid (poll/result handle)."""
        if static_key(req.config, self.n_slots) != static_key(
                self.base_config, self.n_slots):
            raise ValueError(
                "request's static config does not match this farm: "
                f"{static_key(req.config, self.n_slots)} vs "
                f"{static_key(self.base_config, self.n_slots)}")
        if req.steps < 0:
            raise ValueError(f"steps must be >= 0, got {req.steps}")
        if req.sid is None:
            req.sid = self._next_sid
            self._next_sid += 1
        elif req.sid in self._live or req.sid in self.results:
            # a request object is a one-shot ticket: resubmitting it while
            # its sid is queued/resident/finished would silently alias two
            # simulations onto one handle
            raise ValueError(f"sid {req.sid} is already submitted")
        else:
            # caller-set sid (readmission): reserve it so auto-assignment
            # can never alias a fresh request onto the same handle
            self._next_sid = max(self._next_sid, req.sid + 1)
        self._live.add(req.sid)
        self.table.submit(req, priority=req.priority)
        if self.tel.enabled:
            self._submit_ts.setdefault(req.sid, time.perf_counter())
            kind = "submit" if req.step0 == 0 else "readmit_submit"
            self.tel.trace.emit(
                kind, sid=req.sid, farm=self.farm_id, tag=req.tag,
                priority=req.priority, steps=req.steps, step0=req.step0,
                signature=str(static_key(req.config, self.n_slots)))
            self._gauge_load()
        return req.sid

    def _admit(self):
        with self.tel.section("farm.admit"):
            while True:
                admitted = self.table.admit_next()
                if admitted is None:
                    break
                slot, req = admitted
                # replace the queued request with live bookkeeping
                entry = _SlotEntry(req)
                self.table.replace(slot, entry)
                self.tel.trace.emit("admit", sid=req.sid, farm=self.farm_id,
                                    slot=slot, step0=req.step0, tag=req.tag)
                if self.monitor is not None:
                    # rows stamped <= the current device step belong to
                    # the slot's previous occupant
                    self.monitor.admit(req.sid, slot, tag=req.tag,
                                       last_step=self.device_steps - 1)
                try:
                    self.exec.write_slot(slot,
                                         params_from_config(req.config),
                                         state=req.init_state)
                except Exception as e:
                    # a request whose admission raises (bad readmission
                    # state, mis-shaped fields, ...) must fail alone —
                    # recorded as a per-sim failed result — instead of
                    # poisoning the farm or leaving its sid queued/running
                    # forever
                    self._fail(slot, entry, e)
                    continue
                if self.on_transition is not None:
                    self.on_transition("running", req, None)
                if entry.steps_done >= req.steps:
                    # already at (or past) its target: harvest without
                    # stepping, so a steps=0 request never advances the
                    # batch
                    self._finish(slot, entry, "steps")
            self._gauge_load()

    # -- stepping -------------------------------------------------------------
    def _chunk_size(self, max_chunk: int | None) -> int:
        """Device steps until the next host decision point.

        The batch can run on-device (one dispatch, ``fori_loop``) until the
        earliest of: a slot hitting its target step count (slot reclamation
        + admission happen then), the next steady-state check boundary, or
        the caller's budget.  Chunking is numerics-neutral — tested bitwise
        against single-stepping.
        """
        chunk = min(e.req.steps - e.steps_done
                    for _, e in self.table.occupied())
        if self.monitor is not None or any(
                e.req.steady_tol is not None or e.req.residual_tol is not None
                for _, e in self.table.occupied()):
            # health drains share the steady-check cadence: cap the chunk
            # at the boundary so the ring is read exactly there
            boundary = self.check_steady_every - (
                self.device_steps % self.check_steady_every)
            chunk = min(chunk, boundary)
        if max_chunk is not None:
            chunk = min(chunk, max_chunk)
        return max(chunk, 1)

    def step(self, max_chunk: int | None = None) -> int:
        """Admit waiting work, advance the batch one chunk, harvest
        finishers.  Returns the number of device steps taken (0 when the
        farm is empty, or when the chunk failed — the failure is recorded
        as per-sim "failed" results, never re-raised into the drive loop)."""
        self._admit()
        if self.table.n_active == 0:
            return 0
        chunk = self._chunk_size(max_chunk)
        watch_resid = any(e.req.residual_tol is not None
                          for _, e in self.table.occupied())
        at_boundary = (self.device_steps + chunk) % self.check_steady_every == 0
        resid = None
        want_wall = self.tel.enabled or self.heartbeat is not None
        t_chunk = time.perf_counter() if want_wall else 0.0
        try:
            with self.tel.section("farm.step_chunk"):
                if watch_resid and at_boundary:
                    # land the final device step alone: the residual
                    # ||u^{n+1} - u^n||_inf compares consecutive states, and
                    # chunk splitting is numerics-neutral (frozen contract)
                    if chunk > 1:
                        self.exec.step_many(chunk - 1)
                    prev = self.exec.state
                    self.exec.step_many(1)
                    resid = self.exec.residuals(prev)
                else:
                    self.exec.step_many(chunk)
                # the fence exists only behind enabled telemetry: it makes
                # the section's clock (and the watchdog's view) cover the
                # dispatched device work, never the default path
                self.tel.fence(self.exec.state)
        except Exception as e:
            # the compiled step itself failed (first-trace/compile error):
            # it is shared by every resident sim, so all of them fail
            for slot, entry in list(self.table.occupied()):
                self._fail(slot, entry, e)
            return 0
        if self.tel.enabled:
            self.tel.metrics.inc("sim.steps_total",
                                 chunk * self.table.n_active)
            for _, entry in self.table.occupied():
                if not entry.started:
                    entry.started = True
                    self.tel.trace.emit("first_step", sid=entry.req.sid,
                                        farm=self.farm_id,
                                        device_step=self.device_steps)
        if self.heartbeat is not None:
            # service watchdog hook: chunk wall time + liveness beat
            self.heartbeat(time.perf_counter() - t_chunk)
        self.device_steps += chunk
        for slot, entry in list(self.table.occupied()):
            entry.steps_done += chunk
        # drain + quarantine BEFORE the steps-target harvest: a sim that
        # goes bad in the chunk that would also have finished it reports
        # "diverged", not a healthy-looking "steps" result
        self._drain_health()
        for slot, entry in list(self.table.occupied()):
            if entry.steps_done >= entry.req.steps:
                self._finish(slot, entry, "steps")
        self._check_steady(resid)
        return chunk

    def _drain_health(self):
        """Read the device health ring (ONE host sync) at a harvest
        boundary, run every resident sim's state machine, quarantine the
        NaN/diverged ones."""
        if (self.monitor is None
                or self.device_steps % self.check_steady_every):
            return
        occupied = list(self.table.occupied())
        if not occupied:
            return
        with self.tel.section("farm.health_drain"):
            rings = self.exec.read_health()
        self.tel.metrics.inc("health.drains")
        from repro.obs.health import DIVERGED, NAN

        for slot, entry in occupied:
            rec = self.monitor.observe(entry.req.sid, rings[slot])
            if rec.state in (DIVERGED, NAN) and self.health.quarantine:
                self._quarantine(slot, entry, rec)
        self.monitor.export_gauges()

    def _quarantine(self, slot: int, entry: _SlotEntry, rec):
        """Evict a NaN/diverged sim: flight-record its last-K health
        frames + final (poisoned) state, resolve it with
        ``terminated="diverged"``, free the slot.  The surviving slots
        never see any of this — slots are independent under vmap, so
        they keep stepping bitwise as if the bad sim was never admitted.
        """
        req = entry.req
        with self.tel.section("farm.quarantine"):
            state = self.exec.read_slot(slot)
        flight_path = None
        if self.flight is not None:
            flight_path = self.flight.record(
                req.sid, frames=rec.frames_array(), state=state,
                meta={"tag": req.tag, "farm": self.farm_id, "slot": slot,
                      "state": rec.state, "cause": rec.cause,
                      "steps_done": entry.steps_done,
                      "device_step": self.device_steps,
                      "thresholds": dataclasses.asdict(self.health),
                      "signature": str(static_key(req.config,
                                                  self.n_slots))})
        err = (f"health: {rec.state} ({rec.cause}) at device step "
               f"{self.device_steps}"
               + (f"; flight record: {flight_path}" if flight_path else ""))
        self.results[req.sid] = SimResult(
            sid=req.sid, tag=req.tag, steps_done=entry.steps_done,
            terminated="diverged", state=state, config=req.config,
            error=err)
        self._live.discard(req.sid)
        self.table.release(slot)
        self.exec.clear_slot(slot)
        self.monitor.release(req.sid)
        self.tel.metrics.inc("health.quarantines")
        self._resolved(req, entry.steps_done, "diverged", error=err)
        if self.on_transition is not None:
            self.on_transition("diverged", req, self.results[req.sid],
                               flight_path=flight_path)

    def _check_steady(self, resid=None):
        if self.device_steps % self.check_steady_every:
            return
        if resid is not None:
            for slot, entry in list(self.table.occupied()):
                tol = entry.req.residual_tol
                if tol is not None and float(resid[slot]) <= tol:
                    self._finish(slot, entry, "residual")
        watched = [(s, e) for s, e in self.table.occupied()
                   if e.req.steady_tol is not None]
        if not watched:
            return
        ke = self.exec.kinetic_energy()
        for slot, entry in watched:
            k = float(ke[slot])
            prev = entry.ke_prev
            entry.ke_prev = k
            if prev is not None and abs(k - prev) <= entry.req.steady_tol * max(
                    abs(k), 1e-12):
                self._finish(slot, entry, "steady")

    def _finish(self, slot: int, entry: _SlotEntry, reason: str):
        req = entry.req
        with self.tel.section("farm.harvest"):
            state = self.exec.read_slot(slot)
            self.tel.fence(state)
        self.results[req.sid] = SimResult(
            sid=req.sid, tag=req.tag, steps_done=entry.steps_done,
            terminated=reason, state=state, config=req.config)
        self._live.discard(req.sid)
        self.table.release(slot)
        self.exec.clear_slot(slot)
        if self.monitor is not None:
            self.monitor.release(req.sid)
        self._resolved(req, entry.steps_done, reason)
        if self.on_transition is not None:
            self.on_transition("done", req, self.results[req.sid])

    def _fail(self, slot: int, entry: _SlotEntry, exc: BaseException):
        """Record a per-sim failure as a harvestable result and free the
        slot — a sim whose admission or step raised must surface through
        poll/result/drain instead of wedging the farm."""
        req = entry.req
        err = f"{type(exc).__name__}: {exc}"
        self.results[req.sid] = SimResult(
            sid=req.sid, tag=req.tag, steps_done=entry.steps_done,
            terminated="failed", state={}, config=req.config, error=err)
        self._live.discard(req.sid)
        self.table.release(slot)
        self.exec.clear_slot(slot)
        if self.monitor is not None:
            self.monitor.release(req.sid)
        self._resolved(req, entry.steps_done, "failed", error=err)
        if self.on_transition is not None:
            self.on_transition("failed", req, self.results[req.sid])

    def _resolved(self, req: SimRequest, steps_done: int, reason: str,
                  error: str | None = None):
        """Telemetry for a sid leaving the farm (finished or failed)."""
        if not self.tel.enabled:
            return
        if reason in ("steady", "residual"):
            self.tel.trace.emit("steady", sid=req.sid, farm=self.farm_id,
                                criterion=reason, steps_done=steps_done)
        extra = {"error": error} if error else {}
        self.tel.trace.emit("result", sid=req.sid, farm=self.farm_id,
                            terminated=reason, steps_done=steps_done,
                            tag=req.tag, **extra)
        self.tel.metrics.inc("sim.results", terminated=reason)
        t0 = self._submit_ts.pop(req.sid, None)
        if t0 is not None:
            self.tel.metrics.observe("service.submit_to_result_seconds",
                                     time.perf_counter() - t0,
                                     priority=req.priority)
        self._gauge_load()

    def run(self, max_device_steps: int, until=None) -> int:
        """Step until the budget, the farm drains, or ``until()`` is true.

        ``max_device_steps`` budgets *this call*, not the farm's lifetime.
        Returns the device steps taken.
        """
        taken = 0
        while taken < max_device_steps and not (until is not None and until()):
            t = self.step(max_chunk=max_device_steps - taken)
            taken += t
            if not t:
                if self.table.n_active == 0 and self.table.n_queued:
                    # a zero-step round with work still queued means the
                    # resident batch just failed out: keep admitting so
                    # every queued sim resolves (possibly also to "failed")
                    # instead of parking in the queue forever
                    continue
                break
        return taken

    def run_until_drained(self, max_device_steps: int = 100_000
                          ) -> dict[int, SimResult]:
        """Step until queue and slots are empty; returns all results."""
        self.run(max_device_steps)
        return self.results

    # -- eviction (service hook) ---------------------------------------------
    def evict(self, sid: int) -> tuple[SimRequest, dict, int] | None:
        """Pull a *running* simulation off the device mid-flight.

        Returns ``(request, host_state, steps_done)`` and frees the slot;
        None if ``sid`` is not currently resident.  Readmission goes through
        ``submit`` with ``init_state``/``step0`` set (see the service).
        """
        for slot, entry in self.table.occupied():
            if entry.req.sid == sid:
                with self.tel.section("farm.evict"):
                    state = self.exec.read_slot(slot)
                    self.tel.fence(state)
                self._live.discard(sid)
                self.table.release(slot)
                self.exec.clear_slot(slot)
                if self.monitor is not None:
                    self.monitor.release(sid)
                if self.tel.enabled:
                    self.tel.metrics.inc("sim.evictions")
                    self.tel.trace.emit("evict", sid=sid, farm=self.farm_id,
                                        slot=slot,
                                        steps_done=entry.steps_done)
                    self._gauge_load()
                return entry.req, state, entry.steps_done
        return None

    def known(self, sid: int) -> bool:
        """Has this sid ever been issued by the farm?"""
        return 0 <= sid < self._next_sid

    def steps_done(self, sid: int) -> int | None:
        for _, entry in self.table.occupied():
            if entry.req.sid == sid:
                return entry.steps_done
        return None

    def health_snapshot(self) -> dict:
        """One dashboard frame: farm id, device step, queue depth, and a
        fixed-order per-slot row (free slots included) with each resident
        sim's latest health frame when monitoring is on.  Rendered by
        ``repro.obs.health.render_dashboard`` / ``Runtime.watch``."""
        slots = []
        for slot, entry in enumerate(self.table.slots()):
            if entry is None or not isinstance(entry, _SlotEntry):
                slots.append({"slot": slot, "sid": None})
                continue
            row = {"slot": slot, "sid": entry.req.sid, "tag": entry.req.tag,
                   "steps_done": entry.steps_done, "steps": entry.req.steps}
            if self.monitor is not None:
                row["health"] = self.monitor.frame_of(entry.req.sid)
            slots.append(row)
        return {"farm": self.farm_id, "device_steps": self.device_steps,
                "queued": self.table.n_queued, "slots": slots,
                "states": (self.monitor.counts()
                           if self.monitor is not None else {})}
