"""Per-simulation lifecycle traces: JSON-lines log + Chrome trace export.

Every simulation moving through the farm leaves a breadcrumb trail —
``submit -> admit -> first_step -> (evict -> readmit)* -> steady? ->
result`` — with its request id, tag, priority, static signature, and (for
PR 4's surfaced failures) the error string.  Events append to an
in-memory list and, when a path is configured, stream to a JSON-lines
file as they happen (one JSON object per line: crash-durable, ``tail
-f``-able, trivially greppable by ``sid``).

``to_chrome()`` converts the log to the Chrome trace-event format
(https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU):
lifecycle events become instant events on one track per simulation, and
each admit..(result|evict) residency becomes a complete ("X") span on the
slot's track — load the file in Perfetto (ui.perfetto.dev) or
chrome://tracing and the farm's slot occupancy is the picture.
"""
from __future__ import annotations

import json
import threading
import time

# event kinds that end a residency span opened by "admit"
_SPAN_ENDS = ("result", "evict")

# health-vocabulary events (state transitions, watchdog marks,
# quarantines) get their own Chrome-trace process track so the health
# timeline reads separately from the lifecycle instants
_HEALTH_PID = 3


class TraceLog:
    """Append-only event log with monotonic timestamps and sequence ids.

    ``ts`` is seconds since the log was created (monotonic clock — safe
    for ordering and durations); ``wall`` anchors the log's t=0 to the
    epoch for cross-process correlation.
    """

    def __init__(self, path: str | None = None, clock=time.perf_counter):
        self._clock = clock
        self._t0 = clock()
        self.wall0 = time.time()
        self.path = path
        self._file = None
        self._lock = threading.Lock()
        self._seq = 0
        self.events: list[dict] = []

    def emit(self, kind: str, sid: int | None = None, **data) -> dict:
        """Record one event; extra keyword data must be JSON-serializable."""
        ev = {"seq": None, "ts": self._clock() - self._t0, "kind": kind}
        if sid is not None:
            ev["sid"] = sid
        ev.update(data)
        with self._lock:
            ev["seq"] = self._seq
            self._seq += 1
            self.events.append(ev)
            if self.path is not None:
                if self._file is None:
                    self._file = open(self.path, "a")
                self._file.write(json.dumps(ev) + "\n")
                self._file.flush()
        return ev

    def close(self):
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None

    # -- queries --------------------------------------------------------------
    def events_for(self, sid: int) -> list[dict]:
        with self._lock:
            return [e for e in self.events if e.get("sid") == sid]

    def kinds_for(self, sid: int) -> list[str]:
        return [e["kind"] for e in self.events_for(sid)]

    # -- serialization --------------------------------------------------------
    def dumps_jsonl(self) -> str:
        with self._lock:
            return "\n".join(json.dumps(e) for e in self.events)

    def to_chrome(self) -> dict:
        """The log as a Chrome trace-event document (Perfetto-loadable)."""
        with self._lock:
            events = [dict(e) for e in self.events]
        out = []
        open_spans: dict[int, dict] = {}   # sid -> admit event
        for ev in events:
            ts_us = ev["ts"] * 1e6
            sid = ev.get("sid")
            args = {k: v for k, v in ev.items()
                    if k not in ("seq", "ts", "kind")}
            out.append({
                "name": ev["kind"],
                "ph": "i", "s": "p",        # instant, process-scoped
                "ts": ts_us,
                "pid": _HEALTH_PID if ev["kind"] == "health" else 1,
                "tid": sid if sid is not None else 0,
                "args": args,
            })
            if sid is None:
                continue
            if ev["kind"] == "admit":
                open_spans[sid] = ev
            elif ev["kind"] in _SPAN_ENDS and sid in open_spans:
                start = open_spans.pop(sid)
                slot = start.get("slot", 0)
                out.append({
                    "name": start.get("tag") or f"sim {sid}",
                    "ph": "X",
                    "ts": start["ts"] * 1e6,
                    "dur": ts_us - start["ts"] * 1e6,
                    "pid": 2, "tid": slot,
                    "args": {"sid": sid, "until": ev["kind"]},
                })
        meta = [
            {"name": "process_name", "ph": "M", "pid": 1, "ts": 0,
             "args": {"name": "simulations"}},
            {"name": "process_name", "ph": "M", "pid": 2, "ts": 0,
             "args": {"name": "farm slots"}},
            {"name": "process_name", "ph": "M", "pid": _HEALTH_PID, "ts": 0,
             "args": {"name": "health"}},
        ]
        return {"traceEvents": meta + out, "displayTimeUnit": "ms"}

    def save_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


def validate_chrome_trace(doc: dict) -> dict:
    """Schema-check a Chrome trace-event document; returns it or raises.

    Checks the subset Perfetto actually requires: a ``traceEvents`` list
    whose entries carry ``name``/``ph``/``ts``/``pid``/``tid``, known
    phase codes, non-negative microsecond timestamps, and a duration on
    every complete ("X") event.
    """
    problems = []
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        raise ValueError("chrome trace must be a dict with a "
                         "'traceEvents' list")
    for i, ev in enumerate(doc["traceEvents"]):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field == "tid" and ev.get("ph") == "M":
                continue   # metadata events need no thread
            if field not in ev:
                problems.append(f"{where}: missing {field!r}")
        if ev.get("ph") not in ("i", "I", "X", "B", "E", "M"):
            problems.append(f"{where}: unknown phase {ev.get('ph')!r}")
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            problems.append(f"{where}: ts must be a non-negative number")
        if ev.get("ph") == "X" and not isinstance(
                ev.get("dur"), (int, float)):
            problems.append(f"{where}: complete event missing 'dur'")
    if problems:
        raise ValueError("invalid chrome trace: " + "; ".join(problems))
    return doc
