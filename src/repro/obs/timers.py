"""Hierarchical wall-clock timers — the Cactus ``TimerReport`` analogue.

Cactus attaches a clock to every thorn routine in every schedule bin and
prints the nested accumulation at shutdown; that report is how the source
paper's CaKernel work located its GPU hot spots.  :class:`TimerTree` is
the same shape: ``with tree.section("EVOLVE"):`` opens a node under the
current position (a per-thread stack), repeated sections accumulate into
one node, and ``report()`` renders the tree with per-node totals, counts,
and percent-of-parent.

Timing device work meaningfully requires a fence (JAX dispatch is async);
the tree itself is clock-agnostic — callers fence before the section
exits (see ``Telemetry.fence``), and tests inject a fake clock, which is
also what keeps the nesting invariant (sum of child totals <= parent
total once the parent is closed) exactly testable.
"""
from __future__ import annotations

import contextlib
import threading
import time


class TimerNode:
    __slots__ = ("name", "total", "count", "children")

    def __init__(self, name: str):
        self.name = name
        self.total = 0.0          # accumulated wall seconds
        self.count = 0            # completed sections
        self.children: dict[str, "TimerNode"] = {}

    def child(self, name: str) -> "TimerNode":
        node = self.children.get(name)
        if node is None:
            node = self.children[name] = TimerNode(name)
        return node

    def snapshot(self) -> dict:
        return {
            "total_s": self.total,
            "count": self.count,
            "children": {n: c.snapshot() for n, c in self.children.items()},
        }


class TimerTree:
    """Nested section timers with a per-thread position stack.

    The tree (nodes, totals) is shared and lock-guarded; *where you are*
    in it is thread-local, so two threads timing concurrently each nest
    correctly under their own open sections.
    """

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._root = TimerNode("")
        self._lock = threading.Lock()
        self._tls = threading.local()

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = [self._root]
        return st

    @contextlib.contextmanager
    def section(self, name: str):
        """Time a nested section; re-entering a name accumulates."""
        stack = self._stack()
        with self._lock:
            node = stack[-1].child(name)
        stack.append(node)
        t0 = self._clock()
        try:
            yield node
        finally:
            dt = self._clock() - t0
            stack.pop()
            with self._lock:
                node.total += dt
                node.count += 1

    # -- views ----------------------------------------------------------------
    def snapshot(self) -> dict:
        """Nested ``{name: {total_s, count, children}}`` dict."""
        with self._lock:
            return {n: c.snapshot() for n, c in self._root.children.items()}

    def reset(self):
        with self._lock:
            self._root.children.clear()

    def report(self) -> str:
        """Indented TimerReport-style rendering (totals, counts, %parent)."""
        lines = ["-- timers (wall s) --"]

        def emit(node: TimerNode, depth: int, parent_total: float | None):
            pct = ("" if parent_total is None or parent_total <= 0.0
                   else f"  {100.0 * node.total / parent_total:5.1f}%")
            avg = node.total / node.count if node.count else 0.0
            lines.append(
                f"  {'  ' * depth}{node.name:<{max(40 - 2 * depth, 8)}} "
                f"total {node.total:9.4f}  count {node.count:6d}  "
                f"avg {avg:9.6f}{pct}")
            for c in node.children.values():
                emit(c, depth + 1, node.total)

        with self._lock:
            roots = list(self._root.children.values())
        for r in roots:
            emit(r, 0, None)
        if len(lines) == 1:
            lines.append("  (no sections recorded)")
        return "\n".join(lines)
