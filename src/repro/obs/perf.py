"""repro.obs.perf — cost-model-grounded performance accounting.

PR 6's telemetry answers *where the wall-clock went*; this layer answers
*whether that time was any good* — the Cactus/CaKernel move of justifying
every kernel with hardware-grounded accounting.  It runs the
trip-count-aware HLO cost model (:mod:`repro.launch.hlo_cost`) over every
compiled executable the runtime produces — the serial schedule-bin step
and each per-static-signature farm executable, slots × shards
decomposition included — and joins the predicted cost (FLOPs, HBM bytes,
collective wire bytes) against the measured timer sections to report
achieved-vs-roofline utilization and a bottleneck classification
(compute / memory / collective) per row.

Halo traffic is double-entry bookkept: the decomposed ns3d step's
predicted ``collective-permute`` bytes (from the HLO) are compared
against the analytic ghost-zone byte count derived from
``plan_decomposition``'s active axes — :func:`halo_bytes_per_step`
mirrors the exchange sequence of ``NavierStokes3D._step_local`` exactly,
and the fast-lane test pins the two equal.

Executables that refuse both routes (optimized ``compile().as_text()``
and the pre-SPMD ``compiler_ir(dialect="hlo")`` fallback), or whose HLO
dialect the parser has not met, land as ``status="unparsed"`` rows — the
accounting never raises into a drive loop.

Surfaces: ``Runtime.report(perf=True)`` / ``Runtime.perf_report()``, the
``metrics["perf"]`` block of the ``repro.bench.v1`` envelope (consumed by
``benchmarks/check_regression.py``), and scrape-able gauges via
:meth:`PerfReport.export_gauges` behind
``SimulationService.prometheus_text()``.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.rooflinemodel import Chip, resolve_chip, terms_from_counts

PERF_SCHEMA = "repro.perf.v1"

# every attributed row carries at least these keys (the regression gate's
# contract with the bench envelope)
ROW_KEYS = ("name", "kind", "signature", "status", "n_devices", "flops",
            "hbm_bytes", "collective_wire_bytes", "invocations",
            "measured_s", "compute_s", "memory_s", "collective_s",
            "roofline_s", "bottleneck", "utilization")


@dataclasses.dataclass
class CostRow:
    """Predicted cost of ONE executable invocation, per device, plus the
    measured-time join.  ``flops``/``hbm_bytes``/``collective_wire_bytes``
    come from :func:`repro.launch.hlo_cost.safe_analyze`;
    ``measured_s``/``invocations`` from the PR 6 timer sections."""

    name: str
    kind: str                        # "farm-step" | "serial-bin"
    signature: str = "-"             # compile-cache static signature
    status: str = "ok"               # "ok" | "unparsed"
    n_devices: int = 1
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_counts: dict = dataclasses.field(default_factory=dict)
    collective_bytes: dict = dataclasses.field(default_factory=dict)
    halo_bytes_predicted: float | None = None   # permute bytes from the HLO
    halo_bytes_analytic: float | None = None    # ghost-zone model
    invocations: int = 0
    measured_s: float | None = None  # wall seconds per invocation
    # health accounting (farm rows with a health monitor): ring-buffer
    # drains performed vs harvest boundaries crossed — equal means the
    # monitor added ZERO host syncs beyond the steady-check cadence
    health_drains: int | None = None
    health_boundaries: int | None = None
    error: str | None = None


# -- cost extraction ----------------------------------------------------------
def executable_hlo(jitted, *args) -> tuple[str, str]:
    """``(hlo_text, flavor)`` of ``jitted(*args)``.

    Prefers the optimized post-SPMD text (``lower().compile()``); when the
    host cannot run the program's mesh (AbstractMesh lowering, or more
    shards than devices) it falls back to the pre-SPMD
    ``compiler_ir(dialect="hlo")`` dump — still per-shard-shaped under
    ``shard_map``, with every ghost-face ``collective-permute`` explicit.
    """
    lowered = jitted.lower(*args)
    try:
        return lowered.compile().as_text(), "optimized"
    except Exception:
        return lowered.compiler_ir(dialect="hlo").as_hlo_text(), "pre-spmd"


def cost_row_from_hlo(hlo_text: str, *, name: str, kind: str,
                      signature: str = "-", n_devices: int = 1) -> CostRow:
    """Run the cost model over ``hlo_text``; parse failures record
    ``status="unparsed"`` instead of raising."""
    from repro.launch import hlo_cost

    cost, status, err = hlo_cost.safe_analyze(hlo_text, n_devices)
    row = CostRow(
        name=name, kind=kind, signature=signature, status=status,
        n_devices=n_devices, flops=float(cost.flops),
        hbm_bytes=float(cost.bytes),
        collective_wire_bytes=float(cost.collective_wire_bytes),
        collective_counts={k: float(v)
                           for k, v in cost.collective_counts.items()},
        collective_bytes={k: float(v)
                          for k, v in cost.collective_bytes.items()},
        error=err)
    if "collective-permute" in row.collective_bytes:
        row.halo_bytes_predicted = row.collective_bytes["collective-permute"]
    return row


# -- analytic halo model ------------------------------------------------------
def _norm_w(w) -> tuple[int, int]:
    if isinstance(w, int):
        return (w, w)
    lo, hi = w
    return (int(lo), int(hi))


def exchange_permute_bytes(local_shape, widths, active_axes,
                           itemsize: int = 4) -> int:
    """Per-device ``collective-permute`` operand bytes of ONE
    ``exchange_pad(u, widths, specs)`` call.

    Mirrors ``repro.core.halo._pad_axis`` exactly: axes pad sequentially
    (later axes exchange strips of the already-padded earlier axes — the
    corner trick), each decomposed axis side ships one strip of width
    ``w`` at the CURRENT padded shape, and non-decomposed axes still grow
    the shape by their BC padding.
    """
    shape = list(local_shape)
    total = 0
    for ax, w in enumerate(widths):
        lo, hi = _norm_w(w)
        if ax in active_axes:
            for side in (lo, hi):
                if side:
                    strip = list(shape)
                    strip[ax] = side
                    total += math.prod(strip) * itemsize
        shape[ax] += lo + hi
    return total


def halo_bytes_per_step(config, active: dict, mesh_extents: dict, *,
                        slots_local: int = 1, itemsize: int = 4) -> int:
    """Analytic per-device ``collective-permute`` operand bytes of ONE
    decomposed ns3d step — the ground truth the HLO-predicted halo bytes
    are validated against.

    Mirrors the exchange sequence of ``NavierStokes3D._step_local``:
    three velocity fields at widths (1,1,1); three one-sided divergence
    pads ((1,0),)*3; the Jacobi loop — ``max(jacobi_iters //
    max(fused_sweeps,1), 1)`` iterations padding ``p`` (and, when the
    communication-avoiding smoother is on, also ``rhs``) at the sweep
    width; one one-sided projection pad ((0,1),)*3.  ``active`` maps array
    axis -> mesh axis (``plan_decomposition``'s output); ``mesh_extents``
    maps mesh axis -> extent; ``slots_local`` multiplies for the farm's
    per-device resident slots (the vmapped batch dimension rides inside
    every strip).  The in-situ health diagnostics add nothing here: their
    divergence stencil is interior-only (ghost-free by construction), so
    a health-monitored farm step moves exactly these bytes too.
    """
    local = list(config.shape)
    for ax, mesh_axis in active.items():
        local[ax] //= mesh_extents[mesh_axis]
    act = set(active)
    k = max(config.fused_sweeps, 1)
    iters = max(config.jacobi_iters // k, 1)
    per_slot = 3 * exchange_permute_bytes(local, (1, 1, 1), act, itemsize)
    per_slot += 3 * exchange_permute_bytes(local, ((1, 0),) * 3, act,
                                           itemsize)
    if k <= 1:
        per_slot += iters * exchange_permute_bytes(local, (1, 1, 1), act,
                                                   itemsize)
    else:  # fused smoother pads p AND rhs at width k each iteration
        per_slot += iters * 2 * exchange_permute_bytes(local, (k, k, k), act,
                                                       itemsize)
    per_slot += exchange_permute_bytes(local, ((0, 1),) * 3, act, itemsize)
    return per_slot * slots_local


def decomposed_step_hlo(config, *, n_slots: int, mesh_axes,
                        slot_axis: str = "slot") -> tuple[str, dict]:
    """``(hlo_text, active)`` of the slots × shards ensemble step lowered
    over an :class:`jax.sharding.AbstractMesh` — no devices needed.

    The fast-lane cost path: the pre-SPMD dump is per-shard-shaped inside
    ``shmap_body`` with one explicit ``collective-permute`` per ghost
    face, so the cost model sees exactly the decomposed traffic a real
    pod would ship.  ``mesh_axes`` is an ordered tuple of
    ``(name, extent)`` pairs, e.g. ``(("slot", 2), ("shard", 2))``.
    """
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp
    from jax.sharding import AbstractMesh

    from repro.cfd.ns3d import PARAM_KEYS, NavierStokes3D
    from repro.sim.ensemble import make_ensemble_step, plan_decomposition

    mesh = AbstractMesh(tuple(mesh_axes))
    solver_cfg, active = plan_decomposition(config, mesh,
                                            slot_axis=slot_axis)
    # the AbstractMesh satisfies the driver's axis-name/divisibility checks;
    # nothing device-touching (init_state/sharding) runs on this solver
    solver = NavierStokes3D(solver_cfg, mesh if active else None)
    step = make_ensemble_step(solver, mesh=mesh, slot_axis=slot_axis,
                              n_slots=n_slots)
    ref = NavierStokes3D(_dc.replace(solver_cfg, decomposition=()))
    one = jax.eval_shape(ref.init_state)
    state = {k: jax.ShapeDtypeStruct((n_slots,) + tuple(v.shape), v.dtype)
             for k, v in one.items()}
    params = {k: jax.ShapeDtypeStruct((n_slots,), jnp.float32)
              for k in PARAM_KEYS}
    lowered = step.lower(state, params, jax.ShapeDtypeStruct((), jnp.int32))
    return lowered.compiler_ir(dialect="hlo").as_hlo_text(), active


# -- runtime extraction -------------------------------------------------------
def _find_sections(timers: dict, name: str) -> tuple[float, int]:
    """Sum (total_s, count) over every node named ``name`` in a nested
    timer snapshot, wherever it nests."""
    tot, cnt = 0.0, 0

    def walk(children: dict):
        nonlocal tot, cnt
        for k, v in children.items():
            if k == name:
                tot += float(v.get("total_s", 0.0))
                cnt += int(v.get("count", 0))
            walk(v.get("children", {}))

    walk(timers or {})
    return tot, cnt


def _slots_local(n_slots: int, slot_extent: int) -> int:
    """Resident slots per device: the slot axis divides when it can,
    replicates otherwise (``dist.sharding.slot_spec``'s guard)."""
    if slot_extent > 1 and n_slots % slot_extent == 0:
        return n_slots // slot_extent
    return n_slots


def farm_cost_row(service, *, signature: str = "-",
                  measured_s: float | None = None) -> CostRow:
    """Cost row of one ``SimulationService``'s compiled ensemble step
    (one invocation = one device step of the whole slot batch).  On a
    health-monitored farm the row also books the drain accounting
    (``health_drains`` performed vs ``health_boundaries`` crossed) so the
    report shows whether the monitor stayed on the harvest cadence."""
    ex = service.farm.exec
    farm = service.farm
    name = f"farm/{farm.farm_id}"
    n_dev = int(ex.mesh.size) if ex.mesh is not None else 1
    try:
        # step_args carries the health ring when enabled, so the lowered
        # executable is the one the farm actually runs
        text, _ = executable_hlo(ex._run_k, *ex.step_args(1))
    except Exception as e:
        return CostRow(name=name, kind="farm-step", signature=signature,
                       status="unparsed", n_devices=n_dev,
                       error=f"{type(e).__name__}: {e}")
    row = cost_row_from_hlo(text, name=name, kind="farm-step",
                            signature=signature, n_devices=n_dev)
    row.invocations = int(farm.device_steps)
    row.measured_s = measured_s
    if ex.decomposition and ex.mesh is not None:
        extents = dict(ex.mesh.shape)
        # the health diagnostics are ghost-free (interior stencil), so
        # the analytic halo count is the same with the monitor compiled in
        row.halo_bytes_analytic = float(halo_bytes_per_step(
            ex.solver.config, dict(ex.decomposition), extents,
            slots_local=_slots_local(ex.n_slots,
                                     extents.get(ex.slot_axis, 1))))
    if ex.health_window:
        row.health_drains = int(service.tel.metrics.get("health.drains")
                                or 0)
        row.health_boundaries = int(farm.device_steps
                                    // farm.check_steady_every)
    return row


def health_overhead_model(ex_off, ex_on, check_every: int) -> dict:
    """Deterministic steady-state price of the compiled-in health monitor.

    Lowers both executors' real ``run_k`` programs and runs the HLO cost
    model over them.  The chunk length ``k`` is a dynamic operand, so the
    model prices one loop iteration plus the chunk epilogue: exactly one
    device step for the health-off program, one step plus one
    diagnostics pass for the health-on program (the diagnostics sample
    the chunk's final state, outside the loop).  The steady overhead is
    therefore ``(bytes_on - bytes_off) / (check_every * bytes_off)`` —
    one diagnostics pass amortized over the ``check_steady_every`` steps
    whose chunk boundary its drain rides.  The stencil programs carry no
    dot/conv, so HBM traffic is the currency (the binding roofline axis
    for this solver).

    The bench gate holds this number to its bound instead of a
    wall-clock ratio: two separately compiled executables show
    several-percent process-level code-layout/scheduling variance on
    shared hosts (the sign of the difference flips between identical
    runs), which would turn a small wall gate into a coin flip, while
    the modeled byte count is bit-stable across runs and hosts.
    """
    rows = {}
    for tag, ex in (("off", ex_off), ("on", ex_on)):
        try:
            text, _ = executable_hlo(ex._run_k, *ex.step_args(check_every))
            rows[tag] = cost_row_from_hlo(text, name=f"health-model/{tag}",
                                          kind="health-model")
        except Exception as e:
            rows[tag] = CostRow(name=f"health-model/{tag}",
                                kind="health-model", status="unparsed",
                                error=f"{type(e).__name__}: {e}")
    off, on = rows["off"], rows["on"]
    ok = (off.status == "ok" and on.status == "ok" and off.hbm_bytes > 0)
    doc = {
        "status": "ok" if ok else "unparsed",
        "check_every": int(check_every),
        "hbm_bytes_step": off.hbm_bytes,
        "hbm_bytes_step_health": on.hbm_bytes,
        "hbm_bytes_diag_per_chunk": None,
        "modeled_overhead": None,
    }
    if ok:
        doc["hbm_bytes_diag_per_chunk"] = on.hbm_bytes - off.hbm_bytes
        doc["modeled_overhead"] = ((on.hbm_bytes - off.hbm_bytes)
                                   / (check_every * off.hbm_bytes))
    else:
        doc["error"] = off.error or on.error
    return doc


def serial_cost_row(prepared, *, label: str, timers: dict | None = None,
                    mesh=None) -> CostRow:
    """Cost row of one prepared serial run's EVOLVE bin (an uninstrumented
    twin of the bin is lowered, so telemetry wrappers never enter the
    HLO)."""
    import jax

    from repro.core.schedule import canonical_bin

    bname = canonical_bin("EVOLVE")
    name = f"serial/{label}/{bname}"
    active = dict(prepared.solver.domain.decomposition)
    n_dev = int(mesh.size) if (mesh is not None and active) else 1
    try:
        step = prepared.schedule.compile_bin(bname)
        text, _ = executable_hlo(jax.jit(step), prepared.state)
    except Exception as e:
        return CostRow(name=name, kind="serial-bin", status="unparsed",
                       n_devices=n_dev, error=f"{type(e).__name__}: {e}")
    row = cost_row_from_hlo(text, name=name, kind="serial-bin",
                            n_devices=n_dev)
    tot, cnt = _find_sections(timers or {}, f"schedule.{bname}")
    if cnt:
        row.invocations = cnt
        row.measured_s = tot / cnt
    if active and mesh is not None:
        row.halo_bytes_analytic = float(halo_bytes_per_step(
            prepared.solver.config, active, dict(mesh.shape)))
    return row


def report_for_runtime(rt, chip: Chip | str = "auto",
                       dtype: str = "f32") -> "PerfReport":
    """The runtime's full perf accounting: one row per farm signature
    (``farm.step_chunk`` seconds / device steps as the measured join) and
    one per prepared serial scenario (``schedule.EVOLVE`` sections).

    When several farms share one telemetry handle their step-chunk time
    cannot be told apart, so the per-device-step seconds are the
    aggregate across farms — honest for the single-signature common case
    and clearly labeled either way.
    """
    timers = rt.telemetry.timers.snapshot() if rt.telemetry.enabled else {}
    rows: list[CostRow] = []
    services = getattr(rt, "_services", {})
    total_steps = sum(svc.farm.device_steps for svc in services.values())
    chunk_tot, _ = _find_sections(timers, "farm.step_chunk")
    per_step = (chunk_tot / total_steps
                if total_steps and chunk_tot else None)
    for key, svc in services.items():
        rows.append(farm_cost_row(svc, signature=str(key),
                                  measured_s=per_step))
    for label, pr in getattr(rt, "_prepared", {}).items():
        rows.append(serial_cost_row(pr, label=label, timers=timers,
                                    mesh=rt.mesh))
    return PerfReport(rows, chip=resolve_chip(chip), dtype=dtype)


# -- the report ---------------------------------------------------------------
class PerfReport:
    """Attributed cost rows against one chip's roofline."""

    def __init__(self, rows, *, chip: Chip | str = "auto",
                 dtype: str = "f32"):
        self.costs: list[CostRow] = list(rows)
        self.chip = resolve_chip(chip)
        self.dtype = dtype

    def _attribute(self, c: CostRow) -> dict:
        d = dataclasses.asdict(c)
        terms = terms_from_counts(c.flops, c.hbm_bytes,
                                  c.collective_wire_bytes,
                                  dtype=self.dtype, chip=self.chip)
        d.update(
            compute_s=terms.compute_s, memory_s=terms.memory_s,
            collective_s=terms.collective_s, roofline_s=terms.step_time_s,
            bottleneck=terms.bottleneck if c.status == "ok" else "unknown")
        if c.status == "ok" and c.measured_s and c.measured_s > 0:
            d["achieved_flops_s"] = c.flops / c.measured_s
            # fraction of the roofline-optimistic time actually achieved;
            # left uncapped so a model underestimate stays visible
            d["utilization"] = (terms.step_time_s / c.measured_s
                                if terms.step_time_s else None)
        else:
            d["achieved_flops_s"] = None
            d["utilization"] = None
        ha, hp = c.halo_bytes_analytic, c.halo_bytes_predicted
        d["halo_match"] = (
            None if ha is None or hp is None
            else bool(abs(ha - hp) <= 1e-6 * max(abs(ha), abs(hp), 1.0)))
        return d

    def rows(self) -> list[dict]:
        return [self._attribute(c) for c in self.costs]

    def as_dict(self) -> dict:
        return {
            "schema": PERF_SCHEMA,
            "chip": {"name": self.chip.name,
                     "peak_flops": self.chip.peak_flops(self.dtype),
                     "hbm_bandwidth": self.chip.hbm_bandwidth,
                     "ici_link_bandwidth": self.chip.ici_link_bandwidth},
            "dtype": self.dtype,
            "rows": self.rows(),
        }

    def render(self) -> str:
        lines = [f"-- perf accounting (chip {self.chip.name}, "
                 f"{self.dtype} peak {self.chip.peak_flops(self.dtype):.3g} "
                 f"FLOP/s, HBM {self.chip.hbm_bandwidth:.3g} B/s) --"]
        if not self.costs:
            lines.append("  (no executables accounted — enable telemetry "
                         "and run something first)")
            return "\n".join(lines)
        hdr = (f"  {'row':<34} {'status':<8} {'flops/inv':>10} "
               f"{'HBM B/inv':>10} {'wire B/inv':>10} {'bottleneck':<10} "
               f"{'measured_s':>10} {'util':>6}")
        lines.append(hdr)
        for d in self.rows():
            ms = f"{d['measured_s']:.3g}" if d["measured_s"] else "-"
            ut = f"{d['utilization']:.3g}" if d["utilization"] else "-"
            lines.append(
                f"  {d['name']:<34} {d['status']:<8} {d['flops']:>10.3g} "
                f"{d['hbm_bytes']:>10.3g} "
                f"{d['collective_wire_bytes']:>10.3g} "
                f"{d['bottleneck']:<10} {ms:>10} {ut:>6}")
            if d["collective_counts"]:
                coll = "  ".join(
                    f"{k}×{int(v)} ({d['collective_bytes'].get(k, 0):.3g} B)"
                    for k, v in sorted(d["collective_counts"].items()))
                lines.append(f"      collectives: {coll}")
            if d["halo_bytes_analytic"] is not None:
                verdict = {True: "MATCH", False: "MISMATCH",
                           None: "?"}[d["halo_match"]]
                lines.append(
                    f"      halo bytes: predicted "
                    f"{d['halo_bytes_predicted'] or 0:.6g} vs analytic "
                    f"{d['halo_bytes_analytic']:.6g} — {verdict}")
            if d.get("health_drains") is not None:
                lines.append(
                    f"      health: {d['health_drains']} ring drains over "
                    f"{d['health_boundaries']} harvest boundaries "
                    f"(extra host syncs: "
                    f"{d['health_drains'] - d['health_boundaries']})")
            if d["error"]:
                lines.append(f"      error: {d['error']}")
        return "\n".join(lines)

    def export_gauges(self, registry, prefix: str = "perf"):
        """Mirror the attributed rows into scrape-able gauges (the
        Prometheus surface behind ``SimulationService.prometheus_text``)."""
        for d in self.rows():
            row = d["name"]
            registry.set(f"{prefix}.flops_per_invocation", d["flops"],
                         row=row)
            registry.set(f"{prefix}.hbm_bytes_per_invocation",
                         d["hbm_bytes"], row=row)
            registry.set(f"{prefix}.collective_wire_bytes_per_invocation",
                         d["collective_wire_bytes"], row=row)
            registry.set(f"{prefix}.roofline_s", d["roofline_s"], row=row)
            registry.set(f"{prefix}.bottleneck", 1.0, row=row,
                         kind=d["bottleneck"])
            if d["utilization"] is not None:
                registry.set(f"{prefix}.utilization", d["utilization"],
                             row=row)
            if d["achieved_flops_s"] is not None:
                registry.set(f"{prefix}.achieved_flops_s",
                             d["achieved_flops_s"], row=row)
        return registry


def validate_perf(doc: dict) -> dict:
    """Schema check for an embedded ``repro.perf.v1`` block; returns the
    doc or raises ``ValueError`` naming every problem at once."""
    problems = []
    if not isinstance(doc, dict):
        raise ValueError(f"perf block must be a dict, got {type(doc)}")
    if doc.get("schema") != PERF_SCHEMA:
        problems.append(f"schema is {doc.get('schema')!r}, "
                        f"expected {PERF_SCHEMA!r}")
    if not isinstance(doc.get("chip"), dict) or "name" not in doc.get(
            "chip", {}):
        problems.append("chip must be a dict with a 'name'")
    rows = doc.get("rows")
    if not isinstance(rows, list):
        problems.append("rows must be a list")
    else:
        for i, r in enumerate(rows):
            missing = [k for k in ROW_KEYS if k not in r]
            if missing:
                problems.append(f"row {i} missing {missing}")
    if problems:
        raise ValueError("invalid perf block: " + "; ".join(problems))
    return doc
