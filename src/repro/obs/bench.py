"""``BENCH_*.json`` — the fixed schema of the performance trajectory.

The ROADMAP's bench trajectory is a series of ``BENCH_<name>.json``
artifacts, one per benchmark run, comparable across PRs because every
file carries the same envelope: schema version, bench name, creation
time, host fingerprint (backend, device count, versions), pass verdict,
wall time, and the bench's own numbers under ``metrics``.
``benchmarks/run.py`` emits them; CI schema-validates and archives the
``--smoke`` artifact on every push, so a malformed entry can never enter
the trajectory silently.
"""
from __future__ import annotations

import json
import os
import platform
import re
import time

SCHEMA = "repro.bench.v1"

_NAME_RE = re.compile(r"^[a-z0-9_]+$")

# field -> accepted types (the v1 envelope; ``metrics`` is free-form)
_ENVELOPE = {
    "schema": str,
    "bench": str,
    "created_unix": (int, float),
    "host": dict,
    "passed": bool,
    "wall_s": (int, float),
    "metrics": dict,
}

_HOST_FIELDS = ("backend", "device_count", "python", "jax")


def host_info() -> dict:
    """The host fingerprint stamped into every bench document."""
    import jax

    return {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "jax": jax.__version__,
    }


def make_bench_doc(name: str, metrics: dict, *, passed: bool,
                   wall_s: float, host: dict | None = None) -> dict:
    """Assemble (and validate) one schema-conforming bench document."""
    return validate_bench({
        "schema": SCHEMA,
        "bench": name,
        "created_unix": time.time(),
        "host": host if host is not None else host_info(),
        "passed": bool(passed),
        "wall_s": float(wall_s),
        "metrics": dict(metrics),
    })


def validate_bench(doc: dict) -> dict:
    """Check ``doc`` against the v1 envelope; returns it or raises
    ``ValueError`` naming every problem at once."""
    problems = []
    if not isinstance(doc, dict):
        raise ValueError(f"bench document must be a dict, got {type(doc)}")
    for field, types in _ENVELOPE.items():
        if field not in doc:
            problems.append(f"missing field {field!r}")
        elif not isinstance(doc[field], types) or (
                types is not bool and isinstance(doc[field], bool)):
            # bool is an int subclass: reject True as a number
            problems.append(
                f"field {field!r} has type {type(doc[field]).__name__}")
    if isinstance(doc.get("schema"), str) and doc["schema"] != SCHEMA:
        problems.append(f"schema is {doc['schema']!r}, expected {SCHEMA!r}")
    if isinstance(doc.get("bench"), str) and not _NAME_RE.match(doc["bench"]):
        problems.append(f"bench name {doc['bench']!r} must match "
                        f"{_NAME_RE.pattern}")
    if isinstance(doc.get("host"), dict):
        for f in _HOST_FIELDS:
            if f not in doc["host"]:
                problems.append(f"host missing {f!r}")
    if problems:
        raise ValueError("invalid bench document: " + "; ".join(problems))
    return doc


def write_bench(doc: dict, out_dir: str = ".") -> str:
    """Validate and write ``BENCH_<name>.json``; returns the path."""
    validate_bench(doc)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{doc['bench']}.json")
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True, default=str)
        f.write("\n")
    return path


def load_bench(path: str) -> dict:
    with open(path) as f:
        return validate_bench(json.load(f))
