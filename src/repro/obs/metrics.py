"""Labeled metrics registry — counters, gauges, histograms.

The Cactus performance-reporting analogue at the metrics level: every
layer of the stack (farm scheduler, ensemble executor, service front-end,
runtime front door) records its load-bearing quantities into one
:class:`Registry`, which snapshots to a plain dict and dumps as JSON, so
the same numbers feed the human-readable ``repro.obs.report()``, the
``BENCH_*.json`` trajectory, and any external scrape.

Series are identified by a metric name plus optional key=value labels
(``farm.queue_depth{priority=1}``, ``farm.compile_cache{result=hit}``);
the flat ``name{k=v,...}`` spelling — labels sorted by key — is the
canonical serialized form, so a snapshot round-trips through JSON without
a schema.  All mutation is lock-guarded: the registry is shared between
the drive loop and any poller thread.
"""
from __future__ import annotations

import bisect
import json
import re
import threading

# histogram bucket upper bounds: 1-2-5 per decade from 1 µs to 10 ks —
# wide enough for both per-entry schedule timings and submit->result
# latencies without configuration
DEFAULT_BOUNDS = tuple(m * 10.0 ** e for e in range(-6, 5)
                       for m in (1.0, 2.0, 5.0))


def series_key(name: str, labels: dict) -> str:
    """Canonical flat spelling of a labeled series."""
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class Histogram:
    """Fixed-bucket histogram with exact count/sum/min/max.

    Buckets are cumulative-free (each holds its own count, ``le`` upper
    bound); quantiles are estimated from the bucket containing the target
    rank (its upper bound), which is accurate to one 1-2-5 step — plenty
    for wall-clock latencies.
    """

    __slots__ = ("bounds", "counts", "overflow", "count", "sum", "min", "max")

    def __init__(self, bounds=DEFAULT_BOUNDS):
        self.bounds = tuple(bounds)
        self.counts = [0] * len(self.bounds)
        self.overflow = 0
        self.count = 0
        self.sum = 0.0
        self.min: float | None = None
        self.max: float | None = None

    def observe(self, value: float):
        value = float(value)
        self.count += 1
        self.sum += value
        self.min = value if self.min is None else min(self.min, value)
        self.max = value if self.max is None else max(self.max, value)
        i = bisect.bisect_left(self.bounds, value)
        if i < len(self.bounds):
            self.counts[i] += 1
        else:
            self.overflow += 1

    def percentile(self, q: float) -> float | None:
        """Estimated q-th percentile (0..100); None when empty."""
        if not self.count:
            return None
        rank = max(1, int(round(q / 100.0 * self.count)))
        seen = 0
        for le, n in zip(self.bounds, self.counts):
            seen += n
            if seen >= rank:
                return le
        return self.max

    def snapshot(self) -> dict:
        return {
            "count": self.count,
            "sum": self.sum,
            "min": self.min,
            "max": self.max,
            # sparse: only occupied buckets travel
            "buckets": [[le, n] for le, n in zip(self.bounds, self.counts)
                        if n] + ([["inf", self.overflow]] if self.overflow
                                 else []),
        }


class Registry:
    """Thread-safe registry of counters, gauges, and histograms."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, int] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, Histogram] = {}

    # -- recording ------------------------------------------------------------
    def inc(self, name: str, value: int = 1, **labels) -> int:
        """Add ``value`` to a counter series; returns the new total."""
        key = series_key(name, labels)
        with self._lock:
            new = self._counters.get(key, 0) + value
            self._counters[key] = new
        return new

    def set(self, name: str, value: float, **labels):
        """Set a gauge series to ``value`` (last-write-wins)."""
        key = series_key(name, labels)
        with self._lock:
            self._gauges[key] = float(value)

    def observe(self, name: str, value: float, **labels):
        """Record one sample into a histogram series."""
        key = series_key(name, labels)
        with self._lock:
            h = self._hists.get(key)
            if h is None:
                h = self._hists[key] = Histogram()
            h.observe(value)

    def remove(self, name: str, **labels) -> bool:
        """Drop a series outright (any kind); True if it existed.

        Long-lived registries otherwise accumulate dead per-entity
        series — the health monitor retires its per-sim state gauge
        here when a sim leaves the farm.
        """
        key = series_key(name, labels)
        removed = False
        with self._lock:
            for store in (self._counters, self._gauges, self._hists):
                removed |= store.pop(key, None) is not None
        return removed

    # -- reading --------------------------------------------------------------
    def get(self, name: str, **labels):
        """Counter/gauge value or Histogram for a series; None if absent."""
        key = series_key(name, labels)
        with self._lock:
            for store in (self._counters, self._gauges, self._hists):
                if key in store:
                    return store[key]
        return None

    def snapshot(self) -> dict:
        """Plain-dict view: ``{"counters", "gauges", "histograms"}`` keyed
        by the canonical ``name{k=v,...}`` series spelling."""
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {k: h.snapshot()
                               for k, h in self._hists.items()},
            }

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.snapshot(), indent=indent, sort_keys=True)

    def reset(self):
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()

    # -- rendering ------------------------------------------------------------
    def to_prometheus(self, prefix: str = "repro") -> str:
        """Prometheus text-exposition rendering of every series.

        Series names are sanitized (``farm.queue_depth{priority=1}`` ->
        ``repro_farm_queue_depth{priority="1"}``); histograms emit the
        standard cumulative ``_bucket``/``_sum``/``_count`` triple.  This
        is what :meth:`repro.sim.service.SimulationService.prometheus_text`
        serves, so the farm is scrape-able from day one.
        """
        lines: list[str] = []
        snap = self.snapshot()

        def split(key: str) -> tuple[str, str]:
            name, _, inner = key.partition("{")
            metric = prefix + "_" + re.sub(r"[^a-zA-Z0-9_]", "_", name)
            if not inner:
                return metric, ""
            pairs = []
            for kv in inner.rstrip("}").split(","):
                k, _, v = kv.partition("=")
                pairs.append(f'{re.sub(r"[^a-zA-Z0-9_]", "_", k.strip())}'
                             f'="{v.strip()}"')
            return metric, "{" + ",".join(pairs) + "}"

        typed: set = set()

        def emit(key: str, value, kind: str, suffix: str = "",
                 extra_label: str | None = None):
            metric, labels = split(key)
            if (metric, kind) not in typed:
                typed.add((metric, kind))
                lines.append(f"# TYPE {metric}{suffix} {kind}")
            if extra_label:
                labels = (labels[:-1] + "," + extra_label + "}" if labels
                          else "{" + extra_label + "}")
            lines.append(f"{metric}{suffix}{labels} {value:g}")

        for k in sorted(snap["counters"]):
            emit(k, snap["counters"][k], "counter")
        for k in sorted(snap["gauges"]):
            emit(k, snap["gauges"][k], "gauge")
        with self._lock:
            hists = dict(self._hists)
        for k in sorted(hists):
            h = hists[k]
            metric, labels = split(k)
            if (metric, "histogram") not in typed:
                typed.add((metric, "histogram"))
                lines.append(f"# TYPE {metric} histogram")
            seen = 0
            base = labels[1:-1] + "," if labels else ""
            for le, n in zip(h.bounds, h.counts):
                if n:
                    seen += n
                    lines.append(f'{metric}_bucket{{{base}le="{le:g}"}} '
                                 f"{seen}")
            lines.append(f'{metric}_bucket{{{base}le="+Inf"}} {h.count}')
            lines.append(f"{metric}_sum{labels} {h.sum:g}")
            lines.append(f"{metric}_count{labels} {h.count}")
        return "\n".join(lines) + ("\n" if lines else "")

    def report(self) -> str:
        """Human-readable block for ``repro.obs.report()``."""
        snap = self.snapshot()
        lines = []
        if snap["counters"]:
            lines.append("-- counters --")
            for k in sorted(snap["counters"]):
                lines.append(f"  {k:<44} {snap['counters'][k]}")
        if snap["gauges"]:
            lines.append("-- gauges --")
            for k in sorted(snap["gauges"]):
                lines.append(f"  {k:<44} {snap['gauges'][k]:g}")
        if snap["histograms"]:
            lines.append("-- histograms --")
            with self._lock:
                hists = dict(self._hists)
            for k in sorted(hists):
                h = hists[k]
                mean = h.sum / h.count if h.count else 0.0
                p50, p95, p99 = (h.percentile(q) for q in (50, 95, 99))
                lines.append(
                    f"  {k:<44} count {h.count}  mean {mean:.4g}  "
                    f"p50 {p50:.4g}  p95 {p95:.4g}  p99 {p99:.4g}  "
                    f"max {h.max:.4g}")
        return "\n".join(lines)
