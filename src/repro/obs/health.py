"""repro.obs.health — in-situ simulation health: NaN quarantine + flight recorder.

Cactus ships live monitoring of running simulations as a framework
service (analysis thorns + the HTTPD live monitor); this module is that
layer for the farm.  The solver computes a small vector of physics
diagnostics — divergence L∞, kinetic energy, max|u| → CFL number, and a
NaN/Inf sentinel — **inside the compiled ensemble step** on the
slot-stacked state, and the ensemble executor accumulates one frame per
compiled chunk (sampled on the chunk's final state — NaN/Inf and
divergence persist in the fields, so a chunk-end sample detects exactly
what a per-step sample would, at a fraction of the compute) into a
device-side ``(slots, K, N_DIAG)`` ring buffer.  The
farm drains that ring to the host only at its existing
``check_steady_every`` harvest boundary, so steady-state throughput pays
**zero extra host syncs** (the perf report pins this:
``health_drains <= health_boundaries`` on the farm-step cost row).

On drain, a per-sim state machine classifies the new frames::

    healthy -> warning -> diverged / nan

with configurable thresholds (:class:`HealthConfig`).  A sim entering a
terminal state is **quarantined**: its slot is released with
``terminated="diverged"``, the ring of its last-K health frames plus its
final field state is written through ``ckpt.Checkpointer`` as a *flight
record* for post-mortem (:func:`load_flight_record`), and the remaining
slots keep stepping — bitwise-identically to a farm that never admitted
the bad sim, because slots are independent under vmap.

Health is a *functional* feature, not telemetry: quarantine works with
telemetry off (events/metrics/timers simply no-op through ``obs.NULL``),
and with health off (the default) the farm compiles the exact
pre-health executable — the bitwise-invisibility contract of PR 6 holds
in both directions.

This module stays import-light (stdlib + numpy): jax and the
checkpointer are pulled in lazily where needed, mirroring ``obs.perf``.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
from collections import deque

import numpy as np

# One health frame = one row of the device ring buffer, in this column
# order.  Column 0 is the device step the frame was sampled at; a step
# of -1 marks a slot-reset sentinel row (no frame recorded yet).  The
# physics columns mirror ``ns3d.HEALTH_DIAGS`` — a test pins the two
# tuples against each other.
DIAG_COLUMNS = ("step", "div_linf", "ke", "umax", "cfl", "finite")
N_DIAG = len(DIAG_COLUMNS)
_COL = {name: i for i, name in enumerate(DIAG_COLUMNS)}

# health state machine, in severity order; DIVERGED/NAN are terminal
HEALTHY = "healthy"
WARNING = "warning"
DIVERGED = "diverged"
NAN = "nan"
STATES = (HEALTHY, WARNING, DIVERGED, NAN)
STATE_CODE = {s: i for i, s in enumerate(STATES)}


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Health-monitoring knobs (thresholds in solver units).

    ``window`` is K, the per-slot ring depth: how many most-recent
    frames survive to a flight record and how far back ``poll`` /
    ``Runtime.watch`` can look.  Divergence/CFL cross the *warn*
    threshold -> ``warning`` (recoverable), the *diverged* threshold ->
    quarantine; a non-finite field value -> ``nan`` -> quarantine.
    ``flight_dir=None`` disables flight records (quarantine still
    evicts); the Runtime defaults it to ``<ckpt_dir>/flight`` when a
    checkpoint directory is configured.
    """

    window: int = 8
    div_warn: float = 1e3
    div_diverged: float = 1e7
    cfl_warn: float = 2.0
    cfl_diverged: float = 1e3
    quarantine: bool = True
    flight_dir: str | None = None


def resolve_health(spec) -> HealthConfig | None:
    """Coerce a user-facing health spec: None/False -> off, True ->
    defaults, HealthConfig passes through, dict -> kwargs."""
    if spec is None or spec is False:
        return None
    if spec is True:
        return HealthConfig()
    if isinstance(spec, HealthConfig):
        return spec
    if isinstance(spec, dict):
        return HealthConfig(**spec)
    raise TypeError(
        f"health must be a HealthConfig, dict, or bool; got "
        f"{type(spec).__name__}")


def frame_from_row(row) -> dict:
    """Decode one ring row into a named frame (plain python scalars)."""
    frame = {k: float(v) for k, v in zip(DIAG_COLUMNS, row)}
    frame["step"] = int(frame["step"])
    return frame


def classify_frame(frame: dict, cfg: HealthConfig) -> tuple[str, str]:
    """``(state, cause)`` of one frame under ``cfg``'s thresholds."""
    finite = frame.get("finite", 1.0)
    div, cfl = frame.get("div_linf", 0.0), frame.get("cfl", 0.0)
    if finite < 0.5 or not all(math.isfinite(v) for v in (div, cfl)):
        return NAN, "nonfinite"
    if div >= cfg.div_diverged:
        return DIVERGED, "divergence"
    if cfl >= cfg.cfl_diverged:
        return DIVERGED, "cfl"
    if div >= cfg.div_warn:
        return WARNING, "divergence"
    if cfl >= cfg.cfl_warn:
        return WARNING, "cfl"
    return HEALTHY, ""


def _all_healthy(rows: np.ndarray, cfg: HealthConfig) -> bool:
    """Vectorized ``classify_frame(...) == HEALTHY`` over a row batch —
    the steady-state drain path stays in numpy, no per-frame dicts."""
    div, cfl = rows[:, _COL["div_linf"]], rows[:, _COL["cfl"]]
    finite = rows[:, _COL["finite"]]
    ok = ((finite >= 0.5) & np.isfinite(div) & np.isfinite(cfl)
          & (div < cfg.div_warn) & (cfl < cfg.cfl_warn))
    return bool(ok.all())


class SimHealth:
    """Per-sim health record: current state + the last-K frames seen.

    Frames are stored as raw ring rows (numpy, DIAG_COLUMNS order);
    named-dict views (:attr:`frames`, :attr:`latest`) are built on
    demand, so the steady-state drain path never materializes python
    dicts.
    """

    __slots__ = ("sid", "slot", "tag", "state", "cause", "_rows",
                 "last_step", "resident")

    def __init__(self, sid: int, slot: int, tag: str, window: int):
        self.sid = sid
        self.slot = slot
        self.tag = tag
        self.state = HEALTHY
        self.cause = ""
        self._rows: deque = deque(maxlen=window)
        self.last_step = -1
        self.resident = True

    @property
    def frames(self) -> list[dict]:
        return [frame_from_row(r) for r in self._rows]

    @property
    def latest(self) -> dict | None:
        return frame_from_row(self._rows[-1]) if self._rows else None

    def frames_array(self) -> np.ndarray:
        """The record's frames as a ``(k, N_DIAG)`` float32 array
        (DIAG_COLUMNS order) — what the flight recorder persists."""
        if not self._rows:
            return np.zeros((0, N_DIAG), np.float32)
        return np.stack(list(self._rows)).astype(np.float32)


class HealthMonitor:
    """The host half: per-sim state machines fed by ring drains.

    The farm calls :meth:`admit` when a sim takes a slot, feeds each
    drained ring slice through :meth:`observe`, and :meth:`release`-s on
    eviction/quarantine/finish.  Transitions emit ``kind="health"``
    trace events and ``health.*`` metrics (rendered as
    ``repro_health_*`` by ``prometheus_text``); the watchdog shares the
    same event schema through :meth:`mark` so one timeline explains both
    hangs and divergences.
    """

    def __init__(self, config: HealthConfig, telemetry=None,
                 farm_id: str = "farm"):
        from repro import obs

        self.config = config
        self.tel = obs.resolve(telemetry)
        self.farm_id = farm_id
        self.records: dict[int, SimHealth] = {}

    # -- lifecycle ------------------------------------------------------------
    def admit(self, sid: int, slot: int, tag: str = "",
              last_step: int = -1) -> SimHealth:
        """Start tracking ``sid`` in ``slot``.  ``last_step`` is the
        device step just before admission: ring rows at or below it
        belong to the slot's previous occupant (the step column is the
        executor's monotonic counter) and are never attributed to this
        sim — which is what lets admission skip a device-side ring
        reset."""
        rec = SimHealth(sid, slot, tag, self.config.window)
        rec.last_step = int(last_step)
        self.records[sid] = rec
        return rec

    def release(self, sid: int):
        """Sim left the farm: retire its per-sim gauge but keep the
        record (the dashboard shows the last known state)."""
        rec = self.records.get(sid)
        if rec is None:
            return
        rec.resident = False
        self.tel.metrics.remove("health.sim_state", sid=sid)

    # -- observation ----------------------------------------------------------
    def observe(self, sid: int, rows: np.ndarray) -> SimHealth:
        """Feed one drained ring slice ``(K, N_DIAG)`` for ``sid``.

        Rows with ``step < 0`` are reset sentinels (no frame yet);
        already-seen steps are skipped, the rest run through the state
        machine in step order.  Returns the (possibly transitioned)
        record — the farm quarantines on DIVERGED/NAN.
        """
        rec = self.records.get(sid)
        if rec is None:
            rec = self.admit(sid, -1)
        rows = np.asarray(rows, np.float32)
        fresh = rows[(rows[:, 0] >= 0) & (rows[:, 0] > rec.last_step)]
        if not len(fresh):
            return rec
        fresh = fresh[np.argsort(fresh[:, 0], kind="stable")]
        if rec.state == HEALTHY and _all_healthy(fresh, self.config):
            # steady-state fast path: every frame healthy, no transition
            # possible — batch-append the raw rows, build no dicts
            rec._rows.extend(fresh)
            rec.last_step = int(fresh[-1, 0])
        else:
            for row in fresh:
                frame = frame_from_row(row)
                rec._rows.append(row)
                rec.last_step = frame["step"]
                self._transition(rec, *classify_frame(frame, self.config),
                                 frame=frame)
        self.tel.metrics.inc("health.frames", len(fresh))
        self.tel.metrics.set("health.sim_state", STATE_CODE[rec.state],
                             sid=sid)
        return rec

    def mark(self, sid: int, state: str, cause: str, **detail):
        """External transition (the watchdog's hook): push ``sid``
        toward ``state`` with the same event schema as frame-driven
        transitions — stalls and divergences share one timeline."""
        rec = self.records.get(sid)
        if rec is None:
            return
        self._transition(rec, state, cause, frame=rec.latest, detail=detail)

    def _transition(self, rec: SimHealth, state: str, cause: str,
                    frame: dict | None = None, detail: dict | None = None):
        if STATE_CODE[rec.state] >= STATE_CODE[DIVERGED]:
            return                          # terminal states stick
        if state == rec.state:
            return
        if STATE_CODE[state] < STATE_CODE[rec.state] and state != HEALTHY:
            return                          # only warning->healthy recovers
        prev, rec.state, rec.cause = rec.state, state, cause
        ev = {"farm": self.farm_id, "slot": rec.slot, "tag": rec.tag,
              "state": state, "from": prev, "cause": cause}
        if frame is not None:
            ev["frame"] = frame
        if detail:
            ev.update(detail)
        self.tel.trace.emit("health", sid=rec.sid, **ev)
        self.tel.metrics.inc("health.events", state=state, cause=cause)

    # -- views ----------------------------------------------------------------
    def state_of(self, sid: int) -> str | None:
        rec = self.records.get(sid)
        return rec.state if rec is not None else None

    def frame_of(self, sid: int) -> dict | None:
        """Latest health frame + state for ``sid`` (what ``poll``
        streams as the intermediate analysis), or None before the first
        drain."""
        rec = self.records.get(sid)
        if rec is None:
            return None
        out = {"state": rec.state, "cause": rec.cause}
        if rec.latest is not None:
            out.update(rec.latest)
        return out

    def counts(self) -> dict:
        """Resident sims per health state (the dashboard summary row)."""
        out = {s: 0 for s in STATES}
        for rec in self.records.values():
            if rec.resident:
                out[rec.state] += 1
        return out

    def export_gauges(self):
        """Refresh the per-state residency gauges after a drain."""
        for state, n in self.counts().items():
            self.tel.metrics.set("health.sims", n, state=state)


# -- flight recorder ----------------------------------------------------------

class FlightRecorder:
    """Post-mortem persistence for quarantined sims, via the checkpointer.

    One record per sid: the ring of its last-K health frames plus its
    final (poisoned) field state, written through
    ``ckpt.Checkpointer.save`` (atomic npz + manifest, keyed by sid in
    place of a step), with a ``flight.json`` sidecar naming the columns,
    field order, cause, and thresholds so :func:`load_flight_record`
    needs no solver template to read it back.
    """

    def __init__(self, directory: str):
        from repro.ckpt.checkpointer import Checkpointer

        self.directory = directory
        self._ckpt = Checkpointer(directory, keep_last=0)

    def record(self, sid: int, *, frames: np.ndarray, state: dict,
               meta: dict | None = None) -> str:
        fields = sorted(state)
        # dict trees flatten with keys sorted, so this tree's leaf order
        # is (frames, *state[fields]) — flight.json records `fields` and
        # load_flight_record rebuilds the structure from it
        tree = {"frames": np.asarray(frames, np.float32),
                "state": {k: np.asarray(state[k]) for k in fields}}
        self._ckpt.save(sid, tree, blocking=True)
        path = os.path.join(self.directory, f"step_{sid:08d}")
        doc = {"sid": sid, "columns": list(DIAG_COLUMNS),
               "state_fields": fields}
        doc.update(meta or {})
        with open(os.path.join(path, "flight.json"), "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        return path


def load_flight_record(directory: str, sid: int) -> dict:
    """Read one flight record back: ``{"frames", "state", "meta"}``.

    ``frames`` is the ``(k, N_DIAG)`` array of the sim's last health
    frames (columns per ``meta["columns"]``), ``state`` the final field
    dict.  Template-free: structure is rebuilt from the sidecar + the
    checkpointer's raw leaves.
    """
    from repro.ckpt.checkpointer import Checkpointer

    path = os.path.join(directory, f"step_{sid:08d}", "flight.json")
    with open(path) as f:
        meta = json.load(f)
    _, leaves = Checkpointer(directory).read_arrays(sid)
    fields = meta["state_fields"]
    if len(leaves) != 1 + len(fields):
        raise ValueError(
            f"flight record for sid {sid}: {len(leaves)} leaves, expected "
            f"frames + {len(fields)} fields")
    return {"frames": leaves[0],
            "state": dict(zip(fields, leaves[1:])),
            "meta": meta}


# -- dashboard ----------------------------------------------------------------

_STATE_MARK = {HEALTHY: "ok", WARNING: "WARN", DIVERGED: "DIVG", NAN: "NaN!"}


def render_dashboard(snapshots: list[dict]) -> str:
    """Cactus-HTTPD-style live text dashboard over farm health snapshots.

    Each snapshot is ``SimulationFarm.health_snapshot()``: farm id,
    device step, queue depth, and one row per slot (free or resident,
    with the latest health frame when monitoring is on).
    """
    lines = ["== repro health =="]
    for snap in snapshots:
        states = snap.get("states") or {}
        summary = " ".join(f"{k}={v}" for k, v in states.items() if v)
        lines.append(
            f"farm {snap['farm']}  device_step={snap['device_steps']}  "
            f"queued={snap['queued']}" + (f"  [{summary}]" if summary else ""))
        lines.append(f"  {'slot':>4} {'sid':>5} {'steps':>11} "
                     f"{'state':>5} {'div_linf':>9} {'ke':>9} "
                     f"{'cfl':>7} tag")
        for row in snap["slots"]:
            if row.get("sid") is None:
                lines.append(f"  {row['slot']:>4} {'-':>5} {'':>11} "
                             f"{'free':>5}")
                continue
            hf = row.get("health") or {}
            mark = _STATE_MARK.get(hf.get("state", ""), "-")
            div = hf.get("div_linf")
            ke = hf.get("ke")
            cfl = hf.get("cfl")
            fmt = lambda v, w: f"{v:>{w}.3g}" if v is not None else " " * w
            lines.append(
                f"  {row['slot']:>4} {row['sid']:>5} "
                f"{row['steps_done']:>5}/{row['steps']:<5} {mark:>5} "
                f"{fmt(div, 9)} {fmt(ke, 9)} {fmt(cfl, 7)} "
                f"{row.get('tag', '')}")
    return "\n".join(lines)
