"""repro.obs — Cactus-style observability: timers, metrics, traces.

Cactus ships first-class performance reporting (per-thorn, per-schedule-
bin clocks printed as ``TimerReport``) and the CaKernel/Chemora lineage
closes the loop by feeding those measurements back into kernel tuning.
This package is that substrate for the reproduction, three pillars behind
one handle:

* :class:`~repro.obs.metrics.Registry` — labeled counters / gauges /
  histograms (``farm.slot_occupancy``, ``farm.queue_depth{priority}``,
  ``farm.compile_cache{result}``, ``sim.steps_total``,
  ``service.submit_to_result_seconds``), snapshottable to a dict.
* :class:`~repro.obs.timers.TimerTree` — hierarchical wall-clock timers
  around every schedule bin and every farm phase, rendered Cactus-style
  by :func:`report`.
* :class:`~repro.obs.trace.TraceLog` — per-simulation lifecycle events
  (submit -> admit -> first_step -> evict/readmit -> steady -> result),
  streamed as JSON-lines and exportable to Chrome trace-event format
  (Perfetto-loadable).

The contract that makes it safe to thread everywhere: **telemetry off is
bitwise-invisible**.  A disabled :class:`Telemetry` (the :data:`NULL`
singleton) makes every hook a no-op — no timers, no
``jax.block_until_ready`` fences, no named scopes, no events — so the
default execution path is byte-for-byte the pre-telemetry one.  Enable it
per-runtime (``repro.api.runtime(..., telemetry=True)``) or standalone::

    tel = repro.obs.telemetry(trace_path="events.jsonl")
    with tel.section("my_phase"):
        ...
    print(repro.obs.report(tel))
"""
from __future__ import annotations

import contextlib
import dataclasses
import json

from repro.obs.bench import (
    SCHEMA as BENCH_SCHEMA, host_info, load_bench, make_bench_doc,
    validate_bench, write_bench,
)
from repro.obs.health import (
    DIAG_COLUMNS, FlightRecorder, HealthConfig, HealthMonitor,
    load_flight_record, render_dashboard, resolve_health,
)
from repro.obs.metrics import Histogram, Registry, series_key
from repro.obs.timers import TimerNode, TimerTree
from repro.obs.trace import TraceLog, validate_chrome_trace

__all__ = [
    "BENCH_SCHEMA", "DIAG_COLUMNS", "FlightRecorder", "HealthConfig",
    "HealthMonitor", "Histogram", "NULL", "Registry", "Telemetry",
    "TelemetryConfig", "TimerNode", "TimerTree", "TraceLog", "host_info",
    "load_bench", "load_flight_record", "make_bench_doc",
    "render_dashboard", "report", "resolve", "resolve_health",
    "series_key", "telemetry", "validate_bench", "validate_chrome_trace",
    "write_bench",
]

_NULL_CM = contextlib.nullcontext()


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """How much to observe, and where the byproducts land.

    ``named_scopes`` additionally wraps instrumented regions in
    ``jax.named_scope`` + ``jax.profiler.TraceAnnotation`` so schedule
    bins show up in XLA/perfetto device profiles.  The heartbeat fields
    drive the service watchdog: a liveness file touched every
    ``heartbeat_interval_s`` (for an external orchestrator), and a stall
    recorded whenever consecutive beats are further apart than
    ``heartbeat_deadline_s``.
    """

    enabled: bool = True
    trace_path: str | None = None        # stream events as JSON-lines
    named_scopes: bool = True            # annotate XLA profiles
    heartbeat_path: str | None = None    # liveness file (ft.watchdog)
    heartbeat_interval_s: float = 5.0
    heartbeat_deadline_s: float = 60.0


class Telemetry:
    """The live handle: one registry + one timer tree + one trace log."""

    enabled = True

    def __init__(self, config: TelemetryConfig | None = None, **kw):
        self.config = config if config is not None else TelemetryConfig(**kw)
        self.metrics = Registry()
        self.timers = TimerTree()
        self.trace = TraceLog(path=self.config.trace_path)
        global _CURRENT
        _CURRENT = self

    # -- hooks (every one a no-op on NULL) ------------------------------------
    def section(self, name: str):
        """Timer context manager for a nested wall-clock section."""
        return self.timers.section(name)

    def named_scope(self, name: str):
        """XLA-profile annotation: ``jax.named_scope`` (trace-time op
        metadata) + ``jax.profiler.TraceAnnotation`` (host timeline)."""
        if not self.config.named_scopes:
            return _NULL_CM
        import jax

        ctx = contextlib.ExitStack()
        ctx.enter_context(jax.named_scope(name))
        ctx.enter_context(jax.profiler.TraceAnnotation(name))
        return ctx

    def fence(self, x):
        """``jax.block_until_ready`` so a section's clock covers the
        device work it dispatched.  Exists ONLY behind enabled telemetry:
        the off path adds no device syncs."""
        import jax

        try:
            return jax.block_until_ready(x)
        except Exception:   # non-array pytree leaves etc.
            return x

    # -- views ----------------------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "metrics": self.metrics.snapshot(),
            "timers": self.timers.snapshot(),
            "n_events": len(self.trace.events),
        }

    def dump_json(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)
        return path

    def report(self) -> str:
        """Human-readable timers + metrics summary (Cactus TimerReport)."""
        parts = ["== repro.obs report ==", self.timers.report()]
        m = self.metrics.report()
        if m:
            parts.append(m)
        if self.trace.events:
            parts.append(f"-- trace: {len(self.trace.events)} events --")
        return "\n".join(parts)

    def reset(self):
        self.metrics.reset()
        self.timers.reset()


class _NullTelemetry(Telemetry):
    """Disabled telemetry: every hook is a no-op; shared singleton."""

    enabled = False

    def __init__(self):
        self.config = TelemetryConfig(enabled=False)
        self.metrics = _NullRegistry()
        self.timers = _NullTimerTree()
        self.trace = _NullTraceLog()

    def section(self, name):
        return _NULL_CM

    def named_scope(self, name):
        return _NULL_CM

    def fence(self, x):
        return x

    def report(self):
        return "== repro.obs report ==\n(telemetry disabled)"


class _NullRegistry(Registry):
    def inc(self, name, value=1, **labels):
        return 0

    def set(self, name, value, **labels):
        pass

    def observe(self, name, value, **labels):
        pass


class _NullTimerTree(TimerTree):
    def section(self, name):
        return _NULL_CM


class _NullTraceLog(TraceLog):
    def __init__(self):
        super().__init__(path=None)

    def emit(self, kind, sid=None, **data):
        return {}


NULL = _NullTelemetry()
_CURRENT: Telemetry = NULL


def telemetry(**kw) -> Telemetry:
    """Build an enabled :class:`Telemetry` (kwargs per TelemetryConfig)."""
    return Telemetry(TelemetryConfig(**kw))


def resolve(spec) -> Telemetry:
    """Coerce a user-facing telemetry spec to a live handle.

    Accepts: a Telemetry (passes through), None/False (disabled ->
    :data:`NULL`), True (fresh default-config handle), a
    :class:`TelemetryConfig`, or a dict of TelemetryConfig kwargs.
    """
    if isinstance(spec, Telemetry):
        return spec
    if spec is None or spec is False:
        return NULL
    if spec is True:
        return Telemetry()
    if isinstance(spec, TelemetryConfig):
        return Telemetry(spec) if spec.enabled else NULL
    if isinstance(spec, dict):
        cfg = TelemetryConfig(**spec)
        return Telemetry(cfg) if cfg.enabled else NULL
    raise TypeError(
        f"telemetry must be a Telemetry, TelemetryConfig, dict, or bool; "
        f"got {type(spec).__name__}")


def report(tel: Telemetry | None = None) -> str:
    """Render the handle's (default: the most recently enabled
    telemetry's) timer/metrics summary."""
    return (tel if tel is not None else _CURRENT).report()


def __getattr__(name: str):
    # repro.obs.perf pulls in the cost model / roofline chips (and, at
    # call time, jax + the farm stack) — lazy so `import repro.obs` stays
    # light and the farm's own top-level `from repro import obs` cannot
    # cycle through it
    if name == "perf":
        import importlib

        return importlib.import_module("repro.obs.perf")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
