"""Trip-count-aware cost model over optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts every while-loop body ONCE — useless for
scan-over-layers programs (a 94-layer stack reports ~1 layer of FLOPs).
This module parses ``compiled.as_text()`` into computations, resolves each
op's operand shapes through a per-computation symbol table, walks the call
graph from ENTRY, and multiplies while bodies by their trip counts (read
from the loop condition's comparison constant — exact for every
``lax.scan``/``lax.map``-derived loop in this codebase, which contains no
dynamic-bound loops).

Cost conventions (per device — shapes in post-SPMD HLO are per-shard):
  flops: dot = 2·prod(result)·K (K = contracted extent); convolution =
         2·prod(result)·prod(kernel_spatial)·Cin  (unused here);
         elementwise/fusion internals are ignored (vector-unit work is
         bandwidth-dominated and priced by the bytes term).
  bytes: Σ over *top-level* ops of operand+result sizes, skipping
         zero-traffic ops (bitcast/tuple/get-tuple-element/parameter/
         constant) and control ops (while/conditional/call priced by their
         bodies instead).  Fusion internals are free (VMEM-resident).
  collectives: per-op wire bytes via ring factors on the replica-group
         size N (operand sizes inferred from result: AG operand=result/N,
         RS operand=result·N, AR/A2A/CP operand=result).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([0-9,]*)\](?:\{[^}]*\})?")
# the % sigil is optional: optimized post-SPMD text carries it, unoptimized
# (pre-SPMD ``lowered.compiler_ir(dialect="hlo")``) dumps do not
_OP_HEAD_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.:-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w-]+)\(")
# computation headers come signed ("%name (args) -> type {") in optimized
# text and bare ("name {", "ENTRY main.42 {") in unoptimized dumps
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.:-]+)\s*(?:\(.*\)\s*->\s*.+)?\{\s*$")
_ID_RE = re.compile(r"^[\w.:-]+$")
_CALLS_RE = re.compile(r"calls=%?([\w.:-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.:-]+)")
_COND_BODY_RE = re.compile(r"condition=%?([\w.:-]+).*body=%?([\w.:-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONSTANT_RE = re.compile(r"constant\((\d+)\)")
# Kernel-region marker: ops inside a jax.named_scope("__kernel__<name>")
# ship as ONE fused Pallas kernel on the TPU target — the bytes model
# charges only region-external reads/writes (VMEM-resident interior).
_KERNEL_RE = re.compile(r'op_name="[^"]*__kernel__(\w+)')

_SKIP_BYTES = {"bitcast", "tuple", "get-tuple-element", "parameter",
               "constant", "after-all", "add-dependency", "iota",
               "partition-id", "replica-id"}

# Top-level elementwise/shape ops that a TPU compile fuses into neighboring
# kernels (CPU XLA leaves them unfused, which would inflate the HBM-traffic
# estimate ~5-10x).  Treated as zero-traffic: their inputs/outputs are
# charged at the enclosing materialization points (dots, fusions,
# collectives, copies, slices-into-loops, reduces).
_FUSED_THROUGH = {
    "convert", "multiply", "add", "subtract", "divide", "maximum", "minimum",
    "compare", "select", "and", "or", "not", "xor", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "tanh", "sqrt", "rsqrt",
    "power", "negate", "abs", "sign", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "is-finite", "clamp", "broadcast", "reshape",
    "logistic", "cosine", "sine", "atan2", "rem", "shift-left",
    "shift-right-logical", "shift-right-arithmetic", "reverse", "map",
    "reduce-precision", "real", "imag", "complex", "expm1", "log1p",
    "stochastic-convert", "slice", "pad", "concatenate",
}
_CONTROL = {"while", "conditional", "call", "fusion", "async-start",
            "async-done"}
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "all-reduce-start", "all-gather-start",
                "collective-permute-start", "reduce-scatter-start",
                "all-to-all-start", "ragged-all-to-all"}


def _parse_shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_dims(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, ()
    dt, dims = m.group(1), m.group(2)
    shape = tuple(int(d) for d in dims.split(",") if d.strip())
    return dt, shape


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str          # text after the opening paren (args + attributes)

    @property
    def operand_names(self):
        depth, args, cur = 1, [], []
        for ch in self.rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                if ch == "," and depth == 1:
                    args.append("".join(cur))
                    cur = []
                else:
                    cur.append(ch)
        args.append("".join(cur))
        out = []
        for a in args:
            a = a.strip()
            if "*/" in a:                 # strip /*index=N*/ comments
                a = a.split("*/", 1)[1].strip()
            if a.startswith("%"):
                out.append(a[1:])
                continue
            # unsigiled operands ("collective-permute(slice.159)") and the
            # "TYPE name" spelling: the identifier is the last token
            tok = a.split()[-1] if a else ""
            if tok.startswith("%"):
                tok = tok[1:]
            if tok and "[" not in tok and _ID_RE.match(tok):
                out.append(tok)
        return out


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    symbols: dict      # op name -> type string

    def trip_count(self) -> int | None:
        """If this is a loop CONDITION computation: the bound constant."""
        consts = [int(c)
                  for o in self.ops
                  for c in _CONSTANT_RE.findall(f"{o.opcode}({o.rest}")]
        consts = [c for c in consts if c > 0]
        return max(consts) if consts else None


def _parse_op_line(line: str) -> Op | None:
    """Parse '%name = TYPE opcode(args), attrs' with balanced-paren type
    scanning (tuple types may contain /*index=N*/ comments)."""
    mh = _OP_HEAD_RE.match(line)
    if not mh:
        return None
    name = mh.group(1)
    i = mh.end()
    if i < len(line) and line[i] == "(":       # tuple type
        depth = 0
        j = i
        while j < len(line):
            if line[j] == "(":
                depth += 1
            elif line[j] == ")":
                depth -= 1
                if depth == 0:
                    break
            j += 1
        type_str = line[i:j + 1]
        i = j + 1
    else:                                      # array type dtype[dims]{layout}
        ms = _SHAPE_RE.match(line, i)
        if not ms:
            return None
        j = ms.end()
        type_str = line[i:j]
        i = j
    mo = _OPCODE_RE.match(line, i)
    if not mo:
        return None
    return Op(name, type_str, mo.group(1), line[mo.end():])


def parse_module(hlo_text: str) -> dict:
    comps: dict = {}
    cur = None
    entry = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        mc = _COMP_RE.match(line) if " = " not in line else None
        if mc and line.endswith("{"):
            cur = Computation(mc.group(1), [], {})
            comps[cur.name] = cur
            if line.startswith("ENTRY"):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        op = _parse_op_line(line)
        if op is not None:
            cur.ops.append(op)
            cur.symbols[op.name] = op.type_str
    return {"computations": comps, "entry": entry}


def _dot_flops(op: Op, comp: Computation) -> float:
    _, result = _parse_dims(op.type_str)
    operands = op.operand_names
    if not operands:
        return 0.0
    lhs_t = comp.symbols.get(operands[0], "")
    _, lhs = _parse_dims(lhs_t)
    mc = _CONTRACT_RE.search(op.rest)
    k = 1
    if mc and lhs:
        for idx in mc.group(1).split(","):
            if idx.strip() and int(idx) < len(lhs):
                k *= lhs[int(idx)]
    n = 1
    for d in result:
        n *= d
    return 2.0 * n * k


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        return int(m.group(2))
    return default


def _collective_wire_bytes(op: Op, n_default: int, symbols: dict | None = None):
    """(kind, operand_bytes, result_bytes, wire_bytes) for one collective.

    Sync forms derive the operand from the RESULT shape.  The async
    ``-start`` halves carry a tuple result (operand, result[, scratch]) —
    deriving from it would double-count the pair — so there the operand is
    resolved from the operand symbols instead (the matching ``-done`` op
    is skipped by the caller, counting each async pair exactly once).
    """
    kind = op.opcode.replace("-start", "")
    n = max(_group_size(op.rest, n_default), 1)
    operand = None
    if op.opcode.endswith("-start") and symbols is not None:
        ob = sum(_parse_shape_bytes(symbols.get(o, ""))
                 for o in op.operand_names)
        if ob:
            operand = float(ob)
    if kind == "all-gather":
        if operand is None:
            operand = _parse_shape_bytes(op.type_str) / n
        result = operand * n
        wire = operand * (n - 1)
    elif kind == "reduce-scatter":
        if operand is None:
            operand = _parse_shape_bytes(op.type_str) * n
        result = operand / n
        wire = operand * (n - 1) / n
    elif kind == "all-reduce":
        if operand is None:
            operand = _parse_shape_bytes(op.type_str)
        result = operand
        wire = operand * 2.0 * (n - 1) / n
    elif kind in ("all-to-all", "ragged-all-to-all"):
        if operand is None:
            operand = _parse_shape_bytes(op.type_str)
        result = operand
        wire = operand * (n - 1) / n
    else:  # collective-permute
        if operand is None:
            operand = _parse_shape_bytes(op.type_str)
        result = operand
        wire = float(operand)
    return kind, float(operand), float(result), float(wire)


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_counts: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    collective_bytes: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    bytes_by_opcode: dict = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.collective_wire_bytes += other.collective_wire_bytes * mult
        for k, v in other.collective_counts.items():
            self.collective_counts[k] += v * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += v * mult
        for k, v in other.bytes_by_opcode.items():
            self.bytes_by_opcode[k] += v * mult


def _build_sources(comp: Computation):
    """Resolve reads through pass-through (fused) ops to materializing
    producers.  sources(name) -> list of producer op names whose RESULTS
    are actually read from HBM when `name` is consumed."""
    producers = {op.name: op for op in comp.ops}
    memo: dict = {}

    def sources(name: str, depth: int = 0):
        if name in memo:
            return memo[name]
        op = producers.get(name)
        if op is None or depth > 200:
            return [name]
        if op.opcode in _FUSED_THROUGH:
            out, seen = [], set()
            for o in op.operand_names:
                for s in sources(o, depth + 1):
                    if s not in seen:
                        seen.add(s)
                        out.append(s)
            memo[name] = out
            return out
        if op.opcode in ("bitcast",):
            ops_ = op.operand_names
            out = sources(ops_[0], depth + 1) if ops_ else [name]
            memo[name] = out
            return out
        if op.opcode in ("constant", "iota", "partition-id", "replica-id",
                         "after-all"):
            memo[name] = []
            return []
        memo[name] = [name]
        return [name]

    return producers, sources


def _fusion_components(comp: Computation, producers, sources):
    """Union adjacent fusions (connected through pass-through chains) into
    components — the TPU compile would emit them as one kernel."""
    fusion_names = [op.name for op in comp.ops if op.opcode == "fusion"]
    parent = {n: n for n in fusion_names}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    def union(a, b):
        parent[find(a)] = find(b)

    fuset = set(fusion_names)
    for op in comp.ops:
        if op.opcode != "fusion":
            continue
        for o in op.operand_names:
            for src in sources(o):
                if src in fuset:
                    union(op.name, src)
    groups: dict = defaultdict(list)
    for n in fusion_names:
        groups[find(n)].append(n)
    return groups


_PARAM_IDX_RE = re.compile(r"\s*(\d+)")


def _fusion_io(called: Computation):
    """Slice-aware I/O of a fusion computation.

    Returns (read_bytes: {param_idx: bytes|None}, write_bytes: bytes|None).
    A parameter consumed ONLY through (dynamic-)slice reads just the slice
    (the scan-residual indexing pattern); a root dynamic-update-slice
    writes just the update (the in-place stacking pattern).  None = full.
    """
    params = {}
    consumers = defaultdict(list)
    for op in called.ops:
        if op.opcode == "parameter":
            m = _PARAM_IDX_RE.match(op.rest)
            if m:
                params[int(m.group(1))] = op.name
        for o in op.operand_names:
            consumers[o].append(op)
    read_bytes: dict = {}
    for idx, pname in params.items():
        # BFS through pass-through ops: every use path must hit a
        # (dynamic-)slice before any materializing op for slice pricing
        slice_bytes = 0.0
        full = False
        stack = [pname]
        seen: set = set()
        while stack:
            n = stack.pop()
            if n in seen:
                continue
            seen.add(n)
            for c in consumers.get(n, []):
                if c.opcode in ("dynamic-slice", "slice"):
                    slice_bytes += _parse_shape_bytes(c.type_str)
                elif c.opcode in _FUSED_THROUGH or c.opcode == "bitcast":
                    stack.append(c.name)
                else:
                    full = True
        if not full and seen:
            read_bytes[idx] = float(slice_bytes)
        else:
            read_bytes[idx] = None
    write_bytes = None
    root = called.ops[-1] if called.ops else None
    if root is not None and root.opcode == "dynamic-update-slice":
        ops_ = root.operand_names
        if len(ops_) >= 2:
            write_bytes = float(_parse_shape_bytes(
                called.symbols.get(ops_[1], "")))
            # the in-place-updated buffer (operand 0) is aliased, not read:
            # zero its read charge if its ONLY consumer is this dus root
            buf = ops_[0]
            producers_local = {op.name: op for op in called.ops}
            while buf in producers_local and \
                    producers_local[buf].opcode == "bitcast":
                buf = (producers_local[buf].operand_names or [""])[0]
            for idx, pname in params.items():
                if pname == buf and all(
                        c.name == root.name for c in consumers.get(buf, [])):
                    read_bytes[idx] = 0.0
    return read_bytes, write_bytes


def _comp_cost(comp_name: str, module: dict, n_devices: int,
               memo: dict, *, include_bytes: bool = True) -> HloCost:
    if comp_name in memo:
        return memo[comp_name]
    comps = module["computations"]
    comp = comps.get(comp_name)
    cost = HloCost()
    if comp is None:
        memo[comp_name] = cost
        return cost
    memo[comp_name] = cost  # pre-insert (defensive vs cycles)
    producers, sources = _build_sources(comp)

    def charge_reads(op: Op, key: str):
        srcs = {src for o in op.operand_names for src in sources(o)}
        for src in srcs:  # dedupe: one HBM read per distinct tensor
            b = _parse_shape_bytes(comp.symbols.get(src, ""))
            cost.bytes += b
            cost.bytes_by_opcode[key + ":read"] += b

    def charge_write(op: Op, key: str):
        b = _parse_shape_bytes(op.type_str)
        cost.bytes += b
        cost.bytes_by_opcode[key + ":write"] += b

    fusion_groups = (_fusion_components(comp, producers, sources)
                     if include_bytes else {})
    member_of = {}
    for root, members in fusion_groups.items():
        for m in members:
            member_of[m] = root
    # kernel regions (named_scope markers) — grouped per marker name
    kernel_of: dict = {}
    if include_bytes:
        for op in comp.ops:
            mk = _KERNEL_RE.search(op.rest)
            if mk:
                kernel_of[op.name] = mk.group(1)
    kernel_groups: dict = defaultdict(list)
    for n, marker in kernel_of.items():
        kernel_groups[marker].append(n)
    # a fusion's result is written iff some non-member reads it
    external_reads: set = set()
    root_op = comp.ops[-1] if comp.ops else None
    for op in comp.ops:
        if op.opcode in _FUSED_THROUGH or op.opcode in ("bitcast",):
            continue
        for o in op.operand_names:
            for src in sources(o):
                if src in member_of and member_of.get(op.name) != member_of[src]:
                    external_reads.add(src)
                elif src in member_of and op.name not in member_of:
                    external_reads.add(src)
    if root_op is not None and root_op.name in member_of:
        external_reads.add(root_op.name)

    for op in comp.ops:
        oc = op.opcode
        in_kernel = op.name in kernel_of
        if oc == "while":
            m = _COND_BODY_RE.search(op.rest)
            if m:
                cond_name, body_name = m.group(1), m.group(2)
                trip = 1
                if cond_name in comps:
                    trip = comps[cond_name].trip_count() or 1
                body_cost = _comp_cost(body_name, module, n_devices, memo)
                cost.add(body_cost, mult=trip)
            continue
        if oc == "conditional":
            m = _BRANCHES_RE.search(op.rest)
            if m:
                branch_costs = [
                    _comp_cost(b.strip().lstrip("%"), module, n_devices, memo)
                    for b in m.group(1).split(",")]
                # conservative: max-cost branch (no conds in our hot paths)
                best = max(branch_costs, key=lambda c: c.flops + c.bytes,
                           default=HloCost())
                cost.add(best)
            continue
        if oc == "call":
            m = _TO_APPLY_RE.search(op.rest)
            if m:
                cost.add(_comp_cost(m.group(1), module, n_devices, memo))
            continue
        if oc == "fusion":
            m = _CALLS_RE.search(op.rest)
            called = comps.get(m.group(1)) if m else None
            if called is not None:  # flops; internal traffic is VMEM
                inner = _comp_cost(called.name, module, n_devices, memo,
                                   include_bytes=False)
                cost.flops += inner.flops
                # a fusion-wrapped collective (pre-SPMD dumps wrap the
                # permute + its ghost assembly) still puts bytes on the
                # wire — propagate the inner collective inventory
                cost.collective_wire_bytes += inner.collective_wire_bytes
                for ck, cv in inner.collective_counts.items():
                    cost.collective_counts[ck] += cv
                for ck, cv in inner.collective_bytes.items():
                    cost.collective_bytes[ck] += cv
            if include_bytes and not in_kernel:
                io_reads, io_write = (_fusion_io(called)
                                      if called is not None else ({}, None))
                my_comp = member_of.get(op.name)
                full_srcs: set = set()
                for i, o in enumerate(op.operand_names):
                    rb = io_reads.get(i)
                    if rb is not None:     # slice-only access: charge slice
                        cost.bytes += rb
                        cost.bytes_by_opcode["fusion:read"] += rb
                        continue
                    for src in sources(o):
                        if member_of.get(src) == my_comp and src != op.name:
                            continue  # intra-component edge: VMEM
                        full_srcs.add(src)
                for src in full_srcs:      # dedupe per kernel
                    b = _parse_shape_bytes(comp.symbols.get(src, ""))
                    cost.bytes += b
                    cost.bytes_by_opcode["fusion:read"] += b
                if io_write is not None:   # in-place update: charge update
                    cost.bytes += io_write
                    cost.bytes_by_opcode["fusion:write"] += io_write
                elif op.name in external_reads:
                    charge_write(op, "fusion")
            continue
        if oc in _COLLECTIVES:
            kind, operand_b, result_b, wire_b = _collective_wire_bytes(
                op, n_devices, comp.symbols)
            cost.collective_counts[kind] += 1
            cost.collective_bytes[kind] += operand_b
            cost.collective_wire_bytes += wire_b
            if include_bytes and not in_kernel:
                cost.bytes += operand_b + result_b
                cost.bytes_by_opcode["collective"] += operand_b + result_b
            continue
        if oc.endswith("-done") or oc in _SKIP_BYTES or oc in _FUSED_THROUGH \
                or oc == "bitcast":
            continue
        if oc in ("dot", "dot-general"):
            cost.flops += _dot_flops(op, comp)
        if include_bytes and not in_kernel:
            if oc in ("dynamic-slice", "gather"):
                # reads only the addressed slice/rows ≈ result size
                b = 2 * _parse_shape_bytes(op.type_str)
                cost.bytes += b
                cost.bytes_by_opcode["slice:rw"] += b
            elif oc in ("dynamic-update-slice", "scatter"):
                upd = (op.operand_names[1:2] or [""])[0]
                b = 2 * _parse_shape_bytes(comp.symbols.get(upd, ""))
                cost.bytes += b
                cost.bytes_by_opcode["update:rw"] += b
            else:
                charge_reads(op, oc if oc in ("dot", "copy") else "other")
                charge_write(op, oc if oc in ("dot", "copy") else "other")

    # --- kernel regions: charge external I/O once per region ----------------
    if include_bytes and kernel_groups:
        consumers: dict = defaultdict(list)
        for op in comp.ops:
            for o in op.operand_names:
                consumers[o].append(op)
        root_name = comp.ops[-1].name if comp.ops else None
        for marker, members in kernel_groups.items():
            mset = set(members)
            read_srcs: set = set()
            sliced_reads = 0.0
            for opn in members:
                op = producers.get(opn)
                if op is None or op.opcode in _FUSED_THROUGH \
                        or op.opcode in ("bitcast",) or op.opcode in _SKIP_BYTES:
                    continue
                if op.opcode in ("dynamic-slice", "slice", "gather"):
                    ext = any(src not in mset
                              for o in op.operand_names
                              for src in sources(o))
                    if ext:  # reads only the slice
                        sliced_reads += _parse_shape_bytes(op.type_str)
                    continue
                for o in op.operand_names:
                    for src in sources(o):
                        if src not in mset:
                            read_srcs.add(src)
            writes = 0.0
            for opn in members:
                op = producers.get(opn)
                if op is None or op.opcode in _FUSED_THROUGH \
                        or op.opcode in ("bitcast",):
                    continue
                external = opn == root_name
                stack = list(consumers.get(opn, []))
                seen = set()
                while stack and not external:
                    c = stack.pop()
                    if c.name in seen:
                        continue
                    seen.add(c.name)
                    if c.name in mset:
                        continue
                    if c.opcode in _FUSED_THROUGH or c.opcode in ("bitcast",):
                        if c.name == root_name:
                            external = True
                        stack.extend(consumers.get(c.name, []))
                    else:
                        external = True
                if external:
                    writes += _parse_shape_bytes(op.type_str)
            rb = sliced_reads + sum(
                _parse_shape_bytes(comp.symbols.get(s, "")) for s in read_srcs)
            cost.bytes += rb + writes
            cost.bytes_by_opcode[f"kernel:{marker}"] += rb + writes
    memo[comp_name] = cost
    return cost


def analyze(hlo_text: str, n_devices: int) -> HloCost:
    """Per-device trip-count-aware cost of the whole module."""
    module = parse_module(hlo_text)
    if module["entry"] is None:
        return HloCost()
    # fusions' called computations must not be double counted when reached
    # from the entry walk — _comp_cost handles them only via their callers.
    return _comp_cost(module["entry"], module, n_devices, {})


def safe_analyze(hlo_text: str, n_devices: int
                 ) -> tuple[HloCost, str, str | None]:
    """``(cost, status, error)`` — the mid-run-safe front of :func:`analyze`.

    The perf accounting layer runs over every executable the runtime
    produces; an HLO dialect this parser has not met yet must record
    ``status="unparsed"`` (empty cost, error string) instead of raising
    into the drive loop.
    """
    try:
        cost = analyze(hlo_text, n_devices)
    except Exception as e:  # malformed/unknown dialect: never raise mid-run
        return HloCost(), "unparsed", f"{type(e).__name__}: {e}"
    if not (cost.flops or cost.bytes or cost.collective_counts):
        if "ENTRY" not in hlo_text:
            return cost, "unparsed", "no ENTRY computation found"
    return cost, "ok", None
