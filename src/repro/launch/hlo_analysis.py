"""Post-SPMD HLO analysis: collective inventory + roofline terms.

``compiled.cost_analysis()`` gives per-device FLOPs and HBM bytes but NOT
collective traffic — that is parsed from the optimized HLO text
(``compiled.as_text()``), where shapes are already per-device.  Each
collective's wire bytes use the standard ring-algorithm factors on its
replica-group size N:

    all-reduce       2 (N-1)/N × operand          (RS + AG phases)
    all-gather       (N-1)   × operand            (operand is the shard)
    reduce-scatter   (N-1)/N × operand
    all-to-all       (N-1)/N × operand
    collective-permute  1     × operand           (neighbor traffic)

The collective roofline term divides by ONE ICI link (50 GB/s): a
deliberately conservative single-link serialization model (document:
multi-axis tori overlap axes across their 4 links, so real hardware can
beat this term by up to the link count).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")

# "  %x = bf16[16,128]{1,0} all-gather(bf16[1,128]{1,0} %p), ..."
_OP_RE = re.compile(
    r"=\s+(?:\([^)]*\)\s+)?[\w\[\]{},]*\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\(")
_SHAPE_RE = re.compile(
    r"(pred|f8e4m3fn|f8e5m2|[suf]\d+|bf16|c64|c128)\[([0-9,]*)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # iota form [num_groups,group_size]<=[...]
        return int(m.group(2))
    return default


@dataclasses.dataclass
class CollectiveStats:
    count: dict
    operand_bytes: dict          # raw per-device operand bytes by op kind
    wire_bytes: float            # ring-factor adjusted, per device

    def total_operand_bytes(self) -> float:
        return float(sum(self.operand_bytes.values()))


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    count: dict = defaultdict(int)
    operand_bytes: dict = defaultdict(float)
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done" in line.split("=")[1][:120]:
            continue  # count -start, skip -done halves of async pairs
        op = m.group(1)
        # operand shapes: everything inside the call parens
        paren = line[m.end():]
        shapes = _SHAPE_RE.findall(paren)
        ob = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        if ob == 0:
            continue
        n = max(_group_size(line, n_devices), 1)
        factor = {
            "all-reduce": 2.0 * (n - 1) / n,
            "all-gather": float(n - 1),
            "reduce-scatter": (n - 1) / n,
            "all-to-all": (n - 1) / n,
            "collective-permute": 1.0,
        }[op]
        count[op] += 1
        operand_bytes[op] += ob
        wire += ob * factor
    return CollectiveStats(dict(count), dict(operand_bytes), wire)


def cost_summary(compiled, n_devices: int) -> dict:
    """Trip-count-aware FLOPs/bytes/collectives (repro.launch.hlo_cost)
    plus raw XLA cost_analysis (body-once; kept for cross-checking) and
    memory analysis."""
    from repro.launch import hlo_cost

    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    hlo = hlo_cost.analyze(compiled.as_text(), n_devices)
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # pragma: no cover - backend dependent
        mem_info = {"error": str(e)}
    return {
        "flops_per_device": hlo.flops,
        "hbm_bytes_per_device": hlo.bytes,
        "collective_wire_bytes_per_device": hlo.collective_wire_bytes,
        "collective_counts": {k: int(v)
                              for k, v in hlo.collective_counts.items()},
        "collective_operand_bytes": dict(hlo.collective_bytes),
        "xla_flops_body_once": float(ca.get("flops", 0.0)),
        "xla_bytes_body_once": float(ca.get("bytes accessed", 0.0)),
        "memory": mem_info,
    }
