import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Full dry-run sweep: every (arch × applicable shape × mesh) cell, with
per-cell JSON artifacts and a resumable manifest (skips cells whose
artifact already exists unless --force).

    PYTHONPATH=src python -m repro.launch.sweep --mesh both
"""
import argparse
import json
import time

from repro.configs.registry import ARCHS
from repro.configs.shapes import SHAPES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--archs", default=None, help="comma-separated subset")
    ap.add_argument("--shapes", default=None)
    ap.add_argument("--moe-mode", default="tp")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    from repro.launch import dryrun

    out = args.out or os.path.abspath(dryrun.ARTIFACT_DIR)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = args.archs.split(",") if args.archs else list(ARCHS)
    shapes = args.shapes.split(",") if args.shapes else list(SHAPES)

    t0 = time.time()
    results = []
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                path = os.path.join(out, mesh_kind,
                                    f"{arch}__{shape}.json")
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        art = json.load(f)
                    if art.get("status") in ("ok", "skipped"):
                        print(f"[sweep] cached {mesh_kind} {arch} {shape}: "
                              f"{art['status']}", flush=True)
                        results.append(art)
                        continue
                art = dryrun.run_cell(arch, shape, mesh_kind,
                                      moe_mode=args.moe_mode)
                dryrun.save_artifact(art, out)
                results.append(art)
    bad = [r for r in results if r["status"] == "error"]
    print(f"[sweep] {len(results)} cells in {time.time() - t0:.0f}s; "
          f"{len(bad)} errors", flush=True)
    for r in bad:
        print(f"  ERROR {r['mesh']} {r['arch']} {r['shape']}: "
              f"{r.get('error', '')[:200]}", flush=True)
    raise SystemExit(1 if bad else 0)


if __name__ == "__main__":
    main()
