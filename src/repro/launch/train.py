"""End-to-end training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3-8b \
        --smoke --steps 50 --batch 8 --seq 256

Wires together every substrate layer: config registry -> data pipeline ->
sharded params/optimizer -> jitted train step (FSDP x TP when a mesh is
requested) -> watchdog -> async checkpointing -> restart-resume.
``--smoke`` shrinks the arch to the CPU-runnable family config; on a real
TPU pod the same file runs the full config (device count decides the
mesh).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--mesh", default="auto",
                    help="'auto' (1 device -> none), 'DxM' e.g. 4x2")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    from repro.configs.registry import get_config, smoke
    from repro.data.pipeline import DataConfig, PackedLMDataset, Prefetcher
    from repro.dist import sharding as shd
    from repro.ft.watchdog import StepWatchdog
    from repro.models import model
    from repro.models.config import LOCAL
    from repro.optim.adamw import AdamW
    from repro.optim.schedules import warmup_cosine
    from repro.train import step as step_lib

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke(cfg)

    # ---- mesh / sharding ----------------------------------------------------
    ndev = len(jax.devices())
    if args.mesh != "auto" and "x" in args.mesh:
        d, m = (int(v) for v in args.mesh.split("x"))
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((d, m), ("data", "model"))
        shard = shd.make_shard_cfg(mesh, cfg, global_batch=args.batch)
    elif ndev > 1:
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((ndev, 1), ("data", "model"))
        shard = shd.make_shard_cfg(mesh, cfg, global_batch=args.batch)
    else:
        mesh, shard = None, LOCAL

    # ---- data -----------------------------------------------------------------
    data_cfg = DataConfig(seed=args.seed, vocab_size=cfg.vocab_size,
                          seq_len=args.seq, global_batch=args.batch)
    ds = PackedLMDataset(data_cfg, cfg)

    # ---- params / optimizer ---------------------------------------------------
    opt = AdamW(lr=warmup_cosine(args.lr, args.steps // 10 + 1, args.steps))
    key = jax.random.PRNGKey(args.seed)
    params = model.init_params(cfg, key)
    opt_state = opt.init(params)
    if mesh is not None:
        pspecs = shd.param_spec_tree(params, cfg, mesh, shard)
        params = jax.device_put(params, shd.named(pspecs, mesh))
        opt_state = jax.device_put(
            opt_state, shd.named(opt.state_spec_tree(pspecs), mesh))

    train_step = jax.jit(step_lib.make_train_step(
        cfg, shard, opt, grad_accum=args.grad_accum), donate_argnums=(0, 1))

    # ---- checkpointing / restart ----------------------------------------------
    ckpt = None
    start_step = 0
    if args.ckpt_dir:
        from repro.ckpt.checkpointer import Checkpointer

        ckpt = Checkpointer(args.ckpt_dir)
        ckpt.cleanup()
        latest = ckpt.latest_step()
        if latest is not None:
            state = ckpt.restore(latest, {"params": params,
                                          "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            print(f"[train] resumed from step {latest}", flush=True)

    wd = StepWatchdog()
    it = Prefetcher(ds.iterate(start_step), depth=2)
    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        wd.start_step()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        events = wd.end_step(step)
        losses.append(loss)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {loss:8.4f} "
                  f"gnorm {float(metrics['grad_norm']):8.3f} "
                  f"lr {float(metrics['lr']):.2e}", flush=True)
        for e in events:
            print(f"[watchdog] {e.kind} at step {e.step}: "
                  f"{e.step_time:.2f}s (thr {e.threshold:.2f}s)", flush=True)
        if ckpt is not None and ((step + 1) % args.ckpt_every == 0
                                 or wd.should_checkpoint):
            ckpt.save_async(step + 1, {"params": params, "opt": opt_state})
            wd.events = [e for e in wd.events
                         if e.kind != "checkpoint_requested"]
    it.close()
    if ckpt is not None:
        ckpt.wait()
    dt = time.time() - t_start
    print(f"[train] done: {args.steps - start_step} steps in {dt:.1f}s; "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}", flush=True)
    return losses


if __name__ == "__main__":
    main()
