import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
real shardings on the production mesh, and extract memory/cost/collective
analysis — the proof that the distribution config is coherent without real
hardware.  (The XLA_FLAGS line above MUST precede any jax import: jax locks
the backend device count at first initialization.)

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Artifacts: artifacts/dryrun/<mesh>/<arch>__<shape>.json
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCHS, get_config
from repro.configs.shapes import SHAPES, ShapeSpec, applicable
from repro.dist import sharding as shd
from repro.launch.hlo_analysis import cost_summary
from repro.launch.mesh import make_production_mesh
from repro.models import model
from repro.models.config import ModelConfig
from repro.optim.adamw import AdamW
from repro.train import step as step_lib

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


# ---------------------------------------------------------------------------
# per-arch training plan (microbatching + optimizer dtypes at scale)
# ---------------------------------------------------------------------------
def train_plan(cfg: ModelConfig) -> dict:
    big = cfg.d_model >= 4096 or cfg.num_experts >= 128
    return {
        # grad_accum splits global batch 256 into microbatches; bigger models
        # hold fewer live tokens per device (activation budget)
        "grad_accum": 16 if big else 4,
        # bf16 moments at >=8B params (see optim/adamw.py docstring)
        "m_dtype": jnp.bfloat16 if big else jnp.float32,
        "v_dtype": jnp.bfloat16 if big else jnp.float32,
        # layout posture (see dist.sharding.make_shard_cfg); baseline is the
        # big-model 2-D layout for every arch — §Perf tunes this per cell
        "shard_mode": "fsdp_tp",
    }


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """Model inputs for one cell, as ShapeDtypeStructs."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        batch = {}
        if cfg.family == "audio":
            batch["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        elif cfg.family == "vlm":
            text = s - cfg.num_prefix_tokens
            batch["tokens"] = _sds((b, text), i32)
            batch["prefix_embeds"] = _sds(
                (b, cfg.num_prefix_tokens, cfg.d_model), jnp.bfloat16)
        else:
            batch["tokens"] = _sds((b, s), i32)
        tgt_len = s if cfg.family != "vlm" else s - cfg.num_prefix_tokens
        batch["targets"] = _sds((b, tgt_len), i32)
        return batch
    if shape.kind == "prefill":
        batch = {}
        if cfg.family == "audio":
            batch["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        elif cfg.family == "vlm":
            batch["tokens"] = _sds((b, s - cfg.num_prefix_tokens), i32)
            batch["prefix_embeds"] = _sds(
                (b, cfg.num_prefix_tokens, cfg.d_model), jnp.bfloat16)
        else:
            batch["tokens"] = _sds((b, s), i32)
        return batch
    if shape.kind == "decode":
        return {"token": _sds((b, 1), i32)}
    raise ValueError(shape.kind)


def _shapes_of(fn, *args):
    return jax.eval_shape(fn, *args)


# ---------------------------------------------------------------------------
# cell construction
# ---------------------------------------------------------------------------
def build_cell(arch: str, shape_name: str, mesh, *, moe_mode="tp",
               cfg_overrides=None, plan_overrides=None, ssm_sp=False):
    """Returns (jitted_fn, arg_shape_structs) ready to .lower().

    ``cfg_overrides``/``plan_overrides`` are the §Perf hillclimb knobs
    (remat policy, chunk sizes, grad_accum, optimizer dtypes, ...).
    """
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    shard_mode = (plan_overrides or {}).get("shard_mode", "fsdp_tp")
    shard = shd.make_shard_cfg(mesh, cfg, global_batch=shape.global_batch,
                               moe_mode=moe_mode if cfg.num_experts else "tp",
                               ssm_sp=ssm_sp, mode=shard_mode)
    named = lambda tree: shd.named(tree, mesh)

    params_s = _shapes_of(lambda: model.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = shd.param_spec_tree(params_s, cfg, mesh, shard)
    batch = input_specs(cfg, shape)
    bspecs = shd.batch_spec_tree(batch, mesh, shard)

    if shape.kind == "train":
        plan = train_plan(cfg)
        if plan_overrides:
            plan.update(plan_overrides)
        opt = AdamW(m_dtype=plan["m_dtype"], v_dtype=plan["v_dtype"])
        opt_s = _shapes_of(opt.init, params_s)
        ospecs = opt.state_spec_tree(pspecs)
        fn = step_lib.make_train_step(cfg, shard, opt,
                                      grad_accum=plan["grad_accum"])
        jitted = jax.jit(
            fn,
            in_shardings=(named(pspecs), named(ospecs), named(bspecs)),
            out_shardings=(named(pspecs), named(ospecs), None),
            donate_argnums=(0, 1))
        return jitted, (params_s, opt_s, batch), shard, cfg, shape

    # serving cells: cache max length = shape.seq_len
    cache_dtype = (plan_overrides or {}).get("cache_dtype", jnp.bfloat16)
    caches_s = _shapes_of(
        lambda: model.init_caches(cfg, shape.global_batch, shape.seq_len,
                                  cache_dtype))
    cspecs = shd.cache_spec_tree(caches_s, cfg, mesh, shard)

    if shape.kind == "prefill":
        fn = step_lib.make_prefill_step(cfg, shard)
        jitted = jax.jit(
            fn,
            in_shardings=(named(pspecs), named(bspecs), named(cspecs)),
            out_shardings=(None, named(cspecs)),
            donate_argnums=(2,))
        return jitted, (params_s, batch, caches_s), shard, cfg, shape

    if shape.kind == "decode":
        fn = step_lib.make_serve_step(cfg, shard)
        cache_len = _sds((), jnp.int32)
        jitted = jax.jit(
            fn,
            in_shardings=(named(pspecs), named(bspecs)["token"],
                          named(cspecs), NamedSharding(mesh, P())),
            out_shardings=(None, None, named(cspecs)),
            donate_argnums=(2,))
        return jitted, (params_s, batch["token"], caches_s, cache_len), \
            shard, cfg, shape
    raise ValueError(shape.kind)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             moe_mode="tp", verbose=True, mesh=None, cfg_overrides=None,
             plan_overrides=None, ssm_sp=False) -> dict:
    multi = mesh_kind == "multi"
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi)
    n_dev = mesh.size
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    art = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": dict(mesh.shape), "kind": shape.kind,
           "moe_mode": moe_mode}
    if cfg_overrides:
        art["cfg_overrides"] = {k: str(v) for k, v in cfg_overrides.items()}
    if plan_overrides:
        art["plan_overrides"] = {k: str(v) for k, v in plan_overrides.items()}
    if ssm_sp:
        art["ssm_sp"] = True
    if not applicable(cfg, shape):
        art["status"] = "skipped"
        art["reason"] = ("long_500k requires sub-quadratic sequence mixing; "
                        f"{arch} is full-attention (see DESIGN.md)")
        return art
    t0 = time.time()
    try:
        jitted, args, shard, cfg, shape = build_cell(
            arch, shape_name, mesh, moe_mode=moe_mode,
            cfg_overrides=cfg_overrides, plan_overrides=plan_overrides,
            ssm_sp=ssm_sp)
        lowered = jitted.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        summary = cost_summary(compiled, n_dev)
        art.update(summary)
        art["status"] = "ok"
        art["lower_s"] = round(t1 - t0, 2)
        art["compile_s"] = round(t2 - t1, 2)
        # MODEL_FLOPS usefulness ratio
        n_active = cfg.active_param_count()
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                       else 1)
        mult = 6 if shape.kind == "train" else 2
        model_flops = mult * n_active * tokens
        art["n_params"] = cfg.param_count()
        art["n_active_params"] = n_active
        art["model_flops_global"] = float(model_flops)
        art["model_flops_per_device"] = float(model_flops) / n_dev
        hlo_f = summary["flops_per_device"]
        art["useful_flops_ratio"] = (art["model_flops_per_device"] / hlo_f
                                     if hlo_f else None)
        # roofline terms
        from repro.core.rooflinemodel import V5E, terms_from_counts

        terms = terms_from_counts(
            hlo_f, summary["hbm_bytes_per_device"],
            summary["collective_wire_bytes_per_device"])
        art["roofline"] = terms.as_dict()
        # fit check vs v5e HBM
        peak = (summary.get("memory") or {}).get("peak_bytes")
        arg_b = (summary.get("memory") or {}).get("argument_bytes")
        art["fits_hbm"] = (None if peak is None
                           else bool((peak or 0) + (arg_b or 0) <= V5E.hbm_bytes))
    except Exception as e:
        art["status"] = "error"
        art["error"] = f"{type(e).__name__}: {e}"
        art["traceback"] = traceback.format_exc()[-4000:]
    art["total_s"] = round(time.time() - t0, 2)
    if verbose:
        tag = art["status"]
        extra = ""
        if tag == "ok":
            r = art["roofline"]
            extra = (f" bottleneck={r['bottleneck']}"
                     f" frac={r['roofline_fraction']:.3f}"
                     f" compile={art['compile_s']}s")
        print(f"[dryrun {mesh_kind}] {arch} × {shape_name}: {tag}{extra}",
              flush=True)
    return art


def save_artifact(art: dict, out_dir: str):
    d = os.path.join(out_dir, art["mesh"])
    os.makedirs(d, exist_ok=True)
    path = os.path.join(d, f"{art['arch']}__{art['shape']}.json")
    slim = {k: v for k, v in art.items() if k != "traceback"}
    with open(path, "w") as f:
        json.dump(slim, f, indent=1, default=str)
    if art.get("traceback"):
        with open(path + ".err", "w") as f:
            f.write(art["traceback"])
    return path


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--moe-mode", default="tp", choices=["tp", "a2a"])
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]

    failures = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                art = run_cell(arch, shape, mesh_kind,
                               moe_mode=args.moe_mode)
                save_artifact(art, args.out)
                if art["status"] == "error":
                    failures += 1
                    print(art["error"], flush=True)
    print(f"dryrun complete; {failures} failures", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
