import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Hillclimb diagnostics: lower one cell (with optional knob overrides) and
print the roofline terms, per-opcode byte attribution, the most expensive
computations (per-iteration cost × trip), and the biggest charged reads
inside a chosen computation — the dry-run "profiler".

    PYTHONPATH=src python -m repro.launch.explain --arch xlstm-125m \
        --shape train_4k [--set remat=none] [--plan grad_accum=4] [--ssm-sp]
"""
import argparse
import json
from collections import defaultdict


def parse_kv(items):
    out = {}
    for kv in items or []:
        k, v = kv.split("=", 1)
        for cast in (int, float):
            try:
                v = cast(v)
                break
            except ValueError:
                continue
        if v in ("True", "true"):
            v = True
        if v in ("False", "false"):
            v = False
        out[k] = v
    return out


def explain(arch, shape, mesh_kind="single", *, moe_mode="tp",
            cfg_overrides=None, plan_overrides=None, ssm_sp=False,
            top=6, drill=None, mesh=None):
    import jax

    from repro.launch import dryrun, hlo_cost
    from repro.launch.mesh import make_production_mesh

    if mesh is None:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    jitted, args, shard, cfg, shp = dryrun.build_cell(
        arch, shape, mesh, moe_mode=moe_mode, cfg_overrides=cfg_overrides,
        plan_overrides=plan_overrides, ssm_sp=ssm_sp)
    compiled = jitted.lower(*args).compile()
    txt = compiled.as_text()
    mod = hlo_cost.parse_module(txt)
    memo = {}
    total = hlo_cost._comp_cost(mod["entry"], mod, mesh.size, memo)
    from repro.core.rooflinemodel import terms_from_counts

    terms = terms_from_counts(total.flops, total.bytes,
                              total.collective_wire_bytes)
    print(f"== {arch} × {shape} ({mesh_kind}; moe={moe_mode}, "
          f"ssm_sp={ssm_sp}, cfg={cfg_overrides}, plan={plan_overrides})")
    print(f"   compute_s={terms.compute_s:.3f}  memory_s={terms.memory_s:.3f}"
          f"  collective_s={terms.collective_s:.3f}  "
          f"bottleneck={terms.bottleneck}  frac={terms.compute_fraction:.4f}")
    print("   bytes by opcode:")
    for k, v in sorted(total.bytes_by_opcode.items(), key=lambda kv: -kv[1])[:10]:
        print(f"     {k:24s} {v/1e9:10.1f} GB  {100*v/max(total.bytes,1):5.1f}%")
    print("   collectives:", {k: int(v) for k, v in
                              total.collective_counts.items()})
    print("   top computations (per-call cost):")
    rows = sorted(((c.bytes, c.flops, n) for n, c in memo.items()),
                  reverse=True)[:top]
    for b, f, n in rows:
        print(f"     {b/1e9:10.2f} GB {f/1e12:8.2f} TF  {n[:70]}")
    if drill:
        comp = next((c for n, c in mod["computations"].items()
                     if drill in n), None)
        name = next((n for n in mod["computations"] if drill in n), None)
        if comp is None:
            print(f"   drill: no computation matching {drill!r}")
        else:
            print(f"   drill into {name}:")
            comps = mod["computations"]
            producers, sources = hlo_cost._build_sources(comp)
            per = defaultdict(float)
            for op in comp.ops:
                if op.opcode != "fusion":
                    continue
                m = hlo_cost._CALLS_RE.search(op.rest)
                called = comps.get(m.group(1)) if m else None
                io_reads, _ = (hlo_cost._fusion_io(called) if called
                               else ({}, None))
                srcs = set()
                for i, o in enumerate(op.operand_names):
                    if io_reads.get(i) is not None:
                        per["SLICED"] += io_reads[i]
                        continue
                    srcs |= set(sources(o))
                for src in srcs:
                    sh = comp.symbols.get(src, "?").split("{")[0]
                    per[sh] += hlo_cost._parse_shape_bytes(
                        comp.symbols.get(src, ""))
            for sh, b in sorted(per.items(), key=lambda kv: -kv[1])[:12]:
                print(f"     {b/1e9:9.2f} GB/call  {sh}")
    return terms, total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--moe-mode", default="tp")
    ap.add_argument("--set", nargs="*", default=None,
                    help="cfg overrides k=v")
    ap.add_argument("--plan", nargs="*", default=None,
                    help="train-plan overrides k=v")
    ap.add_argument("--ssm-sp", action="store_true")
    ap.add_argument("--drill", default=None)
    args = ap.parse_args()
    explain(args.arch, args.shape, args.mesh, moe_mode=args.moe_mode,
            cfg_overrides=parse_kv(args.set) or None,
            plan_overrides=parse_kv(args.plan) or None,
            ssm_sp=args.ssm_sp, drill=args.drill)


if __name__ == "__main__":
    main()
