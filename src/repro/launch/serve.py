"""Serving launcher: batched requests through the continuous-batching
engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --requests 8 --slots 4 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=160)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs.registry import get_config, smoke
    from repro.models import model
    from repro.serve.engine import Request, ServingEngine

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    params = model.init_params(cfg, jax.random.PRNGKey(args.seed))
    eng = ServingEngine(cfg, params, slots=args.slots, max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for rid in range(args.requests):
        plen = int(rng.integers(4, 48))
        prompt = rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32)
        eng.submit(Request(rid, prompt, max_new_tokens=args.max_new))
    done = eng.run_until_drained()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens, "
          f"{eng.steps} engine steps, {dt:.1f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s)", flush=True)
    for r in done[:3]:
        print(f"  req {r.rid}: {r.output[:8]}...", flush=True)
    return done


if __name__ == "__main__":
    main()
