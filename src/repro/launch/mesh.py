"""Production meshes.  A FUNCTION (not module-level constant) so importing
never touches jax device state — the dry-run sets XLA_FLAGS before any jax
initialization and calls this afterwards.

Single-pod: (16, 16)   ("data", "model")          — 256 chips (v5e pod)
Multi-pod:  (2, 16, 16) ("pod", "data", "model")  — 512 chips, 2 pods

The ``pod`` axis composes with ``data`` for batch/FSDP sharding (DCN-ish
outer axis); ``model`` stays inside a pod (ICI-only TP) — the layout that
scales to 1000+ nodes by growing the pod count only.
"""
from __future__ import annotations

import math

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under XLA_FLAGS=--xla_force_host_platform_device_count=512")
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/benchmarks (first prod(shape) devices)."""
    n = math.prod(shape)
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)
