"""repro.api — one runtime front door for driver, farm, and decomposed runs.

The Cactus "flesh" surface of this repo: applications declare *what* to run
(a registered :class:`~repro.sim.scenarios.Scenario` + per-run parameters)
and a :class:`RuntimeConfig` declares *where/how* (resolution, mesh axes,
per-slot grid decomposition, kernel backend, checkpointing); the
:class:`Runtime` derives the execution stack — a serial
``GridDriver``-jitted step, a slot-parallel ``SimulationFarm``, or the full
slots × shards ``SimulationService`` — behind two verbs:

    rt = repro.api.runtime(n=32)
    res = rt.run("cavity", t_end=5.0, re=100.0)       # one run, blocking
    sid = rt.submit("cavity", steps=400, re=250.0)    # farm intake
    rt.result(sid)                                    # ... submit/poll/result

The migration contract (frozen by ``tests/test_api.py``): everything the
Runtime resolves is *bitwise identical* to hand-assembling the legacy
constructor stack (``NavierStokes3D`` + ``make_step`` loops,
``SimulationFarm``/``SimulationService``) — the front door adds routing,
never numerics.  The legacy constructors remain importable and supported
for one release; new code should not need them.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro import obs
from repro.cfd.ns3d import CFDConfig, NavierStokes3D
from repro.core.schedule import Schedule
from repro.sim.ensemble import plan_decomposition
from repro.sim.farm import SimResult, static_key
from repro.sim.scenarios import (
    ParamSpec, Scenario, UnknownScenarioError, get_scenario,
    register_scenario, scenario_names, unregister_scenario,
)
from repro.sim.service import SimulationService

__all__ = [
    "BACKENDS", "ParamSpec", "PreparedRun", "RunResult", "Runtime",
    "RuntimeConfig", "Scenario", "SimResult", "UnknownScenarioError",
    "compile_cache_stats", "get_scenario", "register_scenario", "runtime",
    "scenario_names", "unregister_scenario",
]

# backend name -> (CFDConfig.template, CFDConfig.interpret, overlap override)
# The 3DBLOCK template is the monolithic tiled kernel: it needs
# tile-divisible interiors, so the Pallas backends disable the
# interior/boundary overlap split (a JNP-path optimization whose deep
# interior is never tile-aligned).  Tiles are chip-aware roofline choices
# (autotune.tile_for) resolved per local interior, so any grid the
# autotuner can divide runs without hand-tuned TILE constants.
# Every backend serves every execution path — serial, slot-parallel farm,
# and slots × shards: per-simulation scalars reach the Pallas kernels
# through the generator's scalar-table operand (scalar prefetch on real
# TPU), so farm runs under "pallas"/"pallas-interpret" share one compiled
# kernel across heterogeneous slots and match "jnp" farms to tolerance
# (and pallas-interpret serial runs bitwise).
# "auto" resolves AT CONFIGURE TIME to "pallas" on TPU hosts and "jnp"
# elsewhere — the resolved config always carries an explicit template,
# never None (the solver would coerce None to JNP regardless of device).
BACKENDS = {
    "jnp": ("JNP", False, None),            # fused-XLA template (CPU/TPU)
    "pallas-interpret": ("3DBLOCK", True, False),  # Pallas tiles, interpret
    "pallas": ("3DBLOCK", False, False),    # Pallas tiles on real hardware
    "auto": None,                           # device default, resolved late
}


def _resolve_backend(name: str) -> tuple:
    if name == "auto":
        name = "pallas" if jax.default_backend() == "tpu" else "jnp"
    return BACKENDS[name]


def compile_cache_stats() -> dict:
    """Process-wide ensemble-step compile cache stats (re-export)."""
    from repro.sim.farm import compile_cache_stats as _stats

    return _stats()


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Everything the runtime needs to resolve an execution stack.

    ``mesh_shape``/``mesh_axes`` name the device mesh (built lazily; an
    empty shape means single-device).  ``decomposition`` maps grid axes to
    mesh axes for per-slot/per-run domain decomposition — validation and
    the extent-1 degrade follow the farm's ``plan_decomposition`` rules,
    so a laptop mesh and a pod fail (or degrade) identically.  ``solver``
    carries static solver overrides (``jacobi_iters``, ``fused_sweeps``,
    ``overlap``, ...) applied to every scenario config this runtime
    builds.
    """

    n: int = 32                          # grid resolution (n, n, nz)
    nz: int | None = None                # None -> scenario default
    backend: str = "jnp"                 # see BACKENDS
    mesh_shape: tuple = ()               # e.g. (2, 4)
    mesh_axes: tuple = ()                # e.g. ("slot", "shard")
    slot_axis: str = "slot"              # farm slot axis when meshed
    decomposition: tuple = ()            # e.g. ((0, "shard"),)
    n_slots: int = 4                     # farm slots per service
    ckpt_dir: str | None = None          # eviction spill directory
    check_every: int = 16                # convergence-check interval
    solver: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    # observability: False (default, bitwise-invisible), True, a
    # repro.obs.TelemetryConfig / Telemetry, or a TelemetryConfig kwargs
    # dict ({"trace_path": ...}); see repro.obs.resolve
    telemetry: Any = False
    # in-situ health monitoring + NaN quarantine on the farm path: False
    # (default: the pre-health executable, nothing compiled in), True, a
    # repro.obs.HealthConfig, or a HealthConfig kwargs dict
    # ({"div_diverged": 1e6, "flight_dir": ...}); flight records default
    # under <ckpt_dir>/flight when a checkpoint dir is set.  Independent
    # of `telemetry` — quarantine is functional, not instrumentation.
    health: Any = False
    # durable job engine (repro.jobs): None (default, the in-memory path,
    # bitwise-invisible), a repro.jobs.JobStore, True (jobs.sqlite under
    # ckpt_dir), a sqlite path string, or a JobStore kwargs dict
    # ({"path": ..., "ttl_s": ...}); see repro.jobs.resolve_store.  With a
    # store, submits are durable before admission, a restarted Runtime
    # resumes incomplete work first, and several processes share one
    # queue via leases.
    store: Any = None

    def __post_init__(self):
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r} "
                             f"(have {sorted(BACKENDS)})")
        if bool(self.mesh_shape) != bool(self.mesh_axes) or \
                len(self.mesh_shape) != len(self.mesh_axes):
            raise ValueError(
                f"mesh_shape {self.mesh_shape!r} and mesh_axes "
                f"{self.mesh_axes!r} must pair up axis-for-axis")


@dataclasses.dataclass
class RunResult:
    """A finished single run: host-visible state + schedule diagnostics."""

    scenario: str
    state: dict
    steps_done: int
    terminated: str              # "steps" | "residual" | "steady"
    config: CFDConfig
    diagnostics: dict


@dataclasses.dataclass
class PreparedRun:
    """A resolved-but-not-run single simulation: the solver, its schedule,
    the initial state (INITIAL bin output) and the jitted EVOLVE step.
    The escape hatch for benchmarks and custom drive loops that need the
    raw step function while still resolving everything through the
    runtime."""

    scenario: Scenario
    solver: NavierStokes3D
    schedule: Schedule
    state: dict
    step: Callable[[dict], dict]
    config: CFDConfig

    def analyze(self, state: dict, steps_done: int = 0) -> dict:
        ctx = {"t": steps_done * self.config.dt, "steps": steps_done}
        return self.scenario.analyze(self.solver, state, ctx)


def _residual_norm(new: dict, old: dict, dt) -> jnp.ndarray:
    """``||u_new - u_old||_inf / dt`` over the velocity fields (the serial
    twin of ``EnsembleExecutor.residuals``)."""
    m = jnp.max(jnp.stack([jnp.max(jnp.abs(new[f] - old[f]))
                           for f in ("vx", "vy", "vz")]))
    return m / jnp.maximum(dt, 1e-30)


_residual_norm_jit = jax.jit(_residual_norm)


class Runtime:
    """The front door: resolves scenarios against one RuntimeConfig.

    Single runs (``run``/``prepare``) build the serial ``GridDriver``
    stack — decomposed over the mesh's shard axes when the config asks
    for it.  Ensemble traffic (``submit``/``poll``/``result``/``drain``)
    routes through ``SimulationService`` farms, one per static signature,
    created lazily on first submit; a signature whose stack fails to
    build (e.g. an indivisible decomposition) resolves its sids to
    ``terminated="failed"`` results instead of wedging the queue.
    """

    def __init__(self, config: RuntimeConfig | None = None,
                 mesh: jax.sharding.Mesh | None = None):
        self.config = config if config is not None else RuntimeConfig()
        # one telemetry handle per runtime: every service/farm this
        # runtime resolves reports into it (scoped compile-cache stats,
        # farm metrics, per-sim traces); NULL when disabled, making every
        # hook a no-op on the default path
        self.telemetry = obs.resolve(self.config.telemetry)
        health = obs.resolve_health(self.config.health)
        if (health is not None and health.flight_dir is None
                and self.config.ckpt_dir is not None):
            health = dataclasses.replace(
                health,
                flight_dir=os.path.join(self.config.ckpt_dir, "flight"))
        self.health = health
        self._mesh = mesh                  # explicit mesh wins over shape
        self._mesh_built = mesh is not None
        self._services: dict[tuple, SimulationService] = {}
        self._routes: dict[int, tuple[SimulationService, int]] = {}
        self._failed: dict[int, SimResult] = {}
        self._scenario_of: dict[int, str] = {}
        # latest PreparedRun per scenario, kept only under telemetry so
        # perf accounting can re-lower the serial EVOLVE bin; the off path
        # pins no extra field state
        self._prepared: dict[str, PreparedRun] = {}
        self._next_sid = 0
        from repro.jobs import resolve_store

        self.store = resolve_store(self.config.store, self.config.ckpt_dir)
        # job_ids this process admitted itself: a claim must never return
        # our own job whose lease briefly expired (a long compile between
        # heartbeats) — that would double-admit it locally
        self._jobs_local: set[int] = set()
        if self.store is not None:
            # the restart contract: orphaned in-flight work resumes FIRST,
            # before any claim() touches the queued backlog
            self.recover()

    # -- resolution -----------------------------------------------------------
    @property
    def mesh(self) -> jax.sharding.Mesh | None:
        if not self._mesh_built:
            if self.config.mesh_shape:
                from repro.launch.mesh import make_mesh

                self._mesh = make_mesh(tuple(self.config.mesh_shape),
                                       tuple(self.config.mesh_axes))
            self._mesh_built = True
        return self._mesh

    def configure(self, scenario, n: int | None = None, **kw) -> CFDConfig:
        """The fully-resolved CFDConfig for ``scenario`` under this
        runtime: scenario builder -> static solver overrides -> backend
        template -> decomposition.  ``n`` overrides the runtime's default
        resolution (a different static signature, hence — on the farm
        path — a different lazily-built service)."""
        sc = get_scenario(scenario)
        template, interpret, overlap = _resolve_backend(self.config.backend)
        builder_kw = dict(self.config.solver)
        if self.config.nz is not None:
            builder_kw["nz"] = self.config.nz
        builder_kw.update(kw)
        cfg = sc.config(self.config.n if n is None else n, **builder_kw)
        return dataclasses.replace(
            cfg, template=template, interpret=interpret,
            overlap=cfg.overlap if overlap is None else overlap,
            decomposition=tuple(self.config.decomposition) or
            cfg.decomposition)

    def prepare(self, scenario, n: int | None = None,
                **params) -> PreparedRun:
        """Resolve one serial run: solver (+ decomposition over the mesh's
        shard axes), schedule, INITIAL state, jitted EVOLVE step."""
        sc = get_scenario(scenario)
        builder_kw, ic_kw = sc.split_kwargs(params)
        cfg = self.configure(sc, n=n, **builder_kw)
        # identical resolution rules to the farm: validate against the
        # mesh, drop extent-1 axes, run meshless when nothing decomposes
        solver_cfg, active = plan_decomposition(
            cfg, self.mesh,
            slot_axis=self.config.slot_axis if self.mesh is not None and
            self.config.slot_axis in self.mesh.axis_names else None)
        solver = NavierStokes3D(solver_cfg, self.mesh if active else None)
        sched = sc.schedule(solver, ic=ic_kw)
        tel = self.telemetry if self.telemetry.enabled else None
        state = sched.compile_bin("INITIAL", telemetry=tel)({})
        step = sched.compile_bin("EVOLVE", telemetry=tel)
        pr = PreparedRun(scenario=sc, solver=solver, schedule=sched,
                         state=state, step=step, config=cfg)
        if self.telemetry.enabled:
            self._prepared[sc.name] = pr
        return pr

    # -- single-run drive -----------------------------------------------------
    def run(self, scenario, *, n: int | None = None,
            steps: int | None = None,
            t_end: float | None = None, residual_tol: float | None = None,
            steady_tol: float | None = None, progress: int | None = None,
            **params) -> RunResult:
        """Run one simulation to completion, blocking.

        Termination: ``steps``/``t_end`` bound the run; ``residual_tol``
        additionally stops at steady state once
        ``||u^{n+1} - u^n||_inf / dt`` falls below it (checked every
        ``RuntimeConfig.check_every`` steps); ``steady_tol`` is the legacy
        kinetic-energy-drift heuristic.  The step sequence is bitwise the
        legacy ``make_step`` loop — convergence checks read snapshots,
        they never perturb the state path.
        """
        pr = self.prepare(scenario, n=n, **params)
        cfg = pr.config
        if steps is None:
            if t_end is None:
                raise ValueError("give either steps= or t_end=")
            steps = int(round(t_end / cfg.dt))
        check = max(int(self.config.check_every), 1)
        state, terminated, done = pr.state, "steps", 0
        ke_prev: float | None = None
        with self.telemetry.section(f"run.{pr.scenario.name}"):
            for i in range(steps):
                # snapshot only when this step lands on a residual check
                # boundary — an unconditional snapshot would pin a second
                # full field state for the whole run
                prev = state if (residual_tol is not None
                                 and (i + 1) % check == 0) else None
                state = pr.step(state)
                done = i + 1
                if progress and (done % progress == 0):
                    print(f"  step {done:6d}/{steps} "
                          f"t={done * cfg.dt:8.3f} "
                          f"KE={pr.solver.kinetic_energy(state):.6f}")
                if residual_tol is not None and done % check == 0:
                    resid = float(_residual_norm_jit(state, prev,
                                                     jnp.float32(cfg.dt)))
                    if resid <= residual_tol:
                        terminated = "residual"
                        break
                if steady_tol is not None and done % check == 0:
                    ke = pr.solver.kinetic_energy(state)
                    if ke_prev is not None and abs(ke - ke_prev) <= \
                            steady_tol * max(abs(ke), 1e-12):
                        terminated = "steady"
                        break
                    ke_prev = ke
        if self.telemetry.enabled:
            self.telemetry.metrics.inc("sim.steps_total", done)
        diagnostics = pr.analyze(state, done)
        return RunResult(scenario=pr.scenario.name,
                         state=jax.device_get(state), steps_done=done,
                         terminated=terminated, config=cfg,
                         diagnostics=diagnostics)

    # -- ensemble / service routing -------------------------------------------
    def _service_for(self, cfg: CFDConfig
                     ) -> tuple[SimulationService | None, str | None]:
        key = static_key(cfg, self.config.n_slots)
        if key in self._services:
            return self._services[key], None
        ckpt = None
        if self.config.ckpt_dir is not None:
            # one spill directory per signature: service-local sids double
            # as checkpoint step ids and must not collide across farms
            ckpt = os.path.join(self.config.ckpt_dir,
                                f"sig{len(self._services):03d}")
        try:
            svc = SimulationService(
                cfg, n_slots=self.config.n_slots, ckpt_dir=ckpt,
                check_steady_every=self.config.check_every,
                mesh=self.mesh, slot_axis=self.config.slot_axis,
                telemetry=self.telemetry, health=self.health,
                farm_id=f"{cfg.case}/sig{len(self._services):03d}",
                store=self.store)
        except Exception as e:
            return None, f"{type(e).__name__}: {e}"
        self._services[key] = svc
        return svc, None

    def submit(self, scenario, *, n: int | None = None,
               steps: int | None = None,
               t_end: float | None = None, tag: str = "",
               steady_tol: float | None = None,
               residual_tol: float | None = None, priority: int = 0,
               **params) -> int:
        """Queue one simulation on the farm; returns its sid.

        Requests of an unseen static signature lazily build their
        ``SimulationService``; a signature whose stack cannot build
        resolves this sid to a ``terminated="failed"`` result (surfaced
        by ``poll``/``result``/``drain``) rather than raising into the
        submit path or blocking a later drain.
        """
        sc = get_scenario(scenario)
        builder_kw, ic_kw = sc.split_kwargs(params)
        cfg = self.configure(sc, n=n, **builder_kw)
        req = sc.request(
            self.config.n if n is None else n, config=cfg,
            steps=steps, t_end=t_end, tag=tag,
            steady_tol=steady_tol, residual_tol=residual_tol,
            priority=priority, **ic_kw)
        sid = self._next_sid
        self._next_sid += 1
        self._scenario_of[sid] = sc.name
        svc, err = self._service_for(cfg)
        if svc is None:
            if self.store is not None:
                # even a sim whose stack cannot build leaves a durable
                # audit row — submitted, failed, never silently dropped
                from repro import jobs

                jid = self.store.submit(
                    req, signature=str(static_key(cfg, self.config.n_slots)),
                    lease=True)
                self.store.transition(jid, jobs.FAILED, error=err,
                                      event="result")
                self._jobs_local.add(jid)
            self._failed[sid] = SimResult(
                sid=sid, tag=req.tag, steps_done=0, terminated="failed",
                state={}, config=cfg, error=err)
            return sid
        inner = svc.submit(req)
        self._routes[sid] = (svc, inner)
        jid = svc.job_of(inner)
        if jid is not None:
            self._jobs_local.add(jid)
        return sid

    def poll(self, sid: int) -> dict:
        if sid in self._failed:
            res = self._failed[sid]
            return {"status": "failed", "steps_done": 0, "error": res.error}
        if sid not in self._routes:
            raise KeyError(f"unknown simulation id {sid}")
        svc, inner = self._routes[sid]
        return svc.poll(inner)

    def result(self, sid: int, block: bool = True) -> SimResult:
        if sid in self._failed:
            res = self._failed[sid]
            raise RuntimeError(
                f"simulation {sid} ({res.tag or 'untagged'}) failed: "
                f"{res.error}")
        if sid not in self._routes:
            raise KeyError(f"unknown simulation id {sid}")
        svc, inner = self._routes[sid]
        return dataclasses.replace(svc.result(inner, block=block), sid=sid)

    def evict(self, sid: int) -> bool:
        if sid not in self._routes:
            return False
        svc, inner = self._routes[sid]
        return svc.evict(inner)

    def readmit(self, sid: int) -> bool:
        if sid not in self._routes:
            return False
        svc, inner = self._routes[sid]
        return svc.readmit(inner)

    # -- durable jobs (repro.jobs) ---------------------------------------------
    def _job_gauges(self):
        if self.store is None or not self.telemetry.enabled:
            return
        self.telemetry.metrics.set("jobs.lease_takeovers",
                                   self.store.takeovers)
        self.telemetry.metrics.set("jobs.store_queue_depth",
                                   self.store.queue_depth())

    def _admit_job(self, job, resumed: bool = False) -> int:
        """Admit one claimed store row into this process's farms,
        resuming from its latest eviction snapshot when asked."""
        from repro import jobs

        req = job.request()
        if resumed:
            snap = self.store.latest_snapshot(job.job_id, "evict")
            if snap is not None and snap["fields"]:
                # resume pointer: re-enter a slot bitwise at the snapshot
                steps_done, state = self.store.load_snapshot(job.job_id,
                                                             "evict")
                req = dataclasses.replace(req, init_state=state,
                                          step0=steps_done)
            # no snapshot: the job was claimed before ever reaching a
            # spill point — it restarts from its payload (step0 intact)
        sid = self._next_sid
        self._next_sid += 1
        self._jobs_local.add(job.job_id)
        svc, err = self._service_for(req.config)
        if svc is None:
            self.store.transition(job.job_id, jobs.FAILED, error=err,
                                  event="result")
            self._failed[sid] = SimResult(
                sid=sid, tag=req.tag, steps_done=0, terminated="failed",
                state={}, config=req.config, error=err)
            return sid
        try:
            inner = svc.submit(req, job_id=job.job_id)
        except Exception as e:
            # service.submit already transitioned the row to failed
            self._failed[sid] = SimResult(
                sid=sid, tag=req.tag, steps_done=0, terminated="failed",
                state={}, config=req.config,
                error=f"{type(e).__name__}: {e}")
            return sid
        self._routes[sid] = (svc, inner)
        return sid

    def enqueue(self, scenario, *, n: int | None = None,
                steps: int | None = None, t_end: float | None = None,
                tag: str = "", steady_tol: float | None = None,
                residual_tol: float | None = None, priority: int = 0,
                **params) -> int:
        """Queue one simulation durably WITHOUT admitting it here;
        returns its store job_id.  The detached half of ``submit``: any
        process sharing the store — this one included — picks it up via
        ``claim()``/``drain()``, so a front-end process can feed worker
        processes through nothing but the store file."""
        if self.store is None:
            raise RuntimeError(
                "enqueue() needs a job store — RuntimeConfig(store=...)")
        sc = get_scenario(scenario)
        builder_kw, ic_kw = sc.split_kwargs(params)
        cfg = self.configure(sc, n=n, **builder_kw)
        req = sc.request(
            self.config.n if n is None else n, config=cfg,
            steps=steps, t_end=t_end, tag=tag,
            steady_tol=steady_tol, residual_tol=residual_tol,
            priority=priority, **ic_kw)
        job_id = self.store.submit(
            req, signature=str(static_key(cfg, self.config.n_slots)),
            lease=False)
        if self.telemetry.enabled:
            self.telemetry.trace.emit("job_enqueue", job_id=job_id, tag=tag)
        self._job_gauges()
        return job_id

    def claim(self, max_jobs: int | None = None) -> list[int]:
        """Lease up to ``max_jobs`` queued store jobs (default: one
        farm's worth) and admit them locally; returns their sids.  Jobs
        this process already admitted are never re-claimed, even if their
        lease briefly lapsed."""
        if self.store is None:
            return []
        limit = max_jobs if max_jobs is not None else self.config.n_slots
        claimed = [j for j in self.store.claim(limit=limit)
                   if j.job_id not in self._jobs_local]
        sids = [self._admit_job(j) for j in claimed]
        if self.telemetry.enabled:
            for j in claimed:
                self.telemetry.trace.emit("job_claim", job_id=j.job_id,
                                          tag=j.tag)
        self._job_gauges()
        return sids

    def recover(self, limit: int = 64) -> list[int]:
        """Claim orphaned in-flight jobs (``running``/``evicted`` rows
        with an expired lease — their process died) and readmit each from
        its latest snapshot.  Runs automatically when a store-configured
        Runtime is built, BEFORE any queued work is claimed — the
        restart-resumes-incomplete-first contract."""
        if self.store is None:
            return []
        claimed = [j for j in self.store.claim_incomplete(limit=limit)
                   if j.job_id not in self._jobs_local]
        sids = [self._admit_job(j, resumed=True) for j in claimed]
        if self.telemetry.enabled:
            if claimed:
                self.telemetry.metrics.inc("jobs.resumed", len(claimed))
            for j in claimed:
                self.telemetry.trace.emit("job_resume", job_id=j.job_id,
                                          tag=j.tag, status=j.status)
        self._job_gauges()
        return sids

    def job_of(self, sid: int) -> int | None:
        """The durable job_id behind a sid (None without a store)."""
        if sid not in self._routes:
            return None
        svc, inner = self._routes[sid]
        return svc.job_of(inner)

    def jobs(self, status=None):
        """Store job rows (optionally filtered by status)."""
        if self.store is None:
            return []
        return self.store.jobs(status)

    def load_result(self, job_id: int) -> dict:
        """A done job's persisted final field state, from any process."""
        if self.store is None:
            raise RuntimeError("load_result() needs a job store")
        return self.store.load_result(job_id)

    def flight_record(self, job_id: int):
        """The flight record of a diverged job, resolved through its
        store registration — works after a process restart, when the
        farm that recorded it is long gone."""
        from repro.obs.health import load_flight_record

        snap = (self.store.latest_snapshot(job_id, "flight")
                if self.store is not None else None)
        if snap is None:
            raise KeyError(f"job {job_id} has no registered flight record")
        return load_flight_record(snap["dir"], snap["step_key"])

    def drain(self, max_device_steps: int = 100_000) -> dict[int, SimResult]:
        """Run every farm dry; ALWAYS returns one result per submitted
        sid, failed sims included (``terminated="failed"`` + error).
        With a job store, also keeps claiming queued store jobs until the
        shared queue is empty (or every remaining job is leased by a live
        peer), so ``drain`` on any worker drives the whole backlog."""
        while self.store is not None and self.claim():
            for svc in self._services.values():
                svc.drain(max_device_steps)
        for svc in self._services.values():
            svc.drain(max_device_steps)
        out: dict[int, SimResult] = {}
        for sid, (svc, inner) in self._routes.items():
            res = svc.farm.results.get(inner)
            if res is not None:
                out[sid] = dataclasses.replace(res, sid=sid)
        out.update(self._failed)
        return out

    def analyze(self, result: SimResult | RunResult) -> dict:
        """Scenario ANALYSIS diagnostics for a finished farm result
        (matches RunResult.diagnostics for the equivalent single run)."""
        name = result.scenario if isinstance(result, RunResult) else \
            self._scenario_of.get(result.sid)
        if name is None:
            # foreign SimResult: match on the config's case string
            for cand in scenario_names():
                if get_scenario(cand).config(result.config.shape[0]).case \
                        == result.config.case:
                    name = cand
                    break
        if name is None:
            raise ValueError("cannot infer a scenario for this result")
        sc = get_scenario(name)
        solver = NavierStokes3D(
            dataclasses.replace(result.config, decomposition=()))
        ctx = {"t": result.steps_done * result.config.dt,
               "steps": result.steps_done}
        return sc.analyze(solver, result.state, ctx)

    def watch(self, refresh_s: float | None = None,
              iterations: int | None = None) -> str:
        """Live per-slot health dashboard over every resolved farm
        (Cactus-HTTPD style, as text).

        Called bare it renders and returns one frame — slot occupancy,
        per-sim progress, latest health state/diagnostics, queue depth.
        With ``refresh_s`` it also prints the frame and re-renders every
        ``refresh_s`` seconds until the farms go idle (or ``iterations``
        frames have printed), returning the last frame — run it from a
        second thread, or interleave with ``services()[i].run(...)``
        from a drive loop.
        """
        from repro.obs.health import render_dashboard

        def frame() -> str:
            return render_dashboard(
                [svc.farm.health_snapshot()
                 for svc in self._services.values()])

        if refresh_s is None:
            return frame()
        import time

        n, text = 0, frame()
        while True:
            text = frame()
            print(text, flush=True)
            n += 1
            if iterations is not None and n >= iterations:
                break
            if all(svc.farm.table.idle for svc in self._services.values()):
                break
            time.sleep(refresh_s)
        return text

    # -- introspection --------------------------------------------------------
    def device_steps(self) -> int:
        """Total device dispatch steps across every resolved farm."""
        return sum(svc.farm.device_steps for svc in self._services.values())

    def services(self) -> tuple[SimulationService, ...]:
        return tuple(self._services.values())

    def perf_report(self, chip="auto", dtype: str = "f32"):
        """Cost-model-grounded accounting of every executable this
        runtime compiled: one :class:`repro.obs.perf.PerfReport` row per
        farm signature and prepared serial scenario, with predicted
        FLOPs / HBM bytes / collective wire bytes joined against the
        measured timer sections (see ``repro.obs.perf``)."""
        from repro.obs import perf

        return perf.report_for_runtime(self, chip=chip, dtype=dtype)

    def report(self, perf: bool = False, chip="auto") -> str:
        """This runtime's ``repro.obs.report()`` (timers + metrics);
        ``perf=True`` appends the roofline-attributed perf accounting."""
        text = obs.report(self.telemetry)
        if perf:
            text += "\n" + self.perf_report(chip=chip).render()
        return text


def runtime(n: int = 32, *, backend: str = "jnp", mesh_shape: tuple = (),
            mesh_axes: tuple = (), decomposition: tuple = (),
            slot_axis: str = "slot", n_slots: int = 4,
            ckpt_dir: str | None = None, check_every: int = 16,
            nz: int | None = None, mesh: jax.sharding.Mesh | None = None,
            telemetry: Any = False, health: Any = False, store: Any = None,
            **solver) -> Runtime:
    """Build a :class:`Runtime` — the one-call front door.

    >>> rt = repro.api.runtime(n=32, telemetry=True, health=True)
    >>> res = rt.run("cavity", t_end=5.0, re=100.0)
    >>> res.diagnostics["ghia"]
    >>> print(rt.report())        # Cactus-style timers + farm metrics
    >>> print(rt.watch())         # live per-slot health dashboard
    """
    cfg = RuntimeConfig(n=n, nz=nz, backend=backend,
                        mesh_shape=tuple(mesh_shape),
                        mesh_axes=tuple(mesh_axes),
                        decomposition=tuple(decomposition),
                        slot_axis=slot_axis, n_slots=n_slots,
                        ckpt_dir=ckpt_dir, check_every=check_every,
                        solver=dict(solver), telemetry=telemetry,
                        health=health, store=store)
    return Runtime(cfg, mesh=mesh)
