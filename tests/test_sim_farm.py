"""Simulation farm: batched ensembles must reproduce serial runs exactly,
slots must recycle through queued work, and the compile cache must hand out
one executable per static signature."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.cfd import cavity, taylor_green
from repro.cfd.ns3d import NavierStokes3D, params_from_config
from repro.core import generate, mol
from repro.kernels import stencil3d
from repro.sim import (
    EnsembleExecutor, SimulationFarm, SimulationService,
    compile_cache_stats, reset_compile_cache, stack_trees,
)
from tests.helpers import run_with_devices

N = 16
KW = dict(jacobi_iters=20)


def serial_reference(re: float, steps: int):
    """The pre-farm workflow: one solver, one GridDriver-jitted step."""
    solver = NavierStokes3D(cavity.config(N, re=re, **KW))
    state = solver.init_state()
    step = solver.make_step()
    for _ in range(steps):
        state = step(state)
    return jax.device_get(state)


FIELDS = ("vx", "vy", "vz", "p")


class TestFarmMatchesSerial:
    # 8 heterogeneous sims through 4 slots: mixed Reynolds numbers AND mixed
    # step counts, so slots reclaim mid-flight and admissions interleave.
    RES = (50.0, 80.0, 100.0, 150.0, 200.0, 250.0, 300.0, 400.0)
    STEPS = (30, 45, 25, 60, 35, 50, 40, 55)

    @pytest.fixture(scope="class")
    def farm_results(self):
        farm = SimulationFarm(cavity.config(N, **KW), n_slots=4)
        sids = {}
        for re, steps in zip(self.RES, self.STEPS):
            sid = farm.submit(cavity.sim_request(N, re=re, steps=steps, **KW))
            sids[sid] = (re, steps)
        results = farm.run_until_drained()
        return farm, sids, results

    def test_all_complete(self, farm_results):
        farm, sids, results = farm_results
        assert set(results) == set(sids)
        for sid, (_, steps) in sids.items():
            assert results[sid].steps_done == steps
            assert results[sid].terminated == "steps"

    def test_bitwise_identical_to_serial(self, farm_results):
        _, sids, results = farm_results
        for sid, (re, steps) in sids.items():
            ref = serial_reference(re, steps)
            for f in FIELDS:
                np.testing.assert_array_equal(
                    ref[f], results[sid].state[f],
                    err_msg=f"sid={sid} re={re} field={f}")

    def test_slot_reclamation_batches_work(self, farm_results):
        farm, sids, _ = farm_results
        # 4 slots served 8 sims: continuous batching must beat one-at-a-time
        # (sum of steps) and a freed slot must have admitted queued work
        # (device steps strictly less than two sequential half-batches of
        # the worst case, and at least the longest single sim).
        total = sum(s for _, s in sids.values())
        assert farm.device_steps < total
        assert farm.device_steps >= max(s for _, s in sids.values())


class TestCompileCache:
    def test_one_compile_per_static_signature(self):
        reset_compile_cache()
        base = cavity.config(N, **KW)
        farm1 = SimulationFarm(base, n_slots=4)
        for re in (70.0, 120.0, 180.0, 220.0, 260.0):
            farm1.submit(cavity.sim_request(N, re=re, steps=5, **KW))
        farm1.run_until_drained()
        assert compile_cache_stats()["misses"] == 1
        # a second farm of the same shape reuses the compiled step
        farm2 = SimulationFarm(base, n_slots=4)
        farm2.submit(cavity.sim_request(N, re=90.0, steps=5, **KW))
        farm2.run_until_drained()
        stats = compile_cache_stats()
        assert stats["misses"] == 1 and stats["hits"] == 1
        # a different slot count is a different executable
        SimulationFarm(base, n_slots=2)
        assert compile_cache_stats()["misses"] == 2

    def test_static_mismatch_rejected(self):
        farm = SimulationFarm(cavity.config(N, **KW), n_slots=2)
        with pytest.raises(ValueError, match="static config"):
            farm.submit(cavity.sim_request(N, re=100.0, steps=5,
                                           jacobi_iters=33))

    def test_double_submit_rejected(self):
        farm = SimulationFarm(cavity.config(N, **KW), n_slots=2)
        req = cavity.sim_request(N, re=100.0, steps=5, **KW)
        farm.submit(req)
        with pytest.raises(ValueError, match="already submitted"):
            farm.submit(req)


class TestService:
    def test_poll_lifecycle_and_eviction(self):
        svc = SimulationService(cavity.config(N, **KW), n_slots=2)
        a = svc.submit(cavity.sim_request(N, re=100.0, steps=40, **KW))
        b = svc.submit(cavity.sim_request(N, re=200.0, steps=40, **KW))
        c = svc.submit(cavity.sim_request(N, re=300.0, steps=10, **KW))
        assert svc.poll(c)["status"] == "queued"
        svc.run(10)
        assert svc.poll(a)["status"] == "running"
        assert svc.evict(a)
        assert svc.poll(a)["status"] == "evicted"
        # the freed slot admits the queued sim on the next step
        svc.run(1)
        assert svc.poll(c)["status"] == "running"
        # an evicted sim resumes at its exact step and matches serial
        ra = svc.result(a)
        assert ra.steps_done == 40
        ref = serial_reference(100.0, 40)
        for f in FIELDS:
            np.testing.assert_array_equal(ref[f], ra.state[f])
        assert svc.result(b).steps_done == 40
        assert svc.poll(c)["status"] == "done"
        with pytest.raises(KeyError):
            svc.poll(10_000)

    def test_eviction_spills_through_checkpointer(self, tmp_path):
        svc = SimulationService(cavity.config(N, **KW), n_slots=1,
                                ckpt_dir=str(tmp_path))
        a = svc.submit(cavity.sim_request(N, re=100.0, steps=30, **KW))
        svc.run(12)
        assert svc.evict(a)
        # state went to disk, not host RAM
        assert svc._evicted[a].state is None
        assert any(p.name.startswith("step_") for p in tmp_path.iterdir())
        ra = svc.result(a)
        ref = serial_reference(100.0, 30)
        for f in FIELDS:
            np.testing.assert_array_equal(ref[f], ra.state[f])

    def test_steady_state_termination(self):
        svc = SimulationService(cavity.config(N, **KW), n_slots=1,
                                check_steady_every=8)
        a = svc.submit(cavity.sim_request(N, re=100.0, steps=5000,
                                          steady_tol=1e-4, **KW))
        ra = svc.result(a)
        assert ra.terminated == "steady"
        assert ra.steps_done < 5000


class TestTaylorGreenEnsemble:
    def test_mixed_viscosity_matches_serial(self):
        base = taylor_green.config(N, nu=0.1)
        farm = SimulationFarm(base, n_slots=3)
        nus = (0.05, 0.1, 0.2)
        sids = {farm.submit(taylor_green.sim_request(N, nu=nu, steps=12)): nu
                for nu in nus}
        results = farm.run_until_drained()
        for sid, nu in sids.items():
            cfg = taylor_green.config(N, nu=nu)
            solver = NavierStokes3D(cfg)
            state = solver.init_state()
            step = solver.make_step()
            for _ in range(12):
                state = step(state)
            ref = jax.device_get(state)
            for f in FIELDS:
                np.testing.assert_array_equal(ref[f], results[sid].state[f])


# the Pallas farm posture: 3DBLOCK tiles through the interpreter (the CPU
# correctness mode of the TPU path), overlap off as BACKENDS resolves it
PKW = dict(jacobi_iters=20, template="3DBLOCK", interpret=True,
           overlap=False)


class TestPallasFarmParity:
    """The farm's Pallas backend: per-slot scalars through the generator's
    scalar table (scalar prefetch on hardware), one compiled 3DBLOCK
    kernel for every slot.

    Contract: a ``pallas-interpret`` farm run is BITWISE the
    pallas-interpret *serial* run of the same request — slots carry
    heterogeneous nu/dt/lid scalars, so any literal-baking regression
    (slot 0's physics smeared over the batch, or one kernel per scalar
    tuple) shows immediately — and matches the JNP farm to fp tolerance
    (separately compiled XLA programs contract FMAs differently; the
    cross-template contract was always tolerance-level, as in
    ``tests/test_kernels.py``)."""

    RES = (50.0, 200.0, 400.0)
    STEPS = (12, 8, 15)

    def _serial(self, cfg, steps):
        solver = NavierStokes3D(cfg)
        state = solver.init_state()
        step = solver.make_step()
        for _ in range(steps):
            state = step(state)
        return jax.device_get(state)

    @pytest.fixture(scope="class")
    def cavity_farms(self):
        """The same heterogeneous requests through a pallas-interpret farm
        and a JNP farm (2 slots serving 3 sims: a reclamation happens)."""
        out = {}
        for kw in (PKW, KW):
            farm = SimulationFarm(cavity.config(N, **kw), n_slots=2)
            sids = {farm.submit(cavity.sim_request(N, re=re, steps=st, **kw)):
                    (re, st) for re, st in zip(self.RES, self.STEPS)}
            results = farm.run_until_drained()
            out[kw["template"] if "template" in kw else "JNP"] = (sids, results)
        return out

    def test_cavity_farm_bitwise_vs_pallas_serial(self, cavity_farms):
        sids, results = cavity_farms["3DBLOCK"]
        for sid, (re, st) in sids.items():
            res = results[sid]
            assert res.terminated == "steps", (res.terminated, res.error)
            ref = self._serial(cavity.config(N, re=re, **PKW), st)
            for f in FIELDS:
                np.testing.assert_array_equal(
                    ref[f], res.state[f], err_msg=f"re={re} field={f}")

    def test_cavity_farm_matches_jnp_farm(self, cavity_farms):
        psids, pres = cavity_farms["3DBLOCK"]
        jsids, jres = cavity_farms["JNP"]
        by_req_p = {k: pres[s] for s, k in psids.items()}
        for sid, key in jsids.items():
            for f in FIELDS:
                np.testing.assert_allclose(
                    jres[sid].state[f], by_req_p[key].state[f],
                    rtol=2e-5, atol=1e-6, err_msg=f"req={key} field={f}")

    def test_taylor_green_heterogeneous_nu_and_dt_bitwise(self):
        """Distinct nu AND dt per slot — dt multiplies every kernel's
        update, so a scalar table that indexed the wrong row (or baked
        slot 0's literals) cannot pass."""
        base = taylor_green.config(N, nu=0.1, dt=1e-3, **PKW)
        farm = SimulationFarm(base, n_slots=3)
        runs = ((0.05, 1.0e-3), (0.1, 0.5e-3), (0.2, 0.25e-3))
        sids = {farm.submit(taylor_green.sim_request(
            N, nu=nu, dt=dt, steps=10, **PKW)): (nu, dt)
            for nu, dt in runs}
        results = farm.run_until_drained()
        for sid, (nu, dt) in sids.items():
            res = results[sid]
            assert res.terminated == "steps", (res.terminated, res.error)
            ref = self._serial(taylor_green.config(N, nu=nu, dt=dt, **PKW),
                               10)
            for f in FIELDS:
                np.testing.assert_array_equal(
                    ref[f], res.state[f], err_msg=f"nu={nu} dt={dt} {f}")

    def test_evict_readmit_cycle_bitwise(self):
        svc = SimulationService(cavity.config(N, **PKW), n_slots=2)
        a = svc.submit(cavity.sim_request(N, re=100.0, steps=24, **PKW))
        b = svc.submit(cavity.sim_request(N, re=200.0, steps=24, **PKW))
        c = svc.submit(cavity.sim_request(N, re=300.0, steps=6, **PKW))
        svc.run(6)
        assert svc.evict(a)
        assert svc.poll(a)["status"] == "evicted"
        ra = svc.result(a)            # readmits and runs to completion
        assert ra.steps_done == 24
        ref = self._serial(cavity.config(N, re=100.0, **PKW), 24)
        for f in FIELDS:
            np.testing.assert_array_equal(ref[f], ra.state[f], err_msg=f)
        assert svc.result(b).steps_done == 24
        assert svc.result(c).steps_done == 6

    def test_one_compile_for_heterogeneous_scalars(self):
        """Scalar values must not fragment the compile cache: five
        Reynolds variants through a pallas farm are ONE executable."""
        reset_compile_cache()
        farm = SimulationFarm(cavity.config(N, **PKW), n_slots=2)
        for re in (70.0, 120.0, 180.0, 220.0, 260.0):
            farm.submit(cavity.sim_request(N, re=re, steps=3, **PKW))
        results = farm.run_until_drained()
        assert all(r.terminated == "steps" for r in results.values())
        stats = compile_cache_stats()
        assert stats["misses"] == 1 and stats["entries"] == 1

    def test_serial_and_farm_share_autotuned_tiles(self):
        """The roofline tile is resolved per (kernel, local interior,
        chip) and memoized: the farm's batched step re-reads the serial
        path's choices (zero extra misses) — the invariant behind the
        bitwise contract above."""
        from repro.core import reset_tile_cache, tile_cache_stats

        reset_compile_cache()
        reset_tile_cache()
        self._serial(cavity.config(N, re=100.0, **PKW), 1)
        after_serial = tile_cache_stats()
        assert after_serial["misses"] > 0          # the tuner really ran
        farm = SimulationFarm(cavity.config(N, **PKW), n_slots=2)
        farm.submit(cavity.sim_request(N, re=150.0, steps=2, **PKW))
        farm.run_until_drained()
        after_farm = tile_cache_stats()
        assert after_farm["misses"] == after_serial["misses"]
        assert after_farm["hits"] > after_serial["hits"]


class TestEnsembleExecutor:
    def test_write_read_clear_slots(self):
        ex = EnsembleExecutor(cavity.config(N, **KW), n_slots=3)
        cfg = cavity.config(N, re=150.0, **KW)
        ex.write_slot(1, params_from_config(cfg))
        assert ex.params["nu"][1] == np.float32(cfg.nu)
        got = ex.read_slot(1)
        assert set(FIELDS) <= set(got)
        ex.clear_slot(1)
        assert ex.params["lid_velocity"][1] == 0.0
        ke = ex.kinetic_energy()
        assert ke.shape == (3,)


class TestDecompositionDegrade:
    """Fast-lane (1-CPU) coverage of the slots × shards plumbing: a mesh
    whose shard axis has extent 1 degrades to the PR-2 slot-parallel fast
    path, and mis-assembled farms fail with accurate errors (regression:
    the executor used to claim decomposition was unsupported on ANY
    mesh)."""

    DKW = dict(jacobi_iters=20, decomposition=((0, "shard"),))

    def _one_shard_farm(self, n_slots=2):
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((1, 1), ("slot", "shard"))
        return SimulationFarm(cavity.config(N, **self.DKW), n_slots=n_slots,
                              mesh=mesh, slot_axis="slot")

    def test_one_shard_mesh_degrades_to_fast_path(self):
        farm = self._one_shard_farm()
        assert farm.exec.decomposition == {}
        assert farm.exec.slot_sharding() is None
        # the solver really runs undecomposed (no halo collectives traced)
        assert farm.exec.solver.config.decomposition == ()
        assert farm.exec.solver.domain.decomposition == {}

    def test_one_shard_mesh_matches_plain_farm_bitwise(self):
        farm = self._one_shard_farm()
        sid = farm.submit(cavity.sim_request(N, re=100.0, steps=10,
                                             **self.DKW))
        res = farm.run_until_drained()
        plain = SimulationFarm(cavity.config(N, **KW), n_slots=2)
        sid2 = plain.submit(cavity.sim_request(N, re=100.0, steps=10, **KW))
        res2 = plain.run_until_drained()
        for f in FIELDS:
            np.testing.assert_array_equal(res[sid].state[f],
                                          res2[sid2].state[f], err_msg=f)

    def test_degraded_step_compiles_without_collectives(self):
        farm = self._one_shard_farm()
        hlo = farm.exec._run_k.lower(
            farm.exec.state, farm.exec._device_params(),
            jnp.int32(1)).compile().as_text()
        assert "collective-permute" not in hlo

    def test_decomposition_without_mesh_raises_accurately(self):
        # the old message claimed decomposition was unsupported outright;
        # the real contract is "bring a mesh that names the axes"
        with pytest.raises(ValueError, match="mesh"):
            EnsembleExecutor(cavity.config(N, **self.DKW), n_slots=2)

    def test_decomposition_missing_mesh_axis_raises(self):
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((1,), ("slot",))
        with pytest.raises(ValueError, match="shard"):
            SimulationFarm(cavity.config(N, **self.DKW), n_slots=2,
                           mesh=mesh, slot_axis="slot")

    def test_invalid_decomposition_fails_even_on_one_shard_mesh(self):
        """Validation runs before the extent-1 degrade filter: a config
        that would raise on a pod raises identically on a laptop."""
        from repro.launch.mesh import make_mesh

        mesh = make_mesh((1, 1), ("slot", "shard"))
        bad_axis = cavity.config(N, jacobi_iters=20,
                                 decomposition=((5, "shard"),))
        with pytest.raises(ValueError, match="array axis 5"):
            SimulationFarm(bad_axis, n_slots=2, mesh=mesh, slot_axis="slot")
        over_slot = cavity.config(N, jacobi_iters=20,
                                  decomposition=((0, "slot"),))
        with pytest.raises(ValueError, match="slot axis"):
            SimulationFarm(over_slot, n_slots=2, mesh=mesh,
                           slot_axis="slot")
        dup = cavity.config(N, jacobi_iters=20,
                            decomposition=((0, "shard"), (0, "shard")))
        with pytest.raises(ValueError, match="more than once"):
            SimulationFarm(dup, n_slots=2, mesh=mesh, slot_axis="slot")

    def test_decomposition_is_part_of_the_static_signature(self):
        farm = self._one_shard_farm()
        with pytest.raises(ValueError, match="static config"):
            farm.submit(cavity.sim_request(N, re=100.0, steps=5, **KW))


class TestBatchedKernelTemplates:
    """The generator-level slot axis: JNP vmap and the batched 3DBLOCK grid."""

    def _arrays(self, nslots, shape, pad, seed=0):
        rng = np.random.RandomState(seed)
        mk = lambda s: jnp.asarray(
            rng.randn(nslots, *[d + 2 * pad for d in s]).astype(np.float32))
        return mk(shape)

    def test_jnp_batched_equals_per_slot(self):
        kern = generate(stencil3d.DESCRIPTORS["JACOBI_PRESSURE"],
                        stencil3d.BODIES["JACOBI_PRESSURE"], template="JNP")
        nslots, shape = 3, (8, 8, 8)
        p = self._arrays(nslots, shape, 1, seed=1)
        rhs = self._arrays(nslots, shape, 0, seed=2)
        out = kern.apply_batched({"p": p, "rhs": rhs}, h=0.1, omega=0.9)
        for s in range(nslots):
            ref = kern({"p": p[s], "rhs": rhs[s]}, h=0.1, omega=0.9)
            np.testing.assert_array_equal(ref["p"], out["p"][s])

    def test_jnp_batched_per_slot_params(self):
        kern = generate(stencil3d.DESCRIPTORS["JACOBI_PRESSURE"],
                        stencil3d.BODIES["JACOBI_PRESSURE"], template="JNP")
        nslots, shape = 3, (8, 8, 8)
        p = self._arrays(nslots, shape, 1, seed=3)
        rhs = self._arrays(nslots, shape, 0, seed=4)
        omegas = jnp.asarray([0.7, 0.9, 1.0], jnp.float32)
        out = kern.apply_batched({"p": p, "rhs": rhs}, h=0.1, omega=omegas,
                                 batched_params=("omega",))
        for s in range(nslots):
            ref = kern({"p": p[s], "rhs": rhs[s]}, h=0.1, omega=omegas[s])
            np.testing.assert_array_equal(ref["p"], out["p"][s])

    def test_pallas_batched_matches_jnp(self):
        desc = stencil3d.DESCRIPTORS["JACOBI_PRESSURE"]
        body = stencil3d.BODIES["JACOBI_PRESSURE"]
        pallas = generate(desc, body, template="3DBLOCK", interpret=True)
        oracle = generate(desc, body, template="JNP")
        nslots, shape = 2, (8, 8, 8)
        p = self._arrays(nslots, shape, 1, seed=5)
        rhs = self._arrays(nslots, shape, 0, seed=6)
        got = pallas.apply_batched({"p": p, "rhs": rhs}, h=0.1, omega=1.0)
        want = oracle.apply_batched({"p": p, "rhs": rhs}, h=0.1, omega=1.0)
        np.testing.assert_allclose(np.asarray(got["p"]),
                                   np.asarray(want["p"]), atol=1e-6)

    def test_pallas_batched_per_slot_params_bitwise(self):
        """Per-slot scalars through the 3DBLOCK scalar table (the path the
        farm's vmapped step rides): each slot's row must reproduce the
        serial operand-table call bit-for-bit."""
        desc = stencil3d.DESCRIPTORS["JACOBI_PRESSURE"]
        pallas = generate(desc, stencil3d.BODIES["JACOBI_PRESSURE"],
                          template="3DBLOCK", interpret=True)
        nslots, shape = 3, (8, 8, 8)
        p = self._arrays(nslots, shape, 1, seed=7)
        rhs = self._arrays(nslots, shape, 0, seed=8)
        omegas = jnp.asarray([0.7, 0.9, 1.1], jnp.float32)
        out = pallas.apply_batched({"p": p, "rhs": rhs}, h=0.1, omega=omegas,
                                   batched_params=("omega",))
        for s in range(nslots):
            ref = pallas({"p": p[s], "rhs": rhs[s]}, h=0.1, omega=omegas[s])
            np.testing.assert_array_equal(np.asarray(ref["p"]),
                                          np.asarray(out["p"][s]))

    def test_pallas_vmap_dispatches_to_batched_grid(self):
        """jax.vmap of the kernel call (exactly what make_ensemble_step
        does to the solver step) hits the custom_vmap rule and matches
        apply_batched bitwise — under jit, with traced scalars."""
        desc = stencil3d.DESCRIPTORS["JACOBI_PRESSURE"]
        pallas = generate(desc, stencil3d.BODIES["JACOBI_PRESSURE"],
                          template="3DBLOCK", interpret=True)
        nslots, shape = 3, (8, 8, 8)
        p = self._arrays(nslots, shape, 1, seed=9)
        rhs = self._arrays(nslots, shape, 0, seed=10)
        omegas = jnp.asarray([0.7, 0.9, 1.1], jnp.float32)

        @jax.jit
        def farm_like(ps, rs, oms):
            return jax.vmap(
                lambda p1, r1, om: pallas({"p": p1, "rhs": r1},
                                          h=0.1, omega=om)["p"])(ps, rs, oms)

        want = pallas.apply_batched({"p": p, "rhs": rhs}, h=0.1,
                                    omega=omegas, batched_params=("omega",))
        np.testing.assert_array_equal(np.asarray(farm_like(p, rhs, omegas)),
                                      np.asarray(want["p"]))

    def test_pallas_batched_non_array_per_slot_param_rejected(self):
        desc = stencil3d.DESCRIPTORS["JACOBI_PRESSURE"]
        pallas = generate(desc, stencil3d.BODIES["JACOBI_PRESSURE"],
                          template="3DBLOCK", interpret=True)
        with pytest.raises(ValueError, match="array-valued"):
            pallas.apply_batched({"p": jnp.zeros((2, 10, 10, 10)),
                                  "rhs": jnp.zeros((2, 8, 8, 8))},
                                 h=0.1, omega=0.9,
                                 batched_params=("omega",))


class TestBatchedMoL:
    def test_batched_integrators_match_serial(self):
        def rhs(y, t):
            return {"u": -0.5 * y["u"] + jnp.sin(t)}

        ys = [{"u": jnp.full((4,), v, jnp.float32)} for v in (1.0, 2.0, 3.0)]
        ts = jnp.asarray([0.0, 0.1, 0.2], jnp.float32)
        dts = jnp.asarray([0.01, 0.02, 0.005], jnp.float32)
        stacked = stack_trees(ys)
        for name, integ in mol.INTEGRATORS.items():
            batched = mol.BATCHED_INTEGRATORS[name]
            out = jax.jit(lambda y, t, dt: batched(rhs, y, t, dt))(
                stacked, ts, dts)
            for s in range(3):
                ref = integ(rhs, ys[s], ts[s], dts[s])
                np.testing.assert_allclose(np.asarray(ref["u"]),
                                           np.asarray(out["u"][s]),
                                           rtol=1e-6)


@pytest.mark.multidevice
class TestDecomposedFarm:
    """Slots × shards: per-slot grid decomposition composed with slot
    parallelism on a 2-axis ("slot", "shard") farm mesh.

    The correctness contract: a decomposed farm slot is *bitwise* the
    serial ``GridDriver`` run of the same decomposition (the pre-farm
    workflow on a shard-only mesh) — the farm's vmap, chunked ``fori_loop``
    stepping, slot reclamation, and eviction add no numerics on top of the
    decomposed step.  Against the *undecomposed* serial run the match is
    tolerance-level only: ``_global_mean``'s pmean reduces in shard order.
    """

    def test_cavity_slot_shard_farm_bitwise_vs_serial(self):
        script = """
import jax, numpy as np
from repro.cfd import cavity
from repro.cfd.ns3d import NavierStokes3D
from repro.launch.mesh import make_mesh
from repro.sim import SimulationFarm

N = 16
KW = dict(jacobi_iters=20, decomposition=((0, "shard"),))
RES = (50.0, 100.0, 200.0, 400.0, 80.0, 300.0)
STEPS = (20, 30, 25, 35, 30, 20)

def serial(re, steps):
    solver = NavierStokes3D(cavity.config(N, re=re, **KW),
                            make_mesh((4,), ("shard",)))
    state = solver.init_state()
    step = solver.make_step()
    for _ in range(steps):
        state = step(state)
    return jax.device_get(state)

mesh = make_mesh((2, 4), ("slot", "shard"))
farm = SimulationFarm(cavity.config(N, **KW), n_slots=4, mesh=mesh,
                      slot_axis="slot")
assert farm.exec.decomposition == {0: "shard"}
sids = {farm.submit(cavity.sim_request(N, re=re, steps=steps, **KW)):
        (re, steps) for re, steps in zip(RES, STEPS)}
results = farm.run_until_drained()
assert set(results) == set(sids)
for sid, (re, steps) in sids.items():
    assert results[sid].steps_done == steps
    ref = serial(re, steps)
    for f in ("vx", "vy", "vz", "p"):
        np.testing.assert_array_equal(ref[f], results[sid].state[f],
                                      err_msg=f"sid={sid} re={re} {f}")

# the ghost zones really cross devices: the compiled ensemble step must
# contain collective-permutes
import jax.numpy as jnp
hlo = farm.exec._run_k.lower(
    farm.exec.state, farm.exec._device_params(),
    jnp.int32(1)).compile().as_text()
assert "collective-permute" in hlo, "expected ppermute in decomposed step"

# vs the UNdecomposed serial run the physics agree to fp tolerance
solver0 = NavierStokes3D(cavity.config(N, re=RES[0], jacobi_iters=20))
s0 = solver0.init_state()
st0 = solver0.make_step()
for _ in range(STEPS[0]):
    s0 = st0(s0)
first = min(sids, key=lambda s: s)
for f in ("vx", "vy", "vz", "p"):
    d = float(np.abs(np.asarray(s0[f]) - results[first].state[f]).max())
    assert d < 1e-5, (f, d)
print("DECOMPOSED FARM OK")
"""
        out = run_with_devices(script, n_devices=8, timeout=540)
        assert "DECOMPOSED FARM OK" in out

    def test_taylor_green_slot_shard_farm_bitwise_vs_serial(self):
        script = """
import jax, numpy as np
from repro.cfd import taylor_green
from repro.cfd.ns3d import NavierStokes3D
from repro.launch.mesh import make_mesh
from repro.sim import SimulationFarm

N = 16
KW = dict(decomposition=((0, "shard"),))
NUS, STEPS = (0.05, 0.1, 0.2), (12, 16, 10)

mesh = make_mesh((2, 4), ("slot", "shard"))
farm = SimulationFarm(taylor_green.config(N, nu=0.1, **KW), n_slots=2,
                      mesh=mesh, slot_axis="slot")
sids = {farm.submit(taylor_green.sim_request(N, nu=nu, steps=s, **KW)):
        (nu, s) for nu, s in zip(NUS, STEPS)}
results = farm.run_until_drained()
mesh1 = make_mesh((4,), ("shard",))
for sid, (nu, steps) in sids.items():
    solver = NavierStokes3D(taylor_green.config(N, nu=nu, **KW), mesh1)
    state = solver.init_state()
    step = solver.make_step()
    for _ in range(steps):
        state = step(state)
    for f in ("vx", "vy", "vz", "p"):
        np.testing.assert_array_equal(np.asarray(state[f]),
                                      results[sid].state[f],
                                      err_msg=f"nu={nu} {f}")
print("DECOMPOSED TG OK")
"""
        out = run_with_devices(script, n_devices=8, timeout=540)
        assert "DECOMPOSED TG OK" in out

    def test_evict_readmit_cycle_stays_bitwise(self):
        """Eviction gathers the decomposed fields, spills them through the
        checkpointer, and readmission scatters them back to the shard
        layout — the resumed run must still equal the uninterrupted serial
        decomposed reference bitwise."""
        script = """
import tempfile
import jax, numpy as np
from repro.cfd import cavity
from repro.cfd.ns3d import NavierStokes3D
from repro.launch.mesh import make_mesh
from repro.sim import SimulationService

N = 16
KW = dict(jacobi_iters=20, decomposition=((0, "shard"),))

def serial(re, steps):
    solver = NavierStokes3D(cavity.config(N, re=re, **KW),
                            make_mesh((4,), ("shard",)))
    state = solver.init_state()
    step = solver.make_step()
    for _ in range(steps):
        state = step(state)
    return jax.device_get(state)

mesh = make_mesh((2, 4), ("slot", "shard"))
with tempfile.TemporaryDirectory() as d:
    svc = SimulationService(cavity.config(N, **KW), n_slots=2, mesh=mesh,
                            slot_axis="slot", ckpt_dir=d)
    a = svc.submit(cavity.sim_request(N, re=100.0, steps=40, **KW))
    b = svc.submit(cavity.sim_request(N, re=200.0, steps=40, **KW))
    svc.run(10)
    assert svc.evict(a)
    assert svc._evicted[a].state is None     # spilled to disk, not host RAM
    ra = svc.result(a)                       # readmits + runs to completion
    assert ra.steps_done == 40
    ref = serial(100.0, 40)
    for f in ("vx", "vy", "vz", "p"):
        np.testing.assert_array_equal(ref[f], ra.state[f], err_msg=f)
    rb = svc.result(b)
    ref_b = serial(200.0, 40)
    for f in ("vx", "vy", "vz", "p"):
        np.testing.assert_array_equal(ref_b[f], rb.state[f], err_msg=f)
print("EVICT/READMIT OK")
"""
        out = run_with_devices(script, n_devices=8, timeout=540)
        assert "EVICT/READMIT OK" in out

    def test_two_axis_decomposition(self):
        """x over "sx" AND y over "sy" (2-D grid decomposition per slot,
        slot axis on top: a 3-axis farm mesh)."""
        script = """
import jax, numpy as np
from repro.cfd import taylor_green
from repro.cfd.ns3d import NavierStokes3D
from repro.launch.mesh import make_mesh
from repro.sim import SimulationFarm

N = 16
KW = dict(decomposition=((0, "sx"), (1, "sy")))
mesh = make_mesh((2, 2, 2), ("slot", "sx", "sy"))
farm = SimulationFarm(taylor_green.config(N, nu=0.1, **KW), n_slots=2,
                      mesh=mesh, slot_axis="slot")
assert farm.exec.decomposition == {0: "sx", 1: "sy"}
sid = farm.submit(taylor_green.sim_request(N, nu=0.08, steps=10, **KW))
results = farm.run_until_drained()
solver = NavierStokes3D(taylor_green.config(N, nu=0.08, **KW),
                        make_mesh((2, 2), ("sx", "sy")))
state = solver.init_state()
step = solver.make_step()
for _ in range(10):
    state = step(state)
for f in ("vx", "vy", "vz", "p"):
    np.testing.assert_array_equal(np.asarray(state[f]),
                                  results[sid].state[f], err_msg=f)
print("2D DECOMP OK")
"""
        out = run_with_devices(script, n_devices=8, timeout=540)
        assert "2D DECOMP OK" in out

    def test_pallas_slot_shard_farm_bitwise_vs_serial(self):
        """The full posture the tentpole unlocks: 3DBLOCK Pallas kernels
        (interpret mode), per-slot scalars through the generator's scalar
        table, grid decomposition per slot, slot parallelism on top —
        bitwise the serial decomposed pallas-interpret run."""
        script = """
import jax, numpy as np
from repro.cfd import cavity
from repro.cfd.ns3d import NavierStokes3D
from repro.launch.mesh import make_mesh
from repro.sim import SimulationFarm

N = 16
KW = dict(jacobi_iters=20, template="3DBLOCK", interpret=True,
          overlap=False, decomposition=((0, "shard"),))
RES = (100.0, 250.0, 400.0)
STEPS = (8, 12, 6)

def serial(re, steps):
    solver = NavierStokes3D(cavity.config(N, re=re, **KW),
                            make_mesh((4,), ("shard",)))
    state = solver.init_state()
    step = solver.make_step()
    for _ in range(steps):
        state = step(state)
    return jax.device_get(state)

mesh = make_mesh((2, 4), ("slot", "shard"))
farm = SimulationFarm(cavity.config(N, **KW), n_slots=2, mesh=mesh,
                      slot_axis="slot")
sids = {farm.submit(cavity.sim_request(N, re=re, steps=s, **KW)): (re, s)
        for re, s in zip(RES, STEPS)}
results = farm.run_until_drained()
for sid, (re, steps) in sids.items():
    res = results[sid]
    assert res.terminated == "steps", (res.terminated, res.error)
    ref = serial(re, steps)
    for f in ("vx", "vy", "vz", "p"):
        np.testing.assert_array_equal(ref[f], res.state[f],
                                      err_msg=f"re={re} {f}")
print("PALLAS SLOT-SHARD OK")
"""
        out = run_with_devices(script, n_devices=8, timeout=540)
        assert "PALLAS SLOT-SHARD OK" in out


@pytest.mark.multidevice
class TestMultiDeviceFarm:
    def test_sharded_farm_matches_single_device(self):
        """Slot axis over a data-parallel mesh axis (vmap x shard_map via
        dist.sharding.slot_spec): the distributed farm must reproduce the
        single-device farm bitwise — slots never interact, so placement
        is pure bookkeeping."""
        from tests.helpers import run_with_devices

        script = """
import numpy as np
from repro.cfd import cavity
from repro.launch.mesh import make_mesh
from repro.sim import SimulationFarm

N = 16
KW = dict(jacobi_iters=20)
RES = (50.0, 100.0, 200.0, 400.0, 80.0, 300.0)
STEPS = (20, 30, 25, 35, 30, 20)

def run(mesh):
    farm = SimulationFarm(cavity.config(N, **KW), n_slots=4, mesh=mesh)
    for re, steps in zip(RES, STEPS):
        farm.submit(cavity.sim_request(N, re=re, steps=steps, **KW))
    return farm.run_until_drained()

res_a = run(None)
res_b = run(make_mesh((4,), ("data",)))
assert set(res_a) == set(res_b) and len(res_a) == len(RES)
for sid in res_a:
    assert res_a[sid].steps_done == res_b[sid].steps_done
    assert res_a[sid].terminated == res_b[sid].terminated
    for f in ("vx", "vy", "vz", "p"):
        np.testing.assert_array_equal(res_a[sid].state[f],
                                      res_b[sid].state[f])
print("FARM MESH OK")
"""
        out = run_with_devices(script, n_devices=4)
        assert "FARM MESH OK" in out
