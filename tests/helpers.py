"""Test helpers: run snippets in a subprocess with a forced device count.

JAX locks the backend device count at first initialization, and the main
test session must see exactly 1 CPU device (smoke tests exercise the
single-device paths).  Multi-device behaviour (halo exchange over a real
mesh, sharded checkpointing, dry-runs) is therefore tested in subprocesses
with ``--xla_force_host_platform_device_count``.
"""
from __future__ import annotations

import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(script: str, n_devices: int = 8, timeout: int = 600):
    """Run ``script`` with ``n_devices`` fake host devices; return stdout."""
    env = dict(os.environ)
    # drop any inherited device-count flag (e.g. the CI multidevice lane's)
    # so the per-test count always wins
    inherited = [f for f in env.get("XLA_FLAGS", "").split()
                 if not f.startswith("--xla_force_host_platform_device_count")]
    env["XLA_FLAGS"] = " ".join(
        [f"--xla_force_host_platform_device_count={n_devices}"] + inherited)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", script],
        env=env,
        capture_output=True,
        text=True,
        timeout=timeout,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}"
        )
    return proc.stdout
