"""CFD system tests: Taylor-Green analytic validation, divergence control,
overlap-path equivalence, cavity physics sanity, and distributed equality."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.cfd import cavity, taylor_green
from repro.cfd.ns3d import CFDConfig, NavierStokes3D
from tests.helpers import run_with_devices


class TestTaylorGreen:
    @pytest.fixture(scope="class")
    def result(self):
        return taylor_green.run(n=32, steps=50, nu=0.1, overlap=False)

    def test_tracks_analytic_solution(self, result):
        assert result["err_vx"] < 5e-3
        assert result["err_vy"] < 5e-3

    def test_energy_decay_rate(self, result):
        assert result["energy_rel_err"] < 5e-3

    def test_divergence_free(self, result):
        assert result["div_max"] < 1e-3

    def test_overlap_equals_plain(self):
        a = taylor_green.run(n=16, steps=10, nu=0.1, overlap=False)
        b = taylor_green.run(n=16, steps=10, nu=0.1, overlap=True)
        assert abs(a["energy"] - b["energy"]) < 1e-7
        assert abs(a["err_vx"] - b["err_vx"]) < 1e-6

    def test_fused_jacobi_matches_plain(self):
        a = taylor_green.run(n=16, steps=10, nu=0.1, fused_sweeps=1,
                             jacobi_iters=40)
        b = taylor_green.run(n=16, steps=10, nu=0.1, fused_sweeps=2,
                             jacobi_iters=40)
        # same sweep count, different comm schedule -> same physics
        assert abs(a["energy"] - b["energy"]) / a["energy"] < 1e-5

    def test_convergence_with_resolution(self):
        # halving h should cut the error (2nd-order interior scheme)
        e16 = taylor_green.run(n=16, steps=20, nu=0.1)["err_vx"]
        e32 = taylor_green.run(n=32, steps=20, nu=0.1)["err_vx"]
        assert e32 < 0.5 * e16


class TestCavity:
    def test_short_run_is_sane(self):
        solver, state, errs = cavity.run(n=24, t_end=1.0, jacobi_iters=25)
        for f in ("vx", "vy", "vz", "p"):
            assert bool(jnp.all(jnp.isfinite(state[f]))), f
        # lid drags fluid: top-adjacent u must be positive, and KE nonzero
        y, u = cavity.centerline_u(solver, state)
        assert u[-1] > 0.1
        assert solver.kinetic_energy(state) > 1e-4

    def test_wall_faces_stay_zero(self):
        solver, state, _ = cavity.run(n=16, t_end=0.5, jacobi_iters=20)
        np.testing.assert_allclose(np.asarray(state["vx"][-1, :, :]), 0.0)
        np.testing.assert_allclose(np.asarray(state["vy"][:, -1, :]), 0.0)

    def test_divergence_stays_small(self):
        solver, state, _ = cavity.run(n=16, t_end=0.5, jacobi_iters=40)
        div = solver.divergence_of(state)
        assert float(jnp.abs(div).max()) < 0.05  # iterative solve tolerance


DISTRIBUTED_EQUIV = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.cfd import taylor_green
from repro.cfd.ns3d import NavierStokes3D

mesh = jax.make_mesh((2, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
kw = dict(n=16, steps=8, nu=0.1)
a = taylor_green.run(**kw)                       # single shard
b = taylor_green.run(**kw, mesh=mesh,
                     decomposition=((0, "data"), (1, "model")))
for k in ("err_vx", "energy", "div_max"):
    assert abs(a[k] - b[k]) < 1e-5, (k, a[k], b[k])
print("OK")
"""


@pytest.mark.multidevice
def test_distributed_solver_matches_single_device():
    out = run_with_devices(DISTRIBUTED_EQUIV, n_devices=4)
    assert "OK" in out
