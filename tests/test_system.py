"""System-level integration: the full training stack end-to-end in-process
(config -> data -> sharded-or-local step -> checkpoint -> resume), and the
examples as smoke tests."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=900):
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    p = subprocess.run([sys.executable] + args, env=env, capture_output=True,
                       text=True, timeout=timeout, cwd=REPO)
    assert p.returncode == 0, (p.stdout[-1500:], p.stderr[-1500:])
    return p.stdout


def test_train_loss_decreases(tmp_path):
    out = _run(["-m", "repro.launch.train", "--arch", "granite-8b",
                "--smoke", "--steps", "40", "--batch", "4", "--seq", "128",
                "--lr", "3e-3", "--ckpt-dir", str(tmp_path)])
    lines = [l for l in out.splitlines() if l.startswith("[train] done")]
    assert lines, out
    first, last = lines[0].split("loss ")[1].split(" -> ")
    assert float(last) < float(first) - 0.3, lines[0]


def test_serve_engine_cli():
    out = _run(["-m", "repro.launch.serve", "--arch", "llama3-8b",
                "--smoke", "--requests", "4", "--slots", "2",
                "--max-new", "6"])
    assert "4 requests" in out and "24 tokens" in out, out


def test_quickstart_example():
    out = _run([os.path.join(REPO, "examples", "quickstart.py")])
    assert "OK" in out


def test_custom_kernel_example():
    out = _run([os.path.join(REPO, "examples", "custom_kernel.py")])
    assert "OK" in out


def test_ensemble_sweep_example():
    out = _run([os.path.join(REPO, "examples", "ensemble_sweep.py"),
                "--n", "16", "--t-end", "2.0"])
    assert "OK" in out
