"""SSD Pallas kernel: interpret-mode validation against the jnp oracle
(shape/dtype sweeps + hypothesis property test), and ssd_core wiring."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.ssd import ssd_intra_pallas, ssd_intra_reference
from repro.models.mamba2 import ssd_chunked, ssd_core


def _inputs(key, bsz, nc, l, g, r, n, p, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (bsz, nc, l, g, r, p), dtype)
    ld = -jax.nn.softplus(jax.random.normal(ks[1], (bsz, nc, l, g, r),
                                            dtype))
    dt = jax.nn.softplus(jax.random.normal(ks[2], (bsz, nc, l, g, r),
                                           dtype))
    b_ = jax.random.normal(ks[3], (bsz, nc, l, g, n), dtype)
    c_ = jax.random.normal(ks[4], (bsz, nc, l, g, n), dtype)
    s0 = jax.random.normal(ks[5], (bsz, nc, g, r, n, p), dtype) * 0.3
    return x, ld, dt, b_, c_, s0


@pytest.mark.parametrize("shape", [
    (1, 2, 16, 1, 4, 8, 8),
    (2, 1, 32, 2, 2, 16, 8),
    (1, 3, 8, 1, 8, 4, 16),
])
def test_ssd_kernel_matches_oracle(shape):
    args = _inputs(jax.random.PRNGKey(0), *shape)
    ref = ssd_intra_reference(*args)
    out = ssd_intra_pallas(*args, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(l=st.sampled_from([8, 16, 32]),
       r=st.sampled_from([1, 2, 4]),
       n=st.sampled_from([4, 8]),
       p=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 2 ** 16))
def test_ssd_kernel_property_sweep(l, r, n, p, seed):
    args = _inputs(jax.random.PRNGKey(seed), 1, 2, l, 1, r, n, p)
    ref = ssd_intra_reference(*args)
    out = ssd_intra_pallas(*args, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-4, atol=3e-4)


def test_ssd_core_still_matches_sequential():
    """ssd_core (which now routes intra-chunk through the tagged oracle)
    must equal the step-by-step recurrence."""
    bsz, s, g, r, n, p = 2, 48, 1, 3, 8, 4
    key = jax.random.PRNGKey(1)
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (bsz, s, g, r, p))
    ld = -jax.nn.softplus(jax.random.normal(ks[1], (bsz, s, g, r)))
    sc = jax.nn.softplus(jax.random.normal(ks[2], (bsz, s, g, r)))
    b_ = jax.random.normal(ks[3], (bsz, s, g, n))
    c_ = jax.random.normal(ks[4], (bsz, s, g, n))
    y, final = ssd_core(x, ld, sc, b_, c_, chunk=16)

    # sequential reference
    st_ = jnp.zeros((bsz, g, r, n, p))
    ys = []
    for t in range(s):
        dec = jnp.exp(ld[:, t])[..., None, None]
        upd = jnp.einsum("bgn,bgr,bgrp->bgrnp", b_[:, t], sc[:, t], x[:, t])
        st_ = st_ * dec + upd
        ys.append(jnp.einsum("bgn,bgrnp->bgrp", c_[:, t], st_))
    ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=2e-3,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(final), np.asarray(st_),
                               rtol=2e-3, atol=2e-4)
