"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp ref oracle,
with hypothesis sweeps over shapes and dtypes."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.attention import flash_attention
from repro.kernels.jacobi import jacobi_fused, jacobi_fused_ref


def _rand(shape, dtype=np.float32, seed=0, scale=1.0):
    return jnp.asarray(np.random.RandomState(seed).randn(*shape).astype(dtype) * scale)


def _pad_all(a, w):
    return jnp.pad(a, w, mode="wrap")  # periodic ghosts for testing


class TestUpdateVelocity:
    @pytest.mark.parametrize("shape", [(8, 8, 8), (16, 8, 8), (8, 16, 24)])
    def test_pallas_matches_ref(self, shape):
        vx, vy, vz = (_rand(shape, seed=s, scale=0.3) for s in (1, 2, 3))
        args = dict(dt=0.01, h=0.1, nu=0.05, fx=0.1, fy=0.0, fz=-0.2)
        pads = {k: _pad_all(a, 1) for k, a in zip("xyz", (vx, vy, vz))}
        got = ops.update_velocity(
            pads["x"], pads["y"], pads["z"], template="3DBLOCK",
            interpret=True, tile=(4, 4, 8), **args)
        want = ref.update_velocity(pads["x"], pads["y"], pads["z"], **args)
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       rtol=2e-5, atol=2e-5)

    def test_momentum_conserved_periodic_no_visc(self):
        # with periodic ghosts, flux-form advection conserves momentum sums
        shape = (8, 8, 8)
        vx, vy, vz = (_rand(shape, seed=s, scale=0.3) for s in (4, 5, 6))
        pads = [_pad_all(a, 1) for a in (vx, vy, vz)]
        nvx, nvy, nvz = ref.update_velocity(*pads, dt=0.01, h=0.5, nu=0.0)
        for new, old in zip((nvx, nvy, nvz), (vx, vy, vz)):
            np.testing.assert_allclose(float(new.sum()), float(old.sum()),
                                       rtol=1e-4, atol=1e-4)


class TestDivergenceProjection:
    def test_divergence_of_constant_is_zero(self):
        c = jnp.full((10, 10, 10), 3.7)
        d = ops.divergence(c, c, c, template="JNP", h=0.1)
        np.testing.assert_allclose(np.asarray(d), 0.0, atol=1e-5)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_pallas_matches_ref(self, dtype):
        shape = (8, 8, 8)
        vx, vy, vz = (_rand(shape, dtype, seed=s) for s in (7, 8, 9))
        # divergence wants (1,0) lo-side ghosts
        pads = [jnp.pad(a, ((1, 0), (1, 0), (1, 0)), mode="wrap")
                for a in (vx, vy, vz)]
        got = ops.apply_kernel("DIVERGENCE", dict(zip(("vx", "vy", "vz"), pads)),
                               template="3DBLOCK", interpret=True,
                               tile=(4, 4, 8), h=0.25)["div"]
        want = ref.divergence(*pads, h=0.25)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_projection_reduces_divergence(self):
        # one exact-Poisson projection on a periodic grid must kill divergence
        n, h = 16, 1.0 / 16
        vx, vy, vz = (_rand((n, n, n), np.float64, seed=s, scale=0.1)
                      for s in (10, 11, 12))
        div = ref.divergence(*[jnp.pad(a, ((1, 0),) * 3, mode="wrap")
                               for a in (vx, vy, vz)], h=h)
        # solve lap p = div/dt exactly via FFT (periodic)
        dt = 1.0
        k = np.fft.fftfreq(n) * n
        kx, ky, kz = np.meshgrid(k, k, k, indexing="ij")
        denom = (2 * (np.cos(2 * np.pi * kx / n) - 1)
                 + 2 * (np.cos(2 * np.pi * ky / n) - 1)
                 + 2 * (np.cos(2 * np.pi * kz / n) - 1)) / h ** 2
        denom[0, 0, 0] = 1.0
        ph = np.fft.fftn(np.asarray(div) / dt) / denom
        ph[0, 0, 0] = 0.0
        p = jnp.asarray(np.real(np.fft.ifftn(ph)))
        p_pad = jnp.pad(p, ((0, 1),) * 3, mode="wrap")
        nvx, nvy, nvz = ref.project_velocity(vx, vy, vz, p_pad, dt=dt, h=h)
        div2 = ref.divergence(*[jnp.pad(a, ((1, 0),) * 3, mode="wrap")
                                for a in (nvx, nvy, nvz)], h=h)
        # f32 roundoff floor (x64 is off in this session)
        assert float(jnp.abs(div2).max()) < 1e-6 * float(jnp.abs(div).max())


class TestJacobi:
    def test_single_sweep_pallas_vs_ref(self):
        p = _rand((10, 10, 10), seed=13)
        rhs = _rand((8, 8, 8), seed=14)
        got = ops.jacobi_pressure(jnp.asarray(p), rhs, template="3DBLOCK",
                                  interpret=True, tile=(4, 4, 8), h=0.1,
                                  omega=0.8)
        want = ref.jacobi_pressure(p, rhs, h=0.1, omega=0.8)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("sweeps", [1, 2, 3])
    def test_fused_equals_iterated(self, sweeps):
        """k fused communication-avoiding sweeps == k plain sweeps."""
        n, k = 8, sweeps
        p = _rand((n + 2 * k,) * 3, seed=15)
        rhs = _rand((n + 2 * k,) * 3, seed=16)
        fused = jacobi_fused_ref(p, rhs, h=0.2, omega=0.9, sweeps=k)
        # iterate single sweeps, shrinking manually
        cur, r = p, rhs
        for _ in range(k):
            cur = ref.jacobi_pressure(cur, r[1:-1, 1:-1, 1:-1], h=0.2, omega=0.9)
            r = r[1:-1, 1:-1, 1:-1]
        np.testing.assert_allclose(np.asarray(fused), np.asarray(cur),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("sweeps", [1, 2])
    def test_fused_pallas_vs_ref(self, sweeps):
        n, k = 8, sweeps
        p = _rand((n + 2 * k,) * 3, seed=17)
        rhs = _rand((n + 2 * k,) * 3, seed=18)
        got = jacobi_fused(p, rhs, h=0.3, sweeps=k, tile=(4, 4, 4),
                           interpret=True)
        want = jacobi_fused_ref(p, rhs, h=0.3, sweeps=k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_jacobi_converges_on_poisson(self):
        # solve lap p = rhs on periodic grid; residual must shrink
        n, h = 16, 1.0 / 16
        rng = np.random.RandomState(3)
        rhs = rng.randn(n, n, n).astype(np.float32)
        rhs -= rhs.mean()  # compatibility condition
        rhs = jnp.asarray(rhs)
        p = jnp.zeros((n, n, n))

        def residual(p):
            lap = ref.laplacian(_pad_all(p, 1), h)
            return float(jnp.abs(lap - rhs).max())

        r0 = residual(p)
        for _ in range(200):
            p = ref.jacobi_pressure(_pad_all(p, 1), rhs, h=h, omega=0.9)
            p = p - p.mean()
        assert residual(p) < 0.05 * r0


class TestFlashAttention:
    @settings(max_examples=10, deadline=None)
    @given(
        h=st.sampled_from([1, 2, 4]),
        rep=st.sampled_from([1, 2]),
        s=st.sampled_from([128, 256]),
        d=st.sampled_from([32, 64]),
        causal=st.booleans(),
        dtype=st.sampled_from([np.float32]),
    )
    def test_property_matches_reference(self, h, rep, s, d, causal, dtype):
        hq = h * rep
        rng = np.random.RandomState(h * 100 + s)
        q = jnp.asarray(rng.randn(hq, s, d).astype(dtype) * 0.3)
        k = jnp.asarray(rng.randn(h, s, d).astype(dtype) * 0.3)
        v = jnp.asarray(rng.randn(h, s, d).astype(dtype))
        got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                              interpret=True)
        want = ref.mha_reference(q.transpose(1, 0, 2), k.transpose(1, 0, 2),
                                 v.transpose(1, 0, 2), causal=causal)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(want.transpose(1, 0, 2)),
                                   rtol=2e-5, atol=2e-5)

    def test_bf16_io(self):
        rng = np.random.RandomState(0)
        q = jnp.asarray(rng.randn(2, 128, 64), dtype=jnp.bfloat16)
        k = jnp.asarray(rng.randn(2, 128, 64), dtype=jnp.bfloat16)
        v = jnp.asarray(rng.randn(2, 128, 64), dtype=jnp.bfloat16)
        got = flash_attention(q, k, v, interpret=True)
        want = ref.mha_reference(q.transpose(1, 0, 2), k.transpose(1, 0, 2),
                                 v.transpose(1, 0, 2)).transpose(1, 0, 2)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=3e-2, atol=3e-2)

    def test_decode_offset(self):
        rng = np.random.RandomState(1)
        q = jnp.asarray(rng.randn(2, 64, 32).astype(np.float32))
        k = jnp.asarray(rng.randn(2, 256, 32).astype(np.float32))
        v = jnp.asarray(rng.randn(2, 256, 32).astype(np.float32))
        got = flash_attention(q, k, v, causal=True, q_offset=192,
                              block_q=64, block_k=64, interpret=True)
        want = ref.mha_reference(q.transpose(1, 0, 2), k.transpose(1, 0, 2),
                                 v.transpose(1, 0, 2), causal=True,
                                 q_offset=192).transpose(1, 0, 2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)
