"""Distribution-equivalence tests (subprocess multi-device): every
parallel execution mode must reproduce the single-device math.

  * Mamba2 sequence parallelism (ssm_sp) — the paper's ghost-zone exchange
    on the sequence axis: conv halo + chunk-state relay == serial scan.
  * MoE tp (sharded-experts psum) and a2a (token all_to_all) == local.
  * Sharded train step (FSDP x TP via pjit) == single-device step.
"""
import pytest

from tests.helpers import run_with_devices

pytestmark = pytest.mark.multidevice


def test_mamba2_ssm_sp_matches_serial():
    script = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_config, smoke
from repro.launch.mesh import make_mesh
from repro.models import mamba2
from repro.models.config import ShardCfg, LOCAL

cfg = smoke(get_config("zamba2-1.2b"))
mesh = make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
params = mamba2.init_mamba2(key, cfg)
B, S = 4, 64
x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model),
                      jnp.float32)
ref, _ = mamba2.mamba2_seq(params, cfg, x, LOCAL)
sp = ShardCfg(mesh=mesh, dp="data", tp="model", ssm_sp=True)
out = jax.jit(lambda p, x: mamba2.mamba2_seq(p, cfg, x, sp)[0])(params, x)
err = float(jnp.abs(out - ref).max())
assert err < 1e-4, err
print("SSM_SP OK", err)
"""
    out = run_with_devices(script, n_devices=8)
    assert "SSM_SP OK" in out


@pytest.mark.parametrize("mode", ["tp", "a2a"])
def test_moe_modes_match_local(mode):
    script = f"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_config, smoke
from repro.launch.mesh import make_mesh
from repro.models import moe
from repro.models.config import ShardCfg, LOCAL

cfg = smoke(get_config("qwen3-moe-235b-a22b"))
cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops: exact match
mesh = make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
params = moe.init_moe(key, cfg)
B, S = 4, 32
x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, cfg.d_model),
                      jnp.float32)
ref, mref = moe.moe_apply(params, cfg, x, LOCAL)
shard = ShardCfg(mesh=mesh, dp="data", tp="model", moe_mode="{mode}")
out, m = jax.jit(lambda p, x: moe_apply_wrap(p, cfg, x, shard))(params, x)
err = float(jnp.abs(out - ref).max())
assert err < 2e-3, err
print("MOE OK", err)
"""
    script = ("def moe_apply_wrap(p, cfg, x, shard):\n"
              "    from repro.models import moe\n"
              "    return moe.moe_apply(p, cfg, x, shard)\n" + script)
    out = run_with_devices(script, n_devices=8)
    assert "MOE OK" in out


def test_sharded_train_step_matches_local():
    script = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_config, smoke
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import model
from repro.models.config import LOCAL
from repro.optim.adamw import AdamW
from repro.train import step as step_lib

cfg = smoke(get_config("llama3-8b"))
key = jax.random.PRNGKey(0)
params = model.init_params(cfg, key)
B, S = 8, 32
batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "targets": jax.random.randint(jax.random.fold_in(key, 1), (B, S),
                                       0, cfg.vocab_size)}
opt = AdamW(lr=1e-3)

# local reference
st = opt.init(params)
p_ref, st_ref, m_ref = step_lib.make_train_step(cfg, LOCAL, opt)(
    params, st, batch)

# sharded
mesh = make_mesh((2, 4), ("data", "model"))
shard = shd.make_shard_cfg(mesh, cfg, global_batch=B)
pspecs = shd.param_spec_tree(params, cfg, mesh, shard)
params_s = jax.device_put(params, shd.named(pspecs, mesh))
st_s = jax.device_put(opt.init(params), shd.named(
    opt.state_spec_tree(pspecs), mesh))
batch_s = jax.device_put(batch, shd.named(
    shd.batch_spec_tree(batch, mesh, shard), mesh))
step = jax.jit(step_lib.make_train_step(cfg, shard, opt))
p_new, st_new, m = step(params_s, st_s, batch_s)

assert abs(float(m["loss"]) - float(m_ref["loss"])) < 1e-3, (
    float(m["loss"]), float(m_ref["loss"]))
# parameter updates agree
errs = jax.tree.map(
    lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                               - b.astype(jnp.float32)).max()),
    p_new, p_ref)
worst = max(jax.tree.leaves(errs))
assert worst < 5e-3, worst
print("TRAIN STEP OK", float(m["loss"]), worst)
"""
    out = run_with_devices(script, n_devices=8)
    assert "TRAIN STEP OK" in out


def test_sharded_decode_matches_local():
    script = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_config, smoke
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import model
from repro.models.config import LOCAL

cfg = smoke(get_config("llama3-8b"))
key = jax.random.PRNGKey(0)
params = model.init_params(cfg, key)
B, S = 8, 24
toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
caches = model.init_caches(cfg, B, 32, jnp.float32)
lg_ref, caches_ref = model.prefill(params, cfg, {"tokens": toks}, caches,
                                   LOCAL)
step_ref, _ = model.decode_step(params, cfg,
                                jnp.argmax(lg_ref, -1).astype(jnp.int32),
                                caches_ref, jnp.int32(S), LOCAL)

mesh = make_mesh((2, 4), ("data", "model"))
shard = shd.make_shard_cfg(mesh, cfg, global_batch=B)
pspecs = shd.param_spec_tree(params, cfg, mesh, shard)
cspecs = shd.cache_spec_tree(
    jax.eval_shape(lambda: model.init_caches(cfg, B, 32, jnp.float32)),
    cfg, mesh, shard)
params_s = jax.device_put(params, shd.named(pspecs, mesh))
caches_s = jax.device_put(model.init_caches(cfg, B, 32, jnp.float32),
                          shd.named(cspecs, mesh))
lg, caches_s = jax.jit(lambda p, t, c: model.prefill(
    p, cfg, {"tokens": t}, c, shard))(params_s, toks, caches_s)
step, _ = jax.jit(lambda p, t, c, l: model.decode_step(
    p, cfg, t, c, l, shard))(params_s,
                             jnp.argmax(lg, -1).astype(jnp.int32),
                             caches_s, jnp.int32(S))
err = float(jnp.abs(step - step_ref).max())
assert err < 2e-3, err
print("DECODE OK", err)
"""
    out = run_with_devices(script, n_devices=8)
    assert "DECODE OK" in out


def test_compressed_dp_step_close_to_exact():
    """int8 EF pod-grad compression: one step must track the exact DP step
    within quantization tolerance (and thread the EF residual)."""
    script = """
import jax, jax.numpy as jnp, numpy as np
from repro.configs.registry import get_config, smoke
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import model
from repro.optim.adamw import AdamW
from repro.train import step as step_lib

cfg = smoke(get_config("llama3-8b"))
key = jax.random.PRNGKey(0)
params = model.init_params(cfg, key)
B, S = 8, 32
batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
         "targets": jax.random.randint(jax.random.fold_in(key, 1), (B, S),
                                       0, cfg.vocab_size)}
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
shard = shd.make_shard_cfg(mesh, cfg, global_batch=B, mode="dp")
opt = AdamW(lr=1e-3)
p_u, _, m_u = jax.jit(step_lib._make_dp_train_step(cfg, shard, opt))(
    params, opt.init(params), batch)
p_c, _, m_c = jax.jit(step_lib._make_dp_train_step(
    cfg, shard, opt, compress_pod_grads=True))(
    params, opt.init(params), batch)
assert abs(float(m_u["loss"]) - float(m_c["loss"])) < 1e-4
err = max(float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
          for a, b in zip(jax.tree.leaves(p_u), jax.tree.leaves(p_c)))
assert err < 5e-3, err
print("COMPRESS OK", err)
"""
    out = run_with_devices(script, n_devices=8)
    assert "COMPRESS OK" in out
