# Make `tests.helpers` importable regardless of invocation directory, and
# keep the main session at exactly 1 CPU device (multi-device behaviour is
# exercised in subprocesses; the 512-device dry-run sets XLA_FLAGS itself).
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))  # bare `pytest` without PYTHONPATH

# The suite must collect on a bare interpreter (pytest + jax only).  Prefer
# the real hypothesis; otherwise install the deterministic fallback so the
# property tests still run their sweeps instead of crashing at import.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from tests import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies
