# Make `tests.helpers` importable regardless of invocation directory, and
# keep the main session at exactly 1 CPU device (multi-device behaviour is
# exercised in subprocesses; the 512-device dry-run sets XLA_FLAGS itself).
import os
import signal
import sys

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _ROOT)
sys.path.insert(0, os.path.join(_ROOT, "src"))  # bare `pytest` without PYTHONPATH


@pytest.fixture(autouse=True)
def _multidevice_per_test_timeout(request):
    """Per-test wall-clock limit for the ``multidevice`` lane.

    Each multidevice test spawns a fresh interpreter that compiles for a
    forced device mesh; a wedged subprocess would otherwise eat the whole
    job-level timeout and mask which test hung.  CI sets
    ``REPRO_TEST_TIMEOUT`` (seconds) for the multidevice lane; unset (or
    on non-POSIX hosts) this is a no-op.  SIGALRM interrupts the blocking
    ``subprocess.run`` wait, so the alarm fires even mid-subprocess.
    """
    limit = int(os.environ.get("REPRO_TEST_TIMEOUT", "0"))
    if (limit <= 0 or not hasattr(signal, "SIGALRM")
            or request.node.get_closest_marker("multidevice") is None):
        yield
        return

    def _expired(signum, frame):
        raise TimeoutError(
            f"multidevice test exceeded REPRO_TEST_TIMEOUT={limit}s")

    old = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(limit)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, old)

# The suite must collect on a bare interpreter (pytest + jax only).  Prefer
# the real hypothesis; otherwise install the deterministic fallback so the
# property tests still run their sweeps instead of crashing at import.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    from tests import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies
