# Make `tests.helpers` importable regardless of invocation directory, and
# keep the main session at exactly 1 CPU device (multi-device behaviour is
# exercised in subprocesses; the 512-device dry-run sets XLA_FLAGS itself).
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
