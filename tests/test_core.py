"""Unit tests for the CaCUDA-analogue core: descriptors, CCL parsing,
generated kernels (Pallas-interpret vs jnp oracle), halo exchange, MoL,
schedule tree, autotuner."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core import (
    AxisSpec, Domain, GridDriver, Intent, Schedule, StencilDescriptor,
    bc_dirichlet, bc_mirror, bc_neumann, choose_tile, descriptor,
    exchange_pad, generate, generate_pair, mol, parse_ccl,
    stencil_step_overlap,
)

PAPER_CCL = '''
# Listing 1 of the paper, verbatim syntax
CCTK_CUDA_KERNEL UPDATE_VELOCITY
  TYPE=3DBLOCK
  STENCIL="1,1,1,1,1,1"
  TILE="16,16,16"
{
  CCTK_CUDA_KERNEL_VARIABLE CACHED=YES INTENT=SEPARATEINOUT
  {
    vx, vy, vz
  } "VELOCITY"
  CCTK_CUDA_KERNEL_VARIABLE CACHED=YES INTENT=IN
  {
    p
  } "PRESSURE"
  CCTK_CUDA_KERNEL_PARAMETER
  {
    density
  } "DENSITY"
}
'''


class TestDescriptor:
    def test_parse_paper_listing(self):
        (k,) = parse_ccl(PAPER_CCL)
        assert k.name == "UPDATE_VELOCITY"
        assert k.type == "3DBLOCK"
        assert k.stencil == (1, 1, 1, 1, 1, 1)
        assert k.tile == (16, 16, 16)
        assert k.inputs == ("vx", "vy", "vz", "p")
        assert k.outputs == ("vx", "vy", "vz")
        assert k.parameters == ("density",)
        assert k.group_of("p").intent is Intent.IN
        assert k.cached_inputs == frozenset({"vx", "vy", "vz", "p"})

    def test_halo_geometry(self):
        d = descriptor("K", stencil=(2, 1, 0, 0, 1, 3),
                       u=dict(names=("u",), intent="IN"))
        assert d.halo_lo == (2, 0, 1)
        assert d.halo_hi == (1, 0, 3)
        assert d.halo_width == (2, 0, 3)

    def test_duplicate_variable_rejected(self):
        with pytest.raises(ValueError, match="twice"):
            descriptor("K", a=dict(names=("u",)), b=dict(names=("u",)))

    def test_vmem_accounting(self):
        d = descriptor("K", stencil=(1,) * 6, tile=(4, 4, 4),
                       u=dict(names=("u",), intent="SEPARATEINOUT"))
        # halo block 6^3 reads + 4^3 separate out, f32
        assert d.vmem_block_bytes(4) == (6 ** 3 + 4 ** 3) * 4

    def test_bad_ccl_raises(self):
        with pytest.raises(ValueError):
            parse_ccl("CCTK_CUDA_KERNEL X TYPE=3DBLOCK { BOGUS { } }")


def _laplacian_body(ctx):
    u = ctx["u"]
    lap = (u.at(1, 0, 0) + u.at(-1, 0, 0) + u.at(0, 1, 0) + u.at(0, -1, 0)
           + u.at(0, 0, 1) + u.at(0, 0, -1) - 6.0 * u.c)
    return {"lap": lap}


LAP = descriptor(
    "LAPLACIAN", stencil=(1,) * 6, tile=(4, 4, 8),
    u=dict(names=("u",), intent="IN"),
    out=dict(names=("lap",), intent="OUT"),
)


class TestGenerator:
    def test_pallas_matches_jnp_oracle(self):
        kp, kj = generate_pair(LAP, _laplacian_body)
        rng = np.random.RandomState(0)
        u = jnp.asarray(rng.randn(8 + 2, 8 + 2, 16 + 2), dtype=jnp.float32)
        out_p = kp({"u": u})["lap"]
        out_j = kj({"u": u})["lap"]
        assert out_p.shape == (8, 8, 16)
        np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_j),
                                   rtol=1e-6, atol=1e-6)

    def test_offset_outside_radius_rejected(self):
        bad = descriptor("B", stencil=(0,) * 6, tile=(4, 4, 8),
                         u=dict(names=("u",), intent="IN"),
                         o=dict(names=("o",), intent="OUT"))
        k = generate(bad, lambda ctx: {"o": ctx["u"].at(1, 0, 0)}, template="JNP")
        with pytest.raises(ValueError, match="exceeds declared radii"):
            k({"u": jnp.zeros((4, 4, 8))})

    def test_indivisible_tile_rejected(self):
        k = generate(LAP, _laplacian_body, template="3DBLOCK", interpret=True)
        with pytest.raises(ValueError, match="not divisible"):
            k({"u": jnp.zeros((7 + 2, 8 + 2, 16 + 2))})

    def test_missing_param_rejected(self):
        d = descriptor("P", stencil=(0,) * 6, tile=(4, 4, 8),
                       u=dict(names=("u",), intent="INOUT"),
                       parameters=("nu",))
        k = generate(d, lambda ctx: {"u": ctx.param("nu") * ctx["u"].c},
                     template="JNP")
        with pytest.raises(ValueError, match="missing runtime parameter"):
            k({"u": jnp.ones((2, 2, 2))})
        out = k({"u": jnp.ones((2, 2, 2))}, nu=3.0)
        assert float(out["u"][0, 0, 0]) == 3.0

    def test_describe_mentions_staging(self):
        k = generate(LAP, _laplacian_body)
        txt = k.describe()
        assert "VMEM halo-block" in txt and "3DBLOCK" in txt

    @settings(max_examples=8, deadline=None)
    @given(
        tx=st.sampled_from([2, 4]), ty=st.sampled_from([2, 4]),
        tz=st.sampled_from([4, 8]),
        mx=st.integers(1, 2), my=st.integers(1, 2), mz=st.integers(1, 2),
        dtype=st.sampled_from([np.float32, np.float64]),
    )
    def test_property_pallas_vs_oracle_shape_sweep(self, tx, ty, tz, mx, my, mz, dtype):
        import dataclasses
        d = dataclasses.replace(LAP, tile=(tx, ty, tz))
        kp = generate(d, _laplacian_body, template="3DBLOCK", interpret=True)
        kj = generate(d, _laplacian_body, template="JNP")
        shape = (tx * mx + 2, ty * my + 2, tz * mz + 2)
        rng = np.random.RandomState(tx * 31 + ty)
        u = jnp.asarray(rng.randn(*shape).astype(dtype))
        np.testing.assert_allclose(
            np.asarray(kp({"u": u})["lap"]), np.asarray(kj({"u": u})["lap"]),
            rtol=1e-5, atol=1e-5)


class TestHaloSingleDevice:
    def test_periodic_pad_matches_numpy_wrap(self):
        u = jnp.arange(24.0).reshape(4, 3, 2)
        specs = [AxisSpec(a, periodic=True) for a in range(3)]
        out = exchange_pad(u, (1, 1, 1), specs)
        ref = np.pad(np.asarray(u), 1, mode="wrap")
        np.testing.assert_array_equal(np.asarray(out), ref)

    def test_dirichlet_and_neumann(self):
        u = jnp.arange(8.0).reshape(2, 2, 2)
        specs = (
            AxisSpec(0, bc_lo=bc_dirichlet(7.0), bc_hi=bc_dirichlet(-1.0)),
            AxisSpec(1, bc_lo=bc_neumann(), bc_hi=bc_neumann()),
            AxisSpec(2, periodic=True),
        )
        out = exchange_pad(u, (1, 1, 0), specs)
        assert out.shape == (4, 4, 2)
        assert float(out[0, 1, 0]) == 7.0 and float(out[-1, 1, 0]) == -1.0
        # neumann: ghost equals adjacent interior
        np.testing.assert_array_equal(np.asarray(out[1:-1, 0, :]),
                                      np.asarray(u[:, 0, :]))

    def test_mirror_no_slip(self):
        u = jnp.ones((2, 2, 2))
        specs = (AxisSpec(0, bc_lo=bc_mirror(-1.0), bc_hi=bc_mirror(-1.0)),
                 AxisSpec(1, periodic=True), AxisSpec(2, periodic=True))
        out = exchange_pad(u, (1, 0, 0), specs)
        np.testing.assert_array_equal(np.asarray(out[0]), -np.ones((2, 2)))

    def test_overlap_split_equals_plain(self):
        rng = np.random.RandomState(1)
        u = jnp.asarray(rng.randn(8, 8, 8).astype(np.float32))
        specs = (AxisSpec(0, periodic=True), AxisSpec(1, periodic=True),
                 AxisSpec(2, periodic=True))
        kern = generate(LAP, _laplacian_body, template="JNP")
        plain = kern({"u": exchange_pad(u, (1, 1, 1), specs)})["lap"]
        split = stencil_step_overlap(
            u, (1, 1, 1), specs, lambda p: kern({"u": p})["lap"])
        np.testing.assert_allclose(np.asarray(plain), np.asarray(split),
                                   rtol=1e-6, atol=1e-6)

    def test_overlap_split_partial_axes(self):
        rng = np.random.RandomState(2)
        u = jnp.asarray(rng.randn(6, 5, 4).astype(np.float32))
        specs = (AxisSpec(0, periodic=True), AxisSpec(1, periodic=True),
                 AxisSpec(2, periodic=True))
        body = lambda ctx: {"o": ctx["u"].at(1, 0, 0) - ctx["u"].at(-1, 0, 0)}
        d = descriptor("DX", stencil=(1, 1, 0, 0, 0, 0), tile=(2, 2, 2),
                       u=dict(names=("u",), intent="IN"),
                       o=dict(names=("o",), intent="OUT"))
        kern = generate(d, body, template="JNP")
        plain = kern({"u": exchange_pad(u, (1, 0, 0), specs)})["o"]
        split = stencil_step_overlap(u, (1, 0, 0), specs,
                                     lambda p: kern({"u": p})["o"])
        np.testing.assert_allclose(np.asarray(plain), np.asarray(split))


class TestMoL:
    def test_rk4_convergence_order(self):
        # dy/dt = -y, exact e^{-t}; halving dt must cut error ~16x
        rhs = lambda y, t: jax.tree_util.tree_map(lambda v: -v, y)
        errs = []
        for dt in (0.1, 0.05):
            y = {"v": jnp.float32(1.0)}
            t, n = 0.0, int(round(1.0 / dt))
            for _ in range(n):
                y = mol.rk4(rhs, y, t, dt)
                t += dt
            errs.append(abs(float(y["v"]) - np.exp(-1.0)))
        assert errs[0] / errs[1] > 10.0

    @pytest.mark.parametrize("name,order", [("euler", 1), ("rk2", 2), ("rk3", 3)])
    def test_integrator_orders(self, name, order):
        rhs = lambda y, t: -y
        errs = []
        for dt in (0.2, 0.1):
            y, t = jnp.float64(1.0) if jax.config.jax_enable_x64 else jnp.float32(1.0), 0.0
            for _ in range(int(round(1.0 / dt))):
                y = mol.INTEGRATORS[name](rhs, y, t, dt)
                t += dt
            errs.append(abs(float(y) - np.exp(-1.0)))
        ratio = errs[0] / errs[1]
        assert ratio > 2 ** order * 0.6, (name, ratio)


class TestSchedule:
    def test_ordering_constraints(self):
        s = Schedule()

        @s.register("EVOL", after=("a",))
        def b(st):
            st["trace"].append("b"); return st

        @s.register("EVOL")
        def a(st):
            st["trace"].append("a"); return st

        @s.register("EVOL", before=("a",))
        def c(st):
            st["trace"].append("c"); return st

        out = s.compile_bin("EVOL")({"trace": []})
        assert out["trace"].index("c") < out["trace"].index("a") < out["trace"].index("b")

    def test_cycle_detected(self):
        s = Schedule()
        s.register("EVOL", "x", after=("y",))(lambda st: st)
        s.register("EVOL", "y", after=("x",))(lambda st: st)
        with pytest.raises(RuntimeError, match="cycle"):
            s.compile_bin("EVOL")


class TestAutotune:
    def test_tile_divides_and_fits(self):
        choice = choose_tile(LAP, (32, 64, 256))
        tx, ty, tz = choice.tile
        assert 32 % tx == 0 and 64 % ty == 0 and 256 % tz == 0
        assert tz % 128 == 0
        assert choice.vmem_bytes <= 64 * 2 ** 20

    def test_bigger_tiles_win_on_intensity(self):
        small = choose_tile(LAP, (8, 8, 128))
        # with a huge domain the tuner should pick a tile at least as intense
        big = choose_tile(LAP, (64, 64, 512))
        assert big.intensity >= small.intensity

    def test_chip_auto_resolves_running_host(self):
        """choose_tile's default chip="auto" must resolve the host we are
        actually on (cpu-host on the CI lane), identical to passing the
        resolved chip explicitly."""
        from repro.core.rooflinemodel import resolve_chip

        chip = resolve_chip("auto")
        assert chip.name == "cpu-host"  # tests run on CPU jax
        auto = choose_tile(LAP, (32, 64, 256))
        explicit = choose_tile(LAP, (32, 64, 256), chip=chip)
        assert auto == explicit

    def test_tile_for_memoizes_per_signature(self):
        from repro.core import reset_tile_cache, tile_cache_stats, tile_for

        reset_tile_cache()
        a = tile_for(LAP, (32, 64, 256))
        b = tile_for(LAP, (32, 64, 256))
        assert a == b and a.tile is not None
        stats = tile_cache_stats()
        assert stats == {"hits": 1, "misses": 1, "entries": 1}
        tile_for(LAP, (16, 64, 256))  # different interior -> new entry
        assert tile_cache_stats()["misses"] == 2


class TestDriver:
    def test_single_device_driver(self):
        dom = Domain(shape=(8, 8, 8), periodic=(True, True, True))
        drv = GridDriver(dom)
        assert drv.local_shape == (8, 8, 8)
        fields = drv.allocate(["u"], init=2.0)
        specs = drv.axis_specs()
        kern = generate(LAP, _laplacian_body, template="JNP")

        def step(u):
            return kern({"u": exchange_pad(u, (1, 1, 1), specs)})["lap"]

        out = drv.sharded_step(step)(fields["u"])
        # laplacian of a constant field is zero
        np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)

    def test_indivisible_decomposition_rejected(self):
        dom = Domain(shape=(9, 8, 8), decomposition={0: "data"})
        mesh = jax.make_mesh((1,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        # 9 % 1 == 0 so this passes; fake a bigger axis via validation path
        GridDriver(Domain(shape=(8, 8, 8), decomposition={0: "data"}), mesh)
        with pytest.raises(ValueError, match="no axis"):
            GridDriver(Domain(shape=(8, 8, 8), decomposition={0: "nope"}), mesh)
