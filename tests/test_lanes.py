"""CI lane hygiene: every test must resolve to exactly one lane.

CI splits the suite into a fast lane (``-m "not multidevice"``) and a
multidevice lane (``-m multidevice``).  Two failure modes would silently
skew that split:

* a test that spawns forced-device-count subprocesses but lacks the
  ``multidevice`` marker runs (slowly, or wrongly) in the fast lane — the
  AST guard below fails the fast lane when that happens;
* a typo'd marker name would neither register nor select — caught at
  collection time by ``--strict-markers`` (pyproject addopts), asserted
  here so the option cannot quietly disappear.
"""
from __future__ import annotations

import ast
import os

try:
    import tomllib
except ModuleNotFoundError:  # py3.10
    tomllib = None

TESTS_DIR = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(TESTS_DIR)

# helpers that force a multi-device subprocess mesh; any test reaching one
# of these must be in the multidevice lane
_DEVICE_HELPERS = {"run_with_devices"}


def _marker_names(decorator_list) -> set:
    """Names of pytest.mark.* decorators (handles bare and called forms)."""
    out = set()
    for dec in decorator_list:
        node = dec.func if isinstance(dec, ast.Call) else dec
        if isinstance(node, ast.Attribute):
            parts = []
            while isinstance(node, ast.Attribute):
                parts.append(node.attr)
                node = node.value
            if isinstance(node, ast.Name):
                parts.append(node.id)
            dotted = ".".join(reversed(parts))
            if dotted.startswith("pytest.mark."):
                out.add(dotted.split(".", 2)[2])
    return out


def _module_markers(tree: ast.Module) -> set:
    """Markers applied module-wide via ``pytestmark = pytest.mark.x`` (or a
    list of marks)."""
    out = set()
    for node in tree.body:
        if not (isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == "pytestmark"
                for t in node.targets)):
            continue
        values = (node.value.elts if isinstance(node.value, (ast.List,
                                                             ast.Tuple))
                  else [node.value])
        out |= _marker_names(values)
    return out


def _called_names(func: ast.AST) -> set:
    out = set()
    for node in ast.walk(func):
        if isinstance(node, ast.Call):
            f = node.func
            name = (f.id if isinstance(f, ast.Name)
                    else f.attr if isinstance(f, ast.Attribute) else None)
            if name:
                out.add(name)
    return out


def _device_reaching_names(tree: ast.Module, seed: set = frozenset()) -> set:
    """Names of functions in this module that reach a device helper,
    transitively: a local wrapper around ``run_with_devices`` flags its
    callers too, so renaming-by-wrapping cannot evade the lane guard.
    ``seed`` carries flagged names from shared helper modules.
    (Name-based, scope-blind — deliberately over-approximate for a
    guard.)"""
    calls = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            calls.setdefault(node.name, set()).update(_called_names(node))
    flagged = set(_DEVICE_HELPERS) | set(seed)
    changed = True
    while changed:
        changed = False
        for name, callees in calls.items():
            if name not in flagged and callees & flagged:
                flagged.add(name)
                changed = True
    return flagged


def _shared_helper_flags() -> set:
    """Device-reaching names defined in the NON-test modules of tests/
    (helpers.py, conftest.py, ...): a wrapper around run_with_devices
    that lives in a shared helper must flag its callers in every test
    module."""
    flagged = set()
    for fname in sorted(os.listdir(TESTS_DIR)):
        if fname.startswith("test_") or not fname.endswith(".py"):
            continue
        with open(os.path.join(TESTS_DIR, fname)) as f:
            tree = ast.parse(f.read(), filename=fname)
        flagged |= _device_reaching_names(tree) - _DEVICE_HELPERS
    return flagged


def _calls_device_helper(func: ast.AST, flagged: set) -> bool:
    return bool(_called_names(func) & flagged)


def _iter_tests(tree: ast.Module):
    """(test function node, markers-in-scope) for every collected test."""
    mod_marks = _module_markers(tree)
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                node.name.startswith("test_"):
            yield node, mod_marks | _marker_names(node.decorator_list)
        elif isinstance(node, ast.ClassDef) and node.name.startswith("Test"):
            cls_marks = mod_marks | _marker_names(node.decorator_list)
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and sub.name.startswith("test_"):
                    yield sub, cls_marks | _marker_names(sub.decorator_list)


def test_device_subprocess_tests_carry_the_multidevice_marker():
    """Any test that forces a multi-device subprocess mesh must be marked
    ``multidevice`` — otherwise the fast lane runs it and the multidevice
    lane silently loses it."""
    offenders = []
    seed = _shared_helper_flags()
    for fname in sorted(os.listdir(TESTS_DIR)):
        if not (fname.startswith("test_") and fname.endswith(".py")):
            continue
        with open(os.path.join(TESTS_DIR, fname)) as f:
            tree = ast.parse(f.read(), filename=fname)
        flagged = _device_reaching_names(tree, seed)
        for func, marks in _iter_tests(tree):
            if _calls_device_helper(func, flagged) and \
                    "multidevice" not in marks:
                offenders.append(f"{fname}::{func.name}")
    assert not offenders, (
        "tests spawning forced-device subprocesses without the multidevice "
        f"marker (would run in the fast lane): {offenders}")


def test_strict_markers_is_enforced():
    """``--strict-markers`` must stay in addopts: with it, a typo'd lane
    marker is a collection error instead of a test that runs in (only)
    the fast lane."""
    path = os.path.join(ROOT, "pyproject.toml")
    if tomllib is not None:
        with open(path, "rb") as f:
            cfg = tomllib.load(f)
        addopts = cfg["tool"]["pytest"]["ini_options"].get("addopts", "")
    else:
        with open(path) as f:
            addopts = next((line for line in f if "addopts" in line), "")
    assert "--strict-markers" in addopts


def test_lanes_partition_the_suite():
    """The two lane expressions are complementary by construction
    (``multidevice`` / ``not multidevice``): every collected test belongs
    to exactly one lane.  Guarded here against someone adding a third
    marker-based lane without updating the CI expressions."""
    with open(os.path.join(ROOT, ".github", "workflows", "ci.yml")) as f:
        ci = f.read()
    assert '-m "not multidevice"' in ci
    assert "-m multidevice" in ci
