"""The runtime front door (repro.api) + scenario registry.

The migration contract frozen here: everything the Runtime resolves —
serial driver runs, slot-parallel farms, slots × shards decomposition —
is *bitwise identical* to hand-assembling the legacy constructor stack.
Plus: registry round-trips, schedule-bin ordering laws (hypothesis),
residual-based convergence, priority admission, per-sim failure
surfacing, and import hygiene for examples/ and benchmarks/.
"""
import ast
import dataclasses
import os

import numpy as np
import pytest
import jax
from hypothesis import given, settings, strategies as st

from repro import api
from repro.cfd import cavity, taylor_green
from repro.cfd.ns3d import NavierStokes3D
from repro.core.schedule import BINS, Schedule, ScheduleError
from repro.sim import SimulationFarm, SimulationService
from tests.helpers import run_with_devices

N = 16
KW = dict(jacobi_iters=20)
FIELDS = ("vx", "vy", "vz", "p")


def serial_reference(scenario: str, steps: int, **kw):
    """The pre-api workflow: one solver, one GridDriver-jitted step."""
    mod = {"cavity": cavity, "taylor_green": taylor_green}[scenario]
    solver = NavierStokes3D(mod.config(N, **kw, **KW))
    state = solver.init_state()
    step = solver.make_step()
    for _ in range(steps):
        state = step(state)
    return jax.device_get(state)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------
class TestRegistry:
    def test_builtins_registered(self):
        names = api.scenario_names()
        for want in ("cavity", "taylor_green", "kelvin_helmholtz"):
            assert want in names

    def test_round_trip(self):
        sc = api.get_scenario("cavity")
        assert sc.name == "cavity"
        assert api.get_scenario(sc) is sc          # Scenario passes through
        assert "re" in sc.params

    def test_unknown_scenario_error_names_the_registry(self):
        with pytest.raises(api.UnknownScenarioError, match="cavity"):
            api.get_scenario("no_such_scenario")
        rt = api.runtime(n=N)
        with pytest.raises(api.UnknownScenarioError):
            rt.run("no_such_scenario", steps=1)

    def test_third_party_registration(self):
        """Registering a custom scenario through the public decorator makes
        it resolvable by name through the same front door."""
        base = api.get_scenario("taylor_green")
        custom = dataclasses.replace(base, name="tg_custom_test",
                                     description="third-party variant")
        try:
            api.register_scenario(custom)
            rt = api.runtime(n=N, **KW)
            res = rt.run("tg_custom_test", steps=3, nu=0.1)
            ref = serial_reference("taylor_green", 3, nu=0.1)
            for f in FIELDS:
                np.testing.assert_array_equal(ref[f], res.state[f])
        finally:
            api.unregister_scenario("tg_custom_test")
        with pytest.raises(api.UnknownScenarioError):
            api.get_scenario("tg_custom_test")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            api.register_scenario(api.get_scenario("cavity"))

    def test_unknown_backend_rejected(self):
        with pytest.raises(ValueError, match="backend"):
            api.runtime(n=N, backend="cuda")


# ---------------------------------------------------------------------------
# schedule-bin ordering (hypothesis property)
# ---------------------------------------------------------------------------
def _entries_strategy():
    """Up to 7 named entries with random before/after constraints drawn
    only against *earlier* entries — a DAG by construction."""
    def build(n, edges):
        out = []
        for i in range(n):
            befores = tuple(f"e{j}" for j in range(i) if (i, j, 0) in edges)
            afters = tuple(f"e{j}" for j in range(i) if (i, j, 1) in edges)
            out.append((f"e{i}", befores, afters))
        return out

    edge = st.tuples(st.integers(0, 6), st.integers(0, 6),
                     st.integers(0, 1))
    return st.builds(build, st.integers(1, 7),
                     st.sets(edge, max_size=8))


class TestScheduleOrdering:
    @settings(max_examples=40, deadline=None)
    @given(entries=_entries_strategy(), bin=st.sampled_from(
        ["INITIAL", "EVOLVE", "ANALYSIS"]))
    def test_order_respects_constraints(self, entries, bin):
        s = Schedule()
        for name, befores, afters in entries:
            s.register(bin, name, before=befores, after=afters)(
                lambda st_, name=name: st_ + [name])
        order = s.compile_bin(bin)([])
        assert sorted(order) == sorted(n for n, _, _ in entries)
        pos = {n: i for i, n in enumerate(order)}
        for name, befores, afters in entries:
            for b in befores:
                assert pos[name] < pos[b], (name, "before", b, order)
            for a in afters:
                assert pos[a] < pos[name], (name, "after", a, order)

    def test_evolve_aliases_evol(self):
        s = Schedule()
        s.register("EVOLVE", "x")(lambda st_: st_ + ["x"])
        assert s.names("EVOL") == ["x"] == s.names("EVOLVE")

    def test_unknown_bin_still_rejected(self):
        with pytest.raises(ScheduleError, match="unknown schedule bin"):
            Schedule().register("EVOLVED", "x")(lambda st_: st_)

    def test_scenario_bins_are_wired(self):
        sc = api.get_scenario("kelvin_helmholtz")
        solver = NavierStokes3D(sc.config(N))
        sched = sc.schedule(solver)
        assert sched.names("INITIAL") == ["allocate_fields",
                                          "ic_kelvin_helmholtz"]
        assert sched.names("EVOLVE") == ["ns3d_step"]
        assert set(sched.names("ANALYSIS")) == {"amplitude",
                                                "kinetic_energy"}
        assert set(BINS) >= {"INITIAL", "EVOL", "ANALYSIS"}


# ---------------------------------------------------------------------------
# bitwise equivalence: Runtime vs legacy constructors (serial, fast lane)
# ---------------------------------------------------------------------------
class TestBitwiseEquivalence:
    @pytest.mark.parametrize("scenario,params", [
        ("cavity", dict(re=120.0)),
        ("taylor_green", dict(nu=0.07)),
    ])
    def test_run_matches_legacy_serial(self, scenario, params):
        rt = api.runtime(n=N, **KW)
        res = rt.run(scenario, steps=20, **params)
        ref = serial_reference(scenario, 20, **params)
        for f in FIELDS:
            np.testing.assert_array_equal(ref[f], res.state[f], err_msg=f)
        assert res.terminated == "steps" and res.steps_done == 20

    def test_submit_matches_legacy_farm(self):
        """Runtime.submit/drain vs a hand-built SimulationFarm, mixed
        Reynolds numbers AND step counts (slots reclaim mid-flight)."""
        jobs = ((80.0, 10), (150.0, 16), (220.0, 12), (300.0, 18))
        rt = api.runtime(n=N, n_slots=2, **KW)
        sids = [rt.submit("cavity", steps=s, re=re) for re, s in jobs]
        results = rt.drain()
        legacy = SimulationFarm(cavity.config(N, template="JNP", **KW),
                                n_slots=2)
        lsids = [legacy.submit(cavity.sim_request(
            N, re=re, steps=s, template="JNP", **KW)) for re, s in jobs]
        lres = legacy.run_until_drained()
        for s_new, s_old in zip(sids, lsids):
            assert results[s_new].steps_done == lres[s_old].steps_done
            for f in FIELDS:
                np.testing.assert_array_equal(
                    results[s_new].state[f], lres[s_old].state[f],
                    err_msg=f)

    def test_prepare_exposes_the_same_step(self):
        """PreparedRun.step is the legacy jitted step: stepping it by hand
        reproduces Runtime.run bitwise (benchmarks rely on this)."""
        rt = api.runtime(n=N, **KW)
        pr = rt.prepare("cavity", re=90.0)
        st = pr.state
        for _ in range(8):
            st = pr.step(st)
        res = rt.run("cavity", steps=8, re=90.0)
        for f in FIELDS:
            np.testing.assert_array_equal(np.asarray(st[f]), res.state[f])

    def test_kh_scenario_farm_matches_serial_run(self):
        """A scenario with a registered IC: the farm path (init_state
        shipped in the request) equals the serial path bitwise."""
        rt = api.runtime(n=N, n_slots=2, jacobi_iters=30)
        res = rt.run("kelvin_helmholtz", steps=10, nu=0.004)
        sid = rt.submit("kelvin_helmholtz", steps=10, nu=0.004)
        far = rt.result(sid)
        for f in FIELDS:
            np.testing.assert_array_equal(res.state[f], far.state[f],
                                          err_msg=f)
        assert res.diagnostics["amplitude"] > 0.0


# ---------------------------------------------------------------------------
# convergence: residual norms replace the KE-drift heuristic
# ---------------------------------------------------------------------------
class TestResidualConvergence:
    def test_serial_and_farm_agree_on_termination_step(self):
        rt_serial = api.runtime(n=N, check_every=8, **KW)
        r1 = rt_serial.run("cavity", steps=5000, re=100.0,
                           residual_tol=1e-3)
        assert r1.terminated == "residual" and r1.steps_done < 5000
        rt_farm = api.runtime(n=N, n_slots=1, check_every=8, **KW)
        sid = rt_farm.submit("cavity", steps=5000, re=100.0,
                             residual_tol=1e-3)
        r2 = rt_farm.result(sid)
        assert r2.terminated == "residual"
        assert r2.steps_done == r1.steps_done
        for f in FIELDS:
            np.testing.assert_array_equal(r1.state[f], r2.state[f])

    def test_residual_checks_do_not_perturb_the_state_path(self):
        """A run with residual watching that terminates on steps is
        bitwise the run without it (snapshots only, no numerics)."""
        rt = api.runtime(n=N, check_every=8, **KW)
        plain = rt.run("cavity", steps=24, re=100.0)
        watched = rt.run("cavity", steps=24, re=100.0, residual_tol=1e-30)
        assert watched.terminated == "steps"
        for f in FIELDS:
            np.testing.assert_array_equal(plain.state[f], watched.state[f])
        farm = SimulationFarm(cavity.config(N, **KW), n_slots=1,
                              check_steady_every=8)
        sid = farm.submit(cavity.sim_request(N, re=100.0, steps=24,
                                             residual_tol=1e-30, **KW))
        res = farm.run_until_drained()[sid]
        assert res.terminated == "steps"
        ref = serial_reference("cavity", 24, re=100.0)
        for f in FIELDS:
            np.testing.assert_array_equal(ref[f], res.state[f])

    def test_legacy_ke_heuristic_still_available(self):
        rt = api.runtime(n=N, check_every=8, **KW)
        r = rt.run("cavity", steps=5000, re=100.0, steady_tol=1e-4)
        assert r.terminated == "steady" and r.steps_done < 5000


# ---------------------------------------------------------------------------
# priority admission
# ---------------------------------------------------------------------------
class TestPriorityAdmission:
    def test_two_level_pop_fifo_within_level(self):
        farm = SimulationFarm(cavity.config(N, **KW), n_slots=1)
        reqs = [cavity.sim_request(N, re=re, steps=2, priority=p, **KW)
                for re, p in ((50.0, 0), (60.0, 0), (70.0, 1), (80.0, 1))]
        sids = [farm.submit(r) for r in reqs]
        finish_order = []
        while len(farm.results) < 4:
            farm.step()
            for sid in farm.results:
                if sid not in finish_order:
                    finish_order.append(sid)
        # high-priority pair first (FIFO within level), then the level-0
        # pair in submission order
        assert finish_order == [sids[2], sids[3], sids[0], sids[1]]

    def test_runtime_priority_passthrough(self):
        rt = api.runtime(n=N, n_slots=1, **KW)
        lo = rt.submit("cavity", steps=2, re=50.0)
        hi = rt.submit("cavity", steps=2, re=60.0, priority=5)
        svc = rt.services()[0]
        svc.farm.step()          # admits exactly one request
        assert rt.poll(hi)["status"] in ("running", "done")
        assert rt.poll(lo)["status"] == "queued"
        rt.drain()


# ---------------------------------------------------------------------------
# failure surfacing (the drain bugfix)
# ---------------------------------------------------------------------------
class TestFailureSurfacing:
    def test_unbuildable_signature_resolves_to_failed_result(self):
        """A decomposition with no mesh to satisfy it fails that sid —
        poll/result/drain all surface it; nothing blocks."""
        rt = api.runtime(n=N, decomposition=((0, "shard"),), **KW)
        sid = rt.submit("cavity", steps=5, re=100.0)
        assert rt.poll(sid)["status"] == "failed"
        assert "decomposition" in rt.poll(sid)["error"]
        out = rt.drain()
        assert out[sid].terminated == "failed"
        with pytest.raises(RuntimeError, match="failed"):
            rt.result(sid)

    def test_admission_failure_is_per_sim_and_drain_completes(self):
        """A request whose slot admission raises (mis-shaped readmission
        state) resolves to a failed result; healthy sims in the same farm
        drain normally — drain never wedges on the broken one."""
        svc = SimulationService(cavity.config(N, **KW), n_slots=1)
        good = svc.submit(cavity.sim_request(N, re=100.0, steps=5, **KW))
        bad_req = cavity.sim_request(N, re=200.0, steps=5, **KW)
        bad_req.init_state = {"vx": np.zeros((3, 3, 3), np.float32)}
        bad = svc.submit(bad_req)
        out = svc.drain()
        assert out[good].terminated == "steps"
        assert out[bad].terminated == "failed" and out[bad].error
        assert svc.poll(bad)["status"] == "failed"
        with pytest.raises(RuntimeError, match="failed"):
            svc.result(bad)
        # the good result is still bitwise exact after the failure
        ref = serial_reference("cavity", 5, re=100.0)
        for f in FIELDS:
            np.testing.assert_array_equal(ref[f], out[good].state[f])


# ---------------------------------------------------------------------------
# import hygiene: examples/ and benchmarks/ go through repro.api
# ---------------------------------------------------------------------------
FORBIDDEN_MODULES = ("repro.sim.ensemble", "repro.sim.farm",
                     "repro.sim.service", "repro.core.driver")


def _imported_modules(path):
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                yield a.name
        elif isinstance(node, ast.ImportFrom) and node.module:
            yield node.module
            for a in node.names:      # "from repro.sim import farm"
                yield f"{node.module}.{a.name}"


def test_examples_and_benchmarks_import_through_the_api():
    """The front door is the only supported path into the farm/driver
    internals: examples and benchmarks must not reach around it."""
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    offenders = []
    for d in ("examples", "benchmarks"):
        for fname in sorted(os.listdir(os.path.join(root, d))):
            if not fname.endswith(".py"):
                continue
            for mod in _imported_modules(os.path.join(root, d, fname)):
                if mod in FORBIDDEN_MODULES:
                    offenders.append(f"{d}/{fname} imports {mod}")
    assert not offenders, (
        "examples/benchmarks must go through repro.api, not the "
        f"constructor internals: {offenders}")


# ---------------------------------------------------------------------------
# decomposed equivalence (multidevice lane)
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
class TestRuntimeDecomposed:
    def test_runtime_matches_legacy_across_postures(self):
        """One script, three postures: slot-parallel farm, slots × shards
        farm, and serial decomposed run — each bitwise against its legacy
        constructor stack."""
        script = """
import numpy as np, jax
from repro import api
from repro.cfd import cavity
from repro.cfd.ns3d import NavierStokes3D
from repro.launch.mesh import make_mesh
from repro.sim import SimulationFarm

N, KW = 16, dict(jacobi_iters=20)
DKW = dict(jacobi_iters=20, decomposition=((0, "shard"),), template="JNP")
JOBS = ((50.0, 20), (100.0, 30), (200.0, 25), (400.0, 35))
FIELDS = ("vx", "vy", "vz", "p")

# 1) slot-parallel: Runtime.submit on a ("slot",) mesh vs single-device farm
rt = api.runtime(n=N, n_slots=4, mesh_shape=(4,), mesh_axes=("slot",), **KW)
sids = [rt.submit("cavity", steps=s, re=re) for re, s in JOBS]
res = rt.drain()
legacy = SimulationFarm(cavity.config(N, template="JNP", **KW), n_slots=4)
lsids = [legacy.submit(cavity.sim_request(N, re=re, steps=s,
                                          template="JNP", **KW))
         for re, s in JOBS]
lres = legacy.run_until_drained()
for a, b in zip(sids, lsids):
    for f in FIELDS:
        np.testing.assert_array_equal(res[a].state[f], lres[b].state[f],
                                      err_msg=f"slot {f}")
print("SLOT-PARALLEL OK")

# 2) slots x shards: Runtime.submit vs serial decomposed GridDriver
rt2 = api.runtime(n=N, n_slots=2, mesh_shape=(2, 4),
                  mesh_axes=("slot", "shard"),
                  decomposition=((0, "shard"),), **KW)
sid = rt2.submit("cavity", steps=30, re=100.0)
r2 = rt2.result(sid)
solver = NavierStokes3D(cavity.config(N, re=100.0, **DKW),
                        make_mesh((4,), ("shard",)))
st = solver.init_state(); step = solver.make_step()
for _ in range(30):
    st = step(st)
st = jax.device_get(st)
for f in FIELDS:
    np.testing.assert_array_equal(st[f], r2.state[f], err_msg=f)
print("SLOTS X SHARDS OK")

# 3) serial decomposed: Runtime.run on a ("shard",) mesh
rt3 = api.runtime(n=N, mesh_shape=(4,), mesh_axes=("shard",),
                  decomposition=((0, "shard"),), **KW)
r3 = rt3.run("cavity", steps=30, re=100.0)
for f in FIELDS:
    np.testing.assert_array_equal(st[f], r3.state[f], err_msg=f)
print("SERIAL DECOMPOSED OK")
"""
        out = run_with_devices(script, n_devices=8, timeout=540)
        for tag in ("SLOT-PARALLEL OK", "SLOTS X SHARDS OK",
                    "SERIAL DECOMPOSED OK"):
            assert tag in out

    def test_indivisible_decomposition_fails_per_sim_on_a_healthy_farm(self):
        """The drain bugfix, at its literal repro: an indivisible
        decomposition (18 % 4 != 0) submitted to a runtime whose healthy
        signature keeps serving — the bad sid resolves to failed, the
        good one drains bitwise-intact, drain returns."""
        script = """
import numpy as np
from repro import api

KW = dict(jacobi_iters=20)
rt = api.runtime(n=16, n_slots=2, mesh_shape=(1, 4),
                 mesh_axes=("slot", "shard"),
                 decomposition=((0, "shard"),), **KW)
ok = rt.submit("cavity", steps=10, re=100.0)
bad = rt.submit("cavity", n=18, steps=10, re=100.0)  # 18 % 4 != 0
assert rt.poll(bad)["status"] == "failed", rt.poll(bad)
out = rt.drain()
assert out[ok].terminated == "steps"
assert out[bad].terminated == "failed"
assert "divisible" in out[bad].error, out[bad].error
print("INDIVISIBLE FAILED-SIM OK")
"""
        out = run_with_devices(script, n_devices=8, timeout=540)
        assert "INDIVISIBLE FAILED-SIM OK" in out
