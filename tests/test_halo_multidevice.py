"""Halo exchange over a real (fake-multi-device) mesh must agree with the
single-shard result — the distributed ghost zones are an implementation
detail, not a numerical one.  Runs in subprocesses (device count is locked
per process)."""
import pytest

from tests.helpers import run_with_devices

pytestmark = pytest.mark.multidevice

EXCHANGE_EQUIV = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import AxisSpec, exchange_pad, bc_dirichlet, bc_mirror, stencil_step_overlap

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
rng = np.random.RandomState(0)
u = jnp.asarray(rng.randn(16, 8, 4).astype(np.float32))

# distributed: decompose x over data, y over model
dspecs = (AxisSpec(0, "data", periodic=%(periodic)s, bc_lo=%(bc)s, bc_hi=%(bc)s),
          AxisSpec(1, "model", periodic=%(periodic)s, bc_lo=%(bc)s, bc_hi=%(bc)s),
          AxisSpec(2, periodic=True))
# reference: same thing on one shard
rspecs = (AxisSpec(0, periodic=%(periodic)s, bc_lo=%(bc)s, bc_hi=%(bc)s),
          AxisSpec(1, periodic=%(periodic)s, bc_lo=%(bc)s, bc_hi=%(bc)s),
          AxisSpec(2, periodic=True))

def lap(p):
    return (p[2:,1:-1,1:-1] + p[:-2,1:-1,1:-1] + p[1:-1,2:,1:-1]
          + p[1:-1,:-2,1:-1] + p[1:-1,1:-1,2:] + p[1:-1,1:-1,:-2]
          - 6.0 * p[1:-1,1:-1,1:-1])

def local_step(x):
    return lap(exchange_pad(x, (1, 1, 1), dspecs))

def local_step_overlap(x):
    return stencil_step_overlap(x, (1, 1, 1), dspecs, lap)

spec = P("data", "model", None)
step = jax.jit(jax.shard_map(local_step, mesh=mesh, in_specs=spec,
                             out_specs=spec, check_vma=False))
step_ov = jax.jit(jax.shard_map(local_step_overlap, mesh=mesh, in_specs=spec,
                                out_specs=spec, check_vma=False))
ref = lap(exchange_pad(u, (1, 1, 1), rspecs))

us = jax.device_put(u, NamedSharding(mesh, spec))
np.testing.assert_allclose(np.asarray(step(us)), np.asarray(ref), rtol=1e-6, atol=1e-6)
np.testing.assert_allclose(np.asarray(step_ov(us)), np.asarray(ref), rtol=1e-6, atol=1e-6)

# the overlap path must actually contain collective-permutes
hlo = jax.jit(jax.shard_map(local_step_overlap, mesh=mesh, in_specs=spec,
              out_specs=spec, check_vma=False)).lower(us).compile().as_text()
assert "collective-permute" in hlo, "expected ppermute in compiled HLO"
print("OK")
"""


def test_distributed_exchange_periodic():
    out = run_with_devices(EXCHANGE_EQUIV % {"periodic": "True", "bc": "None"})
    assert "OK" in out


def test_distributed_exchange_dirichlet():
    out = run_with_devices(
        EXCHANGE_EQUIV % {"periodic": "False", "bc": "bc_dirichlet(3.5)"})
    assert "OK" in out


def test_distributed_exchange_mirror():
    out = run_with_devices(
        EXCHANGE_EQUIV % {"periodic": "False", "bc": "bc_mirror(-1.0)"})
    assert "OK" in out
