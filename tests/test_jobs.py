"""repro.jobs: the durable job engine.

Codec round-trips, store unit behaviour (leases, TTL takeover, threaded
no-double-claim, terminal pruning), the store-off bitwise-invisibility
contract, the durable lifecycle end-to-end (submit -> running -> done
with a persisted result snapshot; evict/readmit through the store;
flight-record registration resolving from a fresh process), the SIGKILL
resume battery (restart resumes incomplete first, results bitwise
against an uninterrupted run), and two workers draining one queue
without double execution.
"""
import dataclasses
import os
import signal
import subprocess
import sys
import textwrap
import threading
import time

import numpy as np
import pytest

from repro import api, jobs
from repro.ckpt.checkpointer import Checkpointer
from repro.jobs import JobStore
from repro.sim.farm import SimRequest
from repro.sim.scenarios import get_scenario

N = 12
KW = dict(jacobi_iters=8)
FIELDS = ("vx", "vy", "vz", "p")


def _request(re=100.0, steps=8, **kw):
    sc = get_scenario("cavity")
    return sc.request(N, steps=steps, re=re,
                      config=sc.config(N, re=re, **KW), **kw)


# ---------------------------------------------------------------------------
# codec
# ---------------------------------------------------------------------------
class TestCodec:
    def test_config_round_trip_restores_tuples(self):
        cfg = get_scenario("cavity").config(N, re=123.0, **KW)
        cfg = dataclasses.replace(cfg, decomposition=((0, "shard"),))
        back = jobs.config_from_dict(jobs.config_to_dict(cfg))
        assert back == cfg
        assert isinstance(back.shape, tuple)
        assert isinstance(back.forcing, tuple)
        assert back.decomposition == ((0, "shard"),)
        hash(back.decomposition)   # static-signature members must hash

    def test_request_round_trip_bitwise(self):
        rng = np.random.default_rng(0)
        init = {f: rng.standard_normal((N, N, N)).astype(np.float32)
                for f in FIELDS}
        req = _request(re=250.0, steps=17, tag="rt", steady_tol=1e-4,
                       residual_tol=1e-3, priority=2)
        req = dataclasses.replace(req, init_state=init, step0=5, sid=99)
        back = jobs.decode_request(*jobs.encode_request(req))
        assert back.config == req.config
        assert (back.steps, back.tag, back.priority, back.step0) == \
            (17, "rt", 2, 5)
        assert back.steady_tol == req.steady_tol
        assert back.residual_tol == req.residual_tol
        assert back.sid is None        # sid is per-process, never durable
        for f in FIELDS:
            np.testing.assert_array_equal(back.init_state[f], init[f])
            assert back.init_state[f].dtype == init[f].dtype

    def test_no_init_state_encodes_no_blob(self):
        payload, blob = jobs.encode_request(_request())
        assert blob is None
        assert jobs.decode_request(payload, None).init_state is None

    def test_unknown_payload_version_rejected(self):
        payload, _ = jobs.encode_request(_request())
        bad = payload.replace(f'"version": {jobs.PAYLOAD_VERSION}',
                              '"version": 999')
        with pytest.raises(ValueError, match="payload version"):
            jobs.decode_request(bad)


# ---------------------------------------------------------------------------
# store unit behaviour
# ---------------------------------------------------------------------------
class TestJobStore:
    def test_submit_is_durable_and_claim_orders_priority_fifo(self, tmp_path):
        st = JobStore(str(tmp_path / "j.sqlite"))
        ids = [st.submit(_request(tag=t, **({"priority": p} if p else {})))
               for t, p in (("a", 0), ("b", 1), ("c", 0))]
        assert st.queue_depth() == 3
        assert st.counts()["queued"] == 3
        claimed = st.claim(limit=3)
        # priority level first, FIFO within a level — admission order
        assert [j.tag for j in claimed] == ["b", "a", "c"]
        assert [j.job_id for j in claimed] == [ids[1], ids[0], ids[2]]
        req = claimed[0].request()
        assert req.tag == "b" and req.priority == 1

    def test_live_lease_blocks_peers_expired_lease_takes_over(self, tmp_path):
        path = str(tmp_path / "j.sqlite")
        a = JobStore(path, ttl_s=0.4, owner="host:1:aaaaaa")
        b = JobStore(path, ttl_s=30.0, owner="host:2:bbbbbb")
        jid = a.submit(_request(tag="x"))
        assert len(a.claim()) == 1
        assert b.claim() == []                 # lease is live
        assert b.lease_of(jid)["owner"] == a.owner
        time.sleep(0.5)
        got = b.claim()                        # a's lease expired -> takeover
        assert [j.job_id for j in got] == [jid]
        assert b.takeovers == 1 and a.takeovers == 0
        assert b.lease_of(jid)["owner"] == b.owner
        assert [e["event"] for e in b.events(jid)] == \
            ["submit", "claim", "takeover"]

    def test_renew_extends_release_frees(self, tmp_path):
        st = JobStore(str(tmp_path / "j.sqlite"), ttl_s=30.0)
        jid = st.submit(_request(), lease=True)   # service-path submit
        before = st.lease_of(jid)["expires_at"]
        time.sleep(0.05)
        assert st.renew() == 1
        assert st.lease_of(jid)["expires_at"] > before
        assert st.release(jid)
        assert st.lease_of(jid) is None

    def test_terminal_transition_releases_lease_and_audits(self, tmp_path):
        st = JobStore(str(tmp_path / "j.sqlite"))
        jid = st.submit(_request(), lease=True)
        st.transition(jid, jobs.RUNNING, steps_done=0, event="admit")
        st.transition(jid, jobs.DONE, steps_done=8, terminated="steps",
                      event="result")
        job = st.get(jid)
        assert job.status == jobs.DONE
        assert (job.steps_done, job.terminated) == (8, "steps")
        assert st.lease_of(jid) is None
        assert [e["event"] for e in st.events(jid)] == \
            ["submit", "admit", "result"]
        with pytest.raises(ValueError, match="unknown job status"):
            st.transition(jid, "bogus")

    def test_no_double_claim_across_threads(self, tmp_path):
        """Eight claimers hammering one file: BEGIN IMMEDIATE serializes
        them — every job claimed exactly once, none lost."""
        path = str(tmp_path / "j.sqlite")
        seed = JobStore(path)
        n_jobs = 24
        for i in range(n_jobs):
            seed.submit(_request(tag=f"t{i}"))
        got: dict[str, list[int]] = {}

        def worker(name):
            st = JobStore(path, ttl_s=60.0, owner=f"host:{name}:x")
            mine = []
            while True:
                batch = st.claim(limit=2)
                if not batch:
                    break
                mine.extend(j.job_id for j in batch)
            got[name] = mine

        threads = [threading.Thread(target=worker, args=(str(i),))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        all_claimed = sorted(jid for m in got.values() for jid in m)
        assert len(all_claimed) == n_jobs          # none double-claimed
        assert len(set(all_claimed)) == n_jobs

    def test_snapshot_round_trip_bitwise(self, tmp_path):
        st = JobStore(str(tmp_path / "j.sqlite"))
        jid = st.submit(_request())
        rng = np.random.default_rng(1)
        state = {f: rng.standard_normal((4, 4)).astype(np.float32)
                 for f in FIELDS}
        st.save_snapshot(jid, state, steps_done=7, kind="evict",
                         status=jobs.EVICTED)
        assert st.get(jid).status == jobs.EVICTED
        steps, back = st.load_snapshot(jid, "evict")
        assert steps == 7
        assert set(back) == set(FIELDS)
        for f in FIELDS:
            np.testing.assert_array_equal(back[f], state[f])
        # overwrite: latest pointer wins
        state2 = {f: v + 1 for f, v in state.items()}
        st.save_snapshot(jid, state2, steps_done=9, kind="evict")
        steps, back = st.load_snapshot(jid, "evict")
        assert steps == 9
        np.testing.assert_array_equal(back["vx"], state2["vx"])

    def test_prune_terminal_drops_rows_and_snapshot_dirs(self, tmp_path):
        st = JobStore(str(tmp_path / "j.sqlite"))
        state = {"vx": np.ones((3, 3), np.float32)}
        done = st.submit(_request(tag="done"))
        st.save_snapshot(done, state, 5, kind="result")
        st.transition(done, jobs.DONE, event="result")
        live = st.submit(_request(tag="live"))
        st.save_snapshot(live, state, 3, kind="evict", status=jobs.EVICTED)
        done_dir = os.path.join(st.snapshot_dir("result"),
                                f"step_{done:08d}")
        live_dir = os.path.join(st.snapshot_dir("evict"), f"step_{live:08d}")
        assert os.path.isdir(done_dir) and os.path.isdir(live_dir)
        assert st.prune_terminal(max_age_s=0.0) == 1
        assert not os.path.isdir(done_dir)       # terminal dir removed
        assert os.path.isdir(live_dir)           # incomplete job untouched
        assert st.get(done) is None and st.events(done) == []
        assert st.get(live).status == jobs.EVICTED
        assert st.prune_terminal(0.0) == 0       # idempotent
        # age guard: a fresh terminal row survives an aged prune
        d2 = st.submit(_request())
        st.transition(d2, jobs.FAILED, error="x", event="result")
        assert st.prune_terminal(max_age_s=3600.0) == 0
        assert st.get(d2) is not None

    def test_opportunistic_prune_after_terminal_transition(self, tmp_path):
        st = JobStore(str(tmp_path / "j.sqlite"), prune_after_s=0.0)
        a = st.submit(_request())
        st.transition(a, jobs.DONE, event="result")   # prunes itself
        assert st.get(a) is None
        assert st.counts()[jobs.DONE] == 0

    def test_resolve_store_specs(self, tmp_path):
        assert jobs.resolve_store(None) is None
        assert jobs.resolve_store(False) is None
        st = JobStore(str(tmp_path / "a.sqlite"))
        assert jobs.resolve_store(st) is st
        assert jobs.resolve_store(str(tmp_path / "b.sqlite")).path == \
            str(tmp_path / "b.sqlite")
        d = jobs.resolve_store({"path": str(tmp_path / "c.sqlite"),
                                "ttl_s": 5.0})
        assert d.ttl_s == 5.0
        t = jobs.resolve_store(True, ckpt_dir=str(tmp_path))
        assert t.path == str(tmp_path / "jobs.sqlite")
        with pytest.raises(ValueError, match="needs ckpt_dir"):
            jobs.resolve_store(True)
        with pytest.raises(TypeError):
            jobs.resolve_store(42)


# ---------------------------------------------------------------------------
# store-off is bitwise-invisible (the telemetry-off contract, again)
# ---------------------------------------------------------------------------
class TestStoreOffInvisible:
    def test_farm_results_identical_store_on_vs_off(self, tmp_path):
        runs = ((70.0, 9), (150.0, 14), (300.0, 7))

        def run(store):
            rt = api.runtime(n=N, n_slots=2, store=store, **KW)
            sids = [rt.submit("cavity", re=re, steps=s) for re, s in runs]
            out = rt.drain()
            return [out[s] for s in sids]

        on = run(str(tmp_path / "jobs.sqlite"))
        off = run(None)
        for a, b in zip(on, off):
            assert a.steps_done == b.steps_done
            assert a.terminated == b.terminated
            for f in FIELDS:
                np.testing.assert_array_equal(a.state[f], b.state[f])

    def test_store_off_installs_no_hooks(self):
        rt = api.runtime(n=N, n_slots=2, **KW)
        assert rt.store is None
        rt.submit("cavity", re=100.0, steps=2)
        svc = rt.services()[0]
        assert svc.store is None
        assert svc.farm.on_transition is None
        assert svc.farm.heartbeat is None      # telemetry off too
        assert rt.claim() == [] and rt.recover() == []
        with pytest.raises(RuntimeError, match="needs a job store"):
            rt.enqueue("cavity", steps=2)


# ---------------------------------------------------------------------------
# durable lifecycle end-to-end (one process)
# ---------------------------------------------------------------------------
class TestDurableLifecycle:
    def test_drain_persists_rows_and_result_snapshots(self, tmp_path):
        rt = api.runtime(n=N, n_slots=2, telemetry=True,
                         store=str(tmp_path / "jobs.sqlite"), **KW)
        sids = [rt.submit("cavity", re=re, steps=6, tag=t)
                for re, t in ((90.0, "a"), (180.0, "b"), (270.0, "c"))]
        res = rt.drain()
        st = rt.store
        assert st.counts()[jobs.DONE] == 3 and st.queue_depth() == 0
        for sid in sids:
            jid = rt.job_of(sid)
            job = st.get(jid)
            assert job.status == jobs.DONE
            assert job.steps_done == 6 and job.terminated == "steps"
            assert st.lease_of(jid) is None
            # the persisted result IS the in-memory result, bitwise
            final = rt.load_result(jid)
            for f in FIELDS:
                np.testing.assert_array_equal(final[f],
                                              np.asarray(res[sid].state[f]))
            assert [e["event"] for e in st.events(jid, event="result")] \
                and len(st.events(jid, event="result")) == 1
        # lifecycle joined the trace + gauges
        kinds = [e["kind"] for e in rt.telemetry.trace.events]
        assert "job_submit" in kinds and "job" in kinds
        assert rt.telemetry.metrics.get("jobs.store_queue_depth") == 0
        assert "repro_jobs_store_queue_depth" in \
            rt.services()[0].prometheus_text()

    def test_farm_side_failure_lands_in_store(self, tmp_path):
        rt = api.runtime(n=N, n_slots=2,
                         store=str(tmp_path / "jobs.sqlite"), **KW)
        good = rt.submit("cavity", re=100.0, steps=4, tag="good")
        bad_sid = rt.submit("cavity", re=100.0, steps=4, tag="bad")
        svc, inner = rt._routes[bad_sid]
        # poison the queued request: mis-shaped fields raise at admission
        for req in svc.farm.table.queued_items():
            if req.sid == inner:
                req.init_state = {f: np.zeros((2, 2), np.float32)
                                  for f in FIELDS}
        rt.drain()
        assert rt.poll(bad_sid)["status"] == "failed"
        bj = rt.store.get(rt.job_of(bad_sid))
        assert bj.status == jobs.FAILED and bj.error
        assert rt.store.get(rt.job_of(good)).status == jobs.DONE

    def test_evict_readmit_via_store_is_bitwise(self, tmp_path):
        def run(store, interrupt):
            rt = api.runtime(n=N, n_slots=1, store=store, **KW)
            sid = rt.submit("cavity", re=140.0, steps=10)
            if interrupt:
                rt.services()[0].run(4)
                assert rt.evict(sid)
                jid = rt.job_of(sid)
                snap = rt.store.latest_snapshot(jid, "evict")
                assert snap["steps_done"] == 4
                assert set(FIELDS) <= set(snap["fields"])
                assert rt.store.get(jid).status == jobs.EVICTED
            return rt.drain()[sid]

        smooth = run(None, interrupt=False)
        bumpy = run(str(tmp_path / "jobs.sqlite"), interrupt=True)
        assert bumpy.steps_done == smooth.steps_done == 10
        for f in FIELDS:
            np.testing.assert_array_equal(bumpy.state[f], smooth.state[f])

    def test_flight_record_registered_and_resolves_from_fresh_process(
            self, tmp_path):
        store_path = str(tmp_path / "jobs.sqlite")
        rt = api.runtime(n=N, n_slots=2, check_every=8, health=True,
                         ckpt_dir=str(tmp_path / "ck"),
                         store=store_path, **KW)
        ok = rt.submit("cavity", re=100.0, steps=16, tag="ok")
        bad = rt.submit("cavity", re=100.0, steps=16, dt=50.0, tag="poison")
        rt.drain()
        assert rt.poll(bad)["status"] == "diverged"
        jid = rt.job_of(bad)
        job = rt.store.get(jid)
        assert job.status == jobs.DIVERGED and "flight record" in job.error
        assert rt.store.get(rt.job_of(ok)).status == jobs.DONE
        # a FRESH runtime on the same store — the recording farm is gone,
        # sids were reassigned — still resolves the flight record
        rt2 = api.runtime(n=N, n_slots=2, store=store_path, **KW)
        rec = rt2.flight_record(jid)
        assert {"frames", "state", "meta"} <= set(rec)
        assert rec["meta"]["tag"] == "poison"
        # and pruning removes the registered flight dir with the job
        snap = rt2.store.latest_snapshot(jid, "flight")
        flight_dir = os.path.join(snap["dir"], f"step_{snap['step_key']:08d}")
        assert os.path.isdir(flight_dir)
        rt2.store.prune_terminal(0.0)
        assert not os.path.isdir(flight_dir)
        with pytest.raises(KeyError):
            rt2.flight_record(jid)


# ---------------------------------------------------------------------------
# SIGKILL resume battery (subprocess)
# ---------------------------------------------------------------------------
_KILL_SCRIPT = textwrap.dedent("""\
    import os, signal
    from repro import api

    rt = api.runtime(n={n}, n_slots=2, jacobi_iters=8,
                     store={{"path": {store!r}, "ttl_s": 1.0}})
    sids = [rt.submit("cavity", re=re, steps=12, tag=tag)
            for re, tag in ((80.0, "a"), (160.0, "b"), (240.0, "c"))]
    rt.enqueue("cavity", re=320.0, steps=12, tag="d")
    svc = rt.services()[0]
    svc.run(4)                     # a, b at step 4; c queued; d detached
    assert rt.evict(sids[0])       # a spills a durable resume pointer
    svc.run(2)                     # b keeps going; c admitted into a's slot
    print("READY", flush=True)
    os.kill(os.getpid(), signal.SIGKILL)
""")


class TestSigkillResume:
    @pytest.fixture(scope="class")
    def killed_store(self, tmp_path_factory):
        """A job store orphaned by a SIGKILLed farm process: one evicted
        sim with a snapshot, two mid-run (their in-memory progress dies
        with the process), one detached enqueue."""
        tmp = tmp_path_factory.mktemp("kill")
        store_path = str(tmp / "jobs.sqlite")
        env = dict(os.environ)
        env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [sys.executable, "-c",
             _KILL_SCRIPT.format(n=N, store=store_path)],
            env=env, cwd=os.path.dirname(os.path.dirname(__file__)),
            capture_output=True, text=True, timeout=600)
        assert "READY" in proc.stdout, proc.stderr
        assert proc.returncode == -signal.SIGKILL
        return store_path

    def test_store_shows_the_orphaned_state(self, killed_store):
        st = JobStore(killed_store)
        by_tag = {j.tag: j for j in st.jobs()}
        assert by_tag["a"].status == jobs.EVICTED
        assert st.latest_snapshot(by_tag["a"].job_id)["steps_done"] == 4
        assert by_tag["b"].status == jobs.RUNNING
        assert by_tag["c"].status == jobs.RUNNING   # took a's freed slot
        assert by_tag["d"].status == jobs.QUEUED
        assert st.lease_of(by_tag["d"].job_id) is None   # detached enqueue

    def test_restart_resumes_incomplete_first_and_matches_bitwise(
            self, killed_store):
        time.sleep(1.2)            # let the dead process's leases expire
        st_probe = JobStore(killed_store)
        jobs_by_tag = {j.tag: j.job_id for j in st_probe.jobs()}
        seq0 = st_probe.last_seq()

        rt = api.runtime(n=N, n_slots=2, telemetry=True,
                         store={"path": killed_store, "ttl_s": 30.0}, **KW)
        # __init__ already ran recover(): incomplete (a, b, c) are
        # claimed BEFORE any queued work
        incomplete = {jobs_by_tag[t] for t in ("a", "b", "c")}
        assert incomplete <= rt._jobs_local
        assert jobs_by_tag["d"] not in rt._jobs_local
        rt.drain()

        st = rt.store
        assert st.counts()[jobs.DONE] == 4 and st.queue_depth() == 0
        # resume-first ordering, from the audit log: every claim of an
        # incomplete job precedes every claim of a queued one
        claims = [e for e in st.events(after_seq=seq0)
                  if e["event"] in ("claim", "takeover")
                  and e["owner"] == st.owner]
        seq_of = {e["job_id"]: e["seq"] for e in claims}
        assert max(seq_of[j] for j in incomplete) < \
            seq_of[jobs_by_tag["d"]]
        # the dead owner's leases were taken over, and it shows in metrics
        assert st.takeovers >= len(incomplete)
        assert rt.telemetry.metrics.get("jobs.resumed") == 3
        assert rt.telemetry.metrics.get("jobs.lease_takeovers") == \
            st.takeovers
        # exactly one execution per job: one terminal result event each
        for tag, jid in jobs_by_tag.items():
            assert len(st.events(jid, event="result")) == 1, tag

        # bitwise parity: interrupted-and-resumed == never interrupted
        ref = api.runtime(n=N, n_slots=2, **KW)
        ref_sids = {tag: ref.submit("cavity", re=re, steps=12, tag=tag)
                    for re, tag in ((80.0, "a"), (160.0, "b"),
                                    (240.0, "c"), (320.0, "d"))}
        ref_res = ref.drain()
        for tag, jid in jobs_by_tag.items():
            final = st.load_result(jid)
            expect = ref_res[ref_sids[tag]].state
            for f in FIELDS:
                np.testing.assert_array_equal(
                    final[f], np.asarray(expect[f]),
                    err_msg=f"job {tag} field {f}")


# ---------------------------------------------------------------------------
# two workers, one queue
# ---------------------------------------------------------------------------
class TestTwoWorkers:
    def test_shared_queue_drains_without_double_execution(self, tmp_path):
        path = str(tmp_path / "jobs.sqlite")
        stA = JobStore(path, ttl_s=60.0, owner="host:1:worker-a")
        stB = JobStore(path, ttl_s=60.0, owner="host:1:worker-b")
        rtA = api.runtime(n=N, n_slots=2, store=stA, **KW)
        rtB = api.runtime(n=N, n_slots=2, store=stB, **KW)
        jids = [rtA.enqueue("cavity", re=80.0 + 40 * i, steps=6, tag=f"t{i}")
                for i in range(4)]
        sA = rtA.claim(2)
        sB = rtB.claim(2)
        assert len(sA) == 2 and len(sB) == 2
        rtA.drain()
        rtB.drain()
        st = JobStore(path, owner="host:1:auditor")
        assert st.counts()[jobs.DONE] == 4
        assert stA.takeovers == 0 and stB.takeovers == 0
        for jid in jids:
            evs = st.events(jid)
            assert len([e for e in evs if e["event"] == "result"]) == 1
            # one worker owned the whole lifecycle — no tug-of-war
            owners = {e["owner"] for e in evs
                      if e["event"] in ("claim", "admit", "result")}
            assert len(owners) == 1
            assert st.load_result(jid)["vx"].shape[:2] == (N, N)
        # claimed sets are disjoint across workers
        claimed_by = {
            w: {e["job_id"] for jid in jids for e in st.events(jid, "claim")
                if (w in e["owner"])} for w in ("worker-a", "worker-b")}
        assert not (claimed_by["worker-a"] & claimed_by["worker-b"])

    def test_ttl_takeover_from_a_dead_claimer(self, tmp_path):
        path = str(tmp_path / "jobs.sqlite")
        wstore = JobStore(path, ttl_s=60.0, owner="host:1:live")
        rt = api.runtime(n=N, n_slots=2, store=wstore, **KW)
        jid = rt.enqueue("cavity", re=110.0, steps=4, tag="stolen")
        dead = JobStore(path, ttl_s=0.4, owner="host:2:dead")
        assert len(dead.claim()) == 1      # claims, then "crashes"
        assert rt.claim() == []            # lease still live: hands off
        time.sleep(0.5)
        sids = rt.claim()
        assert len(sids) == 1
        assert wstore.takeovers == 1
        assert any(e["event"] == "takeover" and e["owner"] == wstore.owner
                   for e in wstore.events(jid))
        rt.drain()
        assert wstore.get(jid).status == jobs.DONE


# ---------------------------------------------------------------------------
# checkpointer satellites
# ---------------------------------------------------------------------------
class TestCheckpointerSatellites:
    def _plant_debris(self, d, name, age_s):
        path = os.path.join(d, name)
        os.makedirs(path)
        old = time.time() - age_s
        os.utime(path, (old, old))
        return path

    def test_startup_cleanup_is_age_guarded(self, tmp_path):
        d = str(tmp_path / "ck")
        os.makedirs(d)
        stale = self._plant_debris(d, "step_00000003.tmp-dead", 7200.0)
        fresh = self._plant_debris(d, "step_00000004.tmp-live", 1.0)
        Checkpointer(d)                     # default: sweep >1h-old debris
        assert not os.path.isdir(stale)
        assert os.path.isdir(fresh)         # a live writer's tmp survives
        Checkpointer(d, cleanup_max_age_s=None)   # opt out: no sweep
        assert os.path.isdir(fresh)

    def test_cleanup_all_and_remove(self, tmp_path):
        d = str(tmp_path / "ck")
        ck = Checkpointer(d, keep_last=0)
        self._plant_debris(d, "step_00000001.tmp-x", 1.0)
        ck.cleanup()                        # unguarded: removes everything
        assert os.listdir(d) == []
        ck.save(5, {"a": np.arange(3)}, blocking=True)
        assert ck.steps() == [5]
        assert ck.remove(5) is True
        assert ck.steps() == [] and ck.remove(5) is False
