"""Minimal stand-in for ``hypothesis`` on bare interpreters.

The tier-1 suite must collect and run without any dev dependencies beyond
pytest + jax.  When the real ``hypothesis`` is installed it is always
preferred (see conftest.py); this fallback implements just the subset the
suite uses — ``given``/``settings`` and the ``sampled_from``/``integers``/
``booleans``/``floats`` strategies — by drawing a fixed number of
deterministic pseudo-random examples, so the property tests still exercise
their shape/dtype sweeps instead of being skipped wholesale.
"""
from __future__ import annotations

import functools
import inspect
import random
import types

DEFAULT_EXAMPLES = 10


class _Strategy:
    def __init__(self, draw):
        self.draw = draw  # rng -> value


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def integers(min_value=0, max_value=2 ** 30):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def booleans():
    return _Strategy(lambda rng: rng.random() < 0.5)


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def tuples(*strats):
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strats))


def lists(elements, min_size=0, max_size=10):
    return _Strategy(lambda rng: [elements.draw(rng)
                                  for _ in range(rng.randint(min_size,
                                                             max_size))])


def sets(elements, min_size=0, max_size=10):
    def draw(rng):
        want = rng.randint(min_size, max_size)
        out = set()
        for _ in range(max(want, 1) * 20):   # bounded retries on duplicates
            if len(out) >= want:
                break
            out.add(elements.draw(rng))
        if len(out) < min_size:
            # never silently weaken a min_size contract: real hypothesis
            # would keep searching or error; a fallback must not pass a
            # property it could not actually draw
            raise ValueError(
                f"sets(min_size={min_size}) could not draw enough distinct "
                f"elements (got {len(out)}) — element domain too small?")
        return out

    return _Strategy(draw)


def builds(fn, *strats, **kwstrats):
    return _Strategy(lambda rng: fn(
        *(s.draw(rng) for s in strats),
        **{k: s.draw(rng) for k, s in kwstrats.items()}))


strategies = types.SimpleNamespace(
    sampled_from=sampled_from,
    integers=integers,
    booleans=booleans,
    floats=floats,
    tuples=tuples,
    lists=lists,
    sets=sets,
    builds=builds,
)


def settings(max_examples: int = DEFAULT_EXAMPLES, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**strats):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples",
                        getattr(fn, "_max_examples", DEFAULT_EXAMPLES))
            rng = random.Random(0)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strats.items()}
                fn(*args, **kwargs, **drawn)

        # pytest introspects the signature for fixtures: hide the drawn
        # parameters (and the __wrapped__ chain that would re-expose them)
        sig = inspect.signature(fn)
        kept = [p for name, p in sig.parameters.items() if name not in strats]
        wrapper.__signature__ = sig.replace(parameters=kept)
        del wrapper.__wrapped__
        wrapper.hypothesis_fallback = True
        return wrapper

    return deco
