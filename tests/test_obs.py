"""repro.obs: timer-nesting invariants, metrics round-trips, per-sim trace
ordering (failed sims included), Chrome-trace schema, the bench-document
schema, watchdog wiring, compile-cache scoping — and the frozen contract
that telemetry off is bitwise-invisible."""
import json
import threading

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import api, obs
from repro.cfd import cavity
from repro.sim import SimulationService, reset_compile_cache
from repro.sim.farm import compile_cache_stats

N = 12
KW = dict(jacobi_iters=8)


class _FakeClock:
    """Deterministic clock: every read advances by one tick."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        self.t += 1.0
        return self.t


# ---------------------------------------------------------------------------
# timers
# ---------------------------------------------------------------------------
class TestTimers:
    def test_nesting_accumulates(self):
        tree = obs.TimerTree(clock=_FakeClock())
        for _ in range(3):
            with tree.section("outer"):
                with tree.section("inner"):
                    pass
        snap = tree.snapshot()
        assert snap["outer"]["count"] == 3
        assert snap["outer"]["children"]["inner"]["count"] == 3
        assert snap["outer"]["children"]["inner"]["total_s"] <= \
            snap["outer"]["total_s"]

    @settings(max_examples=25)
    @given(ops=st.lists(st.integers(min_value=0, max_value=9), max_size=40))
    def test_child_totals_bounded_by_parent(self, ops):
        """Cactus timer invariant: once every section is closed, the sum
        of any node's direct children's totals never exceeds the node's
        own total (children run inside the parent's open interval)."""
        tree = obs.TimerTree(clock=_FakeClock())
        stack = []
        for op in ops:
            if op % 2 == 0 or not stack:   # open a (cycling) section name
                cm = tree.section(f"s{op % 3}")
                cm.__enter__()
                stack.append(cm)
            else:                          # close the innermost
                stack.pop().__exit__(None, None, None)
        while stack:
            stack.pop().__exit__(None, None, None)

        def check(node):
            child_sum = sum(c["total_s"] for c in node["children"].values())
            assert child_sum <= node["total_s"] + 1e-9
            for c in node["children"].values():
                check(c)

        for root in tree.snapshot().values():
            check(root)

    def test_report_renders_all_sections(self):
        tree = obs.TimerTree(clock=_FakeClock())
        with tree.section("a"), tree.section("b"):
            pass
        text = tree.report()
        assert "a" in text and "b" in text and "count" in text

    def test_threaded_sections_stay_separated(self):
        tree = obs.TimerTree()

        def work(name):
            for _ in range(50):
                with tree.section(name):
                    with tree.section(f"{name}.child"):
                        pass

        ts = [threading.Thread(target=work, args=(f"t{i}",))
              for i in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        snap = tree.snapshot()
        assert set(snap) == {f"t{i}" for i in range(4)}
        for i in range(4):
            assert snap[f"t{i}"]["count"] == 50


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------
class TestMetrics:
    def test_labeled_series_round_trip_through_json(self):
        reg = obs.Registry()
        reg.inc("farm.compile_cache", result="hit")
        reg.inc("farm.compile_cache", 2, result="miss")
        reg.set("farm.queue_depth", 3, priority=1)
        for v in (0.01, 0.2, 0.2, 5.0):
            reg.observe("latency", v, priority=0)
        snap = json.loads(reg.to_json())
        assert snap == reg.snapshot()
        assert snap["counters"]["farm.compile_cache{result=hit}"] == 1
        assert snap["counters"]["farm.compile_cache{result=miss}"] == 2
        assert snap["gauges"]["farm.queue_depth{priority=1}"] == 3.0
        h = snap["histograms"]["latency{priority=0}"]
        assert h["count"] == 4 and h["min"] == 0.01 and h["max"] == 5.0
        assert sum(n for _, n in h["buckets"]) == 4

    def test_series_key_is_label_order_insensitive(self):
        assert obs.series_key("m", {"b": 1, "a": 2}) == "m{a=2,b=1}"

    def test_histogram_percentiles(self):
        h = obs.Histogram()
        for v in [0.001] * 90 + [1.0] * 10:
            h.observe(v)
        assert h.percentile(50) <= 0.01
        assert h.percentile(99) >= 0.5

    def test_concurrent_increments_are_not_lost(self):
        reg = obs.Registry()

        def bump():
            for _ in range(1000):
                reg.inc("n")

        ts = [threading.Thread(target=bump) for _ in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert reg.get("n") == 8000


# ---------------------------------------------------------------------------
# traces: lifecycle ordering + chrome export
# ---------------------------------------------------------------------------
class TestTraces:
    @pytest.fixture(scope="class")
    def traced_farm(self):
        """A drained farm with healthy sims AND an admission failure."""
        tel = obs.telemetry()
        svc = SimulationService(cavity.config(N, **KW), n_slots=2,
                                telemetry=tel)
        sids = [svc.submit(cavity.sim_request(N, re=re, steps=s, **KW))
                for re, s in ((80.0, 8), (160.0, 12), (240.0, 6))]
        bad = cavity.sim_request(N, re=320.0, steps=5, **KW)
        bad.init_state = {"vx": np.zeros((2, 2, 2), np.float32)}
        sids.append(svc.submit(bad))
        svc.drain()
        return tel, sids

    def test_per_sim_lifecycle_ordering(self, traced_farm):
        """submit < admit < result for every sid — failed sims included;
        healthy sims additionally record first_step between them."""
        tel, sids = traced_farm
        for sid in sids:
            events = tel.trace.events_for(sid)
            seq = {e["kind"]: e["seq"] for e in events}
            assert {"submit", "admit", "result"} <= set(seq), events
            assert seq["submit"] < seq["admit"] < seq["result"]
            ts = [e["ts"] for e in events]
            assert ts == sorted(ts)

    def test_failed_sim_result_carries_error(self, traced_farm):
        tel, sids = traced_farm
        failed = [e for e in tel.trace.events
                  if e["kind"] == "result" and e.get("terminated") == "failed"]
        assert len(failed) == 1
        assert failed[0]["sid"] == sids[-1] and failed[0]["error"]

    def test_chrome_export_validates_and_spans_slots(self, traced_farm):
        tel, sids = traced_farm
        doc = obs.validate_chrome_trace(tel.trace.to_chrome())
        evs = doc["traceEvents"]
        # one residency span per admitted sim, on the slot track
        spans = [e for e in evs if e["ph"] == "X"]
        assert len(spans) == len(sids)
        assert all(e["dur"] >= 0 and e["pid"] == 2 for e in spans)
        # instants carry the sid track and the original payload
        submits = [e for e in evs if e["name"] == "submit"]
        assert {e["tid"] for e in submits} == set(sids)
        assert all("signature" in e["args"] for e in submits)

    def test_chrome_schema_rejects_malformed(self):
        with pytest.raises(ValueError, match="traceEvents"):
            obs.validate_chrome_trace({"events": []})
        with pytest.raises(ValueError, match="missing 'dur'"):
            obs.validate_chrome_trace({"traceEvents": [
                {"name": "x", "ph": "X", "ts": 0, "pid": 1, "tid": 0}]})
        with pytest.raises(ValueError, match="unknown phase"):
            obs.validate_chrome_trace({"traceEvents": [
                {"name": "x", "ph": "Z", "ts": 0, "pid": 1, "tid": 0}]})

    def test_jsonl_stream_is_line_per_event(self, tmp_path):
        path = str(tmp_path / "events.jsonl")
        tel = obs.telemetry(trace_path=path)
        tel.trace.emit("submit", sid=0, tag="t")
        tel.trace.emit("result", sid=0, terminated="steps")
        tel.trace.close()
        lines = [json.loads(line) for line in
                 open(path).read().splitlines()]
        assert [e["kind"] for e in lines] == ["submit", "result"]
        assert lines[0]["sid"] == 0


# ---------------------------------------------------------------------------
# telemetry-off is bitwise-invisible
# ---------------------------------------------------------------------------
class TestBitwiseInvisible:
    def test_farm_results_identical_on_vs_off(self):
        jobs = ((70.0, 9), (150.0, 14), (300.0, 7))

        def run(telemetry):
            rt = api.runtime(n=N, n_slots=2, telemetry=telemetry, **KW)
            sids = [rt.submit("cavity", re=re, steps=s)
                    for re, s in jobs]
            out = rt.drain()
            return [out[s] for s in sids]

        on, off = run(True), run(False)
        for a, b in zip(on, off):
            assert a.steps_done == b.steps_done
            for f in ("vx", "vy", "vz", "p"):
                np.testing.assert_array_equal(a.state[f], b.state[f])

    def test_serial_run_identical_on_vs_off(self):
        res = [api.runtime(n=N, telemetry=t, **KW).run(
            "cavity", re=120.0, steps=10) for t in (True, False)]
        for f in ("vx", "vy", "vz", "p"):
            np.testing.assert_array_equal(res[0].state[f], res[1].state[f])

    def test_off_runtime_uses_null_telemetry(self):
        rt = api.runtime(n=N, **KW)
        assert rt.telemetry is obs.NULL and not rt.telemetry.enabled
        # every hook degrades to a no-op
        with rt.telemetry.section("x"):
            pass
        rt.telemetry.metrics.inc("x")
        assert rt.telemetry.metrics.snapshot()["counters"] == {}


# ---------------------------------------------------------------------------
# farm/runtime telemetry content
# ---------------------------------------------------------------------------
class TestFarmTelemetry:
    @pytest.fixture(scope="class")
    def run_rt(self):
        rt = api.runtime(n=N, n_slots=2, telemetry=True, **KW)
        sids = [rt.submit("cavity", re=re, steps=10, priority=p)
                for re, p in ((90.0, 0), (180.0, 1), (270.0, 0))]
        rt.drain()
        return rt, sids

    def test_timers_cover_the_farm_phases(self, run_rt):
        rt, _ = run_rt
        snap = rt.telemetry.timers.snapshot()
        assert {"farm.admit", "farm.step_chunk", "farm.harvest"} <= set(snap)
        assert snap["farm.step_chunk"]["count"] >= 1
        assert "ensemble.write_slot" in snap["farm.admit"]["children"]

    def test_metrics_cover_the_farm_load(self, run_rt):
        rt, sids = run_rt
        m = rt.telemetry.metrics
        assert m.get("sim.steps_total") == 10 * len(sids)
        assert m.get("sim.results", terminated="steps") == len(sids)
        assert m.get("farm.slot_occupancy") == 0.0   # drained
        h = m.get("service.submit_to_result_seconds", priority=0)
        assert h is not None and h.count == 2
        assert m.get("service.submit_to_result_seconds", priority=1).count \
            == 1

    def test_report_is_human_readable(self, run_rt):
        rt, _ = run_rt
        text = rt.report()
        assert "repro.obs report" in text
        assert "farm.step_chunk" in text and "sim.steps_total" in text
        assert obs.report(rt.telemetry) == text

    def test_schedule_bins_are_timed_on_serial_runs(self):
        rt = api.runtime(n=N, telemetry=True, **KW)
        rt.run("cavity", re=100.0, steps=6)
        snap = rt.telemetry.timers.snapshot()
        assert "schedule.INITIAL" in snap
        evolve = snap["run.cavity"]["children"]["schedule.EVOL"]
        assert evolve["count"] == 6
        assert "ns3d_step" in evolve["children"]


# ---------------------------------------------------------------------------
# compile-cache lifecycle: scoped to the runtime's registry
# ---------------------------------------------------------------------------
class TestCompileCacheScoping:
    def test_back_to_back_runtimes_report_their_own_hits(self):
        """The satellite fix: a second runtime of the same signature sees
        ITS one cache hit, not the first runtime's miss — while the
        legacy module facade keeps accumulating process-wide."""
        reset_compile_cache()
        rt1 = api.runtime(n=N, n_slots=2, telemetry=True, **KW)
        rt1.submit("cavity", re=100.0, steps=2)
        rt1.drain()
        assert compile_cache_stats(rt1.telemetry.metrics) == {
            "hits": 0, "misses": 1, "entries": 1}
        rt2 = api.runtime(n=N, n_slots=2, telemetry=True, **KW)
        rt2.submit("cavity", re=200.0, steps=2)
        rt2.drain()
        assert compile_cache_stats(rt2.telemetry.metrics) == {
            "hits": 1, "misses": 0, "entries": 1}
        # rt1's scoped view did not absorb rt2's traffic
        assert compile_cache_stats(rt1.telemetry.metrics)["hits"] == 0
        facade = compile_cache_stats()
        assert facade["hits"] == 1 and facade["misses"] == 1

    def test_facade_reset_still_works(self):
        reset_compile_cache()
        assert compile_cache_stats() == {"hits": 0, "misses": 0,
                                         "entries": 0}


# ---------------------------------------------------------------------------
# watchdog wiring (ft.watchdog -> service)
# ---------------------------------------------------------------------------
class TestWatchdog:
    def test_stall_metric_and_trace_on_missed_deadline(self):
        """With a zero heartbeat deadline every inter-beat gap is a
        'missed deadline': the stall counter and trace event must fire."""
        tel = obs.telemetry(heartbeat_deadline_s=0.0)
        svc = SimulationService(cavity.config(N, **KW), n_slots=2,
                                telemetry=tel)
        sid = svc.submit(cavity.sim_request(N, re=100.0, steps=6, **KW))
        svc.result(sid)
        svc.poll(sid)
        assert tel.metrics.get("service.watchdog_stalls") >= 1
        assert any(e["kind"] == "watchdog_stall" for e in tel.trace.events)

    def test_no_stalls_under_generous_deadline(self):
        tel = obs.telemetry(heartbeat_deadline_s=3600.0)
        svc = SimulationService(cavity.config(N, **KW), n_slots=2,
                                telemetry=tel)
        sid = svc.submit(cavity.sim_request(N, re=100.0, steps=6, **KW))
        svc.result(sid)
        assert tel.metrics.get("service.watchdog_stalls") is None
        # but the step watchdog did observe every chunk
        assert svc.watchdog is not None and svc.watchdog.n >= 1

    def test_heartbeat_file_is_touched(self, tmp_path):
        hb = str(tmp_path / "alive")
        tel = obs.telemetry(heartbeat_path=hb, heartbeat_interval_s=0.0)
        svc = SimulationService(cavity.config(N, **KW), n_slots=1,
                                telemetry=tel)
        sid = svc.submit(cavity.sim_request(N, re=100.0, steps=3, **KW))
        svc.result(sid)
        from repro.ft.watchdog import Heartbeat

        assert Heartbeat.is_alive(hb, deadline_s=60.0)

    def test_disabled_telemetry_installs_no_watchdog(self):
        svc = SimulationService(cavity.config(N, **KW), n_slots=1)
        assert svc.watchdog is None and svc.farm.heartbeat is None


# ---------------------------------------------------------------------------
# bench document schema
# ---------------------------------------------------------------------------
class TestBenchSchema:
    def test_round_trip(self, tmp_path):
        doc = obs.make_bench_doc("ensemble_farm", {"speedup": 2.5},
                                 passed=True, wall_s=1.25)
        path = obs.write_bench(doc, str(tmp_path))
        assert path.endswith("BENCH_ensemble_farm.json")
        loaded = obs.load_bench(path)
        assert loaded["metrics"]["speedup"] == 2.5
        assert loaded["schema"] == obs.BENCH_SCHEMA
        for f in ("backend", "device_count", "python", "jax"):
            assert f in loaded["host"]

    def test_malformed_documents_are_named(self):
        good = obs.make_bench_doc("x", {}, passed=False, wall_s=0.0)
        for breakage, match in (
                ({"schema": "repro.bench.v0"}, "schema"),
                ({"bench": "Bad Name"}, "must match"),
                ({"passed": "yes"}, "passed"),
                ({"host": {"backend": "cpu"}}, "host missing"),
        ):
            with pytest.raises(ValueError, match=match):
                obs.validate_bench({**good, **breakage})
        with pytest.raises(ValueError, match="missing field"):
            obs.validate_bench({k: v for k, v in good.items()
                                if k != "metrics"})

    def test_smoke_bench_emits_valid_artifact(self, tmp_path):
        """The CI smoke lane end-to-end: run the telemetry bench, check
        the artifact on disk validates and carries the telemetry
        snapshot."""
        from benchmarks.run import run_smoke

        doc = run_smoke(str(tmp_path))
        assert doc["passed"] is True
        loaded = obs.load_bench(str(tmp_path / "BENCH_smoke.json"))
        assert loaded["bench"] == "smoke"
        assert "timers" in loaded["metrics"]["telemetry"]
        assert loaded["metrics"]["compile_cache"]["entries"] >= 1


# ---------------------------------------------------------------------------
# health: unit layer (state machine, flight records, dashboard)
# ---------------------------------------------------------------------------
def _frame(step=0, div=0.0, ke=0.1, umax=1.0, cfl=0.1, finite=1.0):
    return {"step": step, "div_linf": div, "ke": ke, "umax": umax,
            "cfl": cfl, "finite": finite}


def _row(step, div=0.0, ke=0.1, umax=1.0, cfl=0.1, finite=1.0):
    return [float(step), div, ke, umax, cfl, finite]


class TestHealthUnit:
    def test_diag_columns_pin_the_solver_contract(self):
        """obs.health and ns3d each own a copy of the diagnostics name
        tuple (the solver owes nothing to obs); this is the pin that
        keeps them from drifting apart."""
        from repro.cfd import ns3d
        from repro.obs import health

        assert health.DIAG_COLUMNS == ("step",) + ns3d.HEALTH_DIAGS
        assert health.N_DIAG == len(health.DIAG_COLUMNS)

    def test_classify_frame_thresholds(self):
        from repro.obs import health

        cfg = health.HealthConfig()
        assert health.classify_frame(_frame(), cfg) == (health.HEALTHY, "")
        assert health.classify_frame(_frame(cfl=2.5), cfg) == \
            (health.WARNING, "cfl")
        assert health.classify_frame(_frame(div=1e4), cfg) == \
            (health.WARNING, "divergence")
        assert health.classify_frame(_frame(div=1e8), cfg) == \
            (health.DIVERGED, "divergence")
        assert health.classify_frame(_frame(cfl=1e4), cfg) == \
            (health.DIVERGED, "cfl")
        assert health.classify_frame(_frame(finite=0.0), cfg) == \
            (health.NAN, "nonfinite")
        # a NaN that leaks into the diagnostics themselves is nonfinite
        assert health.classify_frame(_frame(div=float("nan")), cfg) == \
            (health.NAN, "nonfinite")

    def test_monitor_warning_recovers_but_terminal_sticks(self):
        from repro.obs import health

        mon = health.HealthMonitor(health.HealthConfig())
        mon.admit(7, slot=0, tag="t")
        assert mon.observe(7, np.array([_row(0, cfl=3.0)])).state \
            == health.WARNING
        assert mon.observe(7, np.array([_row(1)])).state == health.HEALTHY
        assert mon.observe(7, np.array([_row(2, finite=0.0)])).state \
            == health.NAN
        # terminal: later healthy frames cannot resurrect the record
        assert mon.observe(7, np.array([_row(3)])).state == health.NAN

    def test_monitor_skips_sentinels_and_stale_steps(self):
        from repro.obs import health

        mon = health.HealthMonitor(health.HealthConfig(window=4))
        mon.admit(1, slot=0)
        rec = mon.observe(1, np.array([_row(-1), _row(2), _row(0), _row(1)]))
        assert [f["step"] for f in rec.frames] == [0, 1, 2]
        # a re-drain of the same ring adds nothing
        rec = mon.observe(1, np.array([_row(2), _row(0), _row(1)]))
        assert [f["step"] for f in rec.frames] == [0, 1, 2]

    def test_monitor_emits_trace_and_metrics_on_transition(self):
        from repro.obs import health

        tel = obs.telemetry()
        mon = health.HealthMonitor(health.HealthConfig(), telemetry=tel,
                                   farm_id="f0")
        mon.admit(3, slot=1, tag="x")
        mon.observe(3, np.array([_row(0, div=1e8)]))
        evs = [e for e in tel.trace.events if e["kind"] == "health"]
        assert len(evs) == 1
        ev = evs[0]
        assert ev["sid"] == 3 and ev["farm"] == "f0" and ev["slot"] == 1
        assert ev["state"] == "diverged" and ev["from"] == "healthy"
        assert ev["cause"] == "divergence" and ev["frame"]["step"] == 0
        assert tel.metrics.get("health.events", state="diverged",
                               cause="divergence") == 1

    def test_mark_shares_the_event_schema(self):
        from repro.obs import health

        tel = obs.telemetry()
        mon = health.HealthMonitor(health.HealthConfig(), telemetry=tel)
        mon.admit(5, slot=0)
        mon.mark(5, health.WARNING, cause="watchdog_stall", gap_s=1.5)
        ev = [e for e in tel.trace.events if e["kind"] == "health"][0]
        assert ev["state"] == "warning" and ev["cause"] == "watchdog_stall"
        assert ev["gap_s"] == 1.5
        assert mon.state_of(5) == health.WARNING

    def test_registry_remove_drops_the_series(self):
        reg = obs.Registry()
        reg.set("health.sim_state", 2.0, sid=9)
        reg.inc("health.frames")
        assert reg.remove("health.sim_state", sid=9) is True
        assert reg.get("health.sim_state", sid=9) is None
        assert reg.remove("health.sim_state", sid=9) is False
        assert reg.get("health.frames") == 1   # other series untouched

    def test_flight_record_round_trip(self, tmp_path):
        from repro.obs import health

        fr = health.FlightRecorder(str(tmp_path))
        frames = np.arange(18, dtype=np.float32).reshape(3, 6)
        state = {"vx": np.ones((2, 3, 4), np.float32),
                 "p": np.zeros((2, 3, 4), np.float32)}
        path = fr.record(11, frames=frames, state=state,
                         meta={"cause": "cfl", "tag": "poison"})
        assert path.endswith("step_00000011")
        rec = health.load_flight_record(str(tmp_path), 11)
        np.testing.assert_array_equal(rec["frames"], frames)
        assert set(rec["state"]) == {"vx", "p"}
        np.testing.assert_array_equal(rec["state"]["vx"], state["vx"])
        assert rec["meta"]["cause"] == "cfl"
        assert rec["meta"]["columns"] == list(health.DIAG_COLUMNS)

    def test_resolve_health_specs(self):
        from repro.obs import health

        assert health.resolve_health(None) is None
        assert health.resolve_health(False) is None
        assert health.resolve_health(True) == health.HealthConfig()
        cfg = health.HealthConfig(window=4)
        assert health.resolve_health(cfg) is cfg
        assert health.resolve_health({"cfl_warn": 5.0}).cfl_warn == 5.0
        with pytest.raises(TypeError):
            health.resolve_health(42)


# ---------------------------------------------------------------------------
# health: NaN-injection battery (quarantine, flight record, bitwise twins)
# ---------------------------------------------------------------------------
HEALTH_JOBS = ((80.0, "h0"), (150.0, "h1"), (240.0, "h2"))


def _health_runtime(ckpt_dir, telemetry=True):
    return api.runtime(n=N, n_slots=4, check_every=8, ckpt_dir=ckpt_dir,
                       health=True, telemetry=telemetry, **KW)


def _submit_healthy(rt):
    return [rt.submit("cavity", re=re, steps=24, tag=tag)
            for re, tag in HEALTH_JOBS]


class TestHealthQuarantine:
    @pytest.fixture(scope="class")
    def quarantine_run(self, tmp_path_factory):
        """A drained health-monitored farm: 3 healthy cavity sims plus
        one poisoned with a huge dt (slot-parameterized, so no separate
        compile) that blows past the CFL-diverged threshold."""
        tmp = str(tmp_path_factory.mktemp("health"))
        rt = _health_runtime(tmp)
        healthy = _submit_healthy(rt)
        bad = rt.submit("cavity", re=100.0, steps=24, dt=50.0, tag="poison")
        res = rt.drain()
        return rt, healthy, bad, res, tmp

    def test_poisoned_slot_quarantines(self, quarantine_run):
        rt, healthy, bad, res, _ = quarantine_run
        r = res[bad]
        assert r.terminated == "diverged"
        assert r.steps_done < 24
        assert "health: " in r.error and "flight record" in r.error
        assert rt.poll(bad)["status"] == "diverged"
        for sid in healthy:
            assert res[sid].terminated == "steps"
            assert res[sid].steps_done == 24

    def test_flight_record_is_readable_post_mortem(self, quarantine_run):
        from repro.obs import health

        rt, _, bad, res, tmp = quarantine_run
        inner = rt._routes[bad][1]
        rec = health.load_flight_record(f"{tmp}/flight", inner)
        frames = rec["frames"]
        assert frames.shape[1] == health.N_DIAG
        assert 1 <= frames.shape[0] <= health.HealthConfig().window
        # the recorded tail must contain the killing frame
        cfl = frames[:, health.DIAG_COLUMNS.index("cfl")]
        finite = frames[:, health.DIAG_COLUMNS.index("finite")]
        assert (cfl[np.isfinite(cfl)] >= 1e3).any() or (finite < 0.5).any()
        assert {"vx", "vy", "vz", "p"} <= set(rec["state"])
        meta = rec["meta"]
        assert meta["state"] in ("diverged", "nan") and meta["cause"]
        assert meta["tag"] == "poison" and "thresholds" in meta

    def test_healthy_slots_bitwise_vs_never_admitted(self, quarantine_run,
                                                     tmp_path):
        """The quarantine isolation contract: slots that shared a farm
        with the poisoned sim finish bitwise-identical to a farm that
        never admitted it (same slot assignment: healthy submitted
        first)."""
        _, healthy, _, res, _ = quarantine_run
        rt2 = _health_runtime(str(tmp_path))
        twins = _submit_healthy(rt2)
        res2 = rt2.drain()
        for a, b in zip(healthy, twins):
            for f in ("vx", "vy", "vz", "p"):
                np.testing.assert_array_equal(res[a].state[f],
                                              res2[b].state[f])

    def test_zero_extra_host_syncs_on_harvest_cadence(self, quarantine_run):
        """The perf pin: ring drains ride the existing
        check_steady_every boundary — drains == boundaries crossed, and
        the farm cost row books exactly that."""
        from repro.obs import perf

        rt, _, _, _, _ = quarantine_run
        svc = next(iter(rt._services.values()))
        boundaries = svc.farm.device_steps // svc.farm.check_steady_every
        assert svc.farm.device_steps % svc.farm.check_steady_every == 0
        assert rt.telemetry.metrics.get("health.drains") == boundaries
        timers = rt.telemetry.timers.snapshot()
        drain_s, drain_n = perf._find_sections(timers, "farm.health_drain")
        assert drain_n == boundaries
        row = perf.farm_cost_row(svc)
        assert row.health_drains == boundaries
        assert row.health_boundaries == boundaries
        rendered = perf.PerfReport([row]).render()
        assert "extra host syncs: 0" in rendered

    def test_health_events_join_the_trace(self, quarantine_run):
        rt, _, bad, _, _ = quarantine_run
        inner = rt._routes[bad][1]
        evs = rt.telemetry.trace.events_for(inner)
        kinds = [e["kind"] for e in evs]
        assert "health" in kinds and "result" in kinds
        health_ev = next(e for e in evs if e["kind"] == "health")
        assert health_ev["state"] in ("diverged", "nan")
        result_ev = next(e for e in evs if e["kind"] == "result")
        assert result_ev["terminated"] == "diverged"
        assert rt.telemetry.metrics.get("health.quarantines") == 1
        assert rt.telemetry.metrics.get(
            "sim.results", terminated="diverged") == 1

    def test_chrome_export_puts_health_on_its_own_track(self, quarantine_run):
        rt, _, _, _, _ = quarantine_run
        doc = obs.validate_chrome_trace(rt.telemetry.trace.to_chrome())
        evs = doc["traceEvents"]
        health_evs = [e for e in evs if e["ph"] == "i"
                      and e["name"] == "health"]
        assert health_evs and all(e["pid"] == 3 for e in health_evs)
        assert any(e.get("args", {}).get("name") == "health"
                   for e in evs if e["ph"] == "M")
        # the quarantined sim still closes a residency span on the slot
        # track — 4 admissions, 4 spans
        assert len([e for e in evs if e["ph"] == "X"]) == 4

    def test_prometheus_exposes_health_series(self, quarantine_run):
        rt, _, _, _, _ = quarantine_run
        svc = next(iter(rt._services.values()))
        text = svc.prometheus_text()
        assert "repro_health_quarantines 1" in text
        assert "repro_health_drains" in text
        assert 'repro_health_sims{state="healthy"}' in text
        assert 'repro_health_events{' in text

    def test_watch_renders_the_dashboard(self, quarantine_run):
        rt, _, _, _, _ = quarantine_run
        text = rt.watch()
        assert "== repro health ==" in text
        assert "slot" in text and "free" in text   # drained farm

    def test_quarantine_works_with_telemetry_off(self, quarantine_run,
                                                 tmp_path):
        """Health is functional, not telemetry: with telemetry off the
        quarantine still fires, the flight record still lands, and the
        healthy trajectories are bitwise the telemetry-on ones."""
        from repro.obs import health

        _, healthy, _, res_on, _ = quarantine_run
        rt = _health_runtime(str(tmp_path), telemetry=False)
        assert rt.telemetry is obs.NULL
        twins = _submit_healthy(rt)
        bad = rt.submit("cavity", re=100.0, steps=24, dt=50.0, tag="poison")
        res = rt.drain()
        assert res[bad].terminated == "diverged"
        rec = health.load_flight_record(f"{tmp_path}/flight",
                                        rt._routes[bad][1])
        assert rec["meta"]["tag"] == "poison"
        for a, b in zip(healthy, twins):
            for f in ("vx", "vy", "vz", "p"):
                np.testing.assert_array_equal(res_on[a].state[f],
                                              res[b].state[f])

    def test_poll_streams_the_latest_frame_while_running(self):
        svc = SimulationService(cavity.config(N, **KW), n_slots=1,
                                check_steady_every=4, telemetry=True,
                                health=True)
        sid = svc.submit(cavity.sim_request(N, re=100.0, steps=12, **KW))
        svc.run(4)
        out = svc.poll(sid)
        assert out["status"] == "running" and out["steps_done"] == 4
        h = out["health"]
        assert h["state"] == "healthy" and h["step"] == 3
        assert all(np.isfinite(h[c]) for c in ("div_linf", "ke", "cfl"))
        from repro.obs.health import render_dashboard

        text = render_dashboard([svc.farm.health_snapshot()])
        assert "ok" in text and "cavity" in text
        svc.drain()

    def test_watchdog_stall_marks_resident_sims_warning(self):
        """Satellite: a watchdog stall speaks the health vocabulary —
        resident sims go ``warning`` with the same kind="health" trace
        schema as quarantine (and recover on the next healthy drain)."""
        tel = obs.telemetry(heartbeat_deadline_s=0.0)
        svc = SimulationService(cavity.config(N, **KW), n_slots=2,
                                check_steady_every=2, telemetry=tel,
                                health=True)
        sid = svc.submit(cavity.sim_request(N, re=100.0, steps=6, **KW))
        svc.result(sid)
        evs = [e for e in tel.trace.events if e["kind"] == "health"
               and e["cause"] == "watchdog_stall"]
        assert evs and evs[0]["state"] == "warning" and "gap_s" in evs[0]
        # the sim recovered and finished: warning -> healthy also traced
        recoveries = [e for e in tel.trace.events if e["kind"] == "health"
                      and e["state"] == "healthy" and e["from"] == "warning"]
        assert recoveries

    def test_health_off_runs_the_pre_health_executable(self):
        """health=False compiles the exact PR-8 step signature: no ring,
        no step counter, no monitor — and drain results match a
        health-on farm bitwise (diagnostics are read-only)."""
        def run(health):
            rt = api.runtime(n=N, n_slots=2, health=health, **KW)
            sids = [rt.submit("cavity", re=re, steps=10)
                    for re, _ in HEALTH_JOBS[:2]]
            out = rt.drain()
            svc = next(iter(rt._services.values()))
            return [out[s] for s in sids], svc.farm.exec

        off, ex_off = run(False)
        on, ex_on = run(True)
        assert ex_off.health_ring is None and ex_off.health_window == 0
        assert ex_on.health_ring is not None
        assert len(ex_off.step_args(1)) == 3
        assert len(ex_on.step_args(1)) == 4
        for a, b in zip(off, on):
            for f in ("vx", "vy", "vz", "p"):
                np.testing.assert_array_equal(a.state[f], b.state[f])


# ---------------------------------------------------------------------------
# telemetry resolution
# ---------------------------------------------------------------------------
class TestResolve:
    def test_specs(self):
        assert obs.resolve(None) is obs.NULL
        assert obs.resolve(False) is obs.NULL
        assert obs.resolve(True).enabled
        tel = obs.telemetry()
        assert obs.resolve(tel) is tel
        assert obs.resolve({"named_scopes": False}).config.named_scopes \
            is False
        assert obs.resolve(obs.TelemetryConfig(enabled=False)) is obs.NULL
        with pytest.raises(TypeError):
            obs.resolve(42)
