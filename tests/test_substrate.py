"""Substrate-layer tests: data pipeline, checkpointer (incl. kill-resume),
watchdog, optimizer, sharding rules, serving engine, compression."""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, smoke
from repro.data.pipeline import DataConfig, PackedLMDataset, Prefetcher
from repro.ft.watchdog import Heartbeat, StepWatchdog
from repro.models import model
from repro.optim.adamw import AdamW, global_norm
from repro.optim.schedules import warmup_cosine

from tests.helpers import run_with_devices


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_data_deterministic_and_sharded():
    cfg = DataConfig(seed=7, vocab_size=997, seq_len=64, global_batch=8)
    ds = PackedLMDataset(cfg)
    b1 = ds.batch(3)
    b2 = ds.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # shard slices tile the global batch exactly
    s0 = ds.batch(3, shard_idx=0, num_shards=2)
    s1 = ds.batch(3, shard_idx=1, num_shards=2)
    np.testing.assert_array_equal(
        np.concatenate([s0["tokens"], s1["tokens"]]), b1["tokens"])
    assert b1["tokens"].dtype == np.int32
    assert (b1["tokens"] < cfg.vocab_size).all()
    # document-boundary masking exists
    assert (b1["targets"] == -1).sum() >= 0


def test_data_stream_has_structure():
    """Consecutive tokens carry mutual information — the stream is
    learnable (convergence tests need signal).  Structure is conditional
    (per-Markov-state Zipf), so bigram MI is the right probe."""
    v = 64
    cfg = DataConfig(seed=0, vocab_size=v, seq_len=512, global_batch=8,
                     n_states=8)
    ds = PackedLMDataset(cfg)
    toks = np.concatenate([ds.batch(i)["tokens"].reshape(-1)
                           for i in range(8)])
    joint = np.zeros((v, v))
    np.add.at(joint, (toks[:-1], toks[1:]), 1.0)
    joint /= joint.sum()
    px = joint.sum(1, keepdims=True)
    py = joint.sum(0, keepdims=True)
    nz = joint > 0
    mi = (joint[nz] * np.log(joint[nz] / (px @ py)[nz])).sum()
    assert mi > 0.2, mi  # nats; ~0 for an i.i.d. stream


def test_prefetcher():
    cfg = DataConfig(seed=1, vocab_size=128, seq_len=32, global_batch=2)
    ds = PackedLMDataset(cfg)
    it = Prefetcher(ds.iterate(), depth=2)
    a = next(it)
    b = next(it)
    assert a["tokens"].shape == (2, 32)
    assert not np.array_equal(a["tokens"], b["tokens"])
    it.close()


# ---------------------------------------------------------------------------
# checkpointer
# ---------------------------------------------------------------------------
def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (4, 8)),
            "nested": {"b": jax.random.normal(k2, (3,)),
                       "step": jnp.ones((), jnp.int32) * 7}}


def test_checkpoint_roundtrip_and_retention(tmp_path):
    from repro.ckpt.checkpointer import Checkpointer

    ck = Checkpointer(str(tmp_path), keep_last=2)
    t0 = _tree(jax.random.PRNGKey(0))
    for s in (10, 20, 30):
        ck.save(s, t0)
    assert ck.steps() == [20, 30]  # retention pruned step 10
    restored = ck.restore(30, jax.tree.map(jnp.zeros_like, t0))
    for x, y in zip(jax.tree.leaves(t0), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y))


def test_checkpoint_async_and_cleanup(tmp_path):
    from repro.ckpt.checkpointer import Checkpointer

    ck = Checkpointer(str(tmp_path))
    ck.save_async(5, _tree(jax.random.PRNGKey(1)))
    ck.wait()
    assert ck.latest_step() == 5
    # interrupted write debris is removed
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp-dead"))
    ck.cleanup()
    assert not any(".tmp-" in n for n in os.listdir(str(tmp_path)))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    from repro.ckpt.checkpointer import Checkpointer

    ck = Checkpointer(str(tmp_path))
    ck.save(1, {"a": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ck.restore(1, {"a": jnp.zeros((5,))})


def test_checkpoint_single_sharding_broadcasts(tmp_path):
    """A lone Sharding broadcasts to every leaf (the simulation farm
    scatters one slot's fields this way); a mis-sized shardings tree is
    an error, never a silent zip-truncation that restores one leaf."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.ckpt.checkpointer import Checkpointer
    from repro.launch.mesh import make_mesh

    ck = Checkpointer(str(tmp_path))
    tree = {"a": jnp.arange(4.0), "b": {"c": jnp.arange(6.0)}}
    ck.save(1, tree)
    sh = NamedSharding(make_mesh((1,), ("shard",)), P())
    restored = ck.restore(1, jax.tree.map(jnp.zeros_like, tree),
                          shardings=sh)
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert y.sharding == sh
    with pytest.raises(ValueError, match="shardings has 1"):
        ck.restore(1, jax.tree.map(jnp.zeros_like, tree),
                   shardings={"a": sh})


def test_kill_resume_end_to_end(tmp_path):
    """Kill a training run mid-flight; resume must continue from the last
    checkpoint with identical data order (the node-failure drill)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(os.path.dirname(
                   os.path.abspath(__file__))), "src"))
    args = [sys.executable, "-m", "repro.launch.train", "--arch",
            "llama3-8b", "--smoke", "--batch", "2", "--seq", "64",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
            "--log-every", "1"]
    # phase 1: run 12 steps (checkpoints at 5, 10)
    p1 = subprocess.run(args + ["--steps", "12"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert p1.returncode == 0, p1.stderr[-2000:]
    # phase 2: "restart" to 15 steps -> resumes from step 10
    p2 = subprocess.run(args + ["--steps", "15"], env=env,
                        capture_output=True, text=True, timeout=600)
    assert p2.returncode == 0, p2.stderr[-2000:]
    assert "resumed from step 10" in p2.stdout, p2.stdout


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------
def test_watchdog_flags_stragglers():
    wd = StepWatchdog(warmup_steps=3, slow_factor=1.5, hang_factor=5.0,
                      checkpoint_after_slow=2)
    for i in range(6):
        wd.observe(i, 1.0)
    ev = wd.observe(6, 2.0)          # 2x > 1.5x -> slow
    assert [e.kind for e in ev] == ["slow_step"]
    ev = wd.observe(7, 2.5)          # second consecutive -> ckpt request
    kinds = [e.kind for e in ev]
    assert "slow_step" in kinds and "checkpoint_requested" in kinds
    ev = wd.observe(8, 30.0)         # way past hang threshold
    assert [e.kind for e in ev] == ["hang"]
    assert wd.should_checkpoint


def test_heartbeat(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb"), interval_s=0.0)
    hb.beat()
    assert Heartbeat.is_alive(str(tmp_path / "hb"), deadline_s=60)
    assert not Heartbeat.is_alive(str(tmp_path / "nope"), deadline_s=60)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_descends_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_adamw_clip_and_bf16_moments():
    opt = AdamW(lr=1e-2, clip_norm=1.0, m_dtype=jnp.bfloat16,
                v_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((8,))}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.full((8,), 100.0)}
    _, state2, stats = opt.update(grads, state, params)
    np.testing.assert_allclose(float(stats["clip_scale"]),
                               1.0 / float(global_norm(grads)), rtol=1e-5)


def test_warmup_cosine_schedule():
    lr = warmup_cosine(1.0, 10, 100, final_frac=0.1)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(100))) == pytest.approx(0.1, abs=1e-6)
    assert float(lr(jnp.int32(55))) < 1.0


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_param_specs_divisibility_guard():
    """Rules only shard divisible dims (kv_heads=8 vs model=16 stays
    replicated; ff/vocab shard)."""
    script = """
import jax
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import model

mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_config("llama3-8b")
shard = shd.make_shard_cfg(mesh, cfg, global_batch=8)
shapes = jax.eval_shape(lambda: model.init_params(cfg, jax.random.PRNGKey(0)))
specs = shd.param_spec_tree(shapes, cfg, mesh, shard)
stack = specs["stack"]["layers"]
# wq (L, d, H=32, hd): heads shard over model=4
assert stack["attn"]["wq"] == P(None, "data", "model", None), stack["attn"]["wq"]
# wk (L, d, KH=8, hd): 8 % 4 == 0 -> sharded here
assert stack["attn"]["wk"] == P(None, "data", "model", None)
# mlp down (L, ff, d): TP on ff
assert stack["ffn"]["down"]["w"] == P(None, "model", "data")
# embedding (V, d): vocab-parallel
assert specs["embed"]["table"] == P("model", "data")
print("SPEC OK")
"""
    out = run_with_devices(script, n_devices=8)
    assert "SPEC OK" in out


@pytest.mark.multidevice
def test_cache_specs_seq_sharded():
    script = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs.registry import get_config
from repro.dist import sharding as shd
from repro.launch.mesh import make_mesh
from repro.models import model

mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_config("llama3-8b")
shard = shd.make_shard_cfg(mesh, cfg, global_batch=8)
shapes = jax.eval_shape(lambda: model.init_caches(cfg, 8, 1024, jnp.bfloat16))
specs = shd.cache_spec_tree(shapes, cfg, mesh, shard)
# KV (L, B, S, KH, D): batch over data, SEQ over model (flash-decode)
assert specs.k == P(None, "data", "model", None, None), specs.k
print("CACHE OK")
"""
    out = run_with_devices(script, n_devices=8)
    assert "CACHE OK" in out


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["llama3-8b", "zamba2-1.2b", "xlstm-125m"])
def test_engine_continuous_batching(arch):
    from repro.serve.engine import Request, ServingEngine

    cfg = smoke(get_config(arch))
    params = model.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, slots=2, max_seq=96)
    rng = np.random.default_rng(0)
    for rid in range(5):
        eng.submit(Request(
            rid, rng.integers(0, cfg.vocab_size,
                              size=int(rng.integers(4, 30))).astype(np.int32),
            max_new_tokens=6))
    done = eng.run_until_drained(max_steps=300)
    assert len(done) == 5
    assert all(len(r.output) == 6 for r in done)
    # slots were reused: 5 requests > 2 slots but steps < 5 * 6
    assert eng.steps < 30


def test_engine_matches_unbatched_decode():
    """Continuous-batching output == single-request greedy decode."""
    from repro.serve.engine import Request, ServingEngine

    cfg = smoke(get_config("llama3-8b"))
    params = model.init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 11, 19)]

    # reference: one-at-a-time greedy decode via prefill+decode_step
    def ref_decode(prompt, n_new):
        from repro.models.config import LOCAL
        caches = model.init_caches(cfg, 1, 96, jnp.float32)
        toks = jnp.asarray(prompt)[None]
        logits, caches = model.prefill(params, cfg, {"tokens": toks}, caches,
                                       LOCAL)
        out = []
        t = len(prompt)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        for _ in range(n_new - 1):
            lg, caches = model.decode_step(
                params, cfg, jnp.asarray([[nxt]], jnp.int32), caches,
                jnp.int32(t), LOCAL)
            t += 1
            nxt = int(jnp.argmax(lg[0, -1]))
            out.append(nxt)
        return out

    eng = ServingEngine(cfg, params, slots=2, max_seq=96)
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid, p, max_new_tokens=5))
    done = {r.rid: r.output for r in eng.run_until_drained(max_steps=100)}
    for rid, p in enumerate(prompts):
        assert done[rid] == ref_decode(p, 5), (rid, done[rid])


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
def test_int8_quantize_error_feedback():
    from repro.dist.compression import dequantize_int8, quantize_int8

    g = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
    q, scale, err = quantize_int8(g)
    deq = dequantize_int8(q, scale, g.shape)
    np.testing.assert_allclose(np.asarray(deq + err), np.asarray(g),
                               rtol=0, atol=1e-5)
    # quantization error is small relative to signal
    rel = float(jnp.linalg.norm(err) / jnp.linalg.norm(g))
    assert rel < 0.02, rel


@pytest.mark.multidevice
def test_ef_allreduce_multidevice():
    script = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.compression import ef_allreduce_mean
from repro.launch.mesh import make_mesh

mesh = make_mesh((4,), ("pod",))
g = jax.random.normal(jax.random.PRNGKey(0), (4, 512))  # per-pod grads
err = jnp.zeros((4, 512))

def local(g_l, e_l):
    gm, ne = ef_allreduce_mean(g_l[0], e_l[0], "pod")
    return gm[None], ne[None]

fn = jax.shard_map(local, mesh=mesh, in_specs=(P("pod"), P("pod")),
                   out_specs=(P("pod"), P("pod")), check_vma=False)
gm, ne = fn(g, err)
exact = g.mean(0)
# every pod sees (approximately) the mean; EF bounds the residual
for i in range(4):
    rel = float(jnp.linalg.norm(gm[i] - exact) / jnp.linalg.norm(exact))
    assert rel < 0.05, rel
print("EF OK")
"""
    out = run_with_devices(script, n_devices=4)
    assert "EF OK" in out


# ---------------------------------------------------------------------------
# pipeline parallelism
# ---------------------------------------------------------------------------
@pytest.mark.multidevice
def test_gpipe_forward_matches_sequential():
    script = """
import jax, jax.numpy as jnp, numpy as np
from repro.dist.pipeline_parallel import gpipe_forward, stage_params
from repro.launch.mesh import make_mesh
from repro.models.config import ShardCfg

mesh = make_mesh((4,), ("pod",))
L, B, S, D = 8, 8, 16, 32
key = jax.random.PRNGKey(0)
ws = jax.random.normal(key, (L, D, D)) / np.sqrt(D)
x = jax.random.normal(jax.random.fold_in(key, 1), (B, S, D))

def apply_layer(w, x):
    return jnp.tanh(x @ w)

# sequential reference
ref = x
for i in range(L):
    ref = apply_layer(ws[i], ref)

from repro.models.config import ModelConfig
cfg = ModelConfig(name="t", family="dense", num_layers=L, d_model=D,
                  num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=128)
out = gpipe_forward(cfg, mesh, apply_layer, ws, x, n_microbatch=4)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                           atol=2e-5)
print("GPIPE OK")
"""
    out = run_with_devices(script, n_devices=4)
    assert "GPIPE OK" in out


@pytest.mark.multidevice
def test_checkpoint_elastic_reshard():
    """Save from one mesh, restore onto a DIFFERENT mesh/sharding (the
    N->M elastic restart): values must round-trip exactly."""
    script = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.ckpt.checkpointer import Checkpointer
from repro.launch.mesh import make_mesh
import tempfile, os

tmp = tempfile.mkdtemp()
mesh_a = make_mesh((2, 4), ("data", "model"))
mesh_b = make_mesh((4, 2), ("data", "model"))
x = jax.random.normal(jax.random.PRNGKey(0), (16, 32))
tree = {"w": jax.device_put(x, NamedSharding(mesh_a, P("data", "model"))),
        "b": jax.device_put(jnp.arange(8.0),
                            NamedSharding(mesh_a, P("model")))}
ck = Checkpointer(tmp)
ck.save(3, tree)
target = jax.tree.map(jnp.zeros_like, tree)
shardings = {"w": NamedSharding(mesh_b, P("model", "data")),
             "b": NamedSharding(mesh_b, P("data"))}
restored = ck.restore(3, target, shardings)
np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(x))
np.testing.assert_array_equal(np.asarray(restored["b"]),
                              np.arange(8.0, dtype=np.float32))
assert restored["w"].sharding.spec == P("model", "data")
print("ELASTIC OK")
"""
    out = run_with_devices(script, n_devices=8)
    assert "ELASTIC OK" in out
