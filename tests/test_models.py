"""Per-arch smoke tests (deliverable f) + decode consistency + model unit
tests.

Every assigned architecture instantiates a REDUCED config of the same
family (registry.smoke) and runs forward/train/prefill/decode on CPU,
asserting output shapes and finiteness.  Decode consistency is the strong
cache-correctness check: prefill + step-by-step decode must reproduce the
teacher-forced forward logits exactly (same fp32 math, different dataflow).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, smoke
from repro.configs.shapes import SHAPES, applicable
from repro.models import layers, model, multimodal, transformer
from repro.models.attention import MaskSpec
from repro.models.config import LOCAL

B, S, K = 2, 24, 3


def _cfg(name):
    cfg = smoke(get_config(name))
    if cfg.num_experts:  # no-drop capacity: deterministic across token counts
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return cfg


def _batch(cfg, key, seq, with_targets=True):
    kt, kg, ke = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(kt, (B, seq), 0, cfg.vocab_size)}
    if with_targets:
        batch["targets"] = jax.random.randint(kg, (B, seq), 0, cfg.vocab_size)
    if cfg.family == "audio":
        batch["embeds"] = multimodal.frame_embeddings(ke, cfg, B, seq)
        del batch["tokens"]
    if cfg.family == "vlm":
        batch["prefix_embeds"] = multimodal.patch_embeddings(ke, cfg, B)
    return batch


@pytest.mark.parametrize("name", list(ARCHS))
def test_arch_smoke_train(name):
    cfg = _cfg(name)
    key = jax.random.PRNGKey(0)
    params = model.init_params(cfg, key)
    batch = _batch(cfg, key, S)
    (loss, met), grads = jax.value_and_grad(
        lambda p: model.loss_fn(p, cfg, batch, LOCAL), has_aux=True)(params)
    assert np.isfinite(float(loss)), (name, loss)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, name


@pytest.mark.parametrize("name", list(ARCHS))
def test_arch_decode_consistency(name):
    cfg = _cfg(name)
    key = jax.random.PRNGKey(0)
    params = model.init_params(cfg, key)
    total = S + K
    toks = jax.random.randint(key, (B, total), 0, cfg.vocab_size)
    batch_full = {"tokens": toks}
    batch_pre = {"tokens": toks[:, :S]}
    prefix = 0
    if cfg.family == "vlm":
        pe = multimodal.patch_embeddings(key, cfg, B)
        prefix = pe.shape[1]
        batch_full["prefix_embeds"] = pe
        batch_pre["prefix_embeds"] = pe

    def full_logits(batch):
        x, prefix_len = model.embed_inputs(params, cfg, batch, LOCAL)
        pos = jnp.arange(x.shape[1])
        x, _, _ = transformer.stack_seq(
            params["stack"], cfg, x, LOCAL, positions=pos,
            mask=MaskSpec(True, prefix_len=prefix_len), mode="train")
        x = layers.rmsnorm(params["final_norm"], x, cfg.norm_eps)
        return x @ model._unembed_w(params, cfg).astype(x.dtype)

    ref = full_logits(batch_full)
    caches = model.init_caches(cfg, B, prefix + total + 2, jnp.float32)
    lg, caches = model.prefill(params, cfg, batch_pre, caches, LOCAL)
    errs = [float(jnp.abs(lg[:, 0] - ref[:, prefix + S - 1]).max())]
    t = prefix + S
    for i in range(K):
        tok = toks[:, S + i][:, None]
        lg, caches = model.decode_step(params, cfg, tok, caches,
                                       jnp.int32(t), LOCAL)
        errs.append(float(jnp.abs(lg[:, 0] - ref[:, prefix + S + i]).max()))
        t += 1
    assert max(errs) < 2e-2, (name, errs)


def test_shape_applicability():
    """long_500k runs exactly for the sub-quadratic archs."""
    runs_long = {n for n in ARCHS
                 if applicable(get_config(n), SHAPES["long_500k"])}
    assert runs_long == {"zamba2-1.2b", "xlstm-125m"}
    for n in ARCHS:  # everything decodes (no encoder-only archs assigned)
        assert applicable(get_config(n), SHAPES["decode_32k"])


def test_full_configs_match_assignment():
    """Exact dims from the assignment table."""
    expect = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "llama3-8b": (32, 4096, 32, 8, 14336, 128256),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    }
    for name, (nl, dm, nh, kv, ff, vs) in expect.items():
        c = get_config(name)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (nl, dm, nh, kv, ff, vs), name
    assert get_config("kimi-k2-1t-a32b").num_experts == 384
    assert get_config("kimi-k2-1t-a32b").num_experts_per_tok == 8
    assert get_config("qwen3-moe-235b-a22b").num_experts == 128
    assert get_config("zamba2-1.2b").ssm_state == 64
    assert get_config("qwen1.5-4b").qkv_bias


def test_scan_vs_unrolled_layers():
    """scan_layers=True/False produce identical outputs (llama family)."""
    cfg = _cfg("llama3-8b")
    key = jax.random.PRNGKey(1)
    params = model.init_params(cfg, key)
    batch = _batch(cfg, key, 16)
    l1, _ = model.loss_fn(params, cfg, batch, LOCAL)
    cfg2 = dataclasses.replace(cfg, scan_layers=False)
    l2, _ = model.loss_fn(params, cfg2, batch, LOCAL)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_chunked_xent_matches_dense():
    cfg = _cfg("llama3-8b")
    key = jax.random.PRNGKey(2)
    params = model.init_params(cfg, key)
    hid = jax.random.normal(key, (B, 20, cfg.d_model))
    tgt = jax.random.randint(key, (B, 20), 0, cfg.vocab_size)
    loss, acc = model.chunked_xent(params, cfg, hid, tgt, LOCAL, chunk=7)
    w = model._unembed_w(params, cfg).astype(jnp.float32)
    logits = hid.astype(jnp.float32) @ w
    lse = jax.nn.logsumexp(logits, -1)
    tl = jnp.take_along_axis(logits, tgt[..., None], -1)[..., 0]
    np.testing.assert_allclose(float(loss), float((lse - tl).mean()),
                               rtol=1e-5)


def test_loss_mask_negative_targets():
    cfg = _cfg("granite-8b")
    key = jax.random.PRNGKey(3)
    params = model.init_params(cfg, key)
    hid = jax.random.normal(key, (B, 8, cfg.d_model))
    tgt = jax.random.randint(key, (B, 8), 0, cfg.vocab_size)
    tgt_masked = tgt.at[:, 4:].set(-1)
    l_all, _ = model.chunked_xent(params, cfg, hid, tgt, LOCAL)
    l_head, _ = model.chunked_xent(params, cfg, hid[:, :4], tgt[:, :4], LOCAL)
    l_msk, _ = model.chunked_xent(params, cfg, hid, tgt_masked, LOCAL)
    np.testing.assert_allclose(float(l_msk), float(l_head), rtol=1e-6)
    assert abs(float(l_msk) - float(l_all)) > 1e-6
