"""Fast single-device coverage of ``repro.dist``.

The multi-device subprocess tests (test_substrate / test_dist_equivalence)
prove the distributed *execution*; these tests pin the substrate's *rules*
on the plain 1-CPU session so CPU-only CI exercises ``repro.dist`` on
every run:

  * spec builders are pure functions of (tree paths, leaf shapes, mesh
    shape) — ``jax.eval_shape`` param trees plus a devices-free mesh stub
    cover the full divisibility-guard matrix with zero subprocesses;
  * ``quantize_int8``/``dequantize_int8`` round-trip and error-feedback
    bounds are hypothesis properties (the deterministic fallback shim
    runs them even without hypothesis installed).
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.dist import sharding as shd
from repro.dist.compression import (
    dequantize_int8, ef_allreduce_mean, quantize_int8, wire_bytes,
)
from repro.models import model


class _MeshStub:
    """Just (axis_names, shape) — all the spec builders ever read.

    Lets one CPU assert the layout rules for any mesh geometry without
    forcing a device count.
    """

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def _llama_specs(mesh, global_batch=8):
    cfg = get_config("llama3-8b")
    shard = shd.make_shard_cfg(mesh, cfg, global_batch=global_batch)
    shapes = jax.eval_shape(
        lambda: model.init_params(cfg, jax.random.PRNGKey(0)))
    return cfg, shard, shd.param_spec_tree(shapes, cfg, mesh, shard)


# ---------------------------------------------------------------------------
# sharding rules (mesh-geometry sweep, no devices needed)
# ---------------------------------------------------------------------------
def test_param_specs_fsdp_tp_layout():
    mesh = _MeshStub(data=2, model=4)
    _, _, specs = _llama_specs(mesh)
    stack = specs["stack"]["layers"]
    assert stack["attn"]["wq"] == P(None, "data", "model", None)
    assert stack["attn"]["wk"] == P(None, "data", "model", None)  # 8 % 4 == 0
    assert stack["attn"]["wo"] == P(None, "model", None, "data")
    assert stack["ffn"]["gate"]["w"] == P(None, "data", "model")
    assert stack["ffn"]["down"]["w"] == P(None, "model", "data")
    assert specs["embed"]["table"] == P("model", "data")
    assert specs["unembed"]["w"] == P("data", "model")
    assert specs["final_norm"]["scale"] == P()


def test_param_specs_divisibility_guard_wide_tp():
    """kv_heads=8 over model=16: the guard replicates instead of erroring."""
    mesh = _MeshStub(data=2, model=16)
    _, _, specs = _llama_specs(mesh)
    stack = specs["stack"]["layers"]
    assert stack["attn"]["wk"] == P(None, "data", None, None)   # 8 % 16 != 0
    assert stack["attn"]["wq"] == P(None, "data", "model", None)  # 32 % 16


def test_cache_specs_seq_guard():
    cfg = get_config("llama3-8b")
    mesh = _MeshStub(data=2, model=4)
    shard = shd.make_shard_cfg(mesh, cfg, global_batch=8)
    mk = lambda s: jax.eval_shape(
        lambda: model.init_caches(cfg, 8, s, jnp.bfloat16))
    assert shd.cache_spec_tree(mk(1024), cfg, mesh, shard).k == \
        P(None, "data", "model", None, None)
    # sequence not divisible by tp=4 -> seq dim stays replicated
    assert shd.cache_spec_tree(mk(30), cfg, mesh, shard).k == \
        P(None, "data", None, None, None)


def test_batch_specs_and_non_divisible_batch():
    cfg = get_config("llama3-8b")
    mesh = _MeshStub(data=4, model=2)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    shard = shd.make_shard_cfg(mesh, cfg, global_batch=8)
    assert shard.batch_sharded
    assert shd.batch_spec_tree(batch, mesh, shard)["tokens"] == \
        P("data", None)
    shard3 = shd.make_shard_cfg(mesh, cfg, global_batch=3)  # 3 % 4 != 0
    assert not shard3.batch_sharded
    assert shd.batch_spec_tree(batch, mesh, shard3)["tokens"] == \
        P(None, None)


def test_make_shard_cfg_modes():
    cfg = get_config("llama3-8b")
    mesh = _MeshStub(pod=2, data=2, model=2)
    fsdp = shd.make_shard_cfg(mesh, cfg, global_batch=8)
    assert fsdp.dp == ("pod", "data") and fsdp.tp == "model"
    assert not fsdp.replicate_params
    dp = shd.make_shard_cfg(mesh, cfg, global_batch=8, mode="dp")
    assert dp.replicate_params and dp.tp is None
    assert tuple(dp.dp_axes) == ("pod", "data", "model")
    # dp-mode params are replicated regardless of divisibility
    shapes = jax.eval_shape(
        lambda: model.init_params(cfg, jax.random.PRNGKey(0)))
    specs = shd.param_spec_tree(shapes, cfg, mesh, dp)
    assert all(s == P() or all(e is None for e in s)
               for s in jax.tree.leaves(
                   specs, is_leaf=lambda x: isinstance(x, P)))


def test_moe_and_ssm_spec_trees_cover_all_leaves():
    """Every family's tree gets a spec per leaf (structure mirrors)."""
    mesh = _MeshStub(data=2, model=4)
    for arch in ("qwen3-moe-235b-a22b", "zamba2-1.2b", "xlstm-125m"):
        cfg = get_config(arch)
        shard = shd.make_shard_cfg(mesh, cfg, global_batch=8)
        shapes = jax.eval_shape(
            lambda: model.init_params(cfg, jax.random.PRNGKey(0)))
        specs = shd.param_spec_tree(shapes, cfg, mesh, shard)
        flat_p = jax.tree_util.tree_flatten_with_path(shapes)[0]
        flat_s = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for (path, leaf), spec in zip(flat_p, flat_s):
            assert len(spec) <= len(leaf.shape), (path, spec)
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 99):
                if ax is not None:
                    sizes = [mesh.shape[a] for a in
                             (ax if isinstance(ax, tuple) else (ax,))]
                    assert dim % int(np.prod(sizes)) == 0, (path, spec)


def test_moe_experts_are_expert_parallel():
    mesh = _MeshStub(data=2, model=4)
    cfg = get_config("qwen3-moe-235b-a22b")
    shard = shd.make_shard_cfg(mesh, cfg, global_batch=8)
    shapes = jax.eval_shape(
        lambda: model.init_params(cfg, jax.random.PRNGKey(0)))
    specs = shd.param_spec_tree(shapes, cfg, mesh, shard)
    experts = specs["stack"]["layers"]["ffn"]["experts"]
    assert experts["gate"][1] == "model"    # (L, E, d, f): E over tp
    assert experts["down"][1] == "model"


def test_named_on_single_device_mesh():
    """named() + device_put on the real 1-device mesh round-trips."""
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_config("llama3-8b")
    shard = shd.make_shard_cfg(mesh, cfg, global_batch=8)
    tree = {"w": jnp.ones((4, 8)), "norm": {"scale": jnp.ones((8,))}}
    specs = shd.param_spec_tree(tree, cfg, mesh, shard)
    placed = jax.device_put(tree, shd.named(specs, mesh))
    np.testing.assert_array_equal(np.asarray(placed["w"]),
                                  np.ones((4, 8)))


def test_path_str_matches_decay_filter_contract():
    from repro.optim.adamw import AdamW

    tree = {"stack": {"layers": {"ffn": {"down": {"w": 0, "b": 0}},
                                 "ln1": {"scale": 0},
                                 "mamba": {"A_log": 0, "dt_bias": 0}}},
            "embed": {"table": 0}}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths = {shd._path_str(p) for p, _ in flat}
    assert "stack/layers/ffn/down/w" in paths
    f = AdamW().decay_filter
    decayed = {p for p in paths if f(p)}
    assert decayed == {"stack/layers/ffn/down/w", "embed/table"}


def test_slot_spec():
    mesh = _MeshStub(data=4, model=2)
    assert shd.slot_spec(mesh, 8) == P("data")
    assert shd.slot_spec(mesh, 6) == P(None)        # 6 % 4 != 0 -> replicated


# ---------------------------------------------------------------------------
# compression properties
# ---------------------------------------------------------------------------
@settings(max_examples=30)
@given(n=st.integers(1, 4096), logmag=st.floats(-5.0, 4.0),
       seed=st.integers(0, 2 ** 16), onesided=st.booleans())
def test_quantize_roundtrip_property(n, logmag, seed, onesided):
    g = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * (10.0 ** logmag)
    if onesided:
        g = jnp.abs(g)
    q, scale, err = quantize_int8(g)
    assert q.dtype == jnp.int8
    amax = float(jnp.max(jnp.abs(g)))
    # exact reconstruction: deq + err == g to fp32 rounding
    deq = dequantize_int8(q, scale, g.shape)
    np.testing.assert_allclose(np.asarray(deq + err), np.asarray(g),
                               rtol=0, atol=max(1e-12, amax * 1e-6))
    # quantization error is at most half a step per element
    assert float(jnp.max(jnp.abs(err))) <= float(scale) * 0.5 * (1 + 1e-5)


@settings(max_examples=15)
@given(seed=st.integers(0, 2 ** 16), t=st.integers(1, 8))
def test_error_feedback_telescopes(seed, t):
    """EF invariant: sum of applied (dequantized) updates equals the sum
    of true gradients minus the final residual — nothing is ever lost."""
    key = jax.random.PRNGKey(seed)
    gs = jax.random.normal(key, (t, 256))
    err = jnp.zeros((256,))
    applied = jnp.zeros((256,))
    for i in range(t):
        comp = gs[i] + err
        q, scale, err = quantize_int8(comp)
        applied = applied + dequantize_int8(q, scale, comp.shape)
        # residual stays one quantization step: EF never accumulates
        assert float(jnp.max(jnp.abs(err))) <= float(scale) * 0.5 * (1 + 1e-5)
    np.testing.assert_allclose(np.asarray(applied + err),
                               np.asarray(gs.sum(0)), rtol=0, atol=1e-4)


def test_zero_gradient_quantizes_to_zero():
    q, scale, err = quantize_int8(jnp.zeros((64,)))
    assert float(jnp.abs(q.astype(jnp.float32)).max()) == 0.0
    assert float(jnp.abs(err).max()) == 0.0
    assert np.isfinite(float(scale))


def test_ef_allreduce_single_device_mesh():
    """ef_allreduce_mean on a 1-device 'pod' axis == plain quantize."""
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("pod",))
    g = jax.random.normal(jax.random.PRNGKey(0), (128,))
    err0 = jnp.zeros((128,))
    fn = jax.shard_map(
        lambda g_, e_: ef_allreduce_mean(g_, e_, "pod"), mesh=mesh,
        in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False)
    gm, ne = fn(g, err0)
    np.testing.assert_allclose(np.asarray(gm + ne), np.asarray(g),
                               rtol=0, atol=1e-5)


def test_wire_bytes_model():
    assert wire_bytes(1000, compressed=True) == 1004
    assert wire_bytes(1000, compressed=False) == 4000
