"""Fast single-device coverage of ``repro.dist``.

The multi-device subprocess tests (test_substrate / test_dist_equivalence)
prove the distributed *execution*; these tests pin the substrate's *rules*
on the plain 1-CPU session so CPU-only CI exercises ``repro.dist`` on
every run:

  * spec builders are pure functions of (tree paths, leaf shapes, mesh
    shape) — ``jax.eval_shape`` param trees plus a devices-free mesh stub
    cover the full divisibility-guard matrix with zero subprocesses;
  * ``quantize_int8``/``dequantize_int8`` round-trip and error-feedback
    bounds are hypothesis properties (the deterministic fallback shim
    runs them even without hypothesis installed).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config
from repro.dist import sharding as shd
from repro.dist.compression import (
    dequantize_int8, ef_allreduce_mean, quantize_int8, wire_bytes,
)
from repro.models import model


class _MeshStub:
    """Just (axis_names, shape) — all the spec builders ever read.

    Lets one CPU assert the layout rules for any mesh geometry without
    forcing a device count.
    """

    def __init__(self, **axes):
        self.axis_names = tuple(axes)
        self.shape = dict(axes)


def _llama_specs(mesh, global_batch=8):
    cfg = get_config("llama3-8b")
    shard = shd.make_shard_cfg(mesh, cfg, global_batch=global_batch)
    shapes = jax.eval_shape(
        lambda: model.init_params(cfg, jax.random.PRNGKey(0)))
    return cfg, shard, shd.param_spec_tree(shapes, cfg, mesh, shard)


# ---------------------------------------------------------------------------
# sharding rules (mesh-geometry sweep, no devices needed)
# ---------------------------------------------------------------------------
def test_param_specs_fsdp_tp_layout():
    mesh = _MeshStub(data=2, model=4)
    _, _, specs = _llama_specs(mesh)
    stack = specs["stack"]["layers"]
    assert stack["attn"]["wq"] == P(None, "data", "model", None)
    assert stack["attn"]["wk"] == P(None, "data", "model", None)  # 8 % 4 == 0
    assert stack["attn"]["wo"] == P(None, "model", None, "data")
    assert stack["ffn"]["gate"]["w"] == P(None, "data", "model")
    assert stack["ffn"]["down"]["w"] == P(None, "model", "data")
    assert specs["embed"]["table"] == P("model", "data")
    assert specs["unembed"]["w"] == P("data", "model")
    assert specs["final_norm"]["scale"] == P()


def test_param_specs_divisibility_guard_wide_tp():
    """kv_heads=8 over model=16: the guard replicates instead of erroring."""
    mesh = _MeshStub(data=2, model=16)
    _, _, specs = _llama_specs(mesh)
    stack = specs["stack"]["layers"]
    assert stack["attn"]["wk"] == P(None, "data", None, None)   # 8 % 16 != 0
    assert stack["attn"]["wq"] == P(None, "data", "model", None)  # 32 % 16


def test_cache_specs_seq_guard():
    cfg = get_config("llama3-8b")
    mesh = _MeshStub(data=2, model=4)
    shard = shd.make_shard_cfg(mesh, cfg, global_batch=8)
    mk = lambda s: jax.eval_shape(
        lambda: model.init_caches(cfg, 8, s, jnp.bfloat16))
    assert shd.cache_spec_tree(mk(1024), cfg, mesh, shard).k == \
        P(None, "data", "model", None, None)
    # sequence not divisible by tp=4 -> seq dim stays replicated
    assert shd.cache_spec_tree(mk(30), cfg, mesh, shard).k == \
        P(None, "data", None, None, None)


def test_batch_specs_and_non_divisible_batch():
    cfg = get_config("llama3-8b")
    mesh = _MeshStub(data=4, model=2)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
    shard = shd.make_shard_cfg(mesh, cfg, global_batch=8)
    assert shard.batch_sharded
    assert shd.batch_spec_tree(batch, mesh, shard)["tokens"] == \
        P("data", None)
    shard3 = shd.make_shard_cfg(mesh, cfg, global_batch=3)  # 3 % 4 != 0
    assert not shard3.batch_sharded
    assert shd.batch_spec_tree(batch, mesh, shard3)["tokens"] == \
        P(None, None)


def test_make_shard_cfg_modes():
    cfg = get_config("llama3-8b")
    mesh = _MeshStub(pod=2, data=2, model=2)
    fsdp = shd.make_shard_cfg(mesh, cfg, global_batch=8)
    assert fsdp.dp == ("pod", "data") and fsdp.tp == "model"
    assert not fsdp.replicate_params
    dp = shd.make_shard_cfg(mesh, cfg, global_batch=8, mode="dp")
    assert dp.replicate_params and dp.tp is None
    assert tuple(dp.dp_axes) == ("pod", "data", "model")
    # dp-mode params are replicated regardless of divisibility
    shapes = jax.eval_shape(
        lambda: model.init_params(cfg, jax.random.PRNGKey(0)))
    specs = shd.param_spec_tree(shapes, cfg, mesh, dp)
    assert all(s == P() or all(e is None for e in s)
               for s in jax.tree.leaves(
                   specs, is_leaf=lambda x: isinstance(x, P)))


def test_moe_and_ssm_spec_trees_cover_all_leaves():
    """Every family's tree gets a spec per leaf (structure mirrors)."""
    mesh = _MeshStub(data=2, model=4)
    for arch in ("qwen3-moe-235b-a22b", "zamba2-1.2b", "xlstm-125m"):
        cfg = get_config(arch)
        shard = shd.make_shard_cfg(mesh, cfg, global_batch=8)
        shapes = jax.eval_shape(
            lambda: model.init_params(cfg, jax.random.PRNGKey(0)))
        specs = shd.param_spec_tree(shapes, cfg, mesh, shard)
        flat_p = jax.tree_util.tree_flatten_with_path(shapes)[0]
        flat_s = jax.tree.leaves(specs,
                                 is_leaf=lambda x: isinstance(x, P))
        assert len(flat_p) == len(flat_s)
        for (path, leaf), spec in zip(flat_p, flat_s):
            assert len(spec) <= len(leaf.shape), (path, spec)
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * 99):
                if ax is not None:
                    sizes = [mesh.shape[a] for a in
                             (ax if isinstance(ax, tuple) else (ax,))]
                    assert dim % int(np.prod(sizes)) == 0, (path, spec)


def test_moe_experts_are_expert_parallel():
    mesh = _MeshStub(data=2, model=4)
    cfg = get_config("qwen3-moe-235b-a22b")
    shard = shd.make_shard_cfg(mesh, cfg, global_batch=8)
    shapes = jax.eval_shape(
        lambda: model.init_params(cfg, jax.random.PRNGKey(0)))
    specs = shd.param_spec_tree(shapes, cfg, mesh, shard)
    experts = specs["stack"]["layers"]["ffn"]["experts"]
    assert experts["gate"][1] == "model"    # (L, E, d, f): E over tp
    assert experts["down"][1] == "model"


def test_named_on_single_device_mesh():
    """named() + device_put on the real 1-device mesh round-trips."""
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    cfg = get_config("llama3-8b")
    shard = shd.make_shard_cfg(mesh, cfg, global_batch=8)
    tree = {"w": jnp.ones((4, 8)), "norm": {"scale": jnp.ones((8,))}}
    specs = shd.param_spec_tree(tree, cfg, mesh, shard)
    placed = jax.device_put(tree, shd.named(specs, mesh))
    np.testing.assert_array_equal(np.asarray(placed["w"]),
                                  np.ones((4, 8)))


def test_path_str_matches_decay_filter_contract():
    from repro.optim.adamw import AdamW

    tree = {"stack": {"layers": {"ffn": {"down": {"w": 0, "b": 0}},
                                 "ln1": {"scale": 0},
                                 "mamba": {"A_log": 0, "dt_bias": 0}}},
            "embed": {"table": 0}}
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    paths = {shd._path_str(p) for p, _ in flat}
    assert "stack/layers/ffn/down/w" in paths
    f = AdamW().decay_filter
    decayed = {p for p in paths if f(p)}
    assert decayed == {"stack/layers/ffn/down/w", "embed/table"}


def test_slot_spec():
    mesh = _MeshStub(data=4, model=2)
    assert shd.slot_spec(mesh, 8) == P("data")
    assert shd.slot_spec(mesh, 6) == P(None)        # 6 % 4 != 0 -> replicated


# ---------------------------------------------------------------------------
# slots x shards field specs (the 2-axis farm mesh)
# ---------------------------------------------------------------------------
def test_slot_field_spec_slot_times_shard():
    mesh = _MeshStub(slot=2, shard=4)
    spec = shd.slot_field_spec(mesh, 8, (16, 16, 4), ((0, "shard"),))
    assert spec == P("slot", "shard", None, None)


def test_slot_field_spec_two_axis_grid_decomposition():
    mesh = _MeshStub(slot=2, sx=2, sy=2)
    spec = shd.slot_field_spec(mesh, 4, (16, 16, 8), ((0, "sx"), (1, "sy")))
    assert spec == P("slot", "sx", "sy", None)


def test_slot_field_spec_undecomposed_grid():
    mesh = _MeshStub(slot=4)
    assert shd.slot_field_spec(mesh, 8, (16, 16, 4)) == \
        P("slot", None, None, None)


def test_slot_field_spec_indivisible_slots_replicate():
    """Slots never interact -> the slot axis is guarded, not an error."""
    mesh = _MeshStub(slot=2, shard=4)
    spec = shd.slot_field_spec(mesh, 3, (16, 16, 4), ((0, "shard"),))
    assert spec == P(None, "shard", None, None)


def test_slot_field_spec_indivisible_grid_raises():
    """Grid axes RAISE: halo code ppermutes assuming true shards, so a
    silently replicated axis would be mis-sharded, not just unparallel."""
    mesh = _MeshStub(slot=2, shard=4)
    with pytest.raises(ValueError, match="not divisible"):
        shd.slot_field_spec(mesh, 8, (10, 16, 4), ((0, "shard"),))


def test_slot_field_spec_unknown_axes_raise():
    mesh = _MeshStub(slot=2, shard=4)
    with pytest.raises(ValueError, match="no slot axis"):
        shd.slot_field_spec(mesh, 8, (16, 16, 4), ((0, "shard"),),
                            slot_axis="slots")
    with pytest.raises(ValueError, match="no axis 'model'"):
        shd.slot_field_spec(mesh, 8, (16, 16, 4), ((0, "model"),))
    with pytest.raises(ValueError, match="slot axis"):
        shd.slot_field_spec(mesh, 8, (16, 16, 4), ((0, "slot"),))


def test_slot_field_spec_bad_array_axis_raises():
    mesh = _MeshStub(slot=2, shard=4)
    with pytest.raises(ValueError, match="array axis 3"):
        shd.slot_field_spec(mesh, 8, (16, 16, 4), ((3, "shard"),))


def test_slot_field_spec_duplicate_array_axis_raises():
    """One grid axis mapped twice must raise, not silently keep the last
    mapping (dict() would dedup to half the requested parallelism)."""
    mesh = _MeshStub(slot=2, sx=2, sy=2)
    with pytest.raises(ValueError, match="more than once"):
        shd.slot_field_spec(mesh, 8, (16, 16, 4), ((0, "sx"), (0, "sy")))


def test_slot_field_spec_covers_eval_shape_state():
    """The rule applied over a real solver state tree (eval_shape — no
    arrays, no devices): every field of the slot-stacked ensemble state
    gets the same P(slot, shard, ...) placement."""
    from repro.cfd import cavity
    from repro.cfd.ns3d import NavierStokes3D

    solver = NavierStokes3D(cavity.config(16, jacobi_iters=20))
    shapes = jax.eval_shape(solver.init_state)
    mesh = _MeshStub(slot=2, shard=4)
    specs = {k: shd.slot_field_spec(mesh, 8, v.shape, ((0, "shard"),))
             for k, v in shapes.items()}
    assert set(specs) >= {"vx", "vy", "vz", "p"}
    for k, spec in specs.items():
        assert spec == P("slot", "shard", None, None), k


def test_slot_field_spec_matches_solver_field_pspec():
    """dist's slot-stacked spec == P(slot, *solver.field_pspec): the two
    layers agree on the grid placement by construction."""
    from repro.cfd import cavity
    from repro.cfd.ns3d import NavierStokes3D
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("slot", "shard"))
    solver = NavierStokes3D(
        cavity.config(16, jacobi_iters=20, decomposition=((0, "shard"),)),
        mesh)
    stacked = shd.slot_field_spec(mesh, 4, solver.config.shape,
                                  solver.config.decomposition)
    assert tuple(stacked)[1:] == tuple(solver.field_pspec)


# ---------------------------------------------------------------------------
# compression properties
# ---------------------------------------------------------------------------
@settings(max_examples=30)
@given(n=st.integers(1, 4096), logmag=st.floats(-5.0, 4.0),
       seed=st.integers(0, 2 ** 16), onesided=st.booleans())
def test_quantize_roundtrip_property(n, logmag, seed, onesided):
    g = jax.random.normal(jax.random.PRNGKey(seed), (n,)) * (10.0 ** logmag)
    if onesided:
        g = jnp.abs(g)
    q, scale, err = quantize_int8(g)
    assert q.dtype == jnp.int8
    amax = float(jnp.max(jnp.abs(g)))
    # exact reconstruction: deq + err == g to fp32 rounding
    deq = dequantize_int8(q, scale, g.shape)
    np.testing.assert_allclose(np.asarray(deq + err), np.asarray(g),
                               rtol=0, atol=max(1e-12, amax * 1e-6))
    # quantization error is at most half a step per element
    assert float(jnp.max(jnp.abs(err))) <= float(scale) * 0.5 * (1 + 1e-5)


@settings(max_examples=15)
@given(seed=st.integers(0, 2 ** 16), t=st.integers(1, 8))
def test_error_feedback_telescopes(seed, t):
    """EF invariant: sum of applied (dequantized) updates equals the sum
    of true gradients minus the final residual — nothing is ever lost."""
    key = jax.random.PRNGKey(seed)
    gs = jax.random.normal(key, (t, 256))
    err = jnp.zeros((256,))
    applied = jnp.zeros((256,))
    for i in range(t):
        comp = gs[i] + err
        q, scale, err = quantize_int8(comp)
        applied = applied + dequantize_int8(q, scale, comp.shape)
        # residual stays one quantization step: EF never accumulates
        assert float(jnp.max(jnp.abs(err))) <= float(scale) * 0.5 * (1 + 1e-5)
    np.testing.assert_allclose(np.asarray(applied + err),
                               np.asarray(gs.sum(0)), rtol=0, atol=1e-4)


def test_zero_gradient_quantizes_to_zero():
    q, scale, err = quantize_int8(jnp.zeros((64,)))
    assert float(jnp.abs(q.astype(jnp.float32)).max()) == 0.0
    assert float(jnp.abs(err).max()) == 0.0
    assert np.isfinite(float(scale))


def test_ef_allreduce_single_device_mesh():
    """ef_allreduce_mean on a 1-device 'pod' axis == plain quantize."""
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("pod",))
    g = jax.random.normal(jax.random.PRNGKey(0), (128,))
    err0 = jnp.zeros((128,))
    fn = jax.shard_map(
        lambda g_, e_: ef_allreduce_mean(g_, e_, "pod"), mesh=mesh,
        in_specs=(P(), P()), out_specs=(P(), P()), check_vma=False)
    gm, ne = fn(g, err0)
    np.testing.assert_allclose(np.asarray(gm + ne), np.asarray(g),
                               rtol=0, atol=1e-5)


def test_wire_bytes_model():
    assert wire_bytes(1000, compressed=True) == 1004
    assert wire_bytes(1000, compressed=False) == 4000


# ---------------------------------------------------------------------------
# halo / BC properties (single-shard exchange_pad path — pure rules, no mesh)
# ---------------------------------------------------------------------------
# The slots x shards step trusts exchange_pad for every ghost zone, so the
# farm's correctness reduces to these rules: any halo width >= the stencil
# radius round-trips (the interior is untouched), ghost strips obey the BC
# rule exactly, and an impossible width fails loudly.
from repro.core.halo import (  # noqa: E402
    AxisSpec, bc_dirichlet, bc_mirror, bc_neumann, exchange_pad,
)

_BC_FACTORIES = {
    "dirichlet": lambda: bc_dirichlet(3.5),
    "neumann": bc_neumann,
    "mirror": lambda: bc_mirror(-1.0),
}


def _field(n, seed):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(n, n, n).astype(np.float32))


def _specs(bc_name, periodic=False):
    mk = _BC_FACTORIES[bc_name]
    return tuple(AxisSpec(a, periodic=periodic, bc_lo=mk(), bc_hi=mk())
                 for a in range(3))


@settings(max_examples=25)
@given(w=st.integers(1, 3), n=st.integers(4, 8), seed=st.integers(0, 999),
       bc=st.sampled_from(sorted(_BC_FACTORIES)))
def test_exchange_pad_roundtrips_interior_property(w, n, seed, bc):
    """Padding never rewrites the interior: cropping the ghosts back off
    recovers the original field bitwise, for every BC rule and any halo
    width >= the stencil radius (the width the kernels will ask for)."""
    u = _field(n, seed)
    padded = exchange_pad(u, (w, w, w), _specs(bc))
    assert padded.shape == (n + 2 * w,) * 3
    crop = padded[w:-w, w:-w, w:-w]
    np.testing.assert_array_equal(np.asarray(crop), np.asarray(u))


@settings(max_examples=25)
@given(wlo=st.integers(0, 3), whi=st.integers(0, 3), seed=st.integers(0, 999),
       bc=st.sampled_from(sorted(_BC_FACTORIES)))
def test_exchange_pad_one_sided_widths_property(wlo, whi, seed, bc):
    """(lo, hi) one-sided widths (upwind/staggered stencils) round-trip
    the same way."""
    n = 6
    u = _field(n, seed)
    padded = exchange_pad(u, ((wlo, whi),) * 3, _specs(bc))
    assert padded.shape == (n + wlo + whi,) * 3
    crop = padded[wlo:n + wlo, wlo:n + wlo, wlo:n + wlo]
    np.testing.assert_array_equal(np.asarray(crop), np.asarray(u))


@settings(max_examples=25)
@given(w=st.integers(1, 3), seed=st.integers(0, 999),
       axis=st.integers(0, 2), bc=st.sampled_from(sorted(_BC_FACTORIES)))
def test_exchange_pad_ghosts_obey_bc_rule_property(w, seed, axis, bc):
    """Ghost strips are exactly what the BC rule defines: dirichlet fills
    the value, neumann mirrors the adjacent interior, mirror flips the
    sign of the mirrored interior — on both the lo and hi side."""
    n = 6
    u = _field(n, seed)
    widths = [0, 0, 0]
    widths[axis] = w
    padded = np.asarray(exchange_pad(u, tuple(widths), _specs(bc)))
    un = np.asarray(u)
    lo = np.take(padded, range(0, w), axis=axis)
    hi = np.take(padded, range(n + w, n + 2 * w), axis=axis)
    near_lo = np.take(un, range(0, w), axis=axis)
    near_hi = np.take(un, range(n - w, n), axis=axis)
    if bc == "dirichlet":
        np.testing.assert_array_equal(lo, np.full_like(lo, 3.5))
        np.testing.assert_array_equal(hi, np.full_like(hi, 3.5))
    elif bc == "neumann":
        np.testing.assert_array_equal(lo, np.flip(near_lo, axis=axis))
        np.testing.assert_array_equal(hi, np.flip(near_hi, axis=axis))
    else:  # mirror(-1)
        np.testing.assert_array_equal(lo, -np.flip(near_lo, axis=axis))
        np.testing.assert_array_equal(hi, -np.flip(near_hi, axis=axis))


@settings(max_examples=25)
@given(w=st.integers(1, 3), seed=st.integers(0, 999), axis=st.integers(0, 2))
def test_exchange_pad_periodic_wraps_property(w, seed, axis):
    """Periodic ghosts are the wrapped far-side strips (what the ppermute
    delivers on a real mesh, degenerated to one shard)."""
    n = 6
    u = _field(n, seed)
    widths = [0, 0, 0]
    widths[axis] = w
    specs = tuple(AxisSpec(a, periodic=True) for a in range(3))
    padded = np.asarray(exchange_pad(u, tuple(widths), specs))
    ref = np.asarray(jnp.pad(u, [(wa, wa) if a == axis else (0, 0)
                                 for a, wa in enumerate([w] * 3)],
                             mode="wrap"))
    np.testing.assert_array_equal(padded, ref)


@settings(max_examples=15)
@given(n=st.integers(2, 4), extra=st.integers(1, 3),
       bc=st.sampled_from(sorted(_BC_FACTORIES)))
def test_exchange_pad_width_beyond_extent_raises_property(n, extra, bc):
    """A halo wider than the local block cannot be served by one exchange
    hop — it must fail loudly, not wrap garbage."""
    u = _field(n, 0)
    w = n + extra
    with pytest.raises(ValueError, match="halo width"):
        exchange_pad(u, (w, w, w), _specs(bc))


@settings(max_examples=25)
@given(n=st.integers(5, 64), shards=st.integers(2, 8),
       slots=st.integers(1, 8))
def test_indivisible_grid_shard_combinations_raise_property(n, shards, slots):
    """Every layer that could mis-shard an indivisible grid refuses
    instead: the spec rule raises, and the driver's Domain validation
    raises — never a silently replicated 'shard'."""
    if n % shards == 0:
        n += 1                      # force indivisibility
        if n % shards == 0:         # (can't happen, but keep it obvious)
            return
    mesh = _MeshStub(slot=2, shard=shards)
    with pytest.raises(ValueError, match="not divisible"):
        shd.slot_field_spec(mesh, slots, (n, 16, 4), ((0, "shard"),))

    from repro.core.driver import Domain, GridDriver

    with pytest.raises(ValueError, match="not divisible"):
        GridDriver(Domain(shape=(n, 16, 4), decomposition={0: "shard"}),
                   mesh)
