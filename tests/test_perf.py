"""repro.obs.perf: cost-model accounting end to end — the analytic
ghost-zone model pinned against the HLO-predicted collective-permute
bytes (the fast-lane AbstractMesh lowering needs no devices), the
perf-on/off bitwise contract, the unparsed-HLO fallback, the chip
registry, the Prometheus surface, and the bench regression gate
(including the injected-2x-slowdown failure)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from benchmarks.check_regression import compare
from repro import api, obs
from repro.cfd.ns3d import CFDConfig
from repro.core.rooflinemodel import CHIPS, V5E, Chip, resolve_chip
from repro.launch import hlo_cost
from repro.obs import perf
from repro.sim import SimulationService

N = 12
KW = dict(jacobi_iters=8)


def _cfg(n=16, **kw):
    kw.setdefault("jacobi_iters", 8)
    return CFDConfig(shape=(n, n, n), extent=1.0, case="cavity",
                     decomposition={0: "shard"}, **kw)


# ---------------------------------------------------------------------------
# predicted halo bytes == analytic ghost-zone bytes (the tentpole check)
# ---------------------------------------------------------------------------
class TestHaloPrediction:
    def test_decomposed_step_permute_bytes_match_analytic(self):
        """The slots × shards cavity step's collective-permutes, counted
        by the trip-count-aware cost model over the AbstractMesh
        lowering, must carry exactly the bytes the decomposition plan
        implies — velocity halos, divergence/projection one-sided pads,
        and the Jacobi loop multiplied by its trip count."""
        cfg = _cfg(16)
        text, active = perf.decomposed_step_hlo(
            cfg, n_slots=4, mesh_axes=(("slot", 2), ("shard", 2)))
        assert active == {0: "shard"}
        cost, status, err = hlo_cost.safe_analyze(text, 4)
        assert status == "ok" and err is None
        predicted = cost.collective_bytes["collective-permute"]
        analytic = perf.halo_bytes_per_step(
            cfg, active, {"slot": 2, "shard": 2},
            slots_local=perf._slots_local(4, 2))
        assert predicted == analytic
        # permute inventory on one decomposed axis — velocity two-sided
        # (2×3), divergence one-sided (3), jacobi two-sided × trip count
        # (2×iters), projection one-sided (1)
        assert cost.collective_counts["collective-permute"] == \
            2 * 3 + 3 + 2 * cfg.jacobi_iters + 1
        # the pressure solve's global mean is an all-reduce, not a permute
        assert cost.collective_counts["all-reduce"] >= 1

    def test_fused_sweeps_widen_the_analytic_halo(self):
        """The communication-avoiding smoother (fused_sweeps=k) trades
        k-wide halos for k-fewer exchanges; both sides of the bookkeeping
        must move together."""
        cfg = _cfg(16, fused_sweeps=2)
        text, active = perf.decomposed_step_hlo(
            cfg, n_slots=2, mesh_axes=(("slot", 1), ("shard", 2)))
        cost, status, _ = hlo_cost.safe_analyze(text, 2)
        assert status == "ok"
        analytic = perf.halo_bytes_per_step(
            cfg, active, {"slot": 1, "shard": 2},
            slots_local=perf._slots_local(2, 1))
        assert cost.collective_bytes["collective-permute"] == analytic

    def test_runtime_report_carries_the_match(self):
        rt = api.runtime(n=N, n_slots=2, telemetry=True, **KW)
        rt.submit("cavity", re=100.0, steps=4)
        rt.drain()
        rep = rt.perf_report()
        rows = rep.rows()
        assert len(rows) == 1 and rows[0]["kind"] == "farm-step"
        assert rows[0]["status"] == "ok"
        assert rows[0]["measured_s"] and rows[0]["measured_s"] > 0
        assert rows[0]["bottleneck"] in ("compute", "memory", "collective")
        text = rt.report(perf=True)
        assert "perf accounting" in text and "farm/cavity" in text


# ---------------------------------------------------------------------------
# perf accounting is observation-only: outputs bitwise identical on/off
# ---------------------------------------------------------------------------
class TestBitwiseInvisible:
    @settings(max_examples=3, deadline=None)
    @given(re=st.sampled_from([80.0, 160.0, 320.0]),
           steps=st.integers(min_value=3, max_value=8))
    def test_perf_accounting_never_perturbs_results(self, re, steps):
        def run(with_perf):
            rt = api.runtime(n=N, n_slots=2,
                             telemetry=bool(with_perf), **KW)
            sid = rt.submit("cavity", re=re, steps=steps)
            rt.drain()
            if with_perf:
                rt.report(perf=True)         # lowers + costs mid-session
                sid2 = rt.submit("cavity", re=re, steps=steps)
                rt.drain()
                a, b = rt.result(sid), rt.result(sid2)
                for f in ("vx", "vy", "vz", "p"):
                    np.testing.assert_array_equal(a.state[f], b.state[f])
            return rt.result(sid)

        on, off = run(True), run(False)
        assert on.steps_done == off.steps_done
        for f in ("vx", "vy", "vz", "p"):
            np.testing.assert_array_equal(on.state[f], off.state[f])


# ---------------------------------------------------------------------------
# unparsed fallback: never raise into a drive loop
# ---------------------------------------------------------------------------
class TestUnparsedFallback:
    def test_safe_analyze_flags_garbage(self):
        cost, status, err = hlo_cost.safe_analyze("not hlo at all", 1)
        assert status == "unparsed" and err
        assert cost.flops == 0.0 and cost.bytes == 0.0

    def test_cost_row_and_report_survive_garbage(self):
        row = perf.cost_row_from_hlo("HloModule m {", name="x", kind="farm-step")
        assert row.status == "unparsed"
        rep = perf.PerfReport([row], chip="cpu-host")
        d = rep.rows()[0]
        assert d["bottleneck"] == "unknown" and d["utilization"] is None
        assert "unparsed" in rep.render()
        perf.validate_perf(rep.as_dict())     # still schema-complete

    def test_validate_perf_names_problems(self):
        with pytest.raises(ValueError, match="schema"):
            perf.validate_perf({"schema": "nope", "chip": {"name": "x"},
                                "rows": []})
        with pytest.raises(ValueError, match="rows"):
            perf.validate_perf({"schema": perf.PERF_SCHEMA,
                                "chip": {"name": "x"}, "rows": None})


# ---------------------------------------------------------------------------
# chip registry (the hardcoded-v5e bugfix)
# ---------------------------------------------------------------------------
class TestChipRegistry:
    def test_auto_resolves_to_the_running_platform(self):
        import jax

        chip = resolve_chip("auto")
        assert chip is CHIPS[{"cpu": "cpu-host", "tpu": "tpu-v5e"}.get(
            jax.devices()[0].platform, "gpu-generic")]
        assert resolve_chip(None) is chip

    def test_names_and_passthrough(self):
        assert resolve_chip("tpu-v5e") is V5E
        mine = Chip(name="custom")
        assert resolve_chip(mine) is mine
        with pytest.raises(KeyError, match="unknown chip"):
            resolve_chip("tpu-v9000")

    def test_report_attributes_against_the_resolved_chip(self):
        row = perf.CostRow(name="r", kind="farm-step", flops=1e9,
                           hbm_bytes=1e6, measured_s=1e-3, invocations=1)
        cpu = perf.PerfReport([row], chip="cpu-host").rows()[0]
        tpu = perf.PerfReport([row], chip="tpu-v5e").rows()[0]
        assert cpu["compute_s"] > tpu["compute_s"]   # smaller peak, more s
        assert cpu["utilization"] > tpu["utilization"]


# ---------------------------------------------------------------------------
# Prometheus surface
# ---------------------------------------------------------------------------
class TestPrometheus:
    def test_registry_text_format(self):
        reg = obs.Registry()
        reg.inc("farm.steps", 3, farm="a/b")
        reg.set("farm.occupancy", 0.5)
        reg.observe("service.latency_seconds", 0.004)
        text = reg.to_prometheus()
        assert "# TYPE repro_farm_steps counter" in text
        assert 'repro_farm_steps{farm="a/b"} 3' in text
        assert "# TYPE repro_farm_occupancy gauge" in text
        assert "# TYPE repro_service_latency_seconds histogram" in text
        assert 'repro_service_latency_seconds_bucket{le="+Inf"} 1' in text
        assert "repro_service_latency_seconds_count 1" in text

    def test_service_scrape_includes_perf_gauges(self):
        svc = SimulationService(
            CFDConfig(shape=(N, N, N), extent=1.0, case="cavity", **KW),
            n_slots=2, telemetry=obs.telemetry())
        from repro.sim.farm import SimRequest

        svc.submit(SimRequest(sid=0, config=svc.farm.base_config,
                              steps=3))
        svc.drain()
        text = svc.prometheus_text(perf=True)
        assert "repro_perf_utilization" in text
        assert "repro_perf_bottleneck" in text
        assert "repro_farm_" in text      # farm metrics ride along

    def test_disabled_telemetry_scrapes_empty(self):
        svc = SimulationService(
            CFDConfig(shape=(N, N, N), extent=1.0, case="cavity", **KW),
            n_slots=2)
        assert svc.prometheus_text() == ""


# ---------------------------------------------------------------------------
# the regression gate
# ---------------------------------------------------------------------------
def _bench_doc(tp=100.0, *, passed=True, util=0.2, measured=1e-3,
               wire=6656.0, halo_match=True, host=None, status="ok"):
    row = {k: 0 for k in perf.ROW_KEYS}
    row.update(name="farm/cavity/sig000", kind="farm-step", status=status,
               measured_s=measured, utilization=util,
               collective_wire_bytes=wire, collective_s=wire / 5e10,
               halo_bytes_analytic=6656.0,
               halo_bytes_predicted=6656.0 if halo_match else 9999.0,
               halo_match=halo_match, hbm_bytes=1e6, flops=0.0)
    return {
        "schema": obs.BENCH_SCHEMA, "bench": "smoke", "passed": passed,
        "host": host or {"backend": "cpu", "device_count": 1},
        "metrics": {
            "steady_sim_steps_per_s": tp,
            "perf": {"schema": perf.PERF_SCHEMA,
                     "chip": {"name": "cpu-host"}, "dtype": "f32",
                     "rows": [row]},
        },
    }


class TestRegressionGate:
    def test_identical_docs_pass(self):
        v = compare(_bench_doc(), _bench_doc())
        assert v["passed"] and not v["failures"]

    def test_injected_2x_slowdown_fails_with_attribution(self):
        """The acceptance scenario: halve throughput, double measured
        seconds, leave the predicted cost untouched — the gate must fail
        AND blame the runtime rather than the program."""
        fresh = _bench_doc(tp=50.0, measured=2e-3, util=0.1)
        v = compare(fresh, _bench_doc(tp=100.0))
        assert not v["passed"]
        assert any("throughput regression" in f for f in v["failures"])
        assert any("50.0% slower" in f for f in v["failures"])
        assert any("predicted cost flat" in e for e in v["explanations"])

    def test_within_gate_passes(self):
        v = compare(_bench_doc(tp=85.0), _bench_doc(tp=100.0))
        assert v["passed"]

    def test_utilization_collapse_fails(self):
        v = compare(_bench_doc(util=0.01), _bench_doc(util=0.2))
        assert not v["passed"]
        assert any("utilization collapse" in f for f in v["failures"])

    def test_collective_growth_blames_the_schedule(self):
        fresh = _bench_doc(tp=40.0, measured=3e-3, wire=3 * 6656.0)
        v = compare(fresh, _bench_doc(tp=100.0))
        assert not v["passed"]
        assert any("schedule regression" in e for e in v["explanations"])

    def test_host_mismatch_skips_wall_clock_gates(self):
        fresh = _bench_doc(tp=10.0, host={"backend": "cpu",
                                          "device_count": 8})
        v = compare(fresh, _bench_doc(tp=100.0))
        assert v["passed"]
        assert any("host mismatch" in w for w in v["warnings"])

    def test_halo_mismatch_fails_even_cross_host(self):
        fresh = _bench_doc(halo_match=False,
                           host={"backend": "tpu", "device_count": 4})
        v = compare(fresh, _bench_doc())
        assert not v["passed"]
        assert any("halo bytes" in f for f in v["failures"])

    def test_missing_baseline_warns_and_passes(self):
        v = compare(_bench_doc(), None)
        assert v["passed"]
        assert any("no baseline" in w for w in v["warnings"])

    def test_row_turned_unparsed_fails(self):
        v = compare(_bench_doc(status="unparsed"), _bench_doc())
        assert not v["passed"]
        assert any("turned 'unparsed'" in f for f in v["failures"])

    def test_baseline_for_other_bench_is_ignored(self):
        fresh = dict(_bench_doc(tp=10.0), bench="ensemble")
        v = compare(fresh, _bench_doc(tp=100.0))
        assert v["passed"]
        assert any("baseline gates skipped" in w for w in v["warnings"])

    @staticmethod
    def _pallas_doc(**over):
        m = {
            "resolved_backend": "pallas-interpret",
            "batches": [{"ensemble": 1, "farm_steps_per_s": 100.0},
                        {"ensemble": 4, "farm_steps_per_s": 300.0}],
            "parity": {"bitwise_ok": True},
            "expected_compile_misses": 3,
            "compile_cache": {"misses": 3, "hits": 1, "entries": 3},
        }
        m.update(over)
        return {"schema": obs.BENCH_SCHEMA, "bench": "ensemble_pallas",
                "passed": True,
                "host": {"backend": "cpu", "device_count": 1},
                "metrics": m}

    def test_pallas_structural_gate_passes_clean_doc(self):
        v = compare(self._pallas_doc(), None)
        assert v["passed"], v["failures"]

    def test_pallas_parity_break_fails_without_baseline(self):
        v = compare(self._pallas_doc(parity={"bitwise_ok": False}), None)
        assert not v["passed"]
        assert any("bitwise parity" in f for f in v["failures"])

    def test_pallas_per_scalar_recompile_fails(self):
        """Five scalars fragmenting into five executables is THE failure
        mode the scalar table exists to prevent."""
        v = compare(self._pallas_doc(
            compile_cache={"misses": 7, "hits": 0, "entries": 7}), None)
        assert not v["passed"]
        assert any("per-scalar recompile" in f for f in v["failures"])

    def test_pallas_wrong_backend_fails(self):
        v = compare(self._pallas_doc(resolved_backend="jnp"), None)
        assert not v["passed"]
        assert any("not a pallas backend" in f for f in v["failures"])

    def test_smoke_docs_skip_the_pallas_gate(self):
        # the structural gate keys on the bench name, not on field absence
        assert compare(_bench_doc(), None)["passed"]

    def test_committed_baseline_is_valid(self):
        """The file CI gates against must itself load, validate, and
        carry a well-formed perf block."""
        import os

        path = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "benchmarks", "baselines",
            "BENCH_smoke.json")
        doc = obs.load_bench(path)
        perf.validate_perf(doc["metrics"]["perf"])
        assert doc["passed"] is True


# ---------------------------------------------------------------------------
# health-overhead gate: the modeled monitor cost, not a wall-clock ratio
# ---------------------------------------------------------------------------
def _health_doc(model=..., *, drains=2, boundaries=2, health_tp=100.0):
    if model is ...:
        model = {"status": "ok", "check_every": 8,
                 "hbm_bytes_step": 8e5, "hbm_bytes_step_health": 8.6e5,
                 "hbm_bytes_diag_per_chunk": 6e4,
                 "modeled_overhead": 0.0094}
    doc = _bench_doc()
    health = {"drains": drains, "boundaries": boundaries}
    if model is not None:
        health["model"] = model
    doc["metrics"]["health"] = health
    doc["metrics"]["steady_sim_steps_per_s_checked"] = 100.0
    doc["metrics"]["steady_sim_steps_per_s_health"] = health_tp
    return doc


class TestHealthOverheadGate:
    def test_modeled_overhead_within_bound_passes(self):
        # wall-clock pair 30% apart: recorded but NOT gated — only the
        # deterministic model binds
        v = compare(_health_doc(health_tp=70.0), None)
        assert v["passed"], v["failures"]

    def test_modeled_overhead_over_bound_fails(self):
        doc = _health_doc(dict(_health_doc()["metrics"]["health"]["model"],
                               modeled_overhead=0.08))
        v = compare(doc, None)
        assert not v["passed"]
        assert any("modeled health overhead" in f for f in v["failures"])

    def test_unparsed_model_fails(self):
        doc = _health_doc({"status": "unparsed", "error": "boom",
                           "modeled_overhead": None})
        v = compare(doc, None)
        assert not v["passed"]
        assert any("cost model unparsed" in f for f in v["failures"])

    def test_dropped_model_with_health_throughput_fails(self):
        """An artifact that records health throughput but no model means
        the gate was silently disconnected — fail, don't bootstrap."""
        v = compare(_health_doc(None), None)
        assert not v["passed"]
        assert any("no health.model" in f for f in v["failures"])

    def test_off_cadence_drain_fails(self):
        v = compare(_health_doc(drains=3, boundaries=2), None)
        assert not v["passed"]
        assert any("harvest boundaries" in f for f in v["failures"])

    def test_docs_without_health_block_bootstrap(self):
        assert compare(_bench_doc(), None)["passed"]

    def test_model_on_real_executables_is_deterministic_and_small(self):
        """The number the gate binds on, computed twice from the real
        lowered farm executables: bit-identical across calls (the whole
        point — wall-clock is not) and within the 3% bound."""
        def executor(health):
            rt = api.runtime(n=N, n_slots=2, health=health,
                             check_every=8, **KW)
            rt.submit("cavity", re=100.0, steps=4)
            rt.drain()
            return next(iter(rt._services.values())).farm.exec

        ex_off, ex_on = executor(False), executor(True)
        a = perf.health_overhead_model(ex_off, ex_on, 8)
        b = perf.health_overhead_model(ex_off, ex_on, 8)
        assert a == b
        assert a["status"] == "ok"
        assert 0.0 < a["modeled_overhead"] <= 0.03
        assert a["hbm_bytes_diag_per_chunk"] > 0
        assert compare(_health_doc(a), None)["passed"]


# ---------------------------------------------------------------------------
# durability-smoke gate: kill-and-resume invariants, baseline-free
# ---------------------------------------------------------------------------
def _durability_doc(**over):
    m = {"jobs": 4, "killed": True, "orphaned_ok": True,
         "incomplete_at_restart": 3, "resumed": 3, "resumed_first": True,
         "lease_takeovers": 3, "single_execution": True, "all_done": True,
         "parity_ok": True,
         "store_counts": {"queued": 0, "running": 0, "evicted": 0,
                          "done": 4, "failed": 0, "diverged": 0}}
    m.update(over)
    return {"schema": obs.BENCH_SCHEMA, "bench": "durability_smoke",
            "passed": True,
            "host": {"backend": "cpu", "device_count": 1},
            "metrics": m}


class TestDurabilitySmokeGate:
    def test_clean_doc_passes_without_baseline(self):
        v = compare(_durability_doc(), None)
        assert v["passed"], v["failures"]

    def test_not_killed_fails(self):
        v = compare(_durability_doc(killed=False), None)
        assert not v["passed"]
        assert any("SIGKILLed" in f for f in v["failures"])

    def test_no_resume_fails(self):
        v = compare(_durability_doc(resumed=0), None)
        assert not v["passed"]
        assert any("resumed no" in f for f in v["failures"])

    def test_queued_before_incomplete_fails(self):
        v = compare(_durability_doc(resumed_first=False), None)
        assert not v["passed"]
        assert any("resume-first" in f for f in v["failures"])

    def test_double_execution_fails(self):
        v = compare(_durability_doc(single_execution=False), None)
        assert not v["passed"]
        assert any("double execution" in f for f in v["failures"])

    def test_undrained_queue_fails(self):
        v = compare(_durability_doc(all_done=False), None)
        assert not v["passed"]
        assert any("drain" in f for f in v["failures"])

    def test_parity_break_fails(self):
        v = compare(_durability_doc(parity_ok=False), None)
        assert not v["passed"]
        assert any("bitwise" in f for f in v["failures"])

    def test_other_smokes_skip_this_gate(self):
        # keys on the bench name: a plain smoke doc with none of these
        # metrics must not trip the durability invariants
        assert compare(_bench_doc(), None)["passed"]
